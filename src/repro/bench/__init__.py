"""Benchmark runtime subsystem: timing harness + machine-readable emission.

``harness`` — warmup + median-of-k wall timing for callables returning JAX
pytrees, a stopwatch for one-shot sweeps, and the quick/full size policy.
``emit`` — ``BENCH_<name>.json`` artifact files with run metadata, the
stable interface CI uploads and downstream tooling diffs.
"""
from repro.bench.emit import bench_out_dir, emit_json
from repro.bench.harness import (BenchSizes, Timing, stopwatch,
                                 time_callable, time_interleaved)

__all__ = [
    "BenchSizes", "Timing", "bench_out_dir", "emit_json", "stopwatch",
    "time_callable", "time_interleaved",
]
