"""Machine-readable benchmark artifacts: ``BENCH_<name>.json``.

Each figure benchmark emits one JSON file next to ``benchmarks/results.csv``
(override with ``BENCH_OUT_DIR``).  The envelope carries enough metadata to
interpret a number months later: which backend produced it, whether it was
a quick (CI-sized) or full sweep, when — and the knobs that steer kernel
speed without changing results: the resolved plane format, the autotune
cache fingerprint, and the machine profile the rooflines are drawn
against.  Cross-run comparisons that mix envelopes with different values
for those three fields are comparing different configurations.
"""
from __future__ import annotations

import json
import os
import time

import jax


def bench_out_dir() -> str:
    """Artifact directory: ``$BENCH_OUT_DIR`` or the repo's benchmarks/."""
    env = os.environ.get("BENCH_OUT_DIR")
    if env:
        os.makedirs(env, exist_ok=True)
        return env
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    cand = os.path.join(here, "benchmarks")
    return cand if os.path.isdir(cand) else os.getcwd()


def emit_json(name: str, payload: dict, *, quick: bool | None = None) -> str:
    """Write ``BENCH_<name>.json`` and return its path."""
    from repro.kernels import autotune
    from repro.kernels.common import resolve_plane_format
    from repro.roofline.analysis import current_machine

    doc = {
        "bench": name,
        "created_unix": round(time.time(), 3),
        "jax_backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "plane_format": resolve_plane_format(),
        "autotune_cache": autotune.cache_fingerprint(),
        "machine": current_machine().name,
    }
    if quick is not None:
        doc["quick"] = bool(quick)
    doc.update(payload)
    path = os.path.join(bench_out_dir(), f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=False, default=_coerce)
        f.write("\n")
    return path


def _coerce(obj):
    """JSON fallback for numpy/JAX scalars and arrays."""
    for attr in ("item", "tolist"):
        fn = getattr(obj, attr, None)
        if callable(fn):
            try:
                return fn()
            except Exception:
                pass
    return str(obj)
