"""Timing harness: warmup + median-of-k, stopwatches, quick/full sizing.

Every figure benchmark used to hand-roll its own ``time.time()`` loop with
no warmup discipline and no record of what was measured.  This module is
the one implementation: compile excluded via explicit warmup reps, JAX
async dispatch closed out with ``block_until_ready``, and the median (not
the mean) reported so one scheduler hiccup cannot move a tracked number.
"""
from __future__ import annotations

import contextlib
import dataclasses
import statistics
import time

import jax


@dataclasses.dataclass(frozen=True)
class Timing:
    """One measured callable: all values in microseconds."""
    median_us: float
    best_us: float
    mean_us: float
    reps: int
    warmup: int

    @property
    def median_s(self) -> float:
        return self.median_us / 1e6

    def row(self) -> str:
        return f"{self.median_us:.0f}"


def _block(out) -> None:
    """Wait out JAX async dispatch; harmless on non-JAX results.

    Only the "this isn't a JAX result" complaints (``TypeError`` /
    ``ValueError`` from pytree flattening over host objects) are
    swallowed.  Runtime failures surfaced by ``block_until_ready`` —
    a poisoned buffer, a device error raised at sync — MUST propagate:
    a bench that swallowed them would happily report the launch time of
    a computation that never produced its result."""
    try:
        jax.block_until_ready(out)
    except (TypeError, ValueError):
        pass


def time_callable(fn, *, warmup: int = 1, reps: int = 5) -> Timing:
    """Median-of-``reps`` wall time of ``fn()`` after ``warmup`` unmeasured
    calls (which absorb compilation and first-touch caches)."""
    for _ in range(warmup):
        _block(fn())
    samples = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        out = fn()
        _block(out)
        samples.append((time.perf_counter() - t0) * 1e6)
    return Timing(
        median_us=statistics.median(samples),
        best_us=min(samples),
        mean_us=statistics.fmean(samples),
        reps=len(samples),
        warmup=warmup,
    )


def time_interleaved(fns, *, warmup: int = 1,
                     reps: int = 5) -> list[Timing]:
    """Round-robin single-call timing of several callables: rep ``k``
    times each ``fn`` in turn instead of finishing one before starting
    the next.  On a shared rig a slow phase then lands on EVERY callable
    rather than whichever one happened to be mid-phase, so the RELATIVE
    ordering of the returned medians is trustworthy even when the
    absolute numbers are inflated.  Use for gated A/B comparisons where
    cross-phase noise exceeds the effect size."""
    for fn in fns:
        for _ in range(warmup):
            _block(fn())
    samples: list[list[float]] = [[] for _ in fns]
    for _ in range(max(reps, 1)):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            _block(fn())
            samples[i].append((time.perf_counter() - t0) * 1e6)
    return [Timing(
        median_us=statistics.median(s),
        best_us=min(s),
        mean_us=statistics.fmean(s),
        reps=len(s),
        warmup=warmup,
    ) for s in samples]


@contextlib.contextmanager
def stopwatch(record: dict, key: str):
    """One-shot wall timing for sweeps too big to repeat: stores elapsed
    seconds into ``record[key]``."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        record[key] = round(time.perf_counter() - t0, 3)


@dataclasses.dataclass(frozen=True)
class BenchSizes:
    """The quick (CI smoke) vs full (paper figure) size policy, in one
    place instead of scattered per-module constants."""
    quick: bool = False

    @property
    def fig_requests(self) -> int:
        """Trace length for the Fig. 9/10/11 sweeps."""
        return 40_000 if self.quick else 120_000

    @property
    def kernel_reps(self) -> int:
        return 3 if self.quick else 5

    @property
    def systems(self) -> list[str] | None:
        """Config subset for the cache sweep (None = all §10.2 systems).
        Quick mode keeps the C1-C4 claim set: the D-Cache baselines plus
        the full Monarch M-sweep."""
        if not self.quick:
            return None
        return ["d_cache", "d_cache_ideal", "monarch_unbound",
                "monarch_m1", "monarch_m2", "monarch_m3", "monarch_m4"]
