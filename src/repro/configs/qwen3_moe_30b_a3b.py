"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,                # per-expert FFN width
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    source="hf:Qwen/Qwen3-30B-A3B",
)
