"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only, same backbone as wav2vec2.  [arXiv:2106.07447; unverified]

The conv feature-extractor frontend is a STUB per the assignment:
``input_specs()`` feeds precomputed frame embeddings (B, T, d_model).
Encoder-only: no decode shapes (skip matrix in configs.base).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_head=80,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    encoder_only=True,
    mlp_gated=False,         # w2v2-style plain GELU FFN
    source="arXiv:2106.07447; unverified",
)
