"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]

Arctic is a dense-MoE hybrid: every layer runs a dense FFN residual path in
parallel with the 128-expert top-2 MoE (``dense_residual=True``)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab_size=32_000,
    rope_theta=10_000.0,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)
