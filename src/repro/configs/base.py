"""Architecture + shape configuration schema.

Every assigned architecture gets one module in this package defining
``CONFIG`` (exact published dimensions) and the registry maps ``--arch``
ids to them.  ``reduced()`` produces the CPU-smoke-test variant of the same
family (few layers, narrow, tiny vocab) — the FULL configs are only ever
lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# Layer kinds used in `layer_pattern`.
ATTN_GLOBAL = "attn_global"
ATTN_LOCAL = "attn_local"       # sliding-window attention
MAMBA1 = "mamba1"
MAMBA2 = "mamba2"
SHARED_ATTN = "shared_attn"     # zamba2-style shared block (tied params)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # Attention details.
    rope_theta: float = 10_000.0
    use_rope: bool = True           # False = NoPE (position-free attention)
    sliding_window: int = 1024
    local_global_pattern: int = 0   # N local layers per 1 global (0 = all global)
    causal: bool = True
    encoder_only: bool = False
    logit_softcap: float = 0.0
    # MoE.
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False    # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # SSM.
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    shared_attn_every: int = 0      # zamba2: shared attn block cadence
    # Multimodal stub frontends.
    n_prefix_embeds: int = 0        # vlm: image patches; audio: frames are the seq
    # Norm/MLP details.
    mlp_gated: bool = True          # SwiGLU vs plain GELU
    tie_embeddings: bool = False
    # §Perf knobs (beyond-paper; defaults = the measured baseline).
    moe_dispatch: str = "gather"    # "gather" | "einsum" (GShard one-hot)
    # Sequence-sharded attention (megatron-SP style): shard the sequence
    # dim of q/k/v over `model` instead of letting GSPMD fall back to
    # d_head-sharded contractions (which all-reduce fp32 logits planes
    # when n_(kv_)heads %% model != 0).  Value = the DP axis names tuple
    # (("data",) or ("pod", "data")); empty = off.
    attn_seq_shard: Sequence[str] = ()
    source: str = ""

    # ---- derived ------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.encoder_only

    @property
    def supports_long_context(self) -> bool:
        """long_500k runnable: SSM/hybrid, or local-attention-dominated."""
        return self.family in ("ssm", "hybrid") or self.local_global_pattern > 0

    def layer_pattern(self) -> list[str]:
        """Expanded per-layer kinds, length n_layers."""
        if self.family == "ssm":
            return [MAMBA1] * self.n_layers
        if self.family == "hybrid":
            out = []
            for i in range(self.n_layers):
                if self.shared_attn_every and (i + 1) % self.shared_attn_every == 0:
                    out.append(SHARED_ATTN)
                else:
                    out.append(MAMBA2)
            return out
        if self.local_global_pattern > 0:
            out = []
            for i in range(self.n_layers):
                # N locals then 1 global, repeating (gemma3: 5:1).
                out.append(ATTN_GLOBAL if (i % (self.local_global_pattern + 1)
                                           == self.local_global_pattern)
                           else ATTN_LOCAL)
            return out
        return [ATTN_GLOBAL] * self.n_layers

    def scan_groups(self) -> tuple[list[str], int, list[str]]:
        """(group_pattern, n_groups, remainder_pattern) for scan-over-layers:
        the layer pattern is factored into ``n_groups`` repeats of
        ``group_pattern`` plus a remainder handled unscanned."""
        pat = self.layer_pattern()
        if self.local_global_pattern > 0 or self.family == "hybrid":
            g = (self.local_global_pattern + 1 if self.local_global_pattern
                 else self.shared_attn_every)
        else:
            g = 1
        g = max(g, 1)
        n_groups = len(pat) // g
        rem = pat[n_groups * g:]
        return pat[:g], n_groups, rem

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/topology, tiny dims."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 6),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_head=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            sliding_window=32,
            shared_attn_every=3 if self.shared_attn_every else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 16),
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The assignment's skip matrix (also mirrored in DESIGN.md §4)."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic attention"
    return True, ""
