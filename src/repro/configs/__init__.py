"""Registry of assigned architectures (``--arch <id>``)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, cell_is_runnable

from repro.configs.gemma3_27b import CONFIG as _gemma3
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.command_r_plus_104b import CONFIG as _command_r
from repro.configs.yi_9b import CONFIG as _yi
from repro.configs.zamba2_2p7b import CONFIG as _zamba2
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3
from repro.configs.arctic_480b import CONFIG as _arctic

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        _gemma3, _starcoder2, _command_r, _yi, _zamba2,
        _paligemma, _falcon_mamba, _hubert, _qwen3, _arctic,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells():
    """All (arch, shape, runnable, reason) assignment cells (10 x 4)."""
    out = []
    for a in ARCHS.values():
        for s in SHAPES.values():
            ok, why = cell_is_runnable(a, s)
            out.append((a, s, ok, why))
    return out
