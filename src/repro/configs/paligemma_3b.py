"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP vision frontend + gemma decoder.
[arXiv:2407.07726; hf]

The SigLIP frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed patch embeddings (B, 256, d_model); the decoder prefix-attends
to them (full attention over prefix+text).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,            # MQA
    d_head=256,
    d_ff=16384,
    vocab_size=257_216,
    rope_theta=10_000.0,
    n_prefix_embeds=256,     # 224/14 = 16x16 patches
    source="arXiv:2407.07726; hf",
)
