"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attention-free) d_ff=0
vocab=65024, ssm_state=16 — Mamba-1 architecture.  [arXiv:2410.05355;
unverified]

Arch-applicability note (DESIGN.md §4): no KV cache exists, so the
Monarch KV-prefix-cache technique is INAPPLICABLE here; the arch runs
without it (data-pipeline CAM dedup still applies).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,                  # attention-free, MLP-free: pure mamba blocks
    vocab_size=65_024,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    source="arXiv:2410.05355; unverified",
)
