"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

The shared attention block (attention + MLP with TIED parameters across all
its invocations) is applied every 6th layer; the other layers are Mamba2.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,           # MHA inside the shared block
    d_head=80,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    shared_attn_every=6,
    source="arXiv:2411.15242; hf",
)
