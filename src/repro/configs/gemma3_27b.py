"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_head=128,
    d_ff=21504,
    vocab_size=262_144,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    local_global_pattern=5,   # 5 local : 1 global
    logit_softcap=0.0,
    source="hf:google/gemma-3-1b-pt (27b scaling); unverified",
)
