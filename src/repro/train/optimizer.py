"""AdamW with fp32 master params, bf16 compute cast, global-norm clipping,
and a linear-warmup + cosine-decay schedule.  Built from scratch (no optax
dependency); state shards exactly like the master params (ZeRO-3)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params_fp32):
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params_fp32),
        "v": jax.tree.map(zeros, params_fp32),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / 1D params."""
    name = getattr(path[-1], "key", "")
    return name not in ("ln1", "ln2", "final_ln", "norm_w", "conv_b",
                        "dt_b", "d_skip")


def adamw_update(cfg: OptConfig, params, opt_state, grads):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, m, v, g):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path) and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p
        return p - lr * delta, m, v

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, m, v, g: upd(path, p, m, v, g),
        params, opt_state["m"], opt_state["v"], grads)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gn}
