"""Train-step construction: bf16 compute / fp32 master, grad accumulation,
donated state, pjit shardings.

``TrainState`` = {"params": fp32 master tree, "opt": {m, v, step}}.
The compute graph casts masters to bf16 (one fused cast per weight — XLA
keeps it alongside the FSDP all-gather), takes grads w.r.t. the masters.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import sharding
from repro.models import transformer
from repro.train import optimizer as opt

BF16 = jnp.bfloat16


def cast_bf16(params):
    return jax.tree.map(
        lambda p: p.astype(BF16) if p.dtype == jnp.float32 and p.ndim >= 2 else p,
        params)


def init_state(key, cfg: ArchConfig):
    params = transformer.init_params(key, cfg)
    params = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {"params": params, "opt": opt.init_opt_state(params)}


def make_train_step(cfg: ArchConfig, ocfg: opt.OptConfig = opt.OptConfig(),
                    microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params_fp32, batch):
        return transformer.train_loss(cast_bf16(params_fp32), cfg, batch)

    def train_step(state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                loss, g = jax.value_and_grad(loss_fn)(state["params"], mbatch)
                return (carry[0] + loss,
                        jax.tree.map(jnp.add, carry[1], g)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  state["params"])
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_g), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)

        new_params, new_opt, metrics = opt.adamw_update(
            ocfg, state["params"], state["opt"], grads)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def state_specs(state_shapes, mesh):
    """PartitionSpec tree for a TrainState (masters + moments share the
    param rules; step scalar replicated)."""
    p_specs = sharding.param_specs(state_shapes["params"], mesh)
    return {
        "params": p_specs,
        "opt": {
            "m": p_specs,
            "v": p_specs,
            "step": jax.sharding.PartitionSpec(),
        },
    }
