"""MonarchKVIndex — the paper's technique as a first-class serving feature.

A vLLM-style paged KV prefix cache whose INDEX is a Monarch flat-CAM,
SHARDED along the set axis across a ``("sets",)`` device mesh
(``launch/mesh.make_set_mesh``).  The paper's headline win is in-package
parallelism — many XAM subarrays searched concurrently behind one wide
interface (§III) — and the set axis is exactly that parallelism at serving
scale: shard k owns the contiguous block of physical sets
``[k * sets_per_shard, (k + 1) * sets_per_shard)`` (``geometry.
shard_of_set``) and carries its own stored-bit/validity/fingerprint
planes, D̄&R̄ metadata, per-set replacement counters and §8 ``WearState``
on its own mesh device.

Data flow per batch:

* LOOKUP: every 16-token chunk is fingerprinted (murmur3) and the whole
  batch is answered by ONE device dispatch regardless of the shard
  count: the two-level host grouping emits a stacked ``(n_shards, Qmax,
  R)`` padded layout (per-shard per-set blocks, Qmax pow2-bucketed,
  per-shard valid block counts scalar-prefetched) and
  ``xam_ops.xam_search_multiset_stacked`` wraps the fused multiset
  kernel in a ``shard_map`` over the ``("sets",)`` mesh, so XLA places
  all per-shard searches from a single call — no per-shard host
  round-trips.  With one shard (or all shards co-located on one device)
  the path IS the unsharded fused kernel, bit for bit.  The PR-4 host
  fan-out (one ``pallas_call`` per shard) survives as the differential
  reference behind ``dispatch="fanout"``.
* ADMISSION: like lookup, ONE device dispatch per batch at every shard
  count.  The host packs candidates into the ROUND GRID of
  ``xam_ops.group_admits_stacked`` — a ``(n_parts, n_rounds,
  round_width)`` stacked layout where round r holds each set's rank-r
  candidate (per-set prefix ranks; both axes pow2-bucketed) — and one
  jitted, donated-state dispatch (``shard_map`` over the ``("sets",)``
  mesh when partitions span devices, the plain jitted scan otherwise)
  runs ``_admit_rounds_body``: a ``lax.scan`` over rounds whose step
  admits a whole round VECTORIZED — residency probe, no-allocate gate,
  t_MWW throttle (``core/wear.py`` — the same machinery the Fig. 11
  simulator scans), cold-victim way selection, column install and
  vectorized wear recording (``wear.record_write_rows``).  Decisions
  couple only through per-set state (residency, window budget, the
  per-set replacement counter) and a round's sets are pairwise distinct
  by construction (same-set candidates differ in rank), so the
  round-parallel schedule is bit-equivalent to one global sequential
  scan — the shard-invariance tests replay randomized schedules at
  ``n_shards in {1, 2, 4}`` and require identical hits, installs and
  wear reports.  The PR-5 per-partition ``_admit_batch`` scan survives
  as the differential oracle behind ``admit_dispatch="fanout"``
  (``tests/test_kv_index_differential.py`` pins both paths bit-identical
  after every op).
* ROTATION: the rotary remap is the GLOBAL permutation ``set -> set + 7``
  applied to every shard's planes in lockstep with the ``_set_of`` offset
  bump, so resident entries stay searchable after the remap (pinned since
  the batched-admission PR) and the fingerprint -> physical-set mapping —
  hence wear accounting — is independent of the shard count.  Across
  shards the roll is DEVICE-RESIDENT: per-shard plane rolls plus a
  ``ppermute`` boundary exchange of the sets that cross shard edges
  under the global permutation (``geometry.shard_roll_plan`` /
  ``mesh.make_sharded_roll``) — bits/valid/fp_of/read_after never move
  through the host, and set_writes/WearState track PHYSICAL sets so they
  never move at all.

Intentional change pinned by the shard-invariance tests: the replacement
counter is PER SET (it was one free-running global scalar).  A global
counter couples victim choice in one set to eviction traffic in every
other set — the single cross-set dependency that would make admission
results depend on how sets are sharded.  Per-set counters keep the
§8 "random counter" replacement flavor while making the per-shard scans
exactly equal to the global sequential order.

Asynchronous admission lives in ``serve/admit_queue.py``: ``AdmitQueue``
moves ``admit_fps`` off the serving loop onto a worker thread (installs
overlap model compute), with a drain barrier before rotation and an
optional read-your-writes flush when a looked-up fingerprint is still
pending.

Lifetime targeting: ``KVIndexConfig.with_lifetime`` derives the t_MWW
window length (in ops) from a target lifetime in years, the cell
endurance and an expected op rate — the serving twin of
``wear.make_config``.  ``launch/serve.py`` surfaces it as
``--lifetime-years`` (and the shard count as ``--n-shards``).

The index is exercised by examples/serve_prefix_cache.py and
benchmarks/kernels_bench.py (``kv_index_admit`` pins the batched path
against the pre-batching host loop; ``kv_index_lookup_sharded`` and
``kv_index_admit_async`` pin the sharded fan-out and the queue overlap).
See docs/ARCHITECTURE.md for the paper-concept -> code map and
docs/SERVING.md for the operator guide.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import geometry
from repro.core import lifetime as lifetime_mod
from repro.core import wear
from repro.core.timing import SECONDS_PER_YEAR, t_mww_seconds
from repro.data.pipeline import (fingerprint_blocks, murmur3_np,
                                 prefix_fingerprint_blocks)
from repro.kernels.common import (
    bucket_pow2, pack_bits_np, resolve_plane_format)
from repro.kernels.xam_search import ops as xam_ops
from repro.launch import mesh as mesh_mod

CHUNK_TOKENS = 16
ROTATE_STRIDE = 7          # prime set stride per rotation (§8)
ADMIT_BUCKET_LO = 8        # pow2 bucket floor for admit batch shapes


@dataclasses.dataclass
class KVIndexConfig:
    """Serving-index geometry and §8 durability knobs.

    Parameters
    ----------
    n_sets : int
        CAM sets (global).  Each holds ``set_ways`` searchable columns.
    set_ways : int
        CAM columns (ways) per set — the cache associativity.
    key_bits : int
        Fingerprint bits stored/searched per column.
    admit_after_reads : int
        No-allocate filter: a chunk must be OFFERED this many times
        before it is installed (0 = admit on first touch).
    m_writes : int
        Per-way write budget per t_MWW window; the per-set window budget
        is ``set_ways * m_writes``.
    window_ops : int
        t_MWW window length in CLOCK CYCLES: index ops under
        ``clock="ops"`` (the op counter is the serving cycle proxy),
        wall-clock MICROSECONDS under ``clock="wall"``.
    clock : str
        t_MWW cycle domain (§6.2): ``"ops"`` (default) keeps the
        op-counter proxy — every stamp and window length counts index
        ops, bit-identical to the pre-wall-clock behavior.  ``"wall"``
        expresses the admission window as a latency-era TIME budget:
        stamps are host wall microseconds (``wear.WALL_HZ``), taken once
        per batch on the host so the device scans stay deterministic
        (every candidate in a batch shares the batch's stamp).  Window
        lengths must stay below ``wear.CLOCK_REBASE_AT`` (~17.9 min) —
        the int32 cycle domain's rebase bound.
    rotate_every : int
        Admissions between rotary remaps (prime stride 7).
    n_shards : int
        Set-axis shards; must divide ``n_sets``.  ``1`` (default) is the
        unsharded single-device path, bit-identical to the pre-sharding
        implementation.
    plane_format : str or None
        Stored-bit plane layout (``kernels/common.py``): ``"int8"`` (one
        bit per byte) or ``"packed8"`` (8 bits per uint8 word along the
        key-bit axis — ~8x less HBM->VMEM plane traffic, bit-identical
        results; requires ``key_bits`` divisible by 8).  ``None``
        (default) reads the ``REPRO_PLANE_FORMAT`` env knob.
    fingerprint : str
        Chunk-fingerprint scheme: ``"block"`` (default) hashes each
        16-token chunk independently — right for dedup, where equal
        content is the identity.  ``"prefix"`` chains chunk hashes
        (``data.pipeline.prefix_fingerprint_blocks``) so equal
        fingerprints imply equal ENTIRE prefixes — required whenever the
        index keys KV slabs (a chunk's KV depends on every preceding
        token, so a mid-prompt content match must NOT hit).
    """
    n_sets: int = 32
    set_ways: int = 512           # CAM columns per set
    key_bits: int = 32
    admit_after_reads: int = 1    # no-allocate: admit on 2nd touch
    m_writes: int = 3             # per-way write budget per t_MWW window
    window_ops: int = 4096        # t_MWW window length in clock cycles
    rotate_every: int = 50_000    # admissions between rotary remaps
    n_shards: int = 1             # set-axis mesh shards (divides n_sets)
    plane_format: str | None = None  # None = REPRO_PLANE_FORMAT env knob
    clock: str = "ops"            # t_MWW cycle domain: "ops" | "wall"
    fingerprint: str = "block"    # chunk hashing: "block" | "prefix"

    @classmethod
    def with_lifetime(cls, *, t_life_years: float, endurance: float = 1e8,
                      ops_per_second: float = 1e6, m_writes: int = 3,
                      clock: str = "ops", **kw) -> "KVIndexConfig":
        """Derive ``window_ops`` from a lifetime target (§6.2).

        The t_MWW window in seconds comes from ``core/timing``'s own
        formula ``t_MWW = M * T_life / endurance``.  Under
        ``clock="ops"`` the serving op counter stands in for cycles at
        ``ops_per_second``; under ``clock="wall"`` the window IS the
        time budget, converted straight to wall microseconds
        (``ops_per_second`` is ignored — no rate estimate needed, which
        is the point of the wall clock).

        Parameters
        ----------
        t_life_years : float
            Target index lifetime in years.
        endurance : float
            Cell write endurance (§8 evaluations use 1e8).
        ops_per_second : float
            Expected index op rate (lookup chunks + admission offers per
            second) — converts the window from seconds to ops.  Only
            consulted under ``clock="ops"``.
        m_writes : int
            Per-way write budget per window.
        clock : str
            t_MWW cycle domain, ``"ops"`` or ``"wall"``.
        **kw
            Forwarded to the constructor (``n_sets``, ``n_shards``, ...).

        Returns
        -------
        KVIndexConfig

        Examples
        --------
        >>> cfg = KVIndexConfig.with_lifetime(t_life_years=10.0)
        >>> cfg.window_ops        # 3 * 10y / 1e8 writes * 1e6 ops/s
        9467280
        >>> KVIndexConfig.with_lifetime(
        ...     t_life_years=10.0, clock="wall").window_ops  # 9.467s in us
        9467280
        """
        t_mww_s = t_mww_seconds(m_writes, t_life_years * SECONDS_PER_YEAR,
                                endurance)
        hz = ops_per_second if clock == "ops" else wear.WALL_HZ
        window_ops = max(int(t_mww_s * hz), 1)
        return cls(m_writes=m_writes, window_ops=window_ops, clock=clock,
                   **kw)


@dataclasses.dataclass
class KVIndexStats:
    lookups: int = 0
    chunk_hits: int = 0
    chunk_misses: int = 0
    admissions: int = 0
    admission_skips: int = 0      # no-allocate first touches
    throttled: int = 0            # t_MWW window exhausted
    evictions: int = 0
    rotations: int = 0
    searches: int = 0             # lookup dispatches (1 per batch on the
                                  # single-dispatch paths; 1 per occupied
                                  # shard on the "fanout" reference)
    admit_calls: int = 0          # jitted admit launches (1 per batch on
                                  # the stacked path; 1 per partition
                                  # holding candidates on "fanout")


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _install_column(bits, valid, fp_of, s, w, bitcol, fp):
    """Device-side install of ONE CAM column.  Kept as the pre-batching
    primitive: benchmarks/kernels_bench.py uses it to measure the host-loop
    admission flow the batched pipeline replaced."""
    bits = bits.at[s, :, w].set(bitcol)
    valid = valid.at[s, w].set(jnp.int8(1))
    fp_of = fp_of.at[s, w].set(fp)
    return bits, valid, fp_of


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
def _admit_batch(bits, valid, fp_of, read_after, set_writes, counter,
                 wstate, wdyn, admit_after, sets, fps, bitcols, cycles,
                 touches, active):
    """ONE device call admits a whole (shard-local) candidate batch.

    A ``lax.scan`` over the (order-preserving) candidate list; each step is
    the full per-fingerprint admission pipeline: residency probe ->
    read_after bump | no-allocate gate | t_MWW throttle -> way select ->
    column install fused with §8 wear recording.  Same-set collisions
    resolve through the scan carry (segment conflicts never race — later
    candidates see earlier installs AND earlier evictions: the residency
    and no-allocate decisions are made against the in-batch state, exactly
    as a sequential per-fingerprint loop would), which keeps the batched
    path bit-equivalent to sequential admission.  ``counter`` is the
    PER-SET replacement counter plane (S,) — every decision in the scan
    couples only through per-set state, which is what makes per-shard
    scans equal to one global scan.  ``touches`` carries the host
    first_touch counts (unique fps, so they cannot change mid-batch).
    All mutable planes are donated; outputs feed the host shadow map in
    one transfer.
    """
    n_ways = valid.shape[1]
    iota = jnp.arange(n_ways, dtype=jnp.int32)

    def step(carry, x):
        bits, valid, fp_of, read_after, set_writes, counter, ws = carry
        s, fp, bitcol, cycle, touch, act = x

        vrow = valid[s]
        frow = fp_of[s]
        hitv = (vrow == 1) & (frow == fp)
        is_res = jnp.any(hitv) & act
        res_w = jnp.argmax(hitv).astype(jnp.int32)
        # resident re-offer: D/R metadata only (marks the way re-read).
        read_after = read_after.at[s, res_w].add(
            jnp.where(is_res, 1, 0).astype(jnp.int32))

        # no-allocate gate (D̄&R̄ "never accessed" filter): evaluated against
        # the CURRENT residency, so a fingerprint evicted by an earlier
        # same-batch install re-enters the touch count like the sequential
        # flow would.
        skipped = act & ~is_res & (touch < admit_after)

        # t_MWW lifetime throttle — shared wear machinery (§6.2/§8).
        # window_would_exceed rejects BEFORE the write, so under this
        # policy record_write's lock branch never fires; is_locked is kept
        # as a guard for wear states also driven by other writers.
        locked = wear.is_locked(ws, s, cycle)
        over = wear.window_would_exceed(ws, wdyn, s, cycle)
        throttled = act & ~is_res & ~skipped & (locked | over)
        do_install = act & ~is_res & ~skipped & ~throttled

        # Way selection: first free way, else counter-ordered cold victim
        # (never-re-read ways first — D̄&R̄-style replacement).  The
        # replacement counter free-runs PER SET.
        free = vrow == 0
        has_free = jnp.any(free)
        free_w = jnp.argmax(free).astype(jnp.int32)
        order = ((iota + counter[s]) % n_ways).astype(jnp.int32)
        cold = read_after[s][order] == 0
        victim = jnp.where(jnp.any(cold), order[jnp.argmax(cold)], order[0])
        way = jnp.where(has_free, free_w, victim).astype(jnp.int32)
        evict = do_install & ~has_free
        old_fp = frow[way]
        counter = counter.at[s].add(jnp.where(evict, 1, 0).astype(jnp.int32))

        # Column install (one CAM column + metadata; bitcol arrives in
        # the plane format — packed words scatter as-is).
        bits = bits.at[s, :, way].set(
            jnp.where(do_install, bitcol.astype(bits.dtype),
                      bits[s, :, way]))
        valid = valid.at[s, way].set(
            jnp.where(do_install, 1, vrow[way]).astype(jnp.int8))
        fp_of = fp_of.at[s, way].set(jnp.where(do_install, fp, old_fp))
        read_after = read_after.at[s, way].set(
            jnp.where(do_install, 0, read_after[s, way]).astype(jnp.int32))
        set_writes = set_writes.at[s].add(
            jnp.where(do_install, 1, 0).astype(jnp.int32))

        # Wear recording fused with the install (one implementation: §8's
        # record_write — the same function the Fig. 11 simulator scans).
        ws2, rot, _fl = wear.record_write(ws, wdyn, s, jnp.asarray(True),
                                          cycle)
        ws = jax.tree.map(lambda o, n: jnp.where(do_install, n, o), ws, ws2)

        out = (is_res, skipped, throttled, do_install, way, evict, old_fp)
        return (bits, valid, fp_of, read_after, set_writes, counter, ws), out

    carry = (bits, valid, fp_of, read_after, set_writes, counter, wstate)
    carry, outs = jax.lax.scan(step, carry,
                               (sets, fps, bitcols, cycles, touches, active))
    return carry, outs


def _admit_rounds_body(bits, valid, fp_of, read_after, set_writes, counter,
                       wstate, wdyn, admit_after, sets, fps, bitcols, cycles,
                       touches, active):
    """Segmented-parallel admission over the round grid (ONE partition).

    The candidate operands are ``(n_rounds, round_width)`` grids from
    ``xam_ops.group_admits_stacked``: round r holds each set's rank-r
    candidate, so within a round every active lane targets a DISTINCT
    set.  The ``lax.scan`` over rounds replays intra-set collisions in
    exact batch order (rank order IS batch order within a set) while each
    round's step runs the full per-fingerprint pipeline of
    ``_admit_batch`` vectorized over the lanes — gathers row-clipped,
    installs scattered with an out-of-bounds sentinel so inactive /
    non-installing lanes write nothing, wear recorded via
    ``wear.record_write_rows`` (distinct rows per round is exactly its
    contract).  Because every decision couples only through per-set state,
    the result is bit-identical to the sequential scan — pinned against
    the ``admit_dispatch="fanout"`` oracle after every op.
    """
    n_ways = valid.shape[1]
    s_all = valid.shape[0]
    iota = jnp.arange(n_ways, dtype=jnp.int32)

    def round_step(carry, x):
        bits, valid, fp_of, read_after, set_writes, counter, ws = carry
        s, fp, bitcol, cycle, touch, act = x        # (K,) lanes, one round
        sc = jnp.clip(s, 0, s_all - 1)              # gather-safe row index

        vrow = valid[sc]                            # (K, W)
        frow = fp_of[sc]
        hitv = (vrow == 1) & (frow == fp[:, None])
        is_res = jnp.any(hitv, axis=1) & act
        res_w = jnp.argmax(hitv, axis=1).astype(jnp.int32)
        # resident re-offer: D/R metadata only (marks the way re-read).
        read_after = read_after.at[
            jnp.where(is_res, sc, s_all), res_w].add(1, mode="drop")

        # no-allocate gate (D̄&R̄ "never accessed" filter).
        skipped = act & ~is_res & (touch < admit_after)

        # t_MWW lifetime throttle — same shared wear machinery as the
        # sequential scan (reject-before-write, per-set window).
        locked = wear.is_locked(ws, sc, cycle)
        over = wear.window_would_exceed(ws, wdyn, sc, cycle)
        throttled = act & ~is_res & ~skipped & (locked | over)
        do_install = act & ~is_res & ~skipped & ~throttled

        # Way selection: first free way, else counter-ordered cold victim.
        free = vrow == 0
        has_free = jnp.any(free, axis=1)
        free_w = jnp.argmax(free, axis=1).astype(jnp.int32)
        order = ((iota[None, :] + counter[sc][:, None]) % n_ways
                 ).astype(jnp.int32)
        cold = jnp.take_along_axis(read_after[sc], order, axis=1) == 0
        victim = jnp.where(
            jnp.any(cold, axis=1),
            jnp.take_along_axis(
                order, jnp.argmax(cold, axis=1)[:, None], axis=1)[:, 0],
            order[:, 0])
        way = jnp.where(has_free, free_w, victim).astype(jnp.int32)
        evict = do_install & ~has_free
        old_fp = jnp.take_along_axis(frow, way[:, None], axis=1)[:, 0]
        counter = counter.at[
            jnp.where(evict, sc, s_all)].add(1, mode="drop")

        # Column install: scatter only the installing lanes (sentinel
        # index drops the rest) — rows are distinct within a round, so
        # the scatters never collide.
        ii = jnp.where(do_install, sc, s_all)
        bits = bits.at[ii, :, way].set(bitcol.astype(bits.dtype),
                                       mode="drop")
        valid = valid.at[ii, way].set(jnp.int8(1), mode="drop")
        fp_of = fp_of.at[ii, way].set(fp, mode="drop")
        read_after = read_after.at[ii, way].set(0, mode="drop")
        set_writes = set_writes.at[ii].add(1, mode="drop")

        # Wear recording fused with the install — §8's record_write
        # semantics, vectorized over the round's distinct rows.
        ws = wear.record_write_rows(ws, wdyn, sc, cycle, do_install)

        out = (is_res, skipped, throttled, do_install, way, evict, old_fp)
        return (bits, valid, fp_of, read_after, set_writes, counter, ws), out

    carry = (bits, valid, fp_of, read_after, set_writes, counter, wstate)
    carry, outs = jax.lax.scan(round_step, carry,
                               (sets, fps, bitcols, cycles, touches, active))
    return carry, outs


#: Single-partition entry point for the round-grid admission (donated
#: planes/counters/wear, exactly like ``_admit_batch``).
_admit_rounds = functools.partial(
    jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))(_admit_rounds_body)


@functools.lru_cache(maxsize=None)
def _admit_shardmap_fn(mesh):
    """Jitted ``shard_map`` wrapper admitting EVERY partition's round grid
    from ONE dispatch — the write-path twin of
    ``xam_ops._stacked_shardmap_fn``.  Each mesh device receives its
    ``P("sets")`` slices: plane/counter blocks, the per-set wear rows, its
    ``(1,)`` block of the stacked wear scalars and its ``(1, n_rounds,
    round_width)`` candidate slice; the traced wear knobs and the
    no-allocate threshold arrive replicated.  The §8 wear state is passed
    DECOMPOSED (per-set rows shard, scalar counters stack) because the
    rotary offsets and rotate totals are invariants of the admission path
    (the serving config disables every rotate signal) and stay outside the
    dispatch entirely.  All state operands are donated."""
    def per_shard(bits, valid, fp_of, read_after, set_writes, counter,
                  swt_w, swt_d, window_writes, window_start, locked_until,
                  wc, ssc, dc, wdyn, admit_after,
                  sets, fps, bitcols, cycles, touches, active):
        ws = wear.WearState(
            swt_w=swt_w, swt_d=swt_d,
            write_counter=wc[0], superset_counter=ssc[0],
            dirty_counter=dc[0],
            offsets=geometry.zero_offsets(),      # invariant; discarded
            window_writes=window_writes, window_start=window_start,
            locked_until=locked_until,
            total_rotates=jnp.zeros((), jnp.int32),
            total_flushed=jnp.zeros((), jnp.int32))
        carry, outs = _admit_rounds_body(
            bits, valid, fp_of, read_after, set_writes, counter, ws, wdyn,
            admit_after, sets[0], fps[0], bitcols[0], cycles[0], touches[0],
            active[0])
        bits, valid, fp_of, read_after, set_writes, counter, ws = carry
        return ((bits, valid, fp_of, read_after, set_writes, counter,
                 ws.swt_w, ws.swt_d, ws.window_writes, ws.window_start,
                 ws.locked_until, ws.write_counter[None],
                 ws.superset_counter[None], ws.dirty_counter[None])
                + tuple(o[None] for o in outs))

    spec = (P("sets"),) * 14 + (P(), P()) + (P("sets"),) * 6
    return jax.jit(
        shard_map(per_shard, mesh=mesh, in_specs=spec,
                  out_specs=P("sets"), check_rep=False),
        donate_argnums=tuple(range(14)))


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=("shift",))
def _rotate_planes(bits, valid, fp_of, read_after, shift: int):
    """Device start-gap-style remap: cyclically shift every set plane by the
    prime stride — resident entries move WITH the ``_set_of`` offset bump,
    so they stay searchable under the rotated mapping.  No host rebuild."""
    roll = lambda x: jnp.roll(x, shift, axis=0)
    return roll(bits), roll(valid), roll(fp_of), roll(read_after)


def _shard_property(name: str, doc: str, settable: bool = True):
    """Global view over a per-partition plane list: partition 0's array
    unwrapped when there is only one (zero-copy — donation-safe for
    external callers like the bench host loop), a host-side concatenation
    in partition order otherwise."""
    def get(self):
        parts = getattr(self, name)
        if len(parts) == 1:
            return parts[0]
        return np.concatenate([np.asarray(p) for p in parts], axis=0)

    def set_(self, value):
        if self.n_parts == 1:
            getattr(self, name)[0] = value
        else:
            setattr(self, name, [
                self._put(np.asarray(value)[self._slice(k)], k)
                for k in range(self.n_parts)])

    return property(get, set_ if settable else None, None, doc)


class KVSlabStore:
    """Host-side KV slab store kept in LOCKSTEP with the index.

    Slabs are keyed by the same ``uint32`` fingerprints the index stores
    in its ``fp_of`` columns, and their lifetime is slaved to the
    admission pipeline: a slab is **staged** when its chunk's KV is
    computed (before the async admission drains), **committed** to
    resident exactly when the fingerprint installs (or refreshes a
    resident entry), **discarded** when the offer is skipped or
    throttled, and **dropped** when the fingerprint's way is evicted.
    Set ROTATION never touches the store: rotation remaps fingerprints
    to new physical sets but evicts nothing, and slab keys are
    fingerprints, not (set, way) slots — so resident slabs survive any
    number of rotations by construction.

    Thread safety: all methods take the store lock; staging (serving
    thread, right after prefill) may race commits (AdmitQueue worker).

    A slab is an arbitrary pytree (per-layer k/v arrays for one chunk);
    the store never inspects it beyond byte accounting.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._staged: dict[int, object] = {}
        self._resident: dict[int, object] = {}

    @staticmethod
    def _nbytes(slab) -> int:
        return sum(int(getattr(leaf, "nbytes", 0))
                   for leaf in jax.tree.leaves(slab))

    def stage(self, fp: int, slab) -> None:
        """Hold a freshly computed slab until its admission decides."""
        with self._lock:
            self._staged[int(fp)] = slab

    def commit(self, fp: int) -> None:
        """Fingerprint installed (or re-offered while resident): promote
        its staged slab.  No-op when nothing is staged (e.g. a resident
        refresh admitted via the slab-less ``admit()`` path)."""
        with self._lock:
            slab = self._staged.pop(int(fp), None)
            if slab is not None:
                self._resident[int(fp)] = slab

    def discard(self, fp: int) -> None:
        """Offer skipped/throttled/shed: the staged slab is garbage."""
        with self._lock:
            self._staged.pop(int(fp), None)

    def drop(self, fp: int) -> None:
        """Fingerprint evicted from its way: the resident slab dies with
        it (the lockstep half of the index's eviction)."""
        with self._lock:
            self._resident.pop(int(fp), None)

    def get(self, fp: int):
        """Resident slab for ``fp``, or None (staged slabs are NOT
        servable — their admission has not happened yet)."""
        with self._lock:
            return self._resident.get(int(fp))

    def resident_fps(self) -> set[int]:
        with self._lock:
            return set(self._resident)

    def staged_fps(self) -> set[int]:
        with self._lock:
            return set(self._staged)

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return sum(self._nbytes(s) for s in self._resident.values())


class MonarchKVIndex:
    """Set-sharded Monarch flat-CAM prefix index (see module docstring).

    Parameters
    ----------
    cfg : KVIndexConfig, optional
        Geometry/durability knobs; default-constructed per instance.
    seed : int
        Reserved for future stochastic policies (placement is currently
        deterministic).
    dispatch : {"auto", "fanout"}
        ``"auto"`` (default): single-dispatch paths — state lives in
        ``n_parts = mesh-partition`` blocks (1 when every shard
        co-locates), lookup is one ``shard_map``/``pallas_call`` launch,
        rotation is the on-device ``ppermute`` boundary exchange.
        ``"fanout"``: the PR-4 reference — one storage block PER LOGICAL
        SHARD, one ``pallas_call`` per shard from the host, rotation
        gathered through the host.  Kept as the differential oracle
        (``tests/test_kv_index_differential.py`` pins both paths
        bit-identical after every op); results never depend on it.
    admit_dispatch : {"auto", "fanout"} or None
        Admission dispatch policy; ``None`` (default) follows
        ``dispatch``.  ``"auto"``: the stacked round-grid path — ONE
        donated device dispatch admits the whole batch at every shard
        count.  ``"fanout"``: the PR-5 per-partition ``_admit_batch``
        scan loop, kept as the admission differential oracle (requires
        no mesh layout, so it is also forced whenever
        ``dispatch="fanout"``).  Results never depend on the choice.
    now_fn : callable, optional
        Wall-clock source for ``clock="wall"`` configs: a zero-arg
        callable returning MONOTONIC seconds as a float (default
        ``time.monotonic``).  Injectable so tests drive the latency-era
        t_MWW window deterministically.  Never consulted under
        ``clock="ops"`` (pinned — the op-clock path is bit-identical to
        the pre-wall-clock implementation).

    Attributes
    ----------
    bits, valid, fp_of, read_after : global views (property)
        The CAM planes — ``(n_sets, key_bits, set_ways)`` int8 stored
        bits (``(n_sets, key_bits // 8, set_ways)`` uint8 packed words
        under ``plane_format="packed8"`` — unpack with
        ``kernels.common.unpack_bits_np(..., axis=1)``),
        ``(n_sets, set_ways)`` validity/fingerprint/D̄&R̄ planes.
        With one partition these are THE device arrays; with several they
        are host-side concatenations of the partition-resident planes
        (read-only use intended; assignment re-splits across partitions).
    n_parts : int
        Device partitions actually holding state: the ``("sets",)`` mesh
        size under ``dispatch="auto"`` (1 on a single-device host —
        co-located shards collapse to the unsharded path), ``n_shards``
        under ``dispatch="fanout"``.
    stats : KVIndexStats
        Host-side operation counters.
    ops_total : int
        The op counter — the t_MWW cycle proxy (lookup chunks + admission
        offers), global across shards.

    Examples
    --------
    >>> import numpy as np
    >>> idx = MonarchKVIndex(KVIndexConfig(
    ...     n_sets=4, set_ways=16, admit_after_reads=0, n_shards=2))
    >>> toks = np.arange(1, 65, dtype=np.int32).reshape(1, 64)
    >>> idx.admit(toks)                       # install 4 chunks
    >>> bool(idx.lookup(toks).all())          # now resident
    True
    """

    def __init__(self, cfg: KVIndexConfig | None = None, seed: int = 0,
                 dispatch: str = "auto", admit_dispatch: str | None = None,
                 now_fn=None, slab_store: KVSlabStore | None = None):
        # cfg default constructed per instance: a shared KVIndexConfig()
        # default would alias mutable config across indexes.
        assert dispatch in ("auto", "fanout"), dispatch
        if admit_dispatch is None:
            admit_dispatch = dispatch
        assert admit_dispatch in ("auto", "fanout"), admit_dispatch
        # "fanout" storage keeps one block per LOGICAL shard (no mesh
        # layout to stack over) — its admission is the per-partition loop.
        assert not (dispatch == "fanout" and admit_dispatch == "auto"), (
            "dispatch='fanout' storage only supports fanout admission")
        self.cfg = KVIndexConfig() if cfg is None else cfg
        c = self.cfg
        if c.clock not in wear.CLOCKS:
            raise ValueError(
                f"KVIndexConfig.clock={c.clock!r}: expected one of "
                f"{wear.CLOCKS}")
        if c.fingerprint not in ("block", "prefix"):
            raise ValueError(
                f"KVIndexConfig.fingerprint={c.fingerprint!r}: expected "
                "'block' or 'prefix'")
        # Optional KV slab store, kept in lockstep by admit_fps's host
        # fold (commit on install, discard on skip/throttle, drop on
        # evict); None = tag-only index (dedup, counting).
        self.slab_store = slab_store
        # t_MWW clock domain.  "ops": the op counter is the cycle proxy
        # (pre-existing semantics, now_fn never consulted).  "wall": cycle
        # stamps are host wall microseconds relative to construction,
        # taken ONCE per admission batch so the device scans see only
        # host-provided constants and stay deterministic (the fanout /
        # stacked differential oracle pins bit-identity between dispatch
        # paths for free — both stamp from the same host read).
        self.clock = c.clock
        self._now_fn = time.monotonic if now_fn is None else now_fn
        self._wall_t0 = self._now_fn() if self.clock == "wall" else 0.0
        self._wall_folded = 0       # cycles removed by clock rebases
        self.dispatch = dispatch
        self.admit_dispatch = admit_dispatch
        self.n_shards = c.n_shards
        self.sets_per_shard = geometry.sets_per_shard(c.n_sets, c.n_shards)
        # ("sets",) mesh placement: partition k's planes/wear live on mesh
        # device k; None on a single-device host — every shard co-locates.
        # Under "auto" state is stored in one block per MESH PARTITION
        # (sharding is a pure relabeling, so coarsening co-located shards
        # into one block changes no result — pinned by the invariance
        # tests), which is what lets lookup run as ONE shard_map dispatch
        # and collapses to the exact unsharded path on one device.  Under
        # "fanout" state keeps one block per logical shard (the PR-4
        # reference paths).
        self.set_mesh = mesh_mod.make_set_mesh(c.n_shards)
        if dispatch == "fanout":
            self.n_parts = c.n_shards
            self._devices = mesh_mod.set_shard_devices(
                self.set_mesh, c.n_shards)
        elif self.set_mesh is None:
            self.n_parts = 1
            self._devices = None
        else:
            self.n_parts = int(self.set_mesh.devices.size)
            self._devices = list(self.set_mesh.devices.flat)
        self._use_shard_map = (dispatch == "auto"
                               and self.set_mesh is not None)
        self.sets_per_part = c.n_sets // self.n_parts
        s_loc = self.sets_per_part
        # Stored-bit plane layout: "int8" keeps one bit per byte;
        # "packed8" stores 8 bits per uint8 word along the key-bit axis
        # (the kernel unpacks per tile in VMEM — installs scatter packed
        # COLUMNS, rolls/ppermutes move packed words, lookup keys stay
        # unpacked).  The planes' dtype is the format tag everywhere
        # downstream.
        self.plane_format = resolve_plane_format(c.plane_format)
        if self.plane_format == "packed8" and c.key_bits % 8 != 0:
            raise ValueError(
                f"plane_format='packed8' needs key_bits divisible by 8, "
                f"got key_bits={c.key_bits}")
        self.plane_rows = (c.key_bits if self.plane_format == "int8"
                           else c.key_bits // 8)
        plane_dtype = (np.int8 if self.plane_format == "int8" else np.uint8)
        # Device-resident CAM state, per partition: fingerprint bits
        # column-wise per set, plus the validity / fingerprint / D-R
        # metadata planes, the PER-SET replacement counters and the
        # per-set install (wear) counters.
        self._bits = [
            self._put(
                np.zeros((s_loc, self.plane_rows, c.set_ways), plane_dtype),
                k)
            for k in range(self.n_parts)]
        self._valid = [
            self._put(np.zeros((s_loc, c.set_ways), np.int8), k)
            for k in range(self.n_parts)]
        self._fp_of = [
            self._put(np.zeros((s_loc, c.set_ways), np.uint32), k)
            for k in range(self.n_parts)]
        self._read_after = [
            self._put(np.zeros((s_loc, c.set_ways), np.int32), k)
            for k in range(self.n_parts)]
        self._set_writes = [
            self._put(np.zeros((s_loc,), np.int32), k)
            for k in range(self.n_parts)]
        self._counters = [
            self._put(np.zeros((s_loc,), np.int32), k)
            for k in range(self.n_parts)]
        # §8 wear state over the physical sets — the simulator's own
        # machinery with serving knobs: window length = window_ops (op-count
        # cycle proxy), budget = set_ways * m_writes, WR/WC/DC rotation
        # signals disabled (serving rotates on the rotate_every cadence).
        # wr_shift=32 actually disables WR — int32 MSB distances never
        # reach 32, so ``rotate_signal`` provably never fires (the default
        # shift of 9 left WR armed despite the stated intent).  That
        # invariance is also what makes the vectorized wear recording of
        # the stacked admission exact (``wear.record_write_rows``).
        # One state per partition, over that partition's sets.
        self.wear_cfg = wear.WearConfig(
            n_supersets=c.n_sets, m_writes=c.m_writes,
            dc_limit=1 << 30, wc_limit=1 << 30, wr_shift=32,
            t_mww_cycles=c.window_ops, blocks_per_superset=c.set_ways,
            clock=c.clock)
        self.wear_dyn = wear.dyn_of(self.wear_cfg)
        self._wear_states = [
            self._put_tree(st, k)
            for k, st in enumerate(wear.shard_states(self.wear_cfg,
                                                     self.n_parts))]
        self._wear_dyns = [self._put_tree(self.wear_dyn, k)
                           for k in range(self.n_parts)]
        self._admit_after = [
            self._put(np.asarray(c.admit_after_reads, np.int32), k)
            for k in range(self.n_parts)]
        if self._use_shard_map and self.n_parts > 1:
            # Replicated once at construction so the per-batch stacked
            # admission dispatch performs no implicit host transfers.
            repl = mesh_mod.replicated_sharding(self.set_mesh)
            self._wdyn_repl = jax.device_put(self.wear_dyn, repl)
            self._admit_after_repl = jax.device_put(
                np.asarray(c.admit_after_reads, np.int32), repl)
        # Host-side policy shadow (map + mirrors): keeps assertions and
        # eviction bookkeeping off the device sync path.
        self.valid_np = np.zeros((c.n_sets, c.set_ways), bool)
        self.fp_of_np = np.zeros((c.n_sets, c.set_ways), np.uint32)
        self.slot_of = {}           # fp -> (set, way) (host-side shadow map)
        self.first_touch = {}       # fp -> touch count (pre-admission)
        self.offset = 0             # rotary set offset
        self.ops_total = 0          # op counter == t_MWW cycle proxy
        self.stats = KVIndexStats()

    # -- sharding plumbing ---------------------------------------------
    def _put(self, x, k: int):
        """Place ``x`` on shard k's mesh device (no-op placement when the
        host has one device, preserving the unsharded dispatch path)."""
        if self._devices is None:
            return jnp.asarray(x)
        return jax.device_put(x, self._devices[k])

    def _put_tree(self, tree, k: int):
        if self._devices is None:
            return tree
        return jax.device_put(tree, self._devices[k])

    def _put_admit(self, x):
        """EXPLICIT single-device placement for stacked-admission grids.

        Unlike :meth:`_put`, which falls back to an implicit
        ``jnp.asarray`` transfer on one-device hosts, this always issues
        an explicit ``jax.device_put`` — so the stacked admission path
        stays legal under ``jax.transfer_guard("disallow")``, which
        blocks only IMPLICIT transfers (the no-host-transfer pin)."""
        dev = self._devices[0] if self._devices is not None else jax.devices()[0]
        return jax.device_put(x, dev)

    def _slice(self, k: int) -> slice:
        """Global-set slice owned by storage partition k."""
        return geometry.shard_set_slice(k, self.cfg.n_sets, self.n_parts)

    def _assemble(self, parts: list) -> jnp.ndarray:
        """Zero-copy GLOBAL jax.Array over the per-partition planes:
        each partition's block is already resident on its mesh device, so
        the contiguous ``P("sets")`` sharded view costs no data movement.
        The assembled array SHARES buffers with ``parts`` — donating it
        (rotation) invalidates them, so callers rebind from the output."""
        if self.n_parts == 1:
            return parts[0]
        shape = (self.cfg.n_sets,) + tuple(parts[0].shape[1:])
        return jax.make_array_from_single_device_arrays(
            shape, mesh_mod.set_axis_sharding(self.set_mesh), list(parts))

    def _split_global(self, arr: jnp.ndarray) -> list:
        """Inverse of :meth:`_assemble`: the per-device blocks of a
        ``P("sets")``-sharded global array, in global set order (zero
        copy — each block is a view of the resident shard buffer)."""
        if self.n_parts == 1:
            return [arr]
        shards = sorted(arr.addressable_shards,
                        key=lambda s: s.index[0].start or 0)
        return [s.data for s in shards]

    bits = _shard_property("_bits", "stored-bit planes, global view")
    valid = _shard_property("_valid", "validity planes, global view")
    fp_of = _shard_property("_fp_of", "fingerprint planes, global view")
    read_after = _shard_property(
        "_read_after", "D̄&R̄ re-read counters, global view")
    set_writes = _shard_property(
        "_set_writes", "per-set install counters, global view",
        settable=False)
    counter = _shard_property(
        "_counters", "per-set replacement counters, global view",
        settable=False)

    @property
    def wear_state(self) -> wear.WearState:
        """Global §8 wear view: THE shard state when unsharded, else the
        per-set fields concatenated in shard order (see
        ``wear.concat_states``) — reporting only, never write through."""
        return wear.concat_states(self._wear_states)

    # ------------------------------------------------------------------
    def _set_of(self, fps: np.ndarray) -> np.ndarray:
        """Global PHYSICAL set of each fingerprint under the current
        rotary offset — independent of the shard count by construction
        (sharding only relabels who stores a set)."""
        base = murmur3_np(fps) % np.uint32(self.cfg.n_sets)
        return ((base.astype(np.int64) + self.offset) % self.cfg.n_sets
                ).astype(np.int32)

    def _bitcols(self, fps: np.ndarray) -> np.ndarray:
        """Install columns in the PLANE format: ``(B, key_bits)`` int8
        bit rows, or ``(B, key_bits // 8)`` uint8 packed words under
        ``plane_format="packed8"`` (for 32-bit keys the packed column is
        just the fingerprint's little-endian bytes — same LSB-first
        contract as ``words_to_bits``)."""
        cols = xam_ops.words_to_bits_np(fps, self.cfg.key_bits)
        if self.plane_format == "packed8":
            return pack_bits_np(cols, axis=-1)
        return cols

    def _clock_cycles(self) -> int:
        """Current t_MWW cycle stamp in the config's clock domain: the op
        counter under ``clock="ops"``; elapsed wall MICROSECONDS since
        construction (minus rebased folds) under ``clock="wall"``."""
        if self.clock == "ops":
            return self.ops_total
        return (int((self._now_fn() - self._wall_t0) * wear.WALL_HZ)
                - self._wall_folded)

    def _maybe_rebase_clock(self):
        """Fold the t_MWW clock before the int32 cycle domain wraps
        (timestamps shift in lockstep, so window/lock decisions are
        unchanged).  Op clock: a ~2.1e9-op serving instance would
        otherwise see its windows stop expiring and throttle forever.
        Wall clock: the same fold fires every ~17.9 minutes
        (``CLOCK_REBASE_AT`` microseconds), keeping any window below that
        bound exact indefinitely."""
        if self._clock_cycles() < wear.CLOCK_REBASE_AT:
            return
        for k in range(self.n_parts):
            self._wear_states[k] = wear.rebase_clock(
                self._wear_states[k], wear.CLOCK_REBASE_AT)
        if self.clock == "ops":
            self.ops_total -= wear.CLOCK_REBASE_AT
        else:
            self._wall_folded += wear.CLOCK_REBASE_AT

    def fingerprints(self, tokens: np.ndarray) -> np.ndarray:
        """(B, S) tokens -> (B, S//16) uint32 chunk fingerprints under
        this index's configured scheme (``cfg.fingerprint``).  Every
        caller that feeds fingerprints back to this index (AdmitQueue,
        resume engine, benches) MUST hash through here so lookup,
        admission and slab keys agree."""
        if self.cfg.fingerprint == "prefix":
            return prefix_fingerprint_blocks(tokens, CHUNK_TOKENS)
        return fingerprint_blocks(tokens, CHUNK_TOKENS)

    def lookup(self, tokens: np.ndarray) -> np.ndarray:
        """Probe the index for every whole 16-token chunk of a batch.

        Parameters
        ----------
        tokens : np.ndarray, shape (B, S), int
            Token ids; only complete ``CHUNK_TOKENS``-sized chunks are
            fingerprinted.

        Returns
        -------
        np.ndarray, shape (B, S // 16), bool
            True where the chunk's KV is already cached.  ONE device
            dispatch for the whole batch: the fused multiset kernel
            (one partition) or its ``shard_map`` wrapping over the
            ``("sets",)`` mesh (the stacked layout).  The ``"fanout"``
            reference dispatches one call per shard holding queries.
        """
        self._maybe_rebase_clock()
        fps = self.fingerprints(tokens)
        flat = fps.reshape(-1)
        self.stats.lookups += 1
        if flat.size == 0:
            return np.zeros(fps.shape, bool)
        sets = self._set_of(flat)
        key_bits = xam_ops.words_to_bits_np(
            flat.astype(np.uint32), self.cfg.key_bits)
        if self._use_shard_map and self.n_parts > 1:
            ways = xam_ops.xam_search_multiset_stacked(
                key_bits, sets, self._assemble(self._bits),
                self._assemble(self._valid), mesh=self.set_mesh)
            self.stats.searches += 1
        elif self.n_parts == 1:
            ways = xam_ops.xam_search_multiset(
                key_bits, sets, self._bits[0], self._valid[0])
            self.stats.searches += 1
        else:
            ways = xam_ops.xam_search_multiset_sharded(
                key_bits, sets, self._bits, self._valid)
            self.stats.searches += len(
                np.unique(sets // self.sets_per_part))
        hit = ways >= 0
        self.stats.chunk_hits += int(hit.sum())
        self.stats.chunk_misses += int((~hit).sum())
        self.ops_total += int(flat.shape[0])   # t_MWW cycle proxy advances
        return hit.reshape(fps.shape)

    def _shadow_hits(self, flat_fps: np.ndarray) -> np.ndarray:
        """Oracle for lookup(): hits according to the host shadow map."""
        return np.asarray([int(fp) in self.slot_of for fp in flat_fps], bool)

    # ------------------------------------------------------------------
    def admit(self, tokens: np.ndarray):
        """Offer a batch's chunks for admission (after KV was computed).

        Fingerprints are uniqued (order-preserved) and forwarded to
        :meth:`admit_fps` — O(1) jitted device calls per shard regardless
        of batch size."""
        fps = np.unique(self.fingerprints(tokens).reshape(-1))
        self.admit_fps(fps)

    def _admit_one(self, fp: np.uint32):
        """Single-fingerprint compatibility shim over the batched path."""
        self.admit_fps(np.asarray([fp], np.uint32))

    def admit_fps(self, fps: np.ndarray):
        """Batched admission of (unique, order-preserved) fingerprints.

        Parameters
        ----------
        fps : np.ndarray, shape (B,), uint32
            Candidate fingerprints.  MUST be unique within the call (the
            no-allocate touch counts are latched per batch); ``admit``
            uniques for you.

        Notes
        -----
        With ``admit_dispatch="auto"`` (the default) the whole batch is
        admitted by ONE donated device dispatch at every shard count: the
        host packs candidates into the round grid of
        ``xam_ops.group_admits_stacked`` (cycle stamps keep their global
        batch position) and ``_admit_rounds_body`` admits round after
        round, each round vectorized over its (pairwise-distinct-set)
        lanes.  ``admit_dispatch="fanout"`` keeps the per-partition
        ``_admit_batch`` scan loop as the oracle.  Because every decision
        couples only through per-set state, both are bit-equivalent to
        admitting the same fingerprints one at a time in batch order, at
        any shard count (and any partitioning of shards onto devices).
        """
        fps = np.asarray(fps, np.uint32)
        b = int(fps.size)
        if b == 0:
            return
        self._maybe_rebase_clock()
        sets = self._set_of(fps)
        touches = np.asarray(
            [self.first_touch.get(int(fp), 0) for fp in fps], np.int32)
        bitcols = self._bitcols(fps)
        # t_MWW cycle stamps, computed ONCE here so both dispatch paths
        # stamp identically (the differential oracle pins this).  Op
        # clock: each candidate's global batch position.  Wall clock: one
        # host timestamp for the whole batch — the device scan sees only
        # host constants either way, so it stays deterministic.
        if self.clock == "ops":
            cycles = (self.ops_total + np.arange(b)).astype(np.int32)
        else:
            cycles = np.full(b, self._clock_cycles(), np.int32)
        if self.admit_dispatch == "auto":
            skip, thr, inst, way, evict, old_fp = self._admit_stacked(
                fps, sets, touches, bitcols, cycles)
        else:
            skip, thr, inst, way, evict, old_fp = self._admit_fanout(
                fps, sets, touches, bitcols, cycles)
        self.ops_total += b

        # Host shadow-map fold, in GLOBAL batch order.  (Every shadow-map
        # operation on a given fingerprint — install, touch bump, evict of
        # its slot — happens inside its one owning partition, so batch
        # order and the fanout path's partition-major order produce the
        # same shadow state.)  The slab store folds in lockstep: a
        # victim's slab dies with its way, a staged slab becomes resident
        # exactly when its fingerprint installs (or refreshes a resident
        # way), and is discarded on skip/throttle so rejected KV never
        # serves a hit.
        store = self.slab_store
        for i in range(b):
            if evict[i]:
                self.slot_of.pop(int(old_fp[i]), None)
                if store is not None:
                    store.drop(int(old_fp[i]))
            fp = int(fps[i])
            was_resident = fp in self.slot_of
            if skip[i]:
                self.first_touch[fp] = self.first_touch.get(fp, 0) + 1
            if inst[i]:
                s, w = int(sets[i]), int(way[i])
                self.slot_of[fp] = (s, w)
                self.first_touch.pop(fp, None)
                self.valid_np[s, w] = True
                self.fp_of_np[s, w] = fps[i]
            if store is not None:
                if inst[i] or was_resident:
                    store.commit(fp)
                else:
                    store.discard(fp)
        batch_installs = int(inst.sum())
        self.stats.admissions += batch_installs
        self.stats.admission_skips += int(skip.sum())
        self.stats.evictions += int(evict.sum())
        self.stats.throttled += int(thr.sum())

        # Rotate when the admission count crosses a rotate_every multiple
        # (a plain modulo check would skip the boundary whenever a batch
        # jumps over it).  At most one remap per admit call — batched
        # rotation lands at the batch boundary rather than mid-sequence;
        # the equivalence test pins auto-rotation off for that reason.
        prev = self.stats.admissions - batch_installs
        if (self.stats.admissions // self.cfg.rotate_every
                > prev // self.cfg.rotate_every):
            self._rotate()

    def _admit_stacked(self, fps, sets, touches, bitcols, cycles):
        """ONE-dispatch admission over the stacked round grid.

        Packs the batch into the ``(n_parts, n_rounds, round_width)``
        grid of ``xam_ops.group_admits_stacked`` (pow2-bucketed on both
        candidate axes so repeated batch sizes reuse compilations), then
        launches a single donated device call: the jitted
        ``_admit_rounds`` scan when one partition holds everything, else
        the ``_admit_shardmap_fn`` shard_map over the set mesh.  Returns
        the per-candidate decision arrays in GLOBAL batch order."""
        c = self.cfg
        b = int(fps.size)
        part_of, row, col, n_rounds, round_width = (
            xam_ops.group_admits_stacked(
                sets, c.n_sets, self.n_parts, lo=ADMIT_BUCKET_LO))
        idx = (part_of, row, col)
        g = (self.n_parts, n_rounds, round_width)
        sets_g = np.zeros(g, np.int32)
        sets_g[idx] = sets - part_of * self.sets_per_part  # partition-local
        fps_g = np.zeros(g, np.uint32)
        fps_g[idx] = fps
        bit_g = np.zeros(g + (self.plane_rows,), bitcols.dtype)
        bit_g[idx] = bitcols
        cyc_g = np.full(g, cycles[0], np.int32)      # pad lanes: inactive
        cyc_g[idx] = cycles                          # host-stamped, per batch
        tch_g = np.zeros(g, np.int32)
        tch_g[idx] = touches
        act_g = np.zeros(g, bool)
        act_g[idx] = True

        xam_ops.ADMIT_LAUNCH_COUNT += 1
        self.stats.admit_calls += 1
        if self._use_shard_map and self.n_parts > 1:
            outs = self._dispatch_stacked_shardmap(
                sets_g, fps_g, bit_g, cyc_g, tch_g, act_g)
        else:
            put = self._put_admit
            carry, outs = _admit_rounds(
                self._bits[0], self._valid[0], self._fp_of[0],
                self._read_after[0], self._set_writes[0], self._counters[0],
                self._wear_states[0], self._wear_dyns[0],
                self._admit_after[0],
                put(sets_g[0]), put(fps_g[0]), put(bit_g[0]), put(cyc_g[0]),
                put(tch_g[0]), put(act_g[0]))
            (self._bits[0], self._valid[0], self._fp_of[0],
             self._read_after[0], self._set_writes[0], self._counters[0],
             self._wear_states[0]) = carry

        # One sync for the whole batch; un-grid back to batch order.
        outs_np = [np.asarray(o) for o in jax.device_get(outs)]
        sel = idx if outs_np[0].ndim == 3 else (row, col)
        _res, skip, thr, inst, way, evict, old_fp = (
            o[sel] for o in outs_np)
        return skip, thr, inst, way, evict, old_fp

    def _dispatch_stacked_shardmap(self, sets_g, fps_g, bit_g, cyc_g,
                                   tch_g, act_g):
        """Run the stacked admission grid as ONE ``shard_map`` dispatch.

        Assembles the per-partition planes/counters into zero-copy
        ``P("sets")`` global views, decomposes the §8 wear states (per-set
        rows assemble like planes; scalar counters stack to an
        ``(n_parts,)`` array from fresh per-device ``(1,)`` reshapes, so
        donation never invalidates live state), places the candidate
        grids sharded on their leading partition axis, and calls the
        cached ``_admit_shardmap_fn``.  Every transfer here is an
        EXPLICIT ``device_put`` (the wear knobs and no-allocate threshold
        were replicated once at construction), keeping the per-batch path
        legal under ``jax.transfer_guard("disallow")``.  Rebinds all
        donated state from the outputs and returns the stacked decision
        grids."""
        mesh = self.set_mesh
        shd = mesh_mod.set_axis_sharding(mesh)
        ws = self._wear_states

        def stack_scalar(field):
            # jnp.reshape emits a FRESH (1,) buffer on each scalar's
            # resident device — the assembled stack can be donated
            # without invalidating the live wear states.
            return jax.make_array_from_single_device_arrays(
                (self.n_parts,), shd,
                [jnp.reshape(getattr(w, field), (1,)) for w in ws])

        fn = _admit_shardmap_fn(mesh)
        out = fn(
            self._assemble(self._bits), self._assemble(self._valid),
            self._assemble(self._fp_of), self._assemble(self._read_after),
            self._assemble(self._set_writes), self._assemble(self._counters),
            self._assemble([w.swt_w for w in ws]),
            self._assemble([w.swt_d for w in ws]),
            self._assemble([w.window_writes for w in ws]),
            self._assemble([w.window_start for w in ws]),
            self._assemble([w.locked_until for w in ws]),
            stack_scalar("write_counter"), stack_scalar("superset_counter"),
            stack_scalar("dirty_counter"),
            self._wdyn_repl, self._admit_after_repl,
            jax.device_put(sets_g, shd), jax.device_put(fps_g, shd),
            jax.device_put(bit_g, shd), jax.device_put(cyc_g, shd),
            jax.device_put(tch_g, shd), jax.device_put(act_g, shd))

        parts = [self._split_global(o) for o in out[:14]]
        (self._bits, self._valid, self._fp_of, self._read_after,
         self._set_writes, self._counters) = parts[:6]
        sww_p, swd_p, wwr_p, wst_p, lck_p, wc_p, ssc_p, dc_p = parts[6:]
        # Rotary offsets / rotate totals never entered the dispatch (the
        # serving config disables every rotate signal), so the old
        # buffers are still live — reattach them.
        self._wear_states = [
            wear.WearState(
                swt_w=sww_p[k], swt_d=swd_p[k],
                write_counter=jnp.reshape(wc_p[k], ()),
                superset_counter=jnp.reshape(ssc_p[k], ()),
                dirty_counter=jnp.reshape(dc_p[k], ()),
                offsets=old.offsets,
                window_writes=wwr_p[k], window_start=wst_p[k],
                locked_until=lck_p[k],
                total_rotates=old.total_rotates,
                total_flushed=old.total_flushed)
            for k, old in enumerate(self._wear_states)]
        return out[14:]

    def _admit_fanout(self, fps, sets, touches, bitcols, cycles):
        """PR-5 per-partition admission oracle (``admit_dispatch="fanout"``).

        Groups candidates by owning storage partition (original order
        preserved within each group; cycle stamps keep their global batch
        position) and runs ONE donated ``_admit_batch`` scan per
        partition holding candidates — dispatched back-to-back, synced
        together.  Returns the decision arrays scattered back to GLOBAL
        batch order, so the shared shadow-map fold in ``admit_fps`` is
        identical for both dispatch modes."""
        b = int(fps.size)
        shard_ids = sets // self.sets_per_part
        skip = np.zeros(b, bool)
        thr = np.zeros(b, bool)
        inst = np.zeros(b, bool)
        evict = np.zeros(b, bool)
        way = np.zeros(b, np.int32)
        old_fp = np.zeros(b, np.uint32)
        launches = []
        for k in np.unique(shard_ids):
            k = int(k)
            sel = np.nonzero(shard_ids == k)[0]
            bk = sel.size
            bb = bucket_pow2(bk, lo=ADMIT_BUCKET_LO)
            fps_p = np.zeros(bb, np.uint32)
            fps_p[:bk] = fps[sel]
            sets_p = np.zeros(bb, np.int32)
            sets_p[:bk] = sets[sel] - k * self.sets_per_part  # local rows
            bit_p = np.zeros((bb, self.plane_rows), bitcols.dtype)
            bit_p[:bk] = bitcols[sel]
            cycles_p = np.full(bb, cycles[0], np.int32)  # pad: inactive
            cycles_p[:bk] = cycles[sel]              # host-stamped, per batch
            touch_p = np.zeros(bb, np.int32)
            touch_p[:bk] = touches[sel]
            active = np.zeros(bb, bool)
            active[:bk] = True

            carry, outs = _admit_batch(
                self._bits[k], self._valid[k], self._fp_of[k],
                self._read_after[k], self._set_writes[k], self._counters[k],
                self._wear_states[k], self._wear_dyns[k],
                self._admit_after[k],
                self._put(sets_p, k), self._put(fps_p, k),
                self._put(bit_p, k), self._put(cycles_p, k),
                self._put(touch_p, k), self._put(active, k))
            (self._bits[k], self._valid[k], self._fp_of[k],
             self._read_after[k], self._set_writes[k], self._counters[k],
             self._wear_states[k]) = carry
            xam_ops.ADMIT_LAUNCH_COUNT += 1
            self.stats.admit_calls += 1
            launches.append((sel, outs))

        for sel, outs in launches:
            bk = sel.size
            _res, sk, th, in_, wy, ev, of = (
                np.asarray(o)[:bk] for o in outs)
            skip[sel] = sk
            thr[sel] = th
            inst[sel] = in_
            way[sel] = wy
            evict[sel] = ev
            old_fp[sel] = of
        return skip, thr, inst, way, evict, old_fp

    def _rotate(self):
        """Rotary remap (prime stride 7): shift the set planes by the
        GLOBAL permutation ``set -> set + 7 (mod n_sets)`` while the
        ``_set_of`` offset moves in lockstep, so resident entries stay
        searchable under the rotated placement and the physical mapping is
        identical at every shard count.  One partition: ONE donated device
        roll.  Across partitions: DEVICE-RESIDENT — each shard donates a
        local roll of its block-aligned slab and ``ppermute``s the
        boundary sets that cross shard edges under the global permutation
        (``mesh.make_sharded_roll``); no plane data touches the host.
        The ``"fanout"`` reference keeps the PR-4 host gather.
        Wear/replacement counters track PHYSICAL sets and are untouched.
        When admissions flow through an ``AdmitQueue``, the queue drains
        before calling this (drain barrier)."""
        n = self.cfg.n_sets
        shift = ROTATE_STRIDE % n
        self.offset = (self.offset + ROTATE_STRIDE) % n
        self.stats.rotations += 1
        if shift:
            if self.n_parts == 1:
                (self._bits[0], self._valid[0], self._fp_of[0],
                 self._read_after[0]) = _rotate_planes(
                    self._bits[0], self._valid[0], self._fp_of[0],
                    self._read_after[0], shift=shift)
            elif self._use_shard_map:
                self._rotate_device(shift)
            else:
                # "fanout" reference: cross-shard gather/scatter via the
                # global-view properties (getter concatenates, setter
                # re-splits and re-places per shard).
                self.bits = np.roll(self.bits, shift, axis=0)
                self.valid = np.roll(self.valid, shift, axis=0)
                self.fp_of = np.roll(self.fp_of, shift, axis=0)
                self.read_after = np.roll(self.read_after, shift, axis=0)
            self.valid_np = np.roll(self.valid_np, shift, axis=0)
            self.fp_of_np = np.roll(self.fp_of_np, shift, axis=0)
            self.slot_of = {fp: ((s + shift) % n, w)
                            for fp, (s, w) in self.slot_of.items()}

    def _rotate_device(self, shift: int):
        """On-device cross-shard remap: donated per-shard rolls + the
        ``ppermute`` boundary exchange, applied to all four planes in one
        jitted collective.  The assembled global views share buffers with
        the per-partition lists, so after the donation the lists are
        rebound from the outputs (zero-copy device views)."""
        roll = mesh_mod.make_sharded_roll(
            self.set_mesh, self.cfg.n_sets, shift)
        bits, valid, fp_of, read_after = roll(
            self._assemble(self._bits), self._assemble(self._valid),
            self._assemble(self._fp_of), self._assemble(self._read_after))
        self._bits = self._split_global(bits)
        self._valid = self._split_global(valid)
        self._fp_of = self._split_global(fp_of)
        self._read_after = self._split_global(read_after)

    # ------------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        t = self.stats.chunk_hits + self.stats.chunk_misses
        return self.stats.chunk_hits / max(t, 1)

    def slab_lockstep_report(self) -> dict:
        """Lockstep audit between the index and its attached slab store.

        Returns ``{"missing_slabs": [...], "orphan_slabs": [...]}`` —
        resident fingerprints without a slab (only possible when some
        admissions bypassed slab staging, e.g. plain ``admit()``) and
        slabs whose fingerprint the index no longer holds (a true
        lockstep violation: an evicted way must drop its slab).  Both
        empty when every admission staged a slab — tests assert exactly
        that, across rotation/eviction/async-drain schedules.
        """
        if self.slab_store is None:
            return {"missing_slabs": [], "orphan_slabs": []}
        indexed = {int(fp) for fp in self.slot_of}
        resident = self.slab_store.resident_fps()
        return {"missing_slabs": sorted(indexed - resident),
                "orphan_slabs": sorted(resident - indexed)}

    def write_distribution(self) -> np.ndarray:
        """Installs per PHYSICAL set — the wear-evenness metric (device
        counter; unlike residency it never decays on eviction).  Shape
        (n_sets,), concatenated in shard order."""
        return np.asarray(self.set_writes)

    def wear_report(self) -> dict:
        """Serving-side §8 wear stats from the shared WearState(s).

        Returns
        -------
        dict
            ``installs_per_set_max/mean``, ``skew_max_over_mean`` (wear
            evenness), ``window_writes`` (per-set, shard-concatenated),
            ``throttled_sets_now`` (sets an admission would be rejected
            from right now — the admit path rejects via
            ``window_would_exceed`` BEFORE the write, so
            ``record_write``'s post-overflow lock never engages here),
            plus the throttle/rotation stats.  Identical at every shard
            count for the same schedule.
        """
        w = self.write_distribution().astype(np.float64)
        mean = float(w.mean()) if w.size else 0.0
        cyc = jnp.asarray(min(self._clock_cycles(), 2 ** 31 - 1), jnp.int32)
        throttled_now = sum(
            int(np.asarray(wear.window_would_exceed(
                self._wear_states[k], self._wear_dyns[k],
                jnp.arange(self.sets_per_part), cyc)).sum())
            for k in range(self.n_parts))
        return {
            "installs_per_set_max": float(w.max()) if w.size else 0.0,
            "installs_per_set_mean": mean,
            "skew_max_over_mean": float(w.max() / mean) if mean > 0 else 1.0,
            "window_writes": np.asarray(
                self.wear_state.window_writes).tolist(),
            "throttled_sets_now": throttled_now,
            "throttled": self.stats.throttled,
            "rotations": self.stats.rotations,
        }

    def lifetime_estimate(self, endurance: float = 1e8,
                          ops_per_second: float = 1e6
                          ) -> lifetime_mod.LifetimeResult:
        """Fig. 11-style lifetime projection from the serving write
        snapshot — the same cumulative-crossing replay the simulator's
        curves use, fed by the device install counters."""
        return lifetime_mod.estimate_from_ops(
            self.write_distribution(), self.ops_total,
            self.stats.rotations, endurance=endurance,
            ops_per_second=ops_per_second)
