"""MonarchKVIndex — the paper's technique as a first-class serving feature.

A vLLM-style paged KV prefix cache whose INDEX is a Monarch flat-CAM:

* every 16-token chunk of a request's prefix is fingerprinted (murmur3) and
  the whole fingerprint batch is matched against the resident-block index
  with ONE fused multi-set XAM search (kernels/xam_search) — a single
  ``pallas_call`` per lookup batch, not a hash-map walk and not a Python
  loop over sets.  Per-query set ids ride in scalar prefetch and select
  each query block's stored-bit plane; validity masking is fused into the
  kernel, so dead ways never produce false hits;
* the CAM state is device-resident: ``bits`` (n_sets, key_bits, set_ways),
  ``valid``, ``fp_of``, the D̄&R̄ ``read_after`` metadata, the per-set
  install counters and the §8 ``WearState`` all live on device;
* ADMISSION IS BATCHED: one request batch's worth of candidate
  fingerprints goes through ONE jitted, donated-state device call
  (``_admit_batch``) — a ``lax.scan`` that fuses residency probing,
  t_MWW throttling, way selection, column install and wear recording.
  Same-set collisions are resolved by the scan order (ascending unique
  fingerprints — the seed's sequential admission order), so the batched
  pipeline is step-for-step equivalent to admitting one fingerprint at a
  time while issuing O(1) device calls per batch;
* admission mirrors the paper's cache-mode durability policy (§8):
  - no-allocate on first touch (a block must be seen R times before it is
    admitted — the D̄&R̄ "never accessed" filter),
  - random-counter replacement via a free-running counter shared by all
    sets, preferring never-re-read (cold) victims,
  - the t_MWW lifetime throttle comes from ``core/wear.py`` — the SAME
    ``record_write``/``window_would_exceed``/``is_locked`` machinery the
    Fig. 11 simulator runs, parameterized by a ``WearDyn``.  A set whose
    admission rate exceeds the window budget stops admitting (serves
    misses from recompute) exactly as §6.2 specifies.  The op counter
    (lookup queries + admission attempts) stands in for cycles;
* rotation is a device start-gap-style remap: the set planes (bits /
  valid / fp_of / read_after) are cyclically shifted by the prime stride 7
  in one donated device call — no host rebuild — while ``_set_of`` shifts
  its offset in lockstep, so resident entries REMAIN searchable after the
  remap (the seed's lazy-flush rotation orphaned them; this intentional
  change is pinned by tests/test_kv_index.py).

Lifetime targeting: ``KVIndexConfig.with_lifetime`` derives the t_MWW
window length (in ops) from a target lifetime in years, the cell
endurance and an expected op rate — the serving twin of
``wear.make_config``.  ``launch/serve.py`` surfaces it as
``--lifetime-years``.

The index is exercised by examples/serve_prefix_cache.py and
benchmarks/kernels_bench.py (``kv_index_admit`` pins the batched path's
advantage over the pre-batching host loop).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import lifetime as lifetime_mod
from repro.core import wear
from repro.core.timing import SECONDS_PER_YEAR, t_mww_seconds
from repro.data.pipeline import fingerprint_blocks, murmur3_np
from repro.kernels.common import bucket_pow2
from repro.kernels.xam_search import ops as xam_ops

CHUNK_TOKENS = 16
ROTATE_STRIDE = 7          # prime set stride per rotation (§8)
ADMIT_BUCKET_LO = 8        # pow2 bucket floor for admit batch shapes


@dataclasses.dataclass
class KVIndexConfig:
    n_sets: int = 32
    set_ways: int = 512           # CAM columns per set
    key_bits: int = 32
    admit_after_reads: int = 1    # no-allocate: admit on 2nd touch
    m_writes: int = 3             # per-way write budget per t_MWW window
    window_ops: int = 4096        # ops per t_MWW window (op-count proxy)
    rotate_every: int = 50_000    # admissions between rotary remaps

    @classmethod
    def with_lifetime(cls, *, t_life_years: float, endurance: float = 1e8,
                      ops_per_second: float = 1e6, m_writes: int = 3,
                      **kw) -> "KVIndexConfig":
        """Derive ``window_ops`` from a lifetime target (§6.2): the t_MWW
        window in seconds comes from ``wear``'s own formula; the serving op
        counter stands in for cycles at ``ops_per_second``."""
        t_mww_s = t_mww_seconds(m_writes, t_life_years * SECONDS_PER_YEAR,
                                endurance)
        window_ops = max(int(t_mww_s * ops_per_second), 1)
        return cls(m_writes=m_writes, window_ops=window_ops, **kw)


@dataclasses.dataclass
class KVIndexStats:
    lookups: int = 0
    chunk_hits: int = 0
    chunk_misses: int = 0
    admissions: int = 0
    admission_skips: int = 0      # no-allocate first touches
    throttled: int = 0            # t_MWW window exhausted
    evictions: int = 0
    rotations: int = 0
    searches: int = 0             # fused kernel launches (1 per batch)
    admit_calls: int = 0          # jitted admit launches (1 per batch)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _install_column(bits, valid, fp_of, s, w, bitcol, fp):
    """Device-side install of ONE CAM column.  Kept as the pre-batching
    primitive: benchmarks/kernels_bench.py uses it to measure the host-loop
    admission flow the batched pipeline replaced."""
    bits = bits.at[s, :, w].set(bitcol)
    valid = valid.at[s, w].set(jnp.int8(1))
    fp_of = fp_of.at[s, w].set(fp)
    return bits, valid, fp_of


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
def _admit_batch(bits, valid, fp_of, read_after, set_writes, counter,
                 wstate, wdyn, admit_after, sets, fps, bitcols, cycles,
                 touches, active):
    """ONE device call admits a whole candidate batch.

    A ``lax.scan`` over the (order-preserving) candidate list; each step is
    the full per-fingerprint admission pipeline: residency probe ->
    read_after bump | no-allocate gate | t_MWW throttle -> way select ->
    column install fused with §8 wear recording.  Same-set collisions
    resolve through the scan carry (segment conflicts never race — later
    candidates see earlier installs AND earlier evictions: the residency
    and no-allocate decisions are made against the in-batch state, exactly
    as a sequential per-fingerprint loop would), which keeps the batched
    path bit-equivalent to sequential admission.  ``touches`` carries the
    host first_touch counts (unique fps, so they cannot change mid-batch).
    All mutable planes are donated; outputs feed the host shadow map in
    one transfer.
    """
    n_ways = valid.shape[1]
    iota = jnp.arange(n_ways, dtype=jnp.int32)

    def step(carry, x):
        bits, valid, fp_of, read_after, set_writes, counter, ws = carry
        s, fp, bitcol, cycle, touch, act = x

        vrow = valid[s]
        frow = fp_of[s]
        hitv = (vrow == 1) & (frow == fp)
        is_res = jnp.any(hitv) & act
        res_w = jnp.argmax(hitv).astype(jnp.int32)
        # resident re-offer: D/R metadata only (marks the way re-read).
        read_after = read_after.at[s, res_w].add(
            jnp.where(is_res, 1, 0).astype(jnp.int32))

        # no-allocate gate (D̄&R̄ "never accessed" filter): evaluated against
        # the CURRENT residency, so a fingerprint evicted by an earlier
        # same-batch install re-enters the touch count like the sequential
        # flow would.
        skipped = act & ~is_res & (touch < admit_after)

        # t_MWW lifetime throttle — shared wear machinery (§6.2/§8).
        # window_would_exceed rejects BEFORE the write, so under this
        # policy record_write's lock branch never fires; is_locked is kept
        # as a guard for wear states also driven by other writers.
        locked = wear.is_locked(ws, s, cycle)
        over = wear.window_would_exceed(ws, wdyn, s, cycle)
        throttled = act & ~is_res & ~skipped & (locked | over)
        do_install = act & ~is_res & ~skipped & ~throttled

        # Way selection: first free way, else counter-ordered cold victim
        # (never-re-read ways first — D̄&R̄-style replacement).
        free = vrow == 0
        has_free = jnp.any(free)
        free_w = jnp.argmax(free).astype(jnp.int32)
        order = ((iota + counter) % n_ways).astype(jnp.int32)
        cold = read_after[s][order] == 0
        victim = jnp.where(jnp.any(cold), order[jnp.argmax(cold)], order[0])
        way = jnp.where(has_free, free_w, victim).astype(jnp.int32)
        evict = do_install & ~has_free
        old_fp = frow[way]
        counter = counter + jnp.where(evict, 1, 0).astype(jnp.int32)

        # Column install (one CAM column + metadata).
        bits = bits.at[s, :, way].set(
            jnp.where(do_install, bitcol.astype(jnp.int8), bits[s, :, way]))
        valid = valid.at[s, way].set(
            jnp.where(do_install, 1, vrow[way]).astype(jnp.int8))
        fp_of = fp_of.at[s, way].set(jnp.where(do_install, fp, old_fp))
        read_after = read_after.at[s, way].set(
            jnp.where(do_install, 0, read_after[s, way]).astype(jnp.int32))
        set_writes = set_writes.at[s].add(
            jnp.where(do_install, 1, 0).astype(jnp.int32))

        # Wear recording fused with the install (one implementation: §8's
        # record_write — the same function the Fig. 11 simulator scans).
        ws2, rot, _fl = wear.record_write(ws, wdyn, s, jnp.asarray(True),
                                          cycle)
        ws = jax.tree.map(lambda o, n: jnp.where(do_install, n, o), ws, ws2)

        out = (is_res, skipped, throttled, do_install, way, evict, old_fp)
        return (bits, valid, fp_of, read_after, set_writes, counter, ws), out

    carry = (bits, valid, fp_of, read_after, set_writes, counter, wstate)
    carry, outs = jax.lax.scan(step, carry,
                               (sets, fps, bitcols, cycles, touches, active))
    return carry, outs


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3),
                   static_argnames=("shift",))
def _rotate_planes(bits, valid, fp_of, read_after, shift: int):
    """Device start-gap-style remap: cyclically shift every set plane by the
    prime stride — resident entries move WITH the ``_set_of`` offset bump,
    so they stay searchable under the rotated mapping.  No host rebuild."""
    roll = lambda x: jnp.roll(x, shift, axis=0)
    return roll(bits), roll(valid), roll(fp_of), roll(read_after)


class MonarchKVIndex:
    def __init__(self, cfg: KVIndexConfig | None = None, seed: int = 0):
        # cfg default constructed per instance: a shared KVIndexConfig()
        # default would alias mutable config across indexes.
        self.cfg = KVIndexConfig() if cfg is None else cfg
        c = self.cfg
        # Device-resident CAM state: fingerprint bits column-wise per set,
        # plus the validity / fingerprint / D-R metadata planes, the
        # replacement counter and the per-set install (wear) counters.
        self.bits = jnp.zeros((c.n_sets, c.key_bits, c.set_ways), jnp.int8)
        self.valid = jnp.zeros((c.n_sets, c.set_ways), jnp.int8)
        self.fp_of = jnp.zeros((c.n_sets, c.set_ways), jnp.uint32)
        self.read_after = jnp.zeros((c.n_sets, c.set_ways), jnp.int32)
        self.set_writes = jnp.zeros((c.n_sets,), jnp.int32)
        self.counter = jnp.zeros((), jnp.int32)  # free-running replacement
        # §8 wear state over the physical sets — the simulator's own
        # machinery with serving knobs: window length = window_ops (op-count
        # cycle proxy), budget = set_ways * m_writes, WR/WC/DC rotation
        # signals disabled (serving rotates on the rotate_every cadence).
        self.wear_cfg = wear.WearConfig(
            n_supersets=c.n_sets, m_writes=c.m_writes,
            dc_limit=1 << 30, wc_limit=1 << 30,
            t_mww_cycles=c.window_ops, blocks_per_superset=c.set_ways)
        self.wear_dyn = wear.dyn_of(self.wear_cfg)
        self.wear_state = wear.init_state(self.wear_cfg)
        # Host-side policy shadow (map + mirrors): keeps assertions and
        # eviction bookkeeping off the device sync path.
        self.valid_np = np.zeros((c.n_sets, c.set_ways), bool)
        self.fp_of_np = np.zeros((c.n_sets, c.set_ways), np.uint32)
        self.slot_of = {}           # fp -> (set, way) (host-side shadow map)
        self.first_touch = {}       # fp -> touch count (pre-admission)
        self.offset = 0             # rotary set offset
        self.ops_total = 0          # op counter == t_MWW cycle proxy
        self.stats = KVIndexStats()

    # ------------------------------------------------------------------
    def _set_of(self, fps: np.ndarray) -> np.ndarray:
        base = murmur3_np(fps) % np.uint32(self.cfg.n_sets)
        return ((base.astype(np.int64) + self.offset) % self.cfg.n_sets
                ).astype(np.int32)

    def _maybe_rebase_clock(self):
        """Fold the op-counter clock before the int32 cycle domain wraps
        (timestamps shift in lockstep, so window/lock decisions are
        unchanged — a ~2.1e9-op serving instance would otherwise see its
        windows stop expiring and throttle forever)."""
        self.wear_state, self.ops_total = wear.maybe_rebase(
            self.wear_state, self.ops_total)

    def lookup(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (B, S).  Returns (B, S//16) bool — chunk already cached.
        ONE fused multi-set CAM search for the whole batch."""
        self._maybe_rebase_clock()
        fps = fingerprint_blocks(tokens, CHUNK_TOKENS)
        flat = fps.reshape(-1)
        self.stats.lookups += 1
        if flat.size == 0:
            return np.zeros(fps.shape, bool)
        sets = self._set_of(flat)
        key_bits = xam_ops.words_to_bits_np(
            flat.astype(np.uint32), self.cfg.key_bits)
        ways = xam_ops.xam_search_multiset(
            key_bits, sets, self.bits, self.valid)
        self.stats.searches += 1
        hit = ways >= 0
        self.stats.chunk_hits += int(hit.sum())
        self.stats.chunk_misses += int((~hit).sum())
        self.ops_total += int(flat.shape[0])   # t_MWW cycle proxy advances
        return hit.reshape(fps.shape)

    def _shadow_hits(self, flat_fps: np.ndarray) -> np.ndarray:
        """Oracle for lookup(): hits according to the host shadow map."""
        return np.asarray([int(fp) in self.slot_of for fp in flat_fps], bool)

    # ------------------------------------------------------------------
    def admit(self, tokens: np.ndarray):
        """Offer chunks for admission (after their KV was computed).
        Issues O(1) jitted device calls regardless of batch size."""
        fps = np.unique(fingerprint_blocks(tokens, CHUNK_TOKENS).reshape(-1))
        self.admit_fps(fps)

    def _admit_one(self, fp: np.uint32):
        """Single-fingerprint compatibility shim over the batched path."""
        self.admit_fps(np.asarray([fp], np.uint32))

    def admit_fps(self, fps: np.ndarray):
        """Batched admission of (unique, order-preserved) fingerprints:
        ONE ``_admit_batch`` device call, then one host shadow-map pass
        over the outputs.  Every offered fingerprint is a device lane —
        the no-allocate gate runs on device against the evolving in-batch
        residency, so the pipeline is bit-equivalent to admitting the same
        fingerprints one call at a time."""
        fps = np.asarray(fps, np.uint32)
        b = int(fps.size)
        if b == 0:
            return
        self._maybe_rebase_clock()
        bb = bucket_pow2(b, lo=ADMIT_BUCKET_LO)
        fps_p = np.zeros(bb, np.uint32)
        fps_p[:b] = fps
        sets_p = np.zeros(bb, np.int32)
        sets_p[:b] = self._set_of(fps)
        bitcols = np.zeros((bb, self.cfg.key_bits), np.int8)
        bitcols[:b] = xam_ops.words_to_bits_np(fps, self.cfg.key_bits)
        cycles = (self.ops_total + np.arange(bb)).astype(np.int32)
        touches = np.zeros(bb, np.int32)
        touches[:b] = [self.first_touch.get(int(fp), 0) for fp in fps]
        active = np.zeros(bb, bool)
        active[:b] = True

        carry, outs = _admit_batch(
            self.bits, self.valid, self.fp_of, self.read_after,
            self.set_writes, self.counter, self.wear_state, self.wear_dyn,
            jnp.asarray(self.cfg.admit_after_reads, jnp.int32),
            jnp.asarray(sets_p), jnp.asarray(fps_p), jnp.asarray(bitcols),
            jnp.asarray(cycles), jnp.asarray(touches), jnp.asarray(active))
        (self.bits, self.valid, self.fp_of, self.read_after,
         self.set_writes, self.counter, self.wear_state) = carry
        self.stats.admit_calls += 1
        self.ops_total += b

        # Host shadow-map pass (one device->host transfer for the batch).
        _res, skip, thr, inst, way, evict, old_fp = (np.asarray(o)[:b]
                                                     for o in outs)
        for i in range(b):
            if evict[i]:
                self.slot_of.pop(int(old_fp[i]), None)
            fp = int(fps_p[i])
            if skip[i]:
                self.first_touch[fp] = self.first_touch.get(fp, 0) + 1
            if inst[i]:
                s, w = int(sets_p[i]), int(way[i])
                self.slot_of[fp] = (s, w)
                self.first_touch.pop(fp, None)
                self.valid_np[s, w] = True
                self.fp_of_np[s, w] = fps_p[i]
        self.stats.admissions += int(inst.sum())
        self.stats.admission_skips += int(skip.sum())
        self.stats.evictions += int(evict.sum())
        self.stats.throttled += int(thr.sum())

        # Rotate when the admission count crosses a rotate_every multiple
        # (a plain modulo check would skip the boundary whenever a batch
        # jumps over it).  At most one remap per admit call — batched
        # rotation lands at the batch boundary rather than mid-sequence;
        # the equivalence test pins auto-rotation off for that reason.
        prev = self.stats.admissions - int(inst.sum())
        if (self.stats.admissions // self.cfg.rotate_every
                > prev // self.cfg.rotate_every):
            self._rotate()

    def _rotate(self):
        """Rotary remap (prime stride 7): ONE donated device call shifts
        the set planes; the ``_set_of`` offset moves in lockstep, so
        resident entries stay searchable under the rotated placement (the
        pre-batching implementation orphaned them until eviction)."""
        n = self.cfg.n_sets
        shift = ROTATE_STRIDE % n
        self.offset = (self.offset + ROTATE_STRIDE) % n
        self.stats.rotations += 1
        if shift:
            self.bits, self.valid, self.fp_of, self.read_after = \
                _rotate_planes(self.bits, self.valid, self.fp_of,
                               self.read_after, shift=shift)
            self.valid_np = np.roll(self.valid_np, shift, axis=0)
            self.fp_of_np = np.roll(self.fp_of_np, shift, axis=0)
            self.slot_of = {fp: ((s + shift) % n, w)
                            for fp, (s, w) in self.slot_of.items()}

    # ------------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        t = self.stats.chunk_hits + self.stats.chunk_misses
        return self.stats.chunk_hits / max(t, 1)

    def write_distribution(self) -> np.ndarray:
        """Installs per PHYSICAL set — the wear-evenness metric (device
        counter; unlike residency it never decays on eviction)."""
        return np.asarray(self.set_writes)

    def wear_report(self) -> dict:
        """Serving-side §8 wear stats from the shared WearState."""
        ws = self.wear_state
        w = self.write_distribution().astype(np.float64)
        mean = float(w.mean()) if w.size else 0.0
        return {
            "installs_per_set_max": float(w.max()) if w.size else 0.0,
            "installs_per_set_mean": mean,
            "skew_max_over_mean": float(w.max() / mean) if mean > 0 else 1.0,
            "window_writes": np.asarray(ws.window_writes).tolist(),
            # sets an admission would be rejected from right now (the
            # admit path rejects via window_would_exceed BEFORE the write,
            # so record_write's post-overflow lock never engages here).
            "throttled_sets_now": int(np.asarray(wear.window_would_exceed(
                ws, self.wear_dyn,
                jnp.arange(self.cfg.n_sets),
                jnp.asarray(min(self.ops_total, 2 ** 31 - 1), jnp.int32)
            )).sum()),
            "throttled": self.stats.throttled,
            "rotations": self.stats.rotations,
        }

    def lifetime_estimate(self, endurance: float = 1e8,
                          ops_per_second: float = 1e6
                          ) -> lifetime_mod.LifetimeResult:
        """Fig. 11-style lifetime projection from the serving write
        snapshot — the same cumulative-crossing replay the simulator's
        curves use, fed by the device install counters."""
        return lifetime_mod.estimate_from_ops(
            self.write_distribution(), self.ops_total,
            self.stats.rotations, endurance=endurance,
            ops_per_second=ops_per_second)
