"""MonarchKVIndex — the paper's technique as a first-class serving feature.

A vLLM-style paged KV prefix cache whose INDEX is a Monarch flat-CAM:

* every 16-token chunk of a request's prefix is fingerprinted (murmur3) and
  the whole fingerprint batch is matched against the resident-block index
  with ONE fused multi-set XAM search (kernels/xam_search) — a single
  ``pallas_call`` per lookup batch, not a hash-map walk and not a Python
  loop over sets.  Per-query set ids ride in scalar prefetch and select
  each query block's stored-bit plane; validity masking is fused into the
  kernel, so dead ways never produce false hits;
* the CAM state is device-resident: ``bits`` (n_sets, key_bits, set_ways),
  ``valid`` and ``fp_of`` live on device and installs update exactly one
  column via a donated jitted scatter — admission no longer rebuilds a
  whole (key_bits, set_ways) plane per fingerprint;
* admission mirrors the paper's cache-mode durability policy (§8):
  - no-allocate on first touch (a block must be seen R times before it is
    admitted — the D̄&R̄ "never accessed" filter),
  - D/R-flag selective install: blocks evicted from the on-device pool are
    only written to the host tier when they were re-read after install,
  - random-counter replacement via a free-running counter shared by all
    sets,
  - rotary offset remapping of block→slot placement with prime strides
    (wear leveling — here it levels HBM slot reuse and, on NVM-backed
    hosts, literal cell wear).
* ``t_MWW``-style write throttling: a set whose admission rate exceeds the
  budget within a window stops admitting (serves misses from recompute) —
  lifetime-bounded admission exactly as §6.2 specifies.

The index is exercised by examples/serve_prefix_cache.py and
benchmarks/kernels_bench.py.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.data.pipeline import fingerprint_blocks, murmur3_np
from repro.kernels.xam_search import ops as xam_ops

CHUNK_TOKENS = 16


@dataclasses.dataclass
class KVIndexConfig:
    n_sets: int = 32
    set_ways: int = 512           # CAM columns per set
    key_bits: int = 32
    admit_after_reads: int = 1    # no-allocate: admit on 2nd touch
    m_writes: int = 3             # admissions per set per window
    window_ops: int = 4096        # ops per t_MWW window (op-count proxy)
    rotate_every: int = 50_000    # admissions between rotary remaps


@dataclasses.dataclass
class KVIndexStats:
    lookups: int = 0
    chunk_hits: int = 0
    chunk_misses: int = 0
    admissions: int = 0
    admission_skips: int = 0      # no-allocate first touches
    throttled: int = 0            # t_MWW window exhausted
    evictions: int = 0
    rotations: int = 0
    searches: int = 0             # fused kernel launches (1 per batch)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _install_column(bits, valid, fp_of, s, w, bitcol, fp):
    """Device-side install: write one CAM column + its valid/fp_of entry."""
    bits = bits.at[s, :, w].set(bitcol)
    valid = valid.at[s, w].set(jnp.int8(1))
    fp_of = fp_of.at[s, w].set(fp)
    return bits, valid, fp_of


class MonarchKVIndex:
    def __init__(self, cfg: KVIndexConfig | None = None, seed: int = 0):
        # cfg default constructed per instance: a shared KVIndexConfig()
        # default would alias mutable config across indexes.
        self.cfg = KVIndexConfig() if cfg is None else cfg
        c = self.cfg
        # Device-resident CAM state: fingerprint bits column-wise per set,
        # plus the validity and fingerprint planes the fused kernel reads.
        self.bits = jnp.zeros((c.n_sets, c.key_bits, c.set_ways), jnp.int8)
        self.valid = jnp.zeros((c.n_sets, c.set_ways), jnp.int8)
        self.fp_of = jnp.zeros((c.n_sets, c.set_ways), jnp.uint32)
        # Host-side policy state (shadow map + replacement metadata);
        # valid/fp_of mirrors keep eviction decisions off the device sync
        # path.
        self.valid_np = np.zeros((c.n_sets, c.set_ways), bool)
        self.fp_of_np = np.zeros((c.n_sets, c.set_ways), np.uint32)
        self.slot_of = {}           # fp -> (set, way) (host-side shadow map)
        self.read_after = np.zeros((c.n_sets, c.set_ways), np.int32)
        self.first_touch = {}       # fp -> touch count (pre-admission)
        self.counter = 0            # free-running replacement counter
        self.offset = 0             # rotary set offset
        self.window_admits = np.zeros((c.n_sets,), np.int32)
        self.ops_in_window = 0
        self.stats = KVIndexStats()

    # ------------------------------------------------------------------
    def _set_of(self, fps: np.ndarray) -> np.ndarray:
        base = murmur3_np(fps) % np.uint32(self.cfg.n_sets)
        return ((base.astype(np.int64) + self.offset) % self.cfg.n_sets
                ).astype(np.int32)

    def lookup(self, tokens: np.ndarray) -> np.ndarray:
        """tokens: (B, S).  Returns (B, S//16) bool — chunk already cached.
        ONE fused multi-set CAM search for the whole batch."""
        fps = fingerprint_blocks(tokens, CHUNK_TOKENS)
        flat = fps.reshape(-1)
        self.stats.lookups += 1
        if flat.size == 0:
            return np.zeros(fps.shape, bool)
        sets = self._set_of(flat)
        key_bits = xam_ops.words_to_bits_np(
            flat.astype(np.uint32), self.cfg.key_bits)
        ways = xam_ops.xam_search_multiset(
            key_bits, sets, self.bits, self.valid)
        self.stats.searches += 1
        hit = ways >= 0
        self.stats.chunk_hits += int(hit.sum())
        self.stats.chunk_misses += int((~hit).sum())
        self._account_ops(flat.shape[0])
        return hit.reshape(fps.shape)

    def _shadow_hits(self, flat_fps: np.ndarray) -> np.ndarray:
        """Oracle for lookup(): hits according to the host shadow map."""
        return np.asarray([int(fp) in self.slot_of for fp in flat_fps], bool)

    # ------------------------------------------------------------------
    def _account_ops(self, n: int):
        self.ops_in_window += n
        if self.ops_in_window >= self.cfg.window_ops:
            self.ops_in_window = 0
            self.window_admits[:] = 0

    def admit(self, tokens: np.ndarray):
        """Offer chunks for admission (after their KV was computed)."""
        fps = np.unique(fingerprint_blocks(tokens, CHUNK_TOKENS).reshape(-1))
        for fp in fps:
            self._admit_one(np.uint32(fp))
        if (self.stats.admissions and
                self.stats.admissions % self.cfg.rotate_every == 0):
            self._rotate()

    def _admit_one(self, fp: np.uint32):
        if int(fp) in self.slot_of:
            s, w = self.slot_of[int(fp)]
            self.read_after[s, w] += 1
            return
        touches = self.first_touch.get(int(fp), 0)
        if touches < self.cfg.admit_after_reads:
            # no-allocate: don't spend a XAM write on a once-seen block.
            self.first_touch[int(fp)] = touches + 1
            self.stats.admission_skips += 1
            return
        s = int(self._set_of(np.asarray([fp]))[0])
        budget = self.cfg.m_writes * self.cfg.set_ways // 512 + self.cfg.m_writes
        if self.window_admits[s] >= budget * 64:
            self.stats.throttled += 1   # t_MWW lock: serve by recompute
            return
        self.window_admits[s] += 1
        w = self._pick_way(s)
        self._install(s, w, fp)

    def _pick_way(self, s: int) -> int:
        free = np.nonzero(~self.valid_np[s])[0]
        if free.size:
            return int(free[0])
        ways = self.cfg.set_ways
        start = self.counter % ways
        order = (np.arange(ways) + start) % ways
        # prefer blocks never re-read after install (D̄&R̄-style victims)
        cold = order[self.read_after[s][order] == 0]
        victim = int(cold[0]) if cold.size else int(order[0])
        old_fp = int(self.fp_of_np[s, victim])
        self.slot_of.pop(old_fp, None)
        self.stats.evictions += 1
        self.counter += 1
        return victim

    def _install(self, s: int, w: int, fp: np.uint32):
        bitcol = jnp.asarray(
            xam_ops.words_to_bits_np(np.asarray([fp], np.uint32),
                                     self.cfg.key_bits)[0])
        self.bits, self.valid, self.fp_of = _install_column(
            self.bits, self.valid, self.fp_of,
            jnp.int32(s), jnp.int32(w), bitcol, jnp.uint32(fp))
        self.valid_np[s, w] = True
        self.fp_of_np[s, w] = fp
        self.read_after[s, w] = 0
        self.slot_of[int(fp)] = (s, w)
        self.first_touch.pop(int(fp), None)
        self.stats.admissions += 1

    def _rotate(self):
        """Rotary remap (prime stride 7): flush-and-remap set placement so
        hot fingerprint clusters move across physical sets."""
        self.offset = (self.offset + 7) % self.cfg.n_sets
        self.stats.rotations += 1
        # remap = lazy flush: entries stay searchable under old placement
        # until evicted; new admissions land under the rotated mapping.

    @property
    def hit_rate(self) -> float:
        t = self.stats.chunk_hits + self.stats.chunk_misses
        return self.stats.chunk_hits / max(t, 1)

    def write_distribution(self) -> np.ndarray:
        """Installs per set — wear-evenness metric for tests/benchmarks."""
        return self.valid_np.sum(axis=1)
