"""HTTP network edge over the Monarch serving stack.

The piece in front of everything else: a stdlib-only HTTP server
(``http.server.ThreadingHTTPServer`` — no new dependencies) exposing
the serving loop to the network, backed by a multi-worker router that
drives ``run_request_loop`` semantics against ONE shared
``MonarchKVIndex`` / ``AdmitQueue`` / ``KVSlabStore``.

Endpoints (operator guide: docs/SERVING.md "Network edge"):

* ``POST /v1/generate`` — body ``{"tokens": [[...], ...]}`` (a (B, S)
  int batch); answers the decoded tokens plus the request's prefix
  accounting (``chunks`` / ``hit_chunks`` / ``resumed_chunks``,
  admission outcome, queue + service time).
* ``GET /healthz`` — liveness; 200 while accepting, 503 once draining.
* ``GET /stats`` — JSON snapshot: ``idx.stats``, ``admit_q.stats``,
  ``wear_report()``, ``lifetime_estimate()``, router counters.

Layering (who does what):

* :class:`ServeRouter` — N worker threads pull requests off one bounded
  queue.  Each worker runs the SHARED request loop
  (:func:`repro.launch.serve.run_request_loop`: lookup -> prefill/
  resume -> submit -> decode) on its micro-batch, so every semantic the
  loop pins (read-your-writes lookups, submit-after-prefill slab
  staging, defer-retry with bounded drain-wait) holds verbatim on the
  network path.  A **micro-batcher** coalesces same-shape requests that
  arrive within ``batch_window_s`` into one prefill batch — one fused
  XAM lookup and one prefill dispatch instead of B.
* Back-pressure maps to HTTP semantics: a submit that would overflow
  the router's bounded queue raises :class:`RouterBusy`, which the
  handler answers as **429 with a Retry-After** drain estimate (the
  HTTP twin of the AdmitQueue's shed/defer — reject NEW work, never
  abandon accepted work).  After shutdown begins, new requests get
  **503** while accepted ones drain.
* :class:`HttpFrontend` — socket lifecycle.  ``shutdown()`` is
  graceful: stop admitting (503), drain the router queue and in-flight
  batches, flush the admission queue, then stop the listener — no
  accepted request or submitted admission is lost (pinned by
  tests/test_http_frontend.py).

Thread safety: the router's queue/counters live under one condition
variable; index access is already serialized by the ``AdmitQueue``
locks, and jitted prefill/decode calls are safe to issue from multiple
worker threads (XLA releases the GIL).

Examples
--------
The router round-trip, HTTP layer aside (the handler calls exactly
this):

>>> import numpy as np
>>> from repro.serve.kv_index import KVIndexConfig, MonarchKVIndex
>>> from repro.serve.admit_queue import AdmitQueue
>>> from repro.serve.http_frontend import ServeRouter
>>> q = AdmitQueue(MonarchKVIndex(KVIndexConfig(
...     n_sets=4, set_ways=16, admit_after_reads=0)))
>>> router = ServeRouter(q, prefill_fn=lambda toks, hits: None,
...                      decode_fn=lambda toks, state: toks[:, -1:])
>>> toks = np.arange(1, 33, dtype=np.int32).reshape(1, 32)
>>> out = router.submit(toks)            # lookup -> prefill -> decode
>>> out["tokens"], out["chunks"], out["hit_chunks"]
([[32]], 2, 0)
>>> router.submit(toks)["hit_chunks"]    # read-your-writes: now cached
2
>>> router.close(); q.close()
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import math
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.serve.admit_queue import AdmitQueue

#: Hard cap on tokens per request batch (rows x cols): a request larger
#: than this answers 400 instead of occupying a worker for seconds.
MAX_REQUEST_TOKENS = 1 << 16


class RouterBusy(RuntimeError):
    """Bounded router queue is full — the HTTP layer answers 429.

    ``retry_after_s`` is the drain estimate (queue depth x EWMA batch
    service time / workers) the handler rounds up into ``Retry-After``.
    """

    def __init__(self, retry_after_s: float):
        super().__init__(f"router queue full; retry after "
                         f"~{retry_after_s:.3f}s")
        self.retry_after_s = float(retry_after_s)


class RouterClosed(RuntimeError):
    """Shutdown has begun — the HTTP layer answers 503."""


@dataclasses.dataclass
class RouterStats:
    received: int = 0         # requests accepted into the queue
    completed: int = 0        # requests answered successfully
    errors: int = 0           # requests failed inside a worker
    rejected_busy: int = 0    # 429s: bounded queue full
    rejected_closed: int = 0  # 503s: submit after shutdown began
    batches: int = 0          # micro-batches served
    coalesced: int = 0        # requests merged beyond a batch head


@dataclasses.dataclass
class _Pending:
    """One enqueued request: tokens in, result/error + event out."""
    tokens: np.ndarray
    t_enqueue: float
    event: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: dict | None = None
    error: BaseException | None = None


class ServeRouter:
    """Multi-worker request router over one shared serving front end.

    Parameters
    ----------
    admit_q : AdmitQueue
        THE shared front end — every worker's lookups and admissions go
        through it, so cross-request read-your-writes and the bounded
        admission semantics hold across all workers.
    prefill_fn, decode_fn : callables
        Exactly ``run_request_loop``'s contract (the launcher's model
        fns, the resume engine's pair, or the bench's service proxy).
        ``decode_fn``'s return value is the decoded ``(B, T)`` token
        array answered to the client (``None`` -> no tokens field).
    n_workers : int
        Serving worker threads.  Each runs the shared request loop on
        its own micro-batches; index state stays consistent because all
        index access is serialized by the AdmitQueue locks.
    max_queue : int
        Bound on requests queued (in-flight ones excluded).  At the
        bound :meth:`submit` raises :class:`RouterBusy` — mapped to 429
        by the HTTP layer.
    batch_window_s : float
        Micro-batch window: after popping a request, a worker waits up
        to this long for more SAME-SHAPE requests and serves them as
        one prefill batch.  ``0`` disables coalescing.
    max_batch_rows : int
        Row cap per coalesced batch.
    retry_wait_s : float
        Passed through to ``run_request_loop`` (bounded drain-wait
        before the one defer retry).
    now_fn : callable
        Clock injection for tests.
    """

    def __init__(self, admit_q: AdmitQueue, *, prefill_fn, decode_fn=None,
                 n_workers: int = 2, max_queue: int = 64,
                 batch_window_s: float = 0.002, max_batch_rows: int = 8,
                 retry_wait_s: float = 0.05, now_fn=time.monotonic):
        if n_workers < 1:
            raise ValueError(f"ServeRouter n_workers={n_workers}: expected "
                             ">= 1")
        if max_queue < 1:
            raise ValueError(f"ServeRouter max_queue={max_queue}: expected "
                             ">= 1")
        self.admit_q = admit_q
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.n_workers = n_workers
        self.max_queue = max_queue
        self.batch_window_s = float(batch_window_s)
        self.max_batch_rows = max_batch_rows
        self.retry_wait_s = retry_wait_s
        self._now = now_fn
        self.stats = RouterStats()
        self._cv = threading.Condition()
        self._queue: collections.deque[_Pending] = collections.deque()
        self._inflight = 0                  # batches popped, not answered
        self._closing = False               # no new submits (503)
        self._stop = False                  # workers may exit once drained
        self._service_ewma_s = 1e-3         # per-batch service estimate
        self._workers = [
            threading.Thread(target=self._worker_loop,
                             name=f"monarch-http-{i}", daemon=True)
            for i in range(n_workers)]
        for w in self._workers:
            w.start()

    # ------------------------------------------------------------------
    def _retry_after_s_locked(self) -> float:
        """Drain estimate for Retry-After (``_cv`` held)."""
        depth = len(self._queue) + self._inflight
        return max(depth * self._service_ewma_s / self.n_workers, 1e-3)

    def submit(self, tokens: np.ndarray, timeout: float = 60.0) -> dict:
        """Serve one request batch through the worker pool.

        Blocks the CALLING thread (one HTTP connection thread per
        request) until its micro-batch has been served; workers and
        other clients are never blocked by it.  Raises
        :class:`RouterBusy` at the queue bound, :class:`RouterClosed`
        once shutdown began, and re-raises a worker-side failure."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2 or tokens.size == 0:
            raise ValueError(f"tokens: expected a non-empty (B, S) int "
                             f"batch, got shape {tokens.shape}")
        if tokens.size > MAX_REQUEST_TOKENS:
            raise ValueError(f"tokens: {tokens.size} tokens exceeds the "
                             f"per-request cap {MAX_REQUEST_TOKENS}")
        p = _Pending(tokens=tokens, t_enqueue=self._now())
        with self._cv:
            if self._closing:
                self.stats.rejected_closed += 1
                raise RouterClosed("router is draining (shutdown begun)")
            if len(self._queue) >= self.max_queue:
                self.stats.rejected_busy += 1
                raise RouterBusy(self._retry_after_s_locked())
            self.stats.received += 1
            self._queue.append(p)
            self._cv.notify_all()
        if not p.event.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if p.error is not None:
            raise RuntimeError("request failed in a router worker") \
                from p.error
        return p.result

    def depth(self) -> int:
        """Requests queued or in flight right now."""
        with self._cv:
            return len(self._queue) + self._inflight

    def begin_close(self) -> None:
        """Stop accepting (new submits raise :class:`RouterClosed`);
        queued and in-flight requests keep draining."""
        with self._cv:
            self._closing = True
            self._cv.notify_all()

    def close(self, timeout: float = 30.0) -> None:
        """Graceful shutdown: refuse new work, drain everything accepted
        (requests AND their submitted admissions), stop the workers.
        Idempotent.  The caller still owns ``admit_q.close()``."""
        self.begin_close()
        with self._cv:
            if not self._cv.wait_for(
                    lambda: not self._queue and self._inflight == 0,
                    timeout=timeout):
                raise RuntimeError(
                    f"ServeRouter failed to drain within {timeout}s "
                    f"({len(self._queue)} queued, {self._inflight} in "
                    "flight)")
            self._stop = True
            self._cv.notify_all()
        for w in self._workers:
            w.join(timeout=timeout)
            if w.is_alive():
                raise RuntimeError("ServeRouter worker failed to stop")
        self._workers = []
        self.admit_q.flush()         # every submitted admission lands

    def __enter__(self) -> "ServeRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def _next_batch(self) -> list[_Pending] | None:
        """Pop the next micro-batch (None = stopped and drained): the
        head request plus any same-shape requests arriving within
        ``batch_window_s``, capped at ``max_batch_rows`` rows."""
        with self._cv:
            self._cv.wait_for(lambda: self._queue or self._stop)
            if not self._queue:
                return None              # stopping and fully drained
            head = self._queue.popleft()
            self._inflight += 1
            batch = [head]
            rows = head.tokens.shape[0]
            deadline = self._now() + self.batch_window_s
            while self.batch_window_s > 0 and rows < self.max_batch_rows:
                if self._queue:
                    nxt = self._queue[0]
                    if (nxt.tokens.shape[1:] != head.tokens.shape[1:]
                            or rows + nxt.tokens.shape[0]
                            > self.max_batch_rows):
                        break            # shape mismatch / row cap
                    batch.append(self._queue.popleft())
                    rows += nxt.tokens.shape[0]
                    continue
                remaining = deadline - self._now()
                if remaining <= 0 or self._stop:
                    break
                self._cv.wait(timeout=remaining)
            return batch

    def _serve_batch(self, batch: list[_Pending]) -> None:
        # Local import: launch.serve imports serve.* at module load —
        # importing it lazily here keeps the package acyclic.
        from repro.launch.serve import run_request_loop
        toks = (batch[0].tokens if len(batch) == 1 else
                np.concatenate([p.tokens for p in batch], axis=0))
        t_start = self._now()
        cap: dict = {}

        def on_batch(i, t, hits, rec):
            cap["hits"] = np.asarray(hits, bool)

        err = None
        try:
            rec = run_request_loop(
                self.admit_q, [toks], prefill_fn=self.prefill_fn,
                decode_fn=self.decode_fn, retry_wait_s=self.retry_wait_s,
                on_batch=on_batch)[0]
            t_done = self._now()
            hits = cap["hits"]
            n_rows = toks.shape[0]
            decoded = (None if rec.decoded is None
                       else np.asarray(rec.decoded))
            # resumed_chunks is the batch's resume run x rows — the run
            # is common to every row, so it splits evenly.
            per_row_resumed = rec.resumed_chunks // max(n_rows, 1)
            row = 0
            for p in batch:
                b = p.tokens.shape[0]
                h = hits[row:row + b]
                p.result = {
                    "tokens": (None if decoded is None
                               else decoded[row:row + b].tolist()),
                    "n_rows": b,
                    "chunks": int(h.size),
                    "hit_chunks": int(h.sum()),
                    "resumed_chunks": per_row_resumed * b,
                    "admitted": bool(rec.admitted),
                    "dropped": bool(rec.dropped),
                    "batched_rows": n_rows,
                    "queued_ms": round((t_start - p.t_enqueue) * 1e3, 3),
                    "service_ms": round((t_done - t_start) * 1e3, 3),
                }
                row += b
        except BaseException as e:       # noqa: BLE001 — a worker must
            err = e                      # survive any request failure
            for p in batch:
                p.error = e
        finally:
            with self._cv:
                self._inflight -= 1
                self.stats.batches += 1
                self.stats.coalesced += len(batch) - 1
                if err is None:
                    self.stats.completed += len(batch)
                    dt = max(self._now() - t_start, 1e-6)
                    self._service_ewma_s = (0.8 * self._service_ewma_s
                                            + 0.2 * dt)
                else:
                    self.stats.errors += len(batch)
                self._cv.notify_all()
            for p in batch:
                p.event.set()

    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            self._serve_batch(batch)


# ---------------------------------------------------------------------------
# the socket layer


def stats_snapshot(router: ServeRouter) -> dict:
    """The ``GET /stats`` document: index / admission / wear / lifetime
    / router counters, all JSON-ready.

    Index reads are serialized against the admission worker: the wear /
    lifetime views walk device planes that an in-flight donated
    admission scan would otherwise delete out from under them."""
    q = router.admit_q
    idx = q.index
    idx_lock = getattr(q, "_idx_lock", None) or contextlib.nullcontext()
    with idx_lock:
        lt = idx.lifetime_estimate()
        wear = idx.wear_report()
        istats = dataclasses.asdict(idx.stats)
        hit_rate = round(float(idx.hit_rate), 6)
    with router._cv:
        depth = len(router._queue) + router._inflight
        rstats = dataclasses.asdict(router.stats)
    return {
        "index": istats | {"hit_rate": hit_rate},
        "admit_queue": dataclasses.asdict(q.stats)
        | {"pending": q.pending()},
        "wear": wear,
        "lifetime": dataclasses.asdict(lt),
        "router": rstats | {"depth": depth, "workers": router.n_workers},
    }


class _Handler(BaseHTTPRequestHandler):
    """Request handler over ``self.server.router`` (a ServeRouter)."""

    server_version = "MonarchServe/1.0"
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):   # noqa: A003 — stdlib signature
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    # -- helpers -------------------------------------------------------
    def _send_json(self, status: int, doc: dict,
                   headers: dict | None = None) -> None:
        body = json.dumps(doc).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    # -- endpoints -----------------------------------------------------
    def do_GET(self):                    # noqa: N802 — stdlib hook name
        router: ServeRouter = self.server.router
        if self.path == "/healthz":
            with router._cv:
                closing = router._closing
                depth = len(router._queue) + router._inflight
            if closing:
                self._send_json(503, {"status": "draining",
                                      "depth": depth})
            else:
                self._send_json(200, {"status": "ok", "depth": depth,
                                      "workers": router.n_workers})
        elif self.path == "/stats":
            try:
                self._send_json(200, stats_snapshot(router))
            except RuntimeError as e:    # keep the connection answered
                self._send_json(500, {"error": str(e)})
        else:
            self._send_json(404, {"error": f"unknown path {self.path}; "
                                  "endpoints: POST /v1/generate, "
                                  "GET /healthz, GET /stats"})

    def do_POST(self):                   # noqa: N802 — stdlib hook name
        if self.path != "/v1/generate":
            self._send_json(404, {"error": f"unknown path {self.path}; "
                                  "POST goes to /v1/generate"})
            return
        t0 = time.monotonic()
        try:
            length = int(self.headers.get("Content-Length", 0))
            doc = json.loads(self.rfile.read(length))
            tokens = np.asarray(doc["tokens"], dtype=np.int32)
        except (ValueError, TypeError, KeyError, json.JSONDecodeError):
            self._send_json(400, {"error": "body must be JSON "
                                  '{"tokens": [[...int...], ...]} — a '
                                  "rectangular (B, S) int batch"})
            return
        router: ServeRouter = self.server.router
        try:
            result = router.submit(tokens)
        except ValueError as e:          # shape / size validation
            self._send_json(400, {"error": str(e)})
            return
        except RouterBusy as e:          # back-pressure -> 429
            retry_s = max(math.ceil(e.retry_after_s), 1)
            self._send_json(
                429, {"error": "server overloaded (router queue full)",
                      "retry_after_s": round(e.retry_after_s, 3)},
                headers={"Retry-After": str(retry_s)})
            return
        except RouterClosed:             # draining -> 503
            self._send_json(503, {"error": "server shutting down"})
            return
        except (RuntimeError, TimeoutError) as e:   # worker-side failure
            self._send_json(500, {"error": str(e)})
            return
        result = dict(result)
        result["server_ms"] = round((time.monotonic() - t0) * 1e3, 3)
        self._send_json(200, result)


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    # socketserver's default accept backlog (5) drops connections under
    # bursty open-loop arrivals; router admission is the real limiter
    request_queue_size = 128


class HttpFrontend:
    """Socket lifecycle around a :class:`ServeRouter`.

    ``start()`` serves on a daemon thread; :meth:`shutdown` performs the
    graceful sequence: 503 new requests -> drain router + admissions ->
    stop the listener.  ``port=0`` binds an ephemeral port (read it back
    from :attr:`address`)."""

    def __init__(self, router: ServeRouter, host: str = "127.0.0.1",
                 port: int = 0, verbose: bool = False):
        self.router = router
        self.server = _Server((host, port), _Handler)
        self.server.router = router
        self.server.verbose = verbose
        self._thread: threading.Thread | None = None

    @property
    def address(self) -> tuple[str, int]:
        """(host, port) actually bound."""
        return self.server.server_address[:2]

    def start(self) -> "HttpFrontend":
        self._thread = threading.Thread(
            target=self.server.serve_forever, name="monarch-httpd",
            daemon=True)
        self._thread.start()
        return self

    def begin_shutdown(self) -> None:
        """SIGTERM half: new requests answer 503 from this point on."""
        self.router.begin_close()

    def shutdown(self) -> None:
        """Graceful stop: drain accepted requests and the admission
        queue, then close the listener.  Idempotent."""
        self.begin_shutdown()
        self.router.close()
        self.server.shutdown()
        self.server.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None

    def __enter__(self) -> "HttpFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()
