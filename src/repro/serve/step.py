"""Serving steps: prefill and single-token decode, jit/shard-ready."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer


def make_prefill_step(cfg: ArchConfig, max_seq: int):
    def prefill_step(params, batch):
        return transformer.prefill(params, cfg, batch, max_seq)
    return prefill_step


def make_decode_step(cfg: ArchConfig, greedy: bool = True):
    def serve_step(params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar int32.
        Returns (next_tokens (B, 1), logits (B, V), new cache)."""
        logits, cache = transformer.decode_step(params, cfg, tokens, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step
