"""Serving steps: prefill and single-token decode, jit/shard-ready."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer


def make_prefill_step(cfg: ArchConfig, max_seq: int):
    def prefill_step(params, batch):
        return transformer.prefill(params, cfg, batch, max_seq)
    return prefill_step


def make_resume_prefill_step(cfg: ArchConfig, max_seq: int):
    """Prefill-from-offset for the prefix-cache resume path.

    ``prefix_kv`` holds the cached prefix's post-RoPE per-layer k/v
    (``None`` = ordinary full prefill); ``batch`` holds only the suffix
    tokens, which attend at absolute positions starting at the prefix
    length (the RoPE offset contract).  Always returns
    ``(last-token logits, decode cache, kv-of-this-call)`` — the kv
    pytree is what the caller slices into per-chunk slabs to stage for
    admission.  jit-compatible: prefix/suffix lengths are static shapes,
    so each distinct (P, S_suffix) pair compiles once.
    """
    def resume_prefill_step(params, batch, prefix_kv=None):
        return transformer.prefill(params, cfg, batch, max_seq,
                                   prefix_kv=prefix_kv, return_kv=True)
    return resume_prefill_step


def make_decode_step(cfg: ArchConfig, greedy: bool = True):
    def serve_step(params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar int32.
        Returns (next_tokens (B, 1), logits (B, V), new cache)."""
        logits, cache = transformer.decode_step(params, cfg, tokens, cache, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step
