"""Asynchronous admission queue for the MonarchKVIndex.

Inline admission puts ``admit_fps`` — a device scan plus host shadow-map
bookkeeping — on the serving loop's critical path between batches.  This
module moves it behind a queue drained by a worker thread, so installs
overlap the loop's model compute (prefill/decode): the main thread's
jitted steps release the GIL inside XLA while the worker runs the
admission pipeline, and on a multi-shard index the worker's per-shard
scans additionally overlap each other via jax async dispatch.

Semantics (all pinned by tests/test_kv_index_sharded.py):

* Submission order is preserved, and pending batches are COALESCED into
  one ``admit_fps`` call only while they stay mutually DISJOINT (and
  under ``COALESCE_MAX_FPS``).  Disjointness is what makes the merge
  exact: ``admit_fps`` latches no-allocate touch counts per call, so
  merging two offers of the SAME fingerprint would count one touch where
  inline admission counts two — the worker therefore stops merging at
  the first batch sharing a fingerprint with the unit it is building.
  For disjoint batches the concatenation is bit-exact with the separate
  calls: per-candidate cycle stamps are the global batch positions, which
  concatenate to the same sequence, and the device scan admits in the
  same order.  After ``flush()`` the index state is therefore EXACTLY
  what the same ``admit_fps`` calls issued inline would produce, with
  two documented async relaxations: the op-counter clock may differ when
  lookups interleave (shifting t_MWW cycle stamps), and an auto-rotation
  landing INSIDE a coalesced unit happens at the unit's end rather than
  between the merged batches (serving configs rotate via the explicit
  drain-barrier :meth:`rotate`, where no such window exists).  A failed
  merged unit drops ALL its batches (surfaced at the next barrier, same
  as an unmerged failure).  ``coalesce=False`` restores strict
  one-submit-one-call draining.
* The queue owns an index lock: the worker holds it across each
  ``admit_fps`` (whose donated device calls rebind the shard planes), and
  :meth:`lookup` / :meth:`rotate` take it too, so the serving loop never
  searches planes that an in-flight admission has donated away.
* ``rotate()`` is a DRAIN BARRIER: the queue flushes before the remap, so
  rotation stays the lockstep plane roll the sharded index relies on —
  no admission can land mid-remap.  (Auto-rotation inside ``admit_fps``
  happens under the index lock and is ordered for free.)
* Read-your-writes: with ``read_your_writes=True`` (default),
  :meth:`lookup` flushes the queue first whenever one of the looked-up
  fingerprints is still pending/in-flight, so a request never misses on
  a chunk whose admission it (or a predecessor) already submitted.
* Back-pressure: ``max_pending`` bounds the fingerprints awaiting
  admission; at the bound, ``policy`` picks block / shed-oldest / defer
  (see :class:`AdmitQueue`).  Shedding only ever drops whole QUEUED
  batches — accepted batches still drain in submission order, so the
  coalescing exactness argument above is unchanged.

``background=False`` degrades to a synchronous shim (submit == inline
admit under the same lock) for deterministic tests and single-threaded
callers.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

import numpy as np

from repro.serve.kv_index import CHUNK_TOKENS, MonarchKVIndex

#: Coalesced-unit size cap: bounds the single device dispatch a drained
#: unit turns into (and the work lost if a merged unit fails).
COALESCE_MAX_FPS = 8192


@dataclasses.dataclass
class AdmitQueueStats:
    submitted: int = 0        # fingerprints ACCEPTED by submit()
    batches: int = 0          # submitted batches drained
    coalesced: int = 0        # admit_fps dispatches saved by merging
    flushes: int = 0          # explicit/barrier flushes
    rww_flushes: int = 0      # flushes forced by read-your-writes lookups
    shed: int = 0             # pending batches dropped (policy="shed")
    shed_fps: int = 0         # fingerprints in those shed batches
    deferred: int = 0         # submits rejected (policy="defer")


class AdmitQueue:
    """Admission queue over a :class:`MonarchKVIndex`.

    Parameters
    ----------
    index : MonarchKVIndex
        The index to admit into.  All index access (lookups included)
        should go through this queue once it exists.
    background : bool
        Drain on a daemon worker thread (default).  ``False`` = drain
        synchronously inside :meth:`submit` — same semantics, no overlap.
    read_your_writes : bool
        Flush before a lookup that touches a pending fingerprint.
    coalesce : bool
        Merge consecutive pending batches into one ``admit_fps`` call
        while they stay mutually disjoint (default; see module
        docstring for why disjointness keeps the merge exact).
        ``False`` = one submit, one call.
    max_pending : int, optional
        Bound on fingerprints pending admission (queued + in flight).
        ``None`` (default) keeps the queue unbounded.  When a submit
        would push past the bound, ``policy`` decides what gives.  A
        single batch larger than the bound is still accepted once the
        queue has fully drained — the bound back-pressures, it never
        deadlocks or permanently rejects.
    policy : {"block", "shed", "defer"}
        Back-pressure at the ``max_pending`` bound.  ``"block"``: the
        submit waits until the worker drains below the bound (the
        serving loop absorbs the stall).  ``"shed"``: drop the OLDEST
        queued batch(es) to make room — their chunks simply stay
        unadmitted (a cache miss later, never a correctness issue) and
        are counted in ``stats.shed`` / ``stats.shed_fps``; in-flight
        batches cannot be shed, so the bound may momentarily overshoot
        by one unit.  ``"defer"``: reject the submit (``submit``
        returns ``False``, ``stats.deferred``) and let the caller retry
        after its decode, when the queue has usually drained.  None of
        the policies reorder accepted batches, so the coalescing
        bit-exactness argument and the drain-barrier semantics are
        untouched — the policies only choose WHICH batches enter the
        queue, not how they drain.

    Examples
    --------
    >>> import numpy as np
    >>> from repro.serve.kv_index import KVIndexConfig
    >>> idx = MonarchKVIndex(KVIndexConfig(
    ...     n_sets=4, set_ways=16, admit_after_reads=0))
    >>> q = AdmitQueue(idx)
    >>> toks = np.arange(1, 33, dtype=np.int32).reshape(1, 32)
    >>> q.submit_tokens(toks)                 # returns immediately
    True
    >>> bool(q.lookup(toks).all())            # read-your-writes flush
    True
    >>> q.close()
    """

    POLICIES = ("block", "shed", "defer")

    def __init__(self, index: MonarchKVIndex, *, background: bool = True,
                 read_your_writes: bool = True, coalesce: bool = True,
                 max_pending: int | None = None, policy: str = "block"):
        if policy not in self.POLICIES:
            raise ValueError(f"AdmitQueue policy={policy!r}: expected one "
                             f"of {self.POLICIES}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"AdmitQueue max_pending={max_pending}: "
                             "expected a positive bound or None")
        self.index = index
        self.read_your_writes = read_your_writes
        self._coalesce = coalesce
        self.max_pending = max_pending
        self.policy = policy
        self.stats = AdmitQueueStats()
        self._background = background
        self._idx_lock = threading.Lock()    # serializes index access
        self._cv = threading.Condition()     # guards queue + pending set
        self._queue: collections.deque[np.ndarray] = collections.deque()
        self._pending: collections.Counter = collections.Counter()
        self._inflight = 0                   # batches popped, not yet admitted
        self._stop = False
        self._closed = False                 # close() called: no new work
        self._error: BaseException | None = None   # first worker failure
        self._worker = None
        if background:
            self._worker = threading.Thread(
                target=self._drain_loop, name="monarch-admit", daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------
    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                "AdmitQueue is closed: submit()/lookup() after close() "
                "would feed a queue whose worker has exited (a later "
                "flush() could then block forever)")

    def _over_bound_locked(self, incoming: int) -> bool:
        """Would accepting ``incoming`` fps exceed ``max_pending``?
        (``_cv`` held.)  A fully drained queue always accepts — a single
        oversize batch must not wedge the submitter."""
        if self.max_pending is None:
            return False
        if not self._queue and self._inflight == 0:
            return False
        return sum(self._pending.values()) + incoming > self.max_pending

    def submit(self, fps: np.ndarray) -> bool:
        """Enqueue one admission batch (one future ``admit_fps`` call).

        ``fps`` must be unique within the batch, exactly as ``admit_fps``
        requires; returns immediately in background mode.  Returns
        ``True`` when the batch was accepted; ``False`` only under
        ``policy="defer"`` at the ``max_pending`` bound (the caller
        should retry after its decode).  Raises ``RuntimeError`` after
        :meth:`close`."""
        fps = np.asarray(fps, np.uint32)
        if fps.size == 0:
            return True
        with self._cv:
            self._check_open()
            if self.policy == "block":
                self._cv.wait_for(
                    lambda: self._closed
                    or not self._over_bound_locked(int(fps.size)))
                self._check_open()   # close() woke us: the worker is going
            elif self.policy == "shed":
                store = self.index.slab_store
                while self._over_bound_locked(int(fps.size)) and self._queue:
                    old = self._queue.popleft()
                    self._pending.subtract(int(f) for f in old)
                    self._pending += collections.Counter()  # drop zeros
                    if store is not None:
                        # the shed batch's admission will never run, so
                        # its staged KV slabs are garbage (a later
                        # re-offer recomputes and re-stages them).
                        for f in old:
                            store.discard(int(f))
                    self.stats.shed += 1
                    self.stats.shed_fps += int(old.size)
            elif self._over_bound_locked(int(fps.size)):    # defer
                self.stats.deferred += 1
                return False
            self.stats.submitted += int(fps.size)
            self._queue.append(fps)
            self._pending.update(int(f) for f in fps)
            self._cv.notify_all()
        if not self._background:
            self._drain_available()
        return True

    def submit_tokens(self, tokens: np.ndarray, slabs=None) -> bool:
        """Fingerprint a token batch and :meth:`submit` its unique chunks
        (the queue twin of ``MonarchKVIndex.admit``).

        Hashing goes through ``index.fingerprints`` so the scheme
        (``"block"`` vs ``"prefix"``) always matches lookup.  ``slabs``
        (optional ``{fp: kv-slab}``) are STAGED into the index's slab
        store before the batch enqueues, so by the time the async worker
        drains the batch every installing fingerprint finds its slab to
        commit — the submit-after-prefill ordering the resume path's
        read-your-writes guarantee builds on."""
        if slabs:
            store = self.index.slab_store
            if store is None:
                raise ValueError(
                    "submit_tokens(slabs=...) needs an index with an "
                    "attached KVSlabStore")
            for fp, slab in slabs.items():
                store.stage(int(fp), slab)
        fps = np.unique(self.index.fingerprints(tokens).reshape(-1))
        return self.submit(fps)

    def lookup(self, tokens: np.ndarray) -> np.ndarray:
        """Index lookup with optional read-your-writes consistency.

        When any looked-up fingerprint is still queued or in flight (and
        ``read_your_writes`` is on), the queue drains first so the search
        sees the submitted installs.  Raises ``RuntimeError`` after
        :meth:`close` — go to the index directly once the queue is gone."""
        with self._cv:
            self._check_open()
        if self.read_your_writes:
            fps = self.index.fingerprints(tokens).reshape(-1)
            with self._cv:
                waiting = bool(self._pending) and any(
                    int(fp) in self._pending for fp in fps)
            if waiting:
                self.stats.rww_flushes += 1
                self.flush()
        with self._idx_lock:
            return self.index.lookup(tokens)

    def flush(self) -> None:
        """Drain barrier: block until every submitted batch has been
        admitted (used before rotation and at shutdown).  Re-raises the
        first admission failure, if any (a failed batch is dropped, the
        worker keeps draining — the barrier never hangs on a dead
        worker)."""
        self.stats.flushes += 1
        if not self._background:
            self._drain_available()
        else:
            with self._cv:
                self._cv.wait_for(
                    lambda: not self._queue and self._inflight == 0)
        self._raise_pending_error()

    def _raise_pending_error(self) -> None:
        with self._cv:
            err, self._error = self._error, None
        if err is not None:
            raise RuntimeError(
                "admission batch failed in the AdmitQueue worker") from err

    def rotate(self) -> None:
        """Flush, then rotate the index — admissions never straddle the
        remap (the drain barrier the sharded lockstep roll requires)."""
        self.flush()
        with self._idx_lock:
            self.index._rotate()

    def pending(self) -> int:
        """Fingerprints submitted but not yet admitted."""
        with self._cv:
            return int(sum(self._pending.values()))

    def close(self, timeout: float = 30.0) -> None:
        """Flush and stop the worker.  Idempotent.

        After close, :meth:`submit` and :meth:`lookup` raise
        ``RuntimeError`` — enqueueing into a dead queue would otherwise
        silently strand the batch and wedge the next ``flush()``.  A
        worker that fails to stop within ``timeout`` seconds is a real
        hang (it holds the index lock) and is surfaced as a
        ``RuntimeError``, never swallowed."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()       # wake blocked submitters -> raise
        self.flush()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=timeout)
            if self._worker.is_alive():
                raise RuntimeError(
                    f"AdmitQueue worker failed to stop within {timeout}s "
                    "(admission still in flight?)")
            self._worker = None

    def __enter__(self) -> "AdmitQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def _pop_unit_locked(self) -> tuple[np.ndarray, int]:
        """Pop the next drain unit (``_cv`` held): the head batch plus any
        immediately following batches that stay mutually disjoint with it,
        concatenated in submission order (exactness argument in the module
        docstring), capped at ``COALESCE_MAX_FPS`` fingerprints.  Returns
        the unit and how many submitted batches it merges."""
        fps = self._queue.popleft()
        n_batches = 1
        if self._coalesce:
            seen = {int(f) for f in fps}
            parts = [fps]
            while (self._queue
                   and len(seen) + self._queue[0].size <= COALESCE_MAX_FPS):
                head = {int(f) for f in self._queue[0]}
                if seen & head:
                    break            # shared fp: touch counts need 2 calls
                parts.append(self._queue.popleft())
                seen |= head
                n_batches += 1
            if n_batches > 1:
                fps = np.concatenate(parts)
        self._inflight += 1
        return fps, n_batches

    def _admit_one_batch(self, fps: np.ndarray, n_batches: int = 1) -> None:
        err = None
        try:
            with self._idx_lock:
                self.index.admit_fps(fps)
            self.stats.batches += n_batches
            self.stats.coalesced += n_batches - 1
        except BaseException as e:           # noqa: BLE001 — must not kill
            err = e                          # the drain loop; surfaced at
        finally:                             # the next flush()
            with self._cv:
                self._pending.subtract(int(f) for f in fps)
                self._pending += collections.Counter()  # drop zeros
                self._inflight -= 1
                if err is not None and self._error is None:
                    self._error = err
                self._cv.notify_all()

    def _drain_available(self) -> None:
        """Synchronous drain (background=False path)."""
        while True:
            with self._cv:
                if not self._queue:
                    return
                fps, n_batches = self._pop_unit_locked()
            self._admit_one_batch(fps, n_batches)

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._queue or self._stop)
                if self._stop and not self._queue:
                    return
                fps, n_batches = self._pop_unit_locked()
            self._admit_one_batch(fps, n_batches)
