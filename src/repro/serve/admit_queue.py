"""Asynchronous admission queue for the MonarchKVIndex.

Inline admission puts ``admit_fps`` — a device scan plus host shadow-map
bookkeeping — on the serving loop's critical path between batches.  This
module moves it behind a queue drained by a worker thread, so installs
overlap the loop's model compute (prefill/decode): the main thread's
jitted steps release the GIL inside XLA while the worker runs the
admission pipeline, and on a multi-shard index the worker's per-shard
scans additionally overlap each other via jax async dispatch.

Semantics (all pinned by tests/test_kv_index_sharded.py):

* Submission order is preserved, and pending batches are COALESCED into
  one ``admit_fps`` call only while they stay mutually DISJOINT (and
  under ``COALESCE_MAX_FPS``).  Disjointness is what makes the merge
  exact: ``admit_fps`` latches no-allocate touch counts per call, so
  merging two offers of the SAME fingerprint would count one touch where
  inline admission counts two — the worker therefore stops merging at
  the first batch sharing a fingerprint with the unit it is building.
  For disjoint batches the concatenation is bit-exact with the separate
  calls: per-candidate cycle stamps are the global batch positions, which
  concatenate to the same sequence, and the device scan admits in the
  same order.  After ``flush()`` the index state is therefore EXACTLY
  what the same ``admit_fps`` calls issued inline would produce, with
  two documented async relaxations: the op-counter clock may differ when
  lookups interleave (shifting t_MWW cycle stamps), and an auto-rotation
  landing INSIDE a coalesced unit happens at the unit's end rather than
  between the merged batches (serving configs rotate via the explicit
  drain-barrier :meth:`rotate`, where no such window exists).  A failed
  merged unit drops ALL its batches (surfaced at the next barrier, same
  as an unmerged failure).  ``coalesce=False`` restores strict
  one-submit-one-call draining.
* The queue owns an index lock: the worker holds it across each
  ``admit_fps`` (whose donated device calls rebind the shard planes), and
  :meth:`lookup` / :meth:`rotate` take it too, so the serving loop never
  searches planes that an in-flight admission has donated away.
* ``rotate()`` is a DRAIN BARRIER: the queue flushes before the remap, so
  rotation stays the lockstep plane roll the sharded index relies on —
  no admission can land mid-remap.  (Auto-rotation inside ``admit_fps``
  happens under the index lock and is ordered for free.)
* Read-your-writes: with ``read_your_writes=True`` (default),
  :meth:`lookup` flushes the queue first whenever one of the looked-up
  fingerprints is still pending/in-flight, so a request never misses on
  a chunk whose admission it (or a predecessor) already submitted.

``background=False`` degrades to a synchronous shim (submit == inline
admit under the same lock) for deterministic tests and single-threaded
callers.
"""
from __future__ import annotations

import collections
import dataclasses
import threading

import numpy as np

from repro.data.pipeline import fingerprint_blocks
from repro.serve.kv_index import CHUNK_TOKENS, MonarchKVIndex

#: Coalesced-unit size cap: bounds the single device dispatch a drained
#: unit turns into (and the work lost if a merged unit fails).
COALESCE_MAX_FPS = 8192


@dataclasses.dataclass
class AdmitQueueStats:
    submitted: int = 0        # fingerprints handed to submit()
    batches: int = 0          # submitted batches drained
    coalesced: int = 0        # admit_fps dispatches saved by merging
    flushes: int = 0          # explicit/barrier flushes
    rww_flushes: int = 0      # flushes forced by read-your-writes lookups


class AdmitQueue:
    """Admission queue over a :class:`MonarchKVIndex`.

    Parameters
    ----------
    index : MonarchKVIndex
        The index to admit into.  All index access (lookups included)
        should go through this queue once it exists.
    background : bool
        Drain on a daemon worker thread (default).  ``False`` = drain
        synchronously inside :meth:`submit` — same semantics, no overlap.
    read_your_writes : bool
        Flush before a lookup that touches a pending fingerprint.
    coalesce : bool
        Merge consecutive pending batches into one ``admit_fps`` call
        while they stay mutually disjoint (default; see module
        docstring for why disjointness keeps the merge exact).
        ``False`` = one submit, one call.

    Examples
    --------
    >>> import numpy as np
    >>> from repro.serve.kv_index import KVIndexConfig
    >>> idx = MonarchKVIndex(KVIndexConfig(
    ...     n_sets=4, set_ways=16, admit_after_reads=0))
    >>> q = AdmitQueue(idx)
    >>> toks = np.arange(1, 33, dtype=np.int32).reshape(1, 32)
    >>> q.submit_tokens(toks)                 # returns immediately
    >>> bool(q.lookup(toks).all())            # read-your-writes flush
    True
    >>> q.close()
    """

    def __init__(self, index: MonarchKVIndex, *, background: bool = True,
                 read_your_writes: bool = True, coalesce: bool = True):
        self.index = index
        self.read_your_writes = read_your_writes
        self._coalesce = coalesce
        self.stats = AdmitQueueStats()
        self._background = background
        self._idx_lock = threading.Lock()    # serializes index access
        self._cv = threading.Condition()     # guards queue + pending set
        self._queue: collections.deque[np.ndarray] = collections.deque()
        self._pending: collections.Counter = collections.Counter()
        self._inflight = 0                   # batches popped, not yet admitted
        self._stop = False
        self._error: BaseException | None = None   # first worker failure
        self._worker = None
        if background:
            self._worker = threading.Thread(
                target=self._drain_loop, name="monarch-admit", daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------
    def submit(self, fps: np.ndarray) -> None:
        """Enqueue one admission batch (one future ``admit_fps`` call).

        ``fps`` must be unique within the batch, exactly as ``admit_fps``
        requires; returns immediately in background mode."""
        fps = np.asarray(fps, np.uint32)
        if fps.size == 0:
            return
        self.stats.submitted += int(fps.size)
        with self._cv:
            self._queue.append(fps)
            self._pending.update(int(f) for f in fps)
            self._cv.notify_all()
        if not self._background:
            self._drain_available()

    def submit_tokens(self, tokens: np.ndarray) -> None:
        """Fingerprint a token batch and :meth:`submit` its unique chunks
        (the queue twin of ``MonarchKVIndex.admit``)."""
        fps = np.unique(fingerprint_blocks(tokens, CHUNK_TOKENS).reshape(-1))
        self.submit(fps)

    def lookup(self, tokens: np.ndarray) -> np.ndarray:
        """Index lookup with optional read-your-writes consistency.

        When any looked-up fingerprint is still queued or in flight (and
        ``read_your_writes`` is on), the queue drains first so the search
        sees the submitted installs."""
        if self.read_your_writes:
            fps = fingerprint_blocks(tokens, CHUNK_TOKENS).reshape(-1)
            with self._cv:
                waiting = bool(self._pending) and any(
                    int(fp) in self._pending for fp in fps)
            if waiting:
                self.stats.rww_flushes += 1
                self.flush()
        with self._idx_lock:
            return self.index.lookup(tokens)

    def flush(self) -> None:
        """Drain barrier: block until every submitted batch has been
        admitted (used before rotation and at shutdown).  Re-raises the
        first admission failure, if any (a failed batch is dropped, the
        worker keeps draining — the barrier never hangs on a dead
        worker)."""
        self.stats.flushes += 1
        if not self._background:
            self._drain_available()
        else:
            with self._cv:
                self._cv.wait_for(
                    lambda: not self._queue and self._inflight == 0)
        self._raise_pending_error()

    def _raise_pending_error(self) -> None:
        with self._cv:
            err, self._error = self._error, None
        if err is not None:
            raise RuntimeError(
                "admission batch failed in the AdmitQueue worker") from err

    def rotate(self) -> None:
        """Flush, then rotate the index — admissions never straddle the
        remap (the drain barrier the sharded lockstep roll requires)."""
        self.flush()
        with self._idx_lock:
            self.index._rotate()

    def pending(self) -> int:
        """Fingerprints submitted but not yet admitted."""
        with self._cv:
            return int(sum(self._pending.values()))

    def close(self) -> None:
        """Flush and stop the worker.  Idempotent."""
        self.flush()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=30)
            self._worker = None

    def __enter__(self) -> "AdmitQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def _pop_unit_locked(self) -> tuple[np.ndarray, int]:
        """Pop the next drain unit (``_cv`` held): the head batch plus any
        immediately following batches that stay mutually disjoint with it,
        concatenated in submission order (exactness argument in the module
        docstring), capped at ``COALESCE_MAX_FPS`` fingerprints.  Returns
        the unit and how many submitted batches it merges."""
        fps = self._queue.popleft()
        n_batches = 1
        if self._coalesce:
            seen = {int(f) for f in fps}
            parts = [fps]
            while (self._queue
                   and len(seen) + self._queue[0].size <= COALESCE_MAX_FPS):
                head = {int(f) for f in self._queue[0]}
                if seen & head:
                    break            # shared fp: touch counts need 2 calls
                parts.append(self._queue.popleft())
                seen |= head
                n_batches += 1
            if n_batches > 1:
                fps = np.concatenate(parts)
        self._inflight += 1
        return fps, n_batches

    def _admit_one_batch(self, fps: np.ndarray, n_batches: int = 1) -> None:
        err = None
        try:
            with self._idx_lock:
                self.index.admit_fps(fps)
            self.stats.batches += n_batches
            self.stats.coalesced += n_batches - 1
        except BaseException as e:           # noqa: BLE001 — must not kill
            err = e                          # the drain loop; surfaced at
        finally:                             # the next flush()
            with self._cv:
                self._pending.subtract(int(f) for f in fps)
                self._pending += collections.Counter()  # drop zeros
                self._inflight -= 1
                if err is not None and self._error is None:
                    self._error = err
                self._cv.notify_all()

    def _drain_available(self) -> None:
        """Synchronous drain (background=False path)."""
        while True:
            with self._cv:
                if not self._queue:
                    return
                fps, n_batches = self._pop_unit_locked()
            self._admit_one_batch(fps, n_batches)

    def _drain_loop(self) -> None:
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._queue or self._stop)
                if self._stop and not self._queue:
                    return
                fps, n_batches = self._pop_unit_locked()
            self._admit_one_batch(fps, n_batches)
