"""Prefix-cache resume engine: restore cached KV slabs, prefill the
suffix from its RoPE offset, decode from the combined cache.

This is the consumer side of the Monarch prefix index — the piece that
turns an index HIT into saved prefill compute.  The flow per request
batch (driven by ``launch/serve.py::run_request_loop``):

1. ``lookup`` (through the AdmitQueue) answers which leading chunks of
   the prompt are cached — ONE fused XAM search for the whole batch.
2. :meth:`PrefixResumeEngine.prefill` fetches the hit chunks' KV slabs
   from the index's :class:`~repro.serve.kv_index.KVSlabStore`, assembles
   them into a ``prefix_kv`` pytree, and runs
   ``transformer.prefill(prefix_kv=...)`` over ONLY the suffix tokens —
   suffix positions start at the prefix length (the RoPE offset
   contract: resumed tokens attend at their original absolute
   positions), so the resulting cache and logits are bit-identical to a
   full prefill of the whole prompt.
3. The chunks it DID compute are sliced into per-chunk slabs and handed
   back (:class:`PrefillResult`), which the request loop stages via
   ``AdmitQueue.submit_tokens(toks, slabs=...)`` — submit-after-prefill,
   so the async admission worker commits slabs while decode runs.
4. :meth:`PrefixResumeEngine.decode` greedily decodes from the restored
   cache, positions continuing at the full prompt length.

Correctness ground rules (all pinned by ``tests/test_decode_resume.py``):

* The index MUST hash with ``fingerprint="prefix"`` (chained chunk
  hashes): a chunk's KV depends on its entire prefix, so content-equal
  chunks with different prefixes must not share slabs.
* At least the last prompt token is always recomputed (``run`` is capped
  at ``(S-1) // CHUNK_TOKENS`` chunks) — a fully-cached prompt still
  needs last-token logits to seed decode.
* A hit whose slab is missing (admitted slab-less, or shed/evicted
  between lookup and fetch) truncates the resume run — graceful
  recompute, never a wrong answer.
* Only attention layers resume (``transformer.resume_supported``): SSM
  recurrent state folds the whole prefix into one vector and cannot be
  restored from per-chunk slabs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.serve.kv_index import CHUNK_TOKENS, MonarchKVIndex
from repro.serve.step import make_decode_step, make_resume_prefill_step


@dataclasses.dataclass
class PrefillResult:
    """What a resume-aware ``prefill_fn`` returns to the request loop.

    ``state`` is the opaque decode state (logits/cache/position) for
    ``decode_fn``; ``slabs`` maps chunk fingerprints to freshly computed
    KV slabs for the loop to stage at submit time; the chunk counters
    feed the per-request records and the bench's resumed-fraction
    metric."""
    state: Any
    slabs: dict | None = None
    resumed_chunks: int = 0
    computed_chunks: int = 0


# Slab/kv pytree axis conventions: every leaf is (..., B, S, KV, dh) —
# the sequence axis is third-from-last, the batch axis fourth-from-last
# (scanned group leaves carry a leading (G,) axis, remainder leaves do
# not, so axes are addressed from the right).

def _slice_chunk(tree, row: int, lo: int, hi: int):
    """One row's [lo, hi) token span of a kv pytree, as host arrays."""
    def f(a):
        sl = [slice(None)] * a.ndim
        sl[a.ndim - 4] = slice(row, row + 1)
        sl[a.ndim - 3] = slice(lo, hi)
        return np.ascontiguousarray(a[tuple(sl)])
    return jax.tree.map(f, tree)


def _concat_seq(slabs: list):
    """Concatenate per-chunk slabs along the sequence axis."""
    return jax.tree.map(
        lambda *xs: np.concatenate(xs, axis=xs[0].ndim - 3), *slabs)


def _concat_rows(rows: list):
    """Concatenate per-row prefixes along the batch axis."""
    return jax.tree.map(
        lambda *xs: np.concatenate(xs, axis=xs[0].ndim - 4), *rows)


class PrefixResumeEngine:
    """Prefill/decode pair that serves prefix-cache hits from KV slabs.

    Parameters
    ----------
    params : pytree
        Model parameters (already placed on the serving mesh).
    cfg : ArchConfig
        Must be attention-only (``transformer.resume_supported``).
    max_seq : int
        Decode-cache capacity; prompts + decode tokens must fit.
    index : MonarchKVIndex
        Supplies the fingerprint scheme (must be ``"prefix"``) and the
        attached :class:`KVSlabStore` the engine fetches slabs from.
        The engine never mutates the index — lookups and admissions stay
        with the request loop / AdmitQueue.
    decode_tokens : int
        Default greedy-decode length for :meth:`decode`.
    jit : bool
        jit the prefill/decode steps (on by default; off for debugging).
    """

    def __init__(self, params, cfg: ArchConfig, *, max_seq: int,
                 index: MonarchKVIndex, decode_tokens: int = 8,
                 jit: bool = True):
        if not transformer.resume_supported(cfg):
            raise NotImplementedError(
                f"prefix resume needs attention-only layers; {cfg.name} "
                "carries recurrent (SSM) state that chunk slabs cannot "
                "restore")
        if index.cfg.fingerprint != "prefix":
            raise ValueError(
                "PrefixResumeEngine needs KVIndexConfig(fingerprint="
                "'prefix'): per-chunk-independent fingerprints would let "
                "content-equal chunks with different prefixes share KV")
        if index.slab_store is None:
            raise ValueError(
                "PrefixResumeEngine needs an index with an attached "
                "KVSlabStore (MonarchKVIndex(..., slab_store=...))")
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.index = index
        self.store = index.slab_store
        self.decode_tokens = decode_tokens
        fn = make_resume_prefill_step(cfg, max_seq)
        self._prefill = jax.jit(fn) if jit else fn
        dec = make_decode_step(cfg)
        self._decode = jax.jit(dec) if jit else dec
        self.resumed_chunks = 0          # served from slabs, cumulative
        self.computed_chunks = 0         # recomputed, cumulative

    # ------------------------------------------------------------------
    def _resume_run(self, fps: np.ndarray, hits: np.ndarray,
                    s: int) -> int:
        """Longest leading run of chunks servable for EVERY row: the
        chunk hit in the index AND its slab resident.  Capped at
        ``(s-1) // CHUNK_TOKENS`` so at least one suffix token is always
        recomputed (last-token logits seed decode) — for chunk-aligned
        prompts that forces the last chunk out of the run; a partial
        trailing chunk is recomputed anyway and lifts the cap."""
        b, n_chunks = fps.shape
        cap = max(s - 1, 0) // CHUNK_TOKENS
        run = cap
        for r in range(b):
            k = 0
            while (k < cap and hits[r, k]
                   and self.store.get(int(fps[r, k])) is not None):
                k += 1
            run = min(run, k)
        return run

    def prefill(self, toks: np.ndarray, hits=None) -> PrefillResult:
        """Restore + partial prefill of one request batch.

        ``hits`` is the request loop's lookup answer ((B, n_chunks)
        bool); ``None`` disables resume (full prefill — the no-cache
        baseline path, still returning slabs for admission)."""
        toks = np.asarray(toks, np.int32)
        b, s = toks.shape
        n_chunks = s // CHUNK_TOKENS
        fps = self.index.fingerprints(toks)
        if hits is None:
            hits = np.zeros((b, n_chunks), bool)
        run = self._resume_run(fps, np.asarray(hits, bool), s)
        p_len = run * CHUNK_TOKENS
        if run > 0:
            prefix_kv = _concat_rows([
                _concat_seq([self.store.get(int(fps[r, k]))
                             for k in range(run)])
                for r in range(b)])
            logits, cache, kv_suffix = self._prefill(
                self.params, {"tokens": toks[:, p_len:]},
                jax.tree.map(jnp.asarray, prefix_kv))
        else:
            logits, cache, kv_suffix = self._prefill(
                self.params, {"tokens": toks})
        # Slice the freshly computed whole chunks into slabs to stage.
        kv_np = jax.tree.map(np.asarray, kv_suffix)
        slabs: dict[int, Any] = {}
        for r in range(b):
            for c in range(run, n_chunks):
                fp = int(fps[r, c])
                if fp not in slabs:
                    lo = c * CHUNK_TOKENS - p_len
                    slabs[fp] = _slice_chunk(kv_np, r, lo, lo + CHUNK_TOKENS)
        self.resumed_chunks += run * b
        self.computed_chunks += (n_chunks - run) * b
        state = {"logits": logits, "cache": cache, "pos": s}
        return PrefillResult(state=state, slabs=slabs,
                             resumed_chunks=run * b,
                             computed_chunks=(n_chunks - run) * b)

    def decode(self, result, n_tokens: int | None = None) -> np.ndarray:
        """Greedy decode from a :meth:`prefill` result (or its bare
        ``state``).  Returns the (B, n_tokens) decoded ids; positions
        continue at the full prompt length regardless of how much
        prefill was skipped."""
        state = result.state if isinstance(result, PrefillResult) else result
        n = self.decode_tokens if n_tokens is None else n_tokens
        logits, cache, pos = state["logits"], state["cache"], state["pos"]
        if pos + n > self.max_seq:
            raise ValueError(
                f"decode of {n} tokens from position {pos} overflows "
                f"max_seq={self.max_seq}")
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = []
        for t in range(n):
            outs.append(np.asarray(nxt))
            nxt, _, cache = self._decode(
                self.params, cache, nxt, jnp.int32(pos + t))
        return np.concatenate(outs, axis=1)

    def request_fns(self, n_tokens: int | None = None):
        """(prefill_fn, decode_fn) pair shaped for ``run_request_loop``.
        The decode_fn RETURNS its (B, n_tokens) token array — the loop
        surfaces it as ``RequestRecord.decoded`` — and also stashes it
        on the PrefillResult state as ``state["decoded"]`` for callers
        holding the prefill result."""
        def prefill_fn(toks, hits):
            return self.prefill(toks, hits)

        def decode_fn(toks, result):
            decoded = self.decode(result, n_tokens)
            result.state["decoded"] = decoded
            return decoded

        return prefill_fn, decode_fn
