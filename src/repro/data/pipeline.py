"""Deterministic, seed+step-addressable data pipeline.

Every batch is a pure function of (seed, step, shard, n_shards): any worker
can (re)compute any shard of any step — this is what makes checkpoint
restart, elastic rescaling and straggler re-dispatch correct without a
central data server.  Synthetic token streams are zipf-distributed with
local n-gram structure (enough for loss-goes-down smoke training).

The CAM-dedup path: batches can be fingerprinted (murmur3 over token
blocks) and checked against the Monarch flat-CAM index to drop replayed
sequences (repro.serve.kv_index reuses the same hashing).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.kernels.xam_search import ops as xam_ops


# ---------------------------------------------------------------------------
# Murmur3 finalizer (32-bit avalanche) — paper §9.2.2 uses Murmur3 for
# Hopscotch hashing; we use the finalizer as the hash core everywhere.
# ---------------------------------------------------------------------------

def murmur3_fmix32(x):
    x = jnp.asarray(x, jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def murmur3_np(x: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):   # wraparound is the point
        x = x.astype(np.uint32)
        x ^= x >> 16
        x = (x * np.uint32(0x85EBCA6B)).astype(np.uint32)
        x ^= x >> 13
        x = (x * np.uint32(0xC2B2AE35)).astype(np.uint32)
        x ^= x >> 16
    return x


# ---------------------------------------------------------------------------
# Token stream.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def batch_at(cfg: DataConfig, step: int, shard: int = 0, n_shards: int = 1):
    """Deterministic batch: (tokens, labels) int32 arrays for one shard."""
    per = cfg.global_batch // n_shards
    rng = np.random.default_rng(
        np.uint64(cfg.seed) * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(997) + np.uint64(shard))
    z = rng.zipf(cfg.zipf_a, size=(per, cfg.seq_len + 1))
    toks = (murmur3_np(z.astype(np.uint32)) % np.uint32(cfg.vocab_size - 1) + 1
            ).astype(np.int32)
    # inject local structure: every 8th position repeats a recent token
    toks[:, 8::8] = toks[:, 7:-1:8]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ---------------------------------------------------------------------------
# YCSB-style key-value workloads (paper §9.2.2: YCSB-B zipfian 95/5).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class YcsbConfig:
    n_keys: int
    n_ops: int
    read_fraction: float = 0.95   # YCSB-B
    zipf_a: float = 1.2
    seed: int = 0


def ycsb_ops(cfg: YcsbConfig):
    """Returns (keys uint64, is_read bool) operation stream over a keyspace
    of n_keys existing keys; writes may insert new keys."""
    rng = np.random.default_rng(cfg.seed)
    ranks = rng.zipf(cfg.zipf_a, cfg.n_ops).astype(np.uint64)
    keys = murmur3_np((ranks % np.uint64(cfg.n_keys)).astype(np.uint32)).astype(np.uint64)
    keys = (keys << np.uint64(16)) | (ranks % np.uint64(cfg.n_keys))
    is_read = rng.random(cfg.n_ops) < cfg.read_fraction
    # writes beyond the keyspace are inserts of fresh keys
    fresh = rng.integers(cfg.n_keys, cfg.n_keys * 2, cfg.n_ops).astype(np.uint64)
    keys = np.where(is_read, keys, (murmur3_np(fresh.astype(np.uint32)).astype(np.uint64) << np.uint64(16)) | fresh)
    # 0 is the hash-table EMPTY sentinel (murmur3(0) == 0, so rank
    # multiples of n_keys would produce it)
    keys = np.where(keys == 0, np.uint64(1), keys)
    return keys, is_read


# ---------------------------------------------------------------------------
# CAM dedup over token blocks.
# ---------------------------------------------------------------------------

def fingerprint_blocks(tokens: np.ndarray, block: int = 16) -> np.ndarray:
    """(B, S) int32 -> (B, S//block) uint32 rolling murmur fingerprints."""
    b, s = tokens.shape
    nb = s // block
    t = tokens[:, :nb * block].reshape(b, nb, block).astype(np.uint32)
    acc = np.zeros((b, nb), np.uint32)
    for i in range(block):
        acc = murmur3_np(acc ^ t[:, :, i])
    return acc


def prefix_fingerprint_blocks(tokens: np.ndarray, block: int = 16) -> np.ndarray:
    """(B, S) int32 -> (B, S//block) uint32 prefix-CHAINED fingerprints.

    Chunk i's fingerprint folds chunk i's content hash into chunk i-1's
    fingerprint (``fp_i = fmix(fp_{i-1} ^ h(chunk_i))``), so equal
    fingerprints imply equal *entire prefixes*, not just equal chunks.
    This is the identity the KV-reuse serving path needs: a transformer
    chunk's KV depends on every preceding token, so per-chunk-independent
    fingerprints (:func:`fingerprint_blocks`) must never key KV slabs.
    """
    blocks = fingerprint_blocks(tokens, block)
    out = np.empty_like(blocks)
    acc = np.zeros(blocks.shape[0], np.uint32)
    for i in range(blocks.shape[1]):
        acc = murmur3_np(acc ^ blocks[:, i])
        out[:, i] = acc
    return out


def dedup_mask(fps: np.ndarray, stored_bits: jnp.ndarray) -> np.ndarray:
    """True where a fingerprint already exists in the CAM index plane
    (stored_bits: (32, C) int8).  One XAM search per fingerprint batch."""
    flat = fps.reshape(-1)
    keys = xam_ops.words_to_bits(jnp.asarray(flat, jnp.uint32), 32)
    hits = xam_ops.xam_search(keys, stored_bits)
    return np.asarray(jnp.any(hits == 1, axis=1)).reshape(fps.shape)
