"""Synthetic memory traces standing in for the paper's CRONO + NAS
workloads (§9.2.1).

We cannot ship ESESC/qemu, so each application is modeled by its
memory-access SIGNATURE: footprint (paper: >= 2x the in-package capacity
for the graph apps), power-law reuse (graph frontier), sequential burst
length (CSR neighbor scans / FT strides), and write fraction (rank updates;
EP is write-heavy — the paper's minimum-lifetime app).  Parameters are
recorded per app so the calibration is inspectable.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    footprint_blocks: int      # relative to in-package capacity (x blocks)
    zipf_a: float              # reuse skew
    seq_burst: int             # avg sequential run length
    write_frac: float
    n_requests: int = 200_000
    # Strided conflict family (CSR row/col pointer walks): addresses that
    # alias into FEW cache sets, thrash low-associativity caches, and are
    # absorbed by Monarch's 512-way sets — the access structure behind the
    # paper's Fig-10 hit-rate gains (e.g. BC "more than 2x").
    stride_frac: float = 0.0   # fraction of requests from the family
    stride: int = 256          # block stride (aliases mod small set counts)
    stride_n: int = 192        # distinct lines in the family (<= 512)


# CRONO graph apps + NAS, calibrated signatures.  Graph apps carry a large
# strided conflict family (frontier/index walks); FT/CG a moderate one
# (transpose strides); EP nearly none (embarrassingly parallel RNG).
def crono_nas_specs(inpkg_blocks: int, n_requests: int = 200_000):
    fp = 2 * inpkg_blocks      # paper: inputs sized >= 2x in-package memory
    mk = lambda name, a, burst, wf, f=fp, sf=0.0, sn=192: TraceSpec(
        name, f, a, burst, wf, n_requests, stride_frac=sf, stride_n=sn)
    return [
        mk("BC", 1.10, 4, 0.20, sf=0.12, sn=320),
        mk("BFS", 1.05, 8, 0.10, sf=0.09),
        mk("COM", 1.20, 4, 0.25, sf=0.08),
        mk("CON", 1.10, 8, 0.15, sf=0.09),
        mk("DFS", 1.02, 2, 0.10, sf=0.06),
        mk("PR", 1.25, 16, 0.30, sf=0.11, sn=256),
        mk("SSSP", 1.10, 4, 0.20, sf=0.09),
        mk("TRI", 1.30, 8, 0.05, sf=0.08),
        mk("FT", 1.01, 64, 0.40, fp // 2, sf=0.05, sn=128),
        mk("CG", 1.15, 32, 0.15, fp // 2, sf=0.05, sn=128),
        mk("EP", 1.01, 16, 0.60, inpkg_blocks // 2),  # write-heavy, small fp
    ]


# Fraction of requests that re-reference the recent past (L2 capacity
# re-misses on lines still resident in L3): this is what arms the
# R-after-install flags the §8 D/R filter keys on.  One global constant for
# all apps, calibrated so the filter removes ~1/3 of eviction write traffic
# (paper: ~31%); per-app behavior still comes from the signature params.
REREFERENCE_FRAC = 0.65
REREFERENCE_GAP = 4    # per-THREAD gap (~64 interleaved requests)


N_THREADS = 16   # 8 OoO cores x 2 HW threads (§9.1): the interleaving of
# independent per-thread streams is what destroys DRAM row-buffer locality
# in the parallel apps (and what the refresh-free Monarch is immune to).


def generate(spec: TraceSpec, seed: int = 0):
    """Returns (addrs int64 block ids, is_write bool): N_THREADS per-thread
    streams, interleaved as they would arrive at the shared L3."""
    streams = [_gen_thread(spec, seed * N_THREADS + t)
               for t in range(N_THREADS)]
    rng = np.random.default_rng(seed + 12345)
    n = spec.n_requests
    order = rng.integers(0, N_THREADS, n)
    per = streams[0][0].shape[0]
    # occurrence index of each request within its thread (vectorized cumcount)
    sorted_i = np.argsort(order, kind="stable")
    counts = np.bincount(order, minlength=N_THREADS)
    occ = np.empty(n, np.int64)
    start = 0
    for t in range(N_THREADS):
        occ[sorted_i[start:start + counts[t]]] = np.arange(counts[t])
        start += counts[t]
    a_all = np.stack([s[0] for s in streams])
    w_all = np.stack([s[1] for s in streams])
    return a_all[order, occ % per], w_all[order, occ % per]


def _gen_thread(spec: TraceSpec, seed: int = 0):
    """One thread's stream (shared footprint + shared conflict family)."""
    # crc32, NOT hash(): str hashing is salted per process, which silently
    # made every trace (and so every benchmark number) run-dependent.
    rng = np.random.default_rng(seed + zlib.crc32(spec.name.encode()) % (2 ** 16))
    n = max(spec.n_requests // N_THREADS, 1024)
    # power-law base stream over the footprint
    base = rng.zipf(spec.zipf_a, n).astype(np.int64) % spec.footprint_blocks
    # sequential bursts: run-length extend each base address
    burst = rng.geometric(1.0 / spec.seq_burst, n)
    addrs = np.repeat(base, burst)[: 2 * n]
    run_off = np.concatenate([np.arange(b) for b in burst])[: 2 * n]
    addrs = (addrs + run_off) % spec.footprint_blocks
    # strided conflict family: round-robin walk over stride_n aliasing lines
    if spec.stride_frac > 0:
        in_fam = rng.random(len(addrs)) < spec.stride_frac
        walk = np.cumsum(in_fam) % spec.stride_n
        fam_addr = (walk.astype(np.int64) * spec.stride) % spec.footprint_blocks
        addrs = np.where(in_fam, fam_addr, addrs)
    # temporal re-reference: replay positions re-read the address issued
    # REREFERENCE_GAP requests earlier in the FINAL stream (chains resolved
    # to the first non-replay ancestor, so a replay always targets an
    # address that was actually accessed).
    m = len(addrs)
    replay = rng.random(m) < REREFERENCE_FRAC
    gap = REREFERENCE_GAP
    src = np.arange(m)
    src = np.where(replay & (src >= gap), src - gap, src)
    for _ in range(64):  # chase chains (geometric, quickly exhausted)
        need = replay[src] & (src >= gap)
        if not need.any():
            break
        src = np.where(need, src - gap, src)
    addrs = addrs[src][:n]
    is_write = rng.random(n) < spec.write_frac
    is_write = np.where(replay[:n], False, is_write)  # replays are reads
    return addrs, is_write
