"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 100 \
        [--reduced] [--mesh host|single|multi] [--ckpt-dir DIR]

Wires together: arch config -> mesh -> sharded state -> deterministic data
pipeline -> jit'd train step (donated state) -> atomic checkpoints ->
straggler watchdog -> elastic restart (restore onto whatever mesh this
launch has).  On this CPU rig use ``--reduced`` (full configs only lower
via the dry-run); on a real fleet drop it and pick ``--mesh single|multi``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import pipeline
from repro.dist import checkpoint, elastic, sharding, straggler
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer
from repro.train import optimizer as opt
from repro.train import step as train_step_mod


def get_mesh(kind: str):
    if kind == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=(kind == "multi"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=sorted(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU rigs)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seq-shard-attn", action="store_true",
                    help="§Perf: sequence-sharded attention")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = get_mesh(args.mesh)
    if args.seq_shard_attn and not cfg.is_attention_free:
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        cfg = dataclasses.replace(cfg, attn_seq_shard=dp)

    ocfg = opt.OptConfig(peak_lr=args.lr, total_steps=max(args.steps, 100))
    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                               global_batch=args.batch)

    with mesh:
        state = train_step_mod.init_state(jax.random.PRNGKey(0), cfg)
        st_specs = train_step_mod.state_specs(
            jax.eval_shape(lambda: state), mesh)
        named = sharding.to_named(st_specs, mesh)
        state = jax.tree.map(jax.device_put, state, named)

        start = 0
        if args.ckpt_dir:
            step0, restored = elastic.resume_elastic(
                args.ckpt_dir, state, mesh, run_dir=args.ckpt_dir)
            if restored is not None:
                state, start = restored, step0
                print(f"[launch] elastic restore at step {start} onto "
                      f"{mesh.devices.size} devices")

        step_fn = jax.jit(
            train_step_mod.make_train_step(cfg, ocfg, args.microbatches),
            in_shardings=(named, None),   # GSPMD places the host batch
            donate_argnums=(0,))
        watchdog = straggler.StragglerWatchdog()

        n = transformer.param_count(state["params"])
        print(f"[launch] {cfg.name} ({n/1e6:.1f}M params) on "
              f"{mesh.devices.size} devices {dict(zip(mesh.axis_names, mesh.devices.shape))}")
        for step in range(start, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch_at(dcfg, step).items()}
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            act = watchdog.observe(dt)
            if act != straggler.OK:
                print(f"[watchdog] step {step}: {act}")
            if step % 5 == 0 or step == args.steps - 1:
                print(f"[launch] step {step:4d} loss {float(metrics['loss']):8.4f} "
                      f"{dt:5.1f}s")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step + 1, state)
    print("[launch] done")


if __name__ == "__main__":
    main()
