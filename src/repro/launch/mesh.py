"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import geometry


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # More devices than the mesh needs (single-pod mesh under the 512-device
    # dry-run env): use the first n.
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (tests / examples): (1, N) data x model."""
    devices = jax.devices()
    return Mesh(np.asarray(devices).reshape(len(devices), 1), ("data", "model"))


def set_partitions(n_shards: int) -> int:
    """Device-partition count for ``n_shards`` logical set shards.

    The single-dispatch lookup/rotation paths shard the global plane
    arrays contiguously over the ``("sets",)`` mesh, so the mesh size
    must DIVIDE the logical shard count (partition boundaries coarsen
    shard boundaries).  Returns the largest divisor of ``n_shards`` that
    this host's device count can hold — 1 on a single-device host, where
    every logical shard co-locates and the index collapses to the
    unsharded single-launch path."""
    devices = len(jax.devices())
    if n_shards <= 1 or devices <= 1:
        return 1
    m = min(n_shards, devices)
    while n_shards % m != 0:
        m -= 1
    return m


def make_set_mesh(n_shards: int) -> Mesh | None:
    """1-D ``("sets",)`` mesh for the sharded ``MonarchKVIndex`` set planes.

    The serving index splits its CAM sets into contiguous blocks (see
    ``geometry.shard_of_set``); each mesh device owns one block's plane
    arrays, wear state and replacement counters, lookup runs as ONE
    ``shard_map``-wrapped fused search over the mesh, and rotation is a
    ``ppermute`` boundary exchange on it.

    Parameters
    ----------
    n_shards : int
        Logical shard count requested by the index.

    Returns
    -------
    Mesh | None
        A mesh over ``set_partitions(n_shards)`` devices with the single
        axis ``"sets"`` (the size always divides ``n_shards``, so
        contiguous ``NamedSharding`` partitions align with shard
        boundaries), or ``None`` when this host has one device (all
        shards co-locate; the index collapses to the unsharded
        single-launch path).  Like every constructor here this touches
        jax device state only when CALLED, never at import.
    """
    n = set_partitions(n_shards)
    if n <= 1:
        return None
    return Mesh(np.asarray(jax.devices()[:n]), ("sets",))


def set_shard_devices(mesh: Mesh | None, n_shards: int) -> list | None:
    """Per-shard device assignment over a ``make_set_mesh`` mesh.

    Returns a length-``n_shards`` list mapping shard k to a mesh device
    in CONTIGUOUS blocks (``k * n_devices // n_shards`` — contiguous so
    the per-shard placement agrees with the ``NamedSharding(mesh,
    P("sets"))`` partitions the single-dispatch paths assemble), or
    ``None`` when ``mesh`` is None (single-device host: callers skip
    explicit placement entirely, which keeps the 1-shard path
    byte-identical to the unsharded code)."""
    if mesh is None:
        return None
    devs = list(mesh.devices.flat)
    return [devs[k * len(devs) // n_shards] for k in range(n_shards)]


def set_axis_sharding(mesh: Mesh) -> NamedSharding:
    """Contiguous leading-axis sharding over the ``("sets",)`` mesh —
    the layout of every assembled global plane array."""
    return NamedSharding(mesh, P("sets"))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully replicated placement over ``mesh`` — for small operands every
    shard reads whole (the admission path's traced wear knobs and the
    no-allocate threshold).  Placing them ONCE at index construction keeps
    the per-batch dispatch free of implicit host transfers (the
    ``transfer_guard`` admission pin relies on it)."""
    return NamedSharding(mesh, P())


@functools.lru_cache(maxsize=None)
def make_sharded_roll(mesh: Mesh, n_rows: int, shift: int):
    """Donated on-device cyclic roll of set-sharded plane arrays.

    Builds (and caches) a jitted ``shard_map`` function implementing
    ``new[g] = old[(g - shift) mod n_rows]`` along the leading (set)
    axis of any number of arrays sharded ``P("sets")`` over ``mesh`` —
    the global rotary remap — WITHOUT moving plane data through the
    host: per ``geometry.shard_roll_plan`` each device keeps the
    block-aligned slab local (or ppermutes it whole) and exchanges only
    the ``shift mod sets_per_device`` boundary sets with its neighbor.
    All operands are donated, so the remap is in-place buffer reuse.

    Returns a function ``roll(*arrays) -> tuple`` (one output per input,
    same shapes/shardings).
    """
    m = mesh.shape["sets"]
    s_loc = n_rows // m
    _q, r, low_perm, high_perm = geometry.shard_roll_plan(shift, n_rows, m)

    def _roll_one(x):
        low = x[: s_loc - r] if r else x
        if low_perm is not None:
            low = jax.lax.ppermute(low, "sets", low_perm)
        if r == 0:
            return low
        high = x[s_loc - r:]
        if high_perm is not None:
            high = jax.lax.ppermute(high, "sets", high_perm)
        return jnp.concatenate([high, low], axis=0)

    def _roll(*arrays):
        return tuple(_roll_one(x) for x in arrays)

    jitted = {}   # arity -> jitted donated shard_map (built once, reused)

    def roll(*arrays):
        n = len(arrays)
        if n not in jitted:
            spec = tuple(P("sets") for _ in range(n))
            jitted[n] = jax.jit(
                shard_map(_roll, mesh=mesh, in_specs=spec, out_specs=spec,
                          check_rep=False),
                donate_argnums=tuple(range(n)))
        return jitted[n](*arrays)

    return roll


def make_grid_mesh(grid_size: int) -> Mesh | None:
    """1-D mesh over this host's devices for the batched simulator's
    config x trace grid axis.  Returns None when sharding cannot help
    (single device) or cannot be even (grid not divisible by device
    count) — callers fall back to an unsharded vmap."""
    devices = jax.devices()
    n = len(devices)
    if n <= 1 or grid_size % n != 0:
        return None
    return Mesh(np.asarray(devices), ("grid",))
