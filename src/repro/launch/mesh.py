"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # More devices than the mesh needs (single-pod mesh under the 512-device
    # dry-run env): use the first n.
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (tests / examples): (1, N) data x model."""
    devices = jax.devices()
    return Mesh(np.asarray(devices).reshape(len(devices), 1), ("data", "model"))


def make_grid_mesh(grid_size: int) -> Mesh | None:
    """1-D mesh over this host's devices for the batched simulator's
    config x trace grid axis.  Returns None when sharding cannot help
    (single device) or cannot be even (grid not divisible by device
    count) — callers fall back to an unsharded vmap."""
    devices = jax.devices()
    n = len(devices)
    if n <= 1 or grid_size % n != 0:
        return None
    return Mesh(np.asarray(devices), ("grid",))
