"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # More devices than the mesh needs (single-pod mesh under the 512-device
    # dry-run env): use the first n.
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (tests / examples): (1, N) data x model."""
    devices = jax.devices()
    return Mesh(np.asarray(devices).reshape(len(devices), 1), ("data", "model"))


def make_set_mesh(n_shards: int) -> Mesh | None:
    """1-D ``("sets",)`` mesh for the sharded ``MonarchKVIndex`` set planes.

    The serving index splits its CAM sets into ``n_shards`` contiguous
    blocks (see ``geometry.shard_of_set``); each block's plane arrays,
    wear state and replacement counters live on one mesh device, and
    lookup/admission batches fan out as shard-local device calls.

    Parameters
    ----------
    n_shards : int
        Logical shard count requested by the index.

    Returns
    -------
    Mesh | None
        A mesh over ``min(n_shards, n_devices)`` devices with the single
        axis ``"sets"`` — shards are assigned round-robin over its
        devices — or ``None`` when this host has one device (all shards
        co-locate; the fan-out structure still runs, placement is just a
        no-op).  Like every constructor here this touches jax device
        state only when CALLED, never at import.
    """
    devices = jax.devices()
    if n_shards <= 1 or len(devices) <= 1:
        return None
    n = min(n_shards, len(devices))
    return Mesh(np.asarray(devices[:n]), ("sets",))


def set_shard_devices(mesh: Mesh | None, n_shards: int) -> list | None:
    """Per-shard device assignment over a ``make_set_mesh`` mesh.

    Returns a length-``n_shards`` list (shard k -> device, round-robin
    over the mesh's ``"sets"`` axis), or ``None`` when ``mesh`` is None
    (single-device host: callers skip explicit placement entirely, which
    keeps the 1-shard path byte-identical to the unsharded code)."""
    if mesh is None:
        return None
    devs = list(mesh.devices.flat)
    return [devs[k % len(devs)] for k in range(n_shards)]


def make_grid_mesh(grid_size: int) -> Mesh | None:
    """1-D mesh over this host's devices for the batched simulator's
    config x trace grid axis.  Returns None when sharding cannot help
    (single device) or cannot be even (grid not divisible by device
    count) — callers fall back to an unsharded vmap."""
    devices = jax.devices()
    n = len(devices)
    if n <= 1 or grid_size % n != 0:
        return None
    return Mesh(np.asarray(devices), ("grid",))
