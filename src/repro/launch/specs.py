"""ShapeDtypeStruct input factories for every (arch x shape) cell.

No device allocation happens here — these are the stand-ins fed to
``jax.jit(...).lower()`` in the dry-run, and the shape contract used by the
data pipeline.  Modality frontends are STUBS per the assignment:
``[vlm]``/``[audio]`` cells receive precomputed patch/frame embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer

SDS = jax.ShapeDtypeStruct
BF16 = jnp.bfloat16


def train_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        p = cfg.n_prefix_embeds
        return {
            "embeds": SDS((b, p, cfg.d_model), BF16),
            "tokens": SDS((b, s - p), jnp.int32),
            "labels": SDS((b, s - p), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "embeds": SDS((b, s, cfg.d_model), BF16),
            "labels": SDS((b, s), jnp.int32),
        }
    return {
        "tokens": SDS((b, s), jnp.int32),
        "labels": SDS((b, s), jnp.int32),
    }


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    spec = train_batch_specs(cfg, shape)
    spec.pop("labels", None)
    return spec


def decode_arg_specs(cfg: ArchConfig, shape: ShapeConfig):
    """(cache, tokens, pos) ShapeDtypeStructs for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s))
    tokens = SDS((b, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return cache, tokens, pos


def params_shapes(cfg: ArchConfig):
    return jax.eval_shape(
        lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))


def state_shapes(cfg: ArchConfig):
    from repro.train import step as train_step_mod
    return jax.eval_shape(
        lambda: train_step_mod.init_state(jax.random.PRNGKey(0), cfg))


def bf16_params_shapes(cfg: ArchConfig):
    p = params_shapes(cfg)
    return jax.tree.map(lambda s: SDS(s.shape, BF16 if s.dtype == jnp.bfloat16
                                      or s.dtype == jnp.float32 else s.dtype), p)
