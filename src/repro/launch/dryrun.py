import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.
"""Multi-pod dry-run driver.

For every assigned (architecture x input-shape) cell, on the single-pod
16x16 mesh AND the multi-pod 2x16x16 mesh:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(*ShapeDtypeStructs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())
        print(compiled.cost_analysis())

plus HLO collective-byte extraction for §Roofline.  Results are dumped as
JSON under experiments/dryrun/.  Run one cell:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b \
        --shape train_4k --mesh single

or everything (each cell in a fresh subprocess, sequentially):

    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist import sharding
from repro.launch import specs as lspecs
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.roofline import analysis, jaxpr_cost
from repro.serve import step as serve_step_mod
from repro.train import step as train_step_mod

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _analytic_device_bytes(shapes, specs, mesh) -> int:
    """Fallback 'fits?' estimate: per-device bytes of the sharded inputs."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(sh, spec):
        n = 1
        for d in sh.shape:
            n *= d
        denom = 1
        for ax in spec:
            if ax is None:
                continue
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                denom *= sizes.get(a, 1)
        return n * sh.dtype.itemsize // max(denom, 1)

    return sum(jax.tree.leaves(jax.tree.map(
        one, shapes, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))))


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, opt: bool = False):
    """Returns (fn, arg_shapes tuple, in_shardings, out_shardings, donate).

    ``opt=True`` enables the beyond-paper §Perf set: GShard one-hot MoE
    dispatch + sequence-sharded decode KV cache (see EXPERIMENTS.md §Perf).
    """
    import dataclasses
    # §Perf recipe (measured; see EXPERIMENTS.md):
    # * train: sequence-sharded attention.  (GShard einsum dispatch was
    #   REFUTED for arctic: +2.4x flops, +25% collective vs gather once
    #   attention is seq-sharded — the gather partitions fine by itself.)
    # * decode: sequence-sharded KV cache + 2D-TP MLP weights.
    if (opt and shape.kind in ("train", "prefill")
            and not cfg.is_attention_free):
        dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        cfg = dataclasses.replace(cfg, attn_seq_shard=dp)
    if shape.kind == "train":
        state_sh = lspecs.state_shapes(cfg)
        batch_sh = lspecs.train_batch_specs(cfg, shape)
        st_specs = train_step_mod.state_specs(state_sh, mesh)
        b_specs = sharding.batch_specs(batch_sh, mesh)
        fn = train_step_mod.make_train_step(cfg)
        in_sh = (_named(mesh, st_specs), _named(mesh, b_specs))
        out_sh = (_named(mesh, st_specs), NamedSharding(mesh, P()))
        return fn, (state_sh, batch_sh), in_sh, out_sh, (0,)

    params_sh = lspecs.params_shapes(cfg)
    p_specs = sharding.param_specs(params_sh, mesh)

    if shape.kind == "prefill":
        batch_sh = lspecs.prefill_batch_specs(cfg, shape)
        b_specs = sharding.batch_specs(batch_sh, mesh)
        if cfg.encoder_only:
            def fn(params, batch):  # encoder forward IS the prefill
                return transformer.forward(params, cfg, batch)
            out_sh = NamedSharding(mesh, P())
        else:
            fn = serve_step_mod.make_prefill_step(cfg, shape.seq_len)
            cache_sh = jax.eval_shape(
                lambda: transformer.init_cache(cfg, shape.global_batch,
                                               shape.seq_len))
            c_specs = sharding.cache_specs(cache_sh, mesh)
            logits_spec = _logits_spec(cfg, shape, mesh)
            out_sh = (NamedSharding(mesh, logits_spec), _named(mesh, c_specs))
        in_sh = (_named(mesh, p_specs), _named(mesh, b_specs))
        return fn, (params_sh, batch_sh), in_sh, out_sh, ()

    # decode
    params_sh = lspecs.params_shapes(cfg)
    p_specs = sharding.param_specs(params_sh, mesh, two_d_mlp=opt)
    cache_sh, tok_sh, pos_sh = lspecs.decode_arg_specs(cfg, shape)
    c_specs = sharding.cache_specs(cache_sh, mesh, seq_shard=opt)
    dp = sharding.dp_axes(mesh)
    tok_spec = sharding._guard((dp, None), tok_sh.shape, mesh)
    fn0 = serve_step_mod.make_decode_step(cfg)
    logits_spec = _logits_spec(cfg, shape, mesh)
    in_sh = (_named(mesh, p_specs), _named(mesh, c_specs),
             NamedSharding(mesh, tok_spec), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, tok_spec),
              NamedSharding(mesh, logits_spec), _named(mesh, c_specs))
    return fn0, (params_sh, cache_sh, tok_sh, pos_sh), in_sh, out_sh, (1,)


def _logits_spec(cfg, shape, mesh):
    dp = sharding.dp_axes(mesh)
    return sharding._guard((dp, "model"), (shape.global_batch, cfg.vocab_size),
                           mesh)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str) -> dict:
    cfg = configs.get_arch(arch)
    shape = configs.get_shape(shape_name)
    ok, why = configs.cell_is_runnable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "runnable": ok, "skip_reason": why}
    if not ok:
        return rec

    multi = mesh_kind.endswith("multi")
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.devices.size
    opt = mesh_kind.startswith("opt")
    fn, arg_shapes, in_sh, out_sh, donate = build_cell(cfg, shape, mesh,
                                                       opt=opt)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    # ---- memory analysis ------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        }
        print("memory_analysis:", mem)
    except Exception as e:  # CPU backend may not implement it
        mem = {"error": str(e)}
    mem["analytic_input_bytes_per_device"] = _analytic_device_bytes(
        arg_shapes, jax.tree.map(lambda s: s.spec, in_sh,
                                 is_leaf=lambda x: isinstance(x, NamedSharding)),
        mesh)

    # ---- cost analysis + collectives -------------------------------------
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:
        cost = {"error": str(e)}
    print("cost_analysis:", {k: v for k, v in cost.items()
                             if k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    coll = analysis.collective_bytes(hlo)

    # Trip-count-corrected global flops (XLA counts loop bodies once).
    # (inside the mesh context: sharding constraints name mesh axes)
    try:
        with mesh:
            jx_flops = jaxpr_cost.step_flops(fn, *arg_shapes) / n_dev
    except Exception as e:
        print("jaxpr flops failed:", e)
        jx_flops = None

    mf = analysis.model_flops(cfg, shape, n_dev)
    roof = analysis.analyze(cost, coll, model_flops_per_device=mf,
                            jaxpr_flops_per_device=jx_flops)

    rec.update({
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "flops": roof.flops,
        "hbm_bytes": roof.hbm_bytes,
        "collectives": coll,
        "roofline": roof.as_dict(),
    })
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{mesh_kind}__{arch}__{shape_name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: "
          f"compile {t_compile:.1f}s, flops/dev {roof.flops:.3e}, "
          f"coll {coll['total']:.3e}B, bottleneck {roof.bottleneck}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "optsingle", "optmulti"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(OUT_DIR))
    args = ap.parse_args(argv)

    if args.all:
        failures = []
        for cfg, shape, ok, why in configs.all_cells():
            for mesh_kind in ("single", "multi"):
                if not ok:
                    # record the skip without spawning
                    os.makedirs(args.out, exist_ok=True)
                    p = os.path.join(
                        args.out, f"{mesh_kind}__{cfg.name}__{shape.name}.json")
                    with open(p, "w") as f:
                        json.dump({"arch": cfg.name, "shape": shape.name,
                                   "mesh": mesh_kind, "runnable": False,
                                   "skip_reason": why}, f)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", cfg.name, "--shape", shape.name,
                       "--mesh", mesh_kind, "--out", args.out]
                print(">>", " ".join(cmd), flush=True)
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append((cfg.name, shape.name, mesh_kind))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("ALL CELLS PASSED")
        return

    run_cell(args.arch, args.shape, args.mesh, args.out)


if __name__ == "__main__":
    main()
