"""HTTP serving launcher: the Monarch network edge.

    PYTHONPATH=src python -m repro.launch.httpd --arch yi-9b --reduced \
        --port 8077 --n-workers 2 --decode-tokens 8

Boots the full serving stack — mesh-placed model, `MonarchKVIndex`
prefix cache (+ KV slab store on resume-capable archs), async
`AdmitQueue` — behind the stdlib HTTP edge from
:mod:`repro.serve.http_frontend`:

* ``POST /v1/generate`` with ``{"tokens": [[...], ...]}`` decodes
  through the shared index: prefix hits restore KV slabs and resume
  decode exactly as ``launch/serve.py`` does, because both run the same
  ``run_request_loop`` over the same model fns
  (:func:`repro.launch.serve.build_model_fns`).
* ``GET /healthz`` / ``GET /stats`` for probes and operators.
* N router workers micro-batch same-shape requests; the bounded router
  queue answers 429 + ``Retry-After`` under overload; SIGTERM/SIGINT
  triggers the graceful drain (503 on new requests, accepted ones and
  their admissions complete).

Index/durability knobs mirror ``launch/serve.py`` (the flag table in
docs/SERVING.md applies); the edge-specific knobs are ``--port`` /
``--host``, ``--n-workers``, ``--max-queue``, ``--batch-window-ms``.
``--port 0`` binds an ephemeral port and prints it — tests and the CI
smoke read the "listening on" line.
"""
from __future__ import annotations

import argparse
import signal
import threading
import time

import numpy as np

import jax

from repro import configs
from repro.dist import sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.serve import build_model_fns
from repro.models import transformer
from repro.serve.admit_queue import AdmitQueue
from repro.serve.http_frontend import HttpFrontend, ServeRouter
from repro.serve.kv_index import (KVIndexConfig, KVSlabStore,
                                  MonarchKVIndex)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="yi-9b", choices=sorted(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--prompt-len", type=int, default=96,
                    help="max prompt tokens a request may carry (sizes "
                         "the decode cache)")
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--no-resume", action="store_true")
    # network edge
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8077,
                    help="0 binds an ephemeral port (printed at boot)")
    ap.add_argument("--n-workers", type=int, default=2,
                    help="router serving workers (each runs the shared "
                         "request loop on its micro-batches)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="router queue bound; a full queue answers 429 "
                         "with Retry-After")
    ap.add_argument("--batch-window-ms", type=float, default=2.0,
                    help="micro-batch window: same-shape requests "
                         "arriving within it share one prefill batch "
                         "(0 disables)")
    ap.add_argument("--verbose", action="store_true",
                    help="per-request access log")
    # index scaling / durability (same semantics as launch/serve.py)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--sync-admit", action="store_true")
    ap.add_argument("--max-pending", type=int, default=None)
    ap.add_argument("--admit-policy", default="block",
                    choices=["block", "shed", "defer"])
    ap.add_argument("--admit-after-reads", type=int, default=1,
                    help="no-allocate filter: offers before install "
                         "(0 = admit on first touch; short-lived smoke "
                         "servers want 0 so repeats hit immediately)")
    ap.add_argument("--wear-clock", default="wall",
                    choices=["ops", "wall"],
                    help="t_MWW cycle domain (the edge defaults to "
                         "'wall': serving traffic is bursty, so the "
                         "admission window should be a real time "
                         "budget)")
    ap.add_argument("--lifetime-years", type=float, default=None)
    ap.add_argument("--endurance", type=float, default=1e8)
    ap.add_argument("--m-writes", type=int, default=3)
    ap.add_argument("--ops-per-sec", type=float, default=1e6)
    return ap


def build_frontend(args) -> tuple[HttpFrontend, AdmitQueue]:
    """Model + index + router + socket, not yet started.

    Separated from :func:`main` so tests can boot the real stack on an
    ephemeral port and drive it in-process."""
    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode service")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=(args.mesh == "multi")))
    max_seq = args.prompt_len + args.decode_tokens

    resume = not args.no_resume and transformer.resume_supported(cfg)
    fp_scheme = "prefix" if resume else "block"
    kv_kw = dict(n_sets=8, m_writes=args.m_writes, clock=args.wear_clock,
                 n_shards=args.n_shards, fingerprint=fp_scheme,
                 admit_after_reads=args.admit_after_reads)
    if args.lifetime_years is not None:
        kv_cfg = KVIndexConfig.with_lifetime(
            t_life_years=args.lifetime_years, endurance=args.endurance,
            ops_per_second=args.ops_per_sec, **kv_kw)
    else:
        kv_cfg = KVIndexConfig(**kv_kw)
    idx = MonarchKVIndex(kv_cfg,
                         slab_store=KVSlabStore() if resume else None)
    admit_q = AdmitQueue(idx, background=not args.sync_admit,
                         max_pending=args.max_pending,
                         policy=args.admit_policy)

    with mesh:
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        p_named = sharding.to_named(
            sharding.param_specs(jax.eval_shape(lambda: params), mesh),
            mesh)
        params = jax.tree.map(jax.device_put, params, p_named)
        prefill_fn, decode_fn, _ = build_model_fns(
            params, cfg, max_seq=max_seq,
            decode_tokens=args.decode_tokens, index=idx, resume=resume)
        # one throwaway prefill compiles the hot path before the socket
        # opens, so the first real request doesn't pay the jit
        warm = np.ones((1, min(args.prompt_len, 16)), np.int32)
        state = prefill_fn(warm, None if resume
                           else np.zeros((1, 0), bool))
        jax.block_until_ready(jax.tree.leaves(
            state.state["logits"] if resume else state[0]))

    router = ServeRouter(
        admit_q, prefill_fn=prefill_fn, decode_fn=decode_fn,
        n_workers=args.n_workers, max_queue=args.max_queue,
        batch_window_s=args.batch_window_ms / 1e3)
    frontend = HttpFrontend(router, host=args.host, port=args.port,
                            verbose=args.verbose)
    print(f"[httpd] {cfg.name}: resume "
          f"{'ON' if resume else 'off'}, index n_shards={args.n_shards}, "
          f"admit policy={args.admit_policy} "
          f"max_pending={args.max_pending}, wear clock={args.wear_clock}")
    return frontend, admit_q


def main(argv=None):
    args = build_parser().parse_args(argv)
    frontend, admit_q = build_frontend(args)
    frontend.start()
    host, port = frontend.address
    print(f"[httpd] listening on http://{host}:{port} "
          f"({args.n_workers} workers, queue bound {args.max_queue}, "
          f"batch window {args.batch_window_ms:g} ms)", flush=True)

    stop = threading.Event()

    def _graceful(signum, frame):
        print(f"[httpd] signal {signum}: draining "
              "(new requests -> 503)", flush=True)
        # refuse new work IMMEDIATELY; the full drain runs on the main
        # thread below (signal handlers must stay tiny)
        frontend.begin_shutdown()
        stop.set()

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)
    stop.wait()
    t0 = time.monotonic()
    frontend.shutdown()                  # drain router + admissions
    admit_q.close()
    idx = admit_q.index
    r = frontend.router.stats
    print(f"[httpd] drained in {time.monotonic() - t0:.2f}s: "
          f"{r.completed} served / {r.errors} errors / "
          f"{r.rejected_busy} busy-rejected / "
          f"{r.rejected_closed} drain-rejected; "
          f"index hit rate {idx.hit_rate:.1%}, "
          f"{idx.stats.admissions} admissions", flush=True)


if __name__ == "__main__":
    main()
