"""Production serving launcher: batched prefill + decode with the
MonarchKVIndex prefix cache.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --requests 8 --decode-tokens 8 [--mesh host|single|multi]

The request loop is the same flow examples/serve_prefix_cache.py
demonstrates; this launcher adds mesh placement (params TP/FSDP-sharded,
cache sharded per ``cache_specs`` — ``--seq-shard-kv`` enables the §Perf
split-KV layout) and batch scheduling over a request queue.  The loop
itself lives in :func:`run_request_loop` — one implementation shared by
this launcher (closed-loop: the next batch starts when the previous
finished) and by ``benchmarks/serve_bench.py`` (open-loop: scheduled
Poisson/replayed-trace arrivals, latency charged from the SCHEDULED
arrival so backlog shows up as queueing delay instead of being
coordinated-omission'd away).

Index scaling knobs (see docs/SERVING.md for the full operator guide):
``--n-shards`` splits the Monarch index's CAM sets across the
``("sets",)`` device mesh — lookups run as ONE ``shard_map`` dispatch
over the stacked layout and rotation stays device-resident (``ppermute``
boundary exchange); on a single-device host every shard co-locates and
the index collapses to the unsharded single-launch path.  Admissions run
behind an async ``AdmitQueue`` by default — installs overlap the decode
loop — with ``--sync-admit`` restoring the inline path.  Front-end SLO
knobs: ``--wear-clock wall`` makes the §6.2 admission window a
wall-clock time budget instead of the op-counter proxy;
``--max-pending`` bounds the admission queue with ``--admit-policy``
``block`` / ``shed`` / ``defer`` back-pressure.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer
from repro.serve import step as serve_step
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig, KVSlabStore,
                                  MonarchKVIndex)
from repro.serve.resume import PrefillResult, PrefixResumeEngine


@dataclasses.dataclass
class RequestRecord:
    """Per-request front-end accounting from :func:`run_request_loop`.

    ``latency_s`` is measured from the SCHEDULED arrival when the loop
    runs open-loop (``arrivals_s`` given): a request that arrived while
    the loop was still busy is charged its backlog wait, which is what
    makes open-loop p99 honest under overload.  Closed-loop, arrival ==
    start and latency is pure service time."""
    arrival_s: float            # scheduled (open-loop) or actual start
    start_s: float              # when the loop began serving it
    done_s: float               # when service + submit finished
    latency_s: float            # done_s - arrival_s
    chunks: int                 # whole CHUNK_TOKENS chunks looked up
    hit_chunks: int             # of which already cached
    admitted: bool              # admission submit accepted
    retried: bool               # defer policy: submit retried after decode
    dropped: bool               # retry rejected too — admission forgone
    resumed_chunks: int = 0     # chunks restored from KV slabs (resume path)
    decoded: np.ndarray | None = None   # decode_fn's (B, T) greedy tokens


def run_request_loop(admit_q: AdmitQueue, requests, *, prefill_fn,
                     decode_fn=None, arrivals_s=None, now_fn=time.monotonic,
                     sleep_fn=time.sleep, retry_wait_s=0.05, on_batch=None):
    """THE serving request loop: lookup -> prefill -> submit -> decode.

    Parameters
    ----------
    admit_q : AdmitQueue
        Front end over the MonarchKVIndex; every index access goes
        through it (read-your-writes lookups, bounded-queue admission).
    requests : sequence of np.ndarray
        Token batches, one ``(B, S)`` int array per request batch.
    prefill_fn : callable
        ``prefill_fn(tokens, hits) -> state``: compute the batch's KV
        (the launcher's jitted prefill; the bench's service proxy).
        Called BEFORE the admission submit — chunks are offered as soon
        as their KV exists, the PR-4 submit-after-prefill hook.
    decode_fn : callable, optional
        ``decode_fn(tokens, state) -> decoded | None``: the decode loop,
        run after the submit so the admission worker overlaps it.  Its
        return value (the ``(B, decode_tokens)`` greedy token array, or
        ``None`` for decode-less stand-ins) is surfaced on the record as
        ``RequestRecord.decoded`` — the loop never discards output.
    arrivals_s : sequence of float, optional
        OPEN-LOOP arrival offsets (seconds from loop start), one per
        request, nondecreasing.  The loop sleeps until each scheduled
        arrival; when it is running behind, the request is served
        immediately but its latency still counts from the schedule.
        ``None`` = closed loop (next batch starts when the previous
        finished).
    now_fn, sleep_fn : callables
        Clock/sleep injection for tests.
    retry_wait_s : float
        Bounded drain-wait before the ONE defer retry: when the first
        submit is rejected (``policy="defer"``), the loop polls
        ``admit_q.pending()`` via ``sleep_fn`` for at most this long
        before retrying.  Without it, a decode-less caller (the bench's
        service-proxy path) retries immediately into the still-full
        queue and over-counts ``dropped``.  ``0`` restores the
        immediate retry.
    on_batch : callable, optional
        ``on_batch(i, tokens, hits, record)`` after each batch (the
        launcher prints its per-batch report here).

    Returns
    -------
    list[RequestRecord]

    Notes
    -----
    Back-pressure: ``admit_q.submit_tokens`` may reject under
    ``policy="defer"`` — the loop retries ONCE after the decode (the
    queue usually drained meanwhile); a rejected retry forgoes the
    admission (``dropped=True``) rather than stalling the serving path.
    ``policy="block"``/``"shed"`` never reject, so those records always
    carry ``admitted=True``.
    """
    t0 = now_fn()
    records: list[RequestRecord] = []
    for i, toks in enumerate(requests):
        if arrivals_s is not None:
            arrival = float(arrivals_s[i])
            wait = arrival - (now_fn() - t0)
            if wait > 0:
                sleep_fn(wait)
        start = now_fn() - t0
        if arrivals_s is None:
            arrival = start
        hits = admit_q.lookup(toks)
        state = prefill_fn(toks, hits)
        # Resume-aware prefills return a PrefillResult: its freshly
        # computed KV slabs are staged WITH the submit, so the async
        # admission commits slab and fingerprint together (lockstep).
        slabs = state.slabs if isinstance(state, PrefillResult) else None
        resumed = state.resumed_chunks if isinstance(state, PrefillResult) else 0
        # Only resume-aware prefills produce slabs; plain queues (and
        # stand-ins) keep the slab-less submit_tokens(tokens) signature.
        submit = (lambda: admit_q.submit_tokens(toks, slabs=slabs)) \
            if slabs is not None else (lambda: admit_q.submit_tokens(toks))
        accepted = submit()
        decoded = decode_fn(toks, state) if decode_fn is not None else None
        retried = dropped = False
        if not accepted:               # defer: retry once after decode
            retried = True
            # Bounded drain-wait before the single retry: give the
            # admission worker a window to drain below the bound (a
            # decode above usually provided one; a decode-less caller
            # would otherwise race the still-full queue).
            pending_fn = getattr(admit_q, "pending", None)
            if pending_fn is not None and retry_wait_s > 0:
                deadline = now_fn() + retry_wait_s
                while pending_fn() > 0 and now_fn() < deadline:
                    sleep_fn(retry_wait_s / 16)
            accepted = submit()
            dropped = not accepted
            if dropped and slabs:      # forgone admission: staged slabs
                store = admit_q.index.slab_store      # are garbage
                for fp in slabs:
                    store.discard(fp)
        done = now_fn() - t0
        rec = RequestRecord(
            arrival_s=arrival, start_s=start, done_s=done,
            latency_s=done - arrival,
            chunks=int(hits.size), hit_chunks=int(hits.sum()),
            admitted=bool(accepted), retried=retried, dropped=dropped,
            resumed_chunks=resumed, decoded=decoded)
        records.append(rec)
        if on_batch is not None:
            on_batch(i, toks, hits, rec)
    return records


def build_model_fns(params, cfg, *, max_seq, decode_tokens, index=None,
                    resume=False):
    """(prefill_fn, decode_fn, engine) for :func:`run_request_loop`.

    One construction shared by this launcher and the HTTP edge
    (``launch/httpd.py``).  With ``resume=True`` the pair comes from a
    :class:`PrefixResumeEngine` over ``index`` (which must carry a slab
    store); otherwise it is the plain jitted prefill/greedy-decode pair.
    Either way ``decode_fn`` RETURNS the ``(B, decode_tokens)`` greedy
    token array — the request loop surfaces it as
    ``RequestRecord.decoded`` (decoded output is never discarded).
    ``engine`` is ``None`` on the non-resume path."""
    if resume:
        engine = PrefixResumeEngine(params, cfg, max_seq=max_seq,
                                    index=index,
                                    decode_tokens=decode_tokens)
        prefill_fn, decode_fn = engine.request_fns()
        return prefill_fn, decode_fn, engine

    prefill_step = jax.jit(serve_step.make_prefill_step(cfg, max_seq))
    decode_step = jax.jit(serve_step.make_decode_step(cfg))

    def model_prefill(toks, hits):
        logits, cache = prefill_step(params, {"tokens": jnp.asarray(toks)})
        return logits, cache

    def model_decode(toks, state):
        logits, cache = state
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        outs = [np.asarray(nxt)]
        for t in range(decode_tokens - 1):
            pos = jnp.asarray(toks.shape[1] + t, jnp.int32)
            nxt, logits, cache = decode_step(params, cache, nxt, pos)
            outs.append(np.asarray(nxt))
        return np.concatenate(outs, axis=1)

    return model_prefill, model_decode, None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=sorted(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--seq-shard-kv", action="store_true",
                    help="§Perf: split-KV decode cache layout")
    ap.add_argument("--no-resume", action="store_true",
                    help="disable the prefix-cache DECODE resume path "
                         "(index still counts hits, but every request "
                         "recomputes its full prefill) — the no-cache "
                         "reference behavior")
    # §6.2 durability knobs: the index derives its t_MWW admission window
    # from the lifetime target via the same formula as core/wear.py.
    ap.add_argument("--lifetime-years", type=float, default=None,
                    help="target index lifetime (enables the derived t_MWW "
                         "admission window; default: fixed window_ops)")
    ap.add_argument("--endurance", type=float, default=1e8,
                    help="cell endurance for --lifetime-years")
    ap.add_argument("--m-writes", type=int, default=3,
                    help="per-way write budget per t_MWW window")
    ap.add_argument("--ops-per-sec", type=float, default=1e6,
                    help="expected index op rate (cycle proxy) for "
                         "--lifetime-years under --wear-clock ops")
    ap.add_argument("--wear-clock", default="ops", choices=["ops", "wall"],
                    help="t_MWW cycle domain: 'ops' counts index ops (the "
                         "historic proxy), 'wall' makes the admission "
                         "window a wall-clock time budget (no op-rate "
                         "estimate needed)")
    # Index scaling knobs.
    ap.add_argument("--n-shards", type=int, default=1,
                    help="set-axis shards for the Monarch index (must "
                         "divide its n_sets; shards map onto the "
                         '("sets",) device mesh in contiguous blocks; '
                         "lookup stays ONE dispatch at any shard count)")
    ap.add_argument("--sync-admit", action="store_true",
                    help="admit inline on the serving loop instead of "
                         "behind the async AdmitQueue")
    ap.add_argument("--max-pending", type=int, default=None,
                    help="bound on fingerprints pending admission; None "
                         "(default) keeps the queue unbounded")
    ap.add_argument("--admit-policy", default="block",
                    choices=["block", "shed", "defer"],
                    help="back-pressure when --max-pending is hit: block "
                         "the submit, shed the oldest pending batch, or "
                         "defer (reject; the loop retries after decode)")
    args = ap.parse_args(argv)

    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode service")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=(args.mesh == "multi")))

    rng = np.random.default_rng(0)
    max_seq = args.prompt_len + args.decode_tokens
    # Prefix-cache DECODE resume: on when the arch supports it (attention
    # only).  The resume index hashes with chained prefix fingerprints
    # and carries the KV slab store the engine restores from.
    resume = not args.no_resume and transformer.resume_supported(cfg)
    fp_scheme = "prefix" if resume else "block"
    if args.lifetime_years is not None:
        kv_cfg = KVIndexConfig.with_lifetime(
            t_life_years=args.lifetime_years, endurance=args.endurance,
            ops_per_second=args.ops_per_sec, m_writes=args.m_writes,
            clock=args.wear_clock, n_sets=8, n_shards=args.n_shards,
            fingerprint=fp_scheme)
        unit = "ops" if args.wear_clock == "ops" else "us of wall time"
        print(f"[serve] lifetime target {args.lifetime_years}y @ "
              f"{args.endurance:.0e} endurance -> t_MWW window = "
              f"{kv_cfg.window_ops} {unit}, M={kv_cfg.m_writes}")
    else:
        kv_cfg = KVIndexConfig(n_sets=8, m_writes=args.m_writes,
                               clock=args.wear_clock, n_shards=args.n_shards,
                               fingerprint=fp_scheme)
    idx = MonarchKVIndex(kv_cfg,
                         slab_store=KVSlabStore() if resume else None)
    if not resume and not args.no_resume:
        print(f"[serve] resume path off: {cfg.name} has recurrent layers "
              "(prefix hits counted, prefill not skipped)")
    if args.n_shards > 1:
        placement = ("co-located, 1 device (collapsed to the unsharded "
                     "single-launch path)" if idx.set_mesh is None
                     else f"{idx.set_mesh}, single shard_map dispatch "
                          f"over {idx.n_parts} partitions")
        print(f"[serve] index sharded over {args.n_shards} set shards "
              f"({idx.sets_per_shard} sets each; {placement})")
    admit_q = AdmitQueue(idx, background=not args.sync_admit,
                         max_pending=args.max_pending,
                         policy=args.admit_policy)

    with mesh:
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        p_named = sharding.to_named(
            sharding.param_specs(jax.eval_shape(lambda: params), mesh), mesh)
        params = jax.tree.map(jax.device_put, params, p_named)
        model_prefill, model_decode, engine = build_model_fns(
            params, cfg, max_seq=max_seq, decode_tokens=args.decode_tokens,
            index=idx, resume=resume)

        # shared prefix -> index hits after the first batch
        prefix = rng.integers(1, cfg.vocab_size,
                              args.prompt_len // 2).astype(np.int32)
        batches = []
        served = 0
        while served < args.requests:
            b = min(args.batch, args.requests - served)
            tails = rng.integers(
                1, cfg.vocab_size,
                (b, args.prompt_len - len(prefix))).astype(np.int32)
            batches.append(np.concatenate(
                [np.tile(prefix, (b, 1)), tails], axis=1))
            served += b
        # whole chunks of the shared prefix — 0 for short prompts, in
        # which case the per-batch report has no prefix column to average
        # (printing the empty-slice mean would be a NaN + RuntimeWarning)
        n_prefix_chunks = len(prefix) // CHUNK_TOKENS

        def report(i, toks, hits, rec):
            cached = (f"{hits[:, :n_prefix_chunks].mean():.0%}"
                      if n_prefix_chunks else "n/a")
            extra = (f", resumed {rec.resumed_chunks}/{rec.chunks} chunks"
                     if resume else "")
            # rec.decoded is the ACTUAL decode output (not the knob):
            # a decode path that stopped returning tokens shows up here.
            n_dec = (rec.decoded.shape[1] if rec.decoded is not None
                     else 0)
            print(f"[serve] batch of {toks.shape[0]}: prefix chunks cached "
                  f"{cached}{extra}, decoded {n_dec} tokens each")

        t0 = time.time()
        records = run_request_loop(admit_q, batches,
                                   prefill_fn=model_prefill,
                                   decode_fn=model_decode, on_batch=report)
        admit_q.close()                   # drain barrier before reporting
        dt = time.time() - t0
    s = idx.stats
    print(f"[serve] {served} requests in {dt:.1f}s; index hit rate "
          f"{idx.hit_rate:.1%}, {s.searches} CAM searches, "
          f"{s.admissions} admissions ({s.admit_calls} device calls), "
          f"{s.throttled} throttles")
    if resume:
        tot = engine.resumed_chunks + engine.computed_chunks
        print(f"[serve] resume: {engine.resumed_chunks}/{tot} prompt chunks "
              f"served from KV slabs "
              f"({idx.slab_store.resident_bytes / 1e6:.2f} MB resident)")
    aq = admit_q.stats
    print(f"[serve] admit queue: {aq.submitted} fps in {aq.batches} batches "
          f"({'inline' if args.sync_admit else 'async'}), "
          f"{aq.rww_flushes} read-your-writes flushes, "
          f"{aq.shed} batches shed, {aq.deferred} submits deferred")
    w = idx.wear_report()
    lt = idx.lifetime_estimate(endurance=args.endurance,
                               ops_per_second=args.ops_per_sec)
    print(f"[serve] wear: installs/set max {w['installs_per_set_max']:.0f} "
          f"(skew {w['skew_max_over_mean']:.2f}x mean), "
          f"{w['rotations']} rotations, "
          f"{w['throttled_sets_now']} sets at window budget; "
          f"projected lifetime {lt.years:.1f}y (ideal {lt.ideal_years:.1f}y)")
    return records


if __name__ == "__main__":
    main()
