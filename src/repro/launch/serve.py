"""Production serving launcher: batched prefill + decode with the
MonarchKVIndex prefix cache.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
        --requests 8 --decode-tokens 8 [--mesh host|single|multi]

The request loop is the same flow examples/serve_prefix_cache.py
demonstrates; this launcher adds mesh placement (params TP/FSDP-sharded,
cache sharded per ``cache_specs`` — ``--seq-shard-kv`` enables the §Perf
split-KV layout) and batch scheduling over a request queue.

Index scaling knobs (see docs/SERVING.md for the full operator guide):
``--n-shards`` splits the Monarch index's CAM sets across the
``("sets",)`` device mesh — lookups run as ONE ``shard_map`` dispatch
over the stacked layout and rotation stays device-resident (``ppermute``
boundary exchange); on a single-device host every shard co-locates and
the index collapses to the unsharded single-launch path.  Admissions run
behind an async ``AdmitQueue`` by default — installs overlap the decode
loop — with ``--sync-admit`` restoring the inline path.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer
from repro.serve import step as serve_step
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=sorted(configs.ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--seq-shard-kv", action="store_true",
                    help="§Perf: split-KV decode cache layout")
    # §6.2 durability knobs: the index derives its t_MWW admission window
    # from the lifetime target via the same formula as core/wear.py.
    ap.add_argument("--lifetime-years", type=float, default=None,
                    help="target index lifetime (enables the derived t_MWW "
                         "admission window; default: fixed window_ops)")
    ap.add_argument("--endurance", type=float, default=1e8,
                    help="cell endurance for --lifetime-years")
    ap.add_argument("--m-writes", type=int, default=3,
                    help="per-way write budget per t_MWW window")
    ap.add_argument("--ops-per-sec", type=float, default=1e6,
                    help="expected index op rate (cycle proxy) for "
                         "--lifetime-years")
    # Index scaling knobs.
    ap.add_argument("--n-shards", type=int, default=1,
                    help="set-axis shards for the Monarch index (must "
                         "divide its n_sets; shards map onto the "
                         '("sets",) device mesh in contiguous blocks; '
                         "lookup stays ONE dispatch at any shard count)")
    ap.add_argument("--sync-admit", action="store_true",
                    help="admit inline on the serving loop instead of "
                         "behind the async AdmitQueue")
    args = ap.parse_args(argv)

    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode service")
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=(args.mesh == "multi")))

    rng = np.random.default_rng(0)
    max_seq = args.prompt_len + args.decode_tokens
    if args.lifetime_years is not None:
        kv_cfg = KVIndexConfig.with_lifetime(
            t_life_years=args.lifetime_years, endurance=args.endurance,
            ops_per_second=args.ops_per_sec, m_writes=args.m_writes,
            n_sets=8, n_shards=args.n_shards)
        print(f"[serve] lifetime target {args.lifetime_years}y @ "
              f"{args.endurance:.0e} endurance -> t_MWW window = "
              f"{kv_cfg.window_ops} ops, M={kv_cfg.m_writes}")
    else:
        kv_cfg = KVIndexConfig(n_sets=8, m_writes=args.m_writes,
                               n_shards=args.n_shards)
    idx = MonarchKVIndex(kv_cfg)
    if args.n_shards > 1:
        placement = ("co-located, 1 device (collapsed to the unsharded "
                     "single-launch path)" if idx.set_mesh is None
                     else f"{idx.set_mesh}, single shard_map dispatch "
                          f"over {idx.n_parts} partitions")
        print(f"[serve] index sharded over {args.n_shards} set shards "
              f"({idx.sets_per_shard} sets each; {placement})")
    admit_q = AdmitQueue(idx, background=not args.sync_admit)

    with mesh:
        params = transformer.init_params(jax.random.PRNGKey(0), cfg)
        p_named = sharding.to_named(
            sharding.param_specs(jax.eval_shape(lambda: params), mesh), mesh)
        params = jax.tree.map(jax.device_put, params, p_named)
        prefill_fn = jax.jit(serve_step.make_prefill_step(cfg, max_seq))
        decode_fn = jax.jit(serve_step.make_decode_step(cfg))

        # shared prefix -> index hits after the first batch
        prefix = rng.integers(1, cfg.vocab_size,
                              args.prompt_len // 2).astype(np.int32)
        served = 0
        t0 = time.time()
        while served < args.requests:
            b = min(args.batch, args.requests - served)
            tails = rng.integers(
                1, cfg.vocab_size,
                (b, args.prompt_len - len(prefix))).astype(np.int32)
            toks = np.concatenate(
                [np.tile(prefix, (b, 1)), tails], axis=1)
            hits = admit_q.lookup(toks)   # read-your-writes via the queue
            logits, cache = prefill_fn(params, {"tokens": jnp.asarray(toks)})
            # Submit as soon as the prefill produced this batch's KV: the
            # worker drains the install while the decode loop runs, and
            # the queue is (usually) empty again before the next batch's
            # read-your-writes lookup.
            admit_q.submit_tokens(toks)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            outs = [np.asarray(nxt)]
            for t in range(args.decode_tokens - 1):
                pos = jnp.asarray(toks.shape[1] + t, jnp.int32)
                nxt, logits, cache = decode_fn(params, cache, nxt, pos)
                outs.append(np.asarray(nxt))
            served += b
            print(f"[serve] batch of {b}: prefix chunks cached "
                  f"{hits[:, :len(prefix) // CHUNK_TOKENS].mean():.0%}, "
                  f"decoded {args.decode_tokens} tokens each")
        admit_q.close()                   # drain barrier before reporting
        dt = time.time() - t0
    s = idx.stats
    print(f"[serve] {served} requests in {dt:.1f}s; index hit rate "
          f"{idx.hit_rate:.1%}, {s.searches} CAM searches, "
          f"{s.admissions} admissions ({s.admit_calls} device calls), "
          f"{s.throttled} throttles")
    aq = admit_q.stats
    print(f"[serve] admit queue: {aq.submitted} fps in {aq.batches} batches "
          f"({'inline' if args.sync_admit else 'async'}), "
          f"{aq.rww_flushes} read-your-writes flushes")
    w = idx.wear_report()
    lt = idx.lifetime_estimate(endurance=args.endurance,
                               ops_per_second=args.ops_per_sec)
    print(f"[serve] wear: installs/set max {w['installs_per_set_max']:.0f} "
          f"(skew {w['skew_max_over_mean']:.2f}x mean), "
          f"{w['rotations']} rotations, "
          f"{w['throttled_sets_now']} sets at window budget; "
          f"projected lifetime {lt.years:.1f}y (ideal {lt.ideal_years:.1f}y)")


if __name__ == "__main__":
    main()
