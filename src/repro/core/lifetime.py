"""Lifetime estimation from wear snapshots (paper §10.3, Fig. 11).

The paper's methodology: record per-row/column write counts at every
rotation, then model a constantly repeated execution of the application with
the rotary offset applied at each rotation; lifetime ends when any cell
exceeds its endurance.  We reproduce that as a CUMULATIVE-CROSSING replay:
accumulate the epoch's per-superset write counts under the rotating prime-
offset schedule until the hottest physical location crosses ``endurance``,
then convert crossing time to years.

Granularity note (recorded in EXPERIMENTS.md): our snapshots are per-
SUPERSET (the wear-leveling mechanism's own granularity); the paper's
snapshots additionally resolve within-superset rows/columns, whose residual
skew is why their Monarch lands at 61% of ideal.  At superset granularity a
covering prime schedule approaches ideal; the within-superset term is
bounded separately by ``intra_set_skew``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import geometry
from repro.core.timing import CPU_HZ, SECONDS_PER_YEAR, DEFAULT_ENDURANCE


@dataclasses.dataclass
class LifetimeResult:
    years: float
    ideal_years: float
    max_cell_writes_per_epoch: float
    epochs_to_death: float


def estimate_from_ops(
    writes_per_set: np.ndarray,
    ops_total: int,
    rotations: int,
    endurance: float = DEFAULT_ENDURANCE,
    ops_per_second: float = 1e6,
) -> "LifetimeResult":
    """Serving-side bridge: op-counter clock -> the Fig. 11 replay.

    The serving layers (MonarchKVIndex, HopscotchTable) count ops instead
    of cycles; this is the ONE conversion (ops / ops_per_second seconds,
    then CPU cycles) both use, so the cycle-proxy semantics cannot drift
    between them."""
    epoch_s = max(int(ops_total), 1) / ops_per_second
    return estimate_lifetime(
        np.asarray(writes_per_set, np.float64),
        epoch_cycles=epoch_s * CPU_HZ,
        rotations_per_epoch=int(rotations),
        endurance=endurance)


def _offsets_sequence(n_rotations: int) -> np.ndarray:
    """Cumulative combined offset (superset-granularity permutation shift)
    after each rotation, following the prime schedule of §8."""
    off = geometry.zero_offsets()
    shifts = np.zeros((n_rotations,), np.int64)
    for r in range(n_rotations):
        off = geometry.apply_rotate(off)
        shifts[r] = int(off.superset) + int(off.set_) + int(off.bank) + int(off.vault)
    return shifts


def estimate_lifetime(
    writes_per_superset: np.ndarray,
    epoch_cycles: float,
    rotations_per_epoch: int = 1,
    endurance: float = DEFAULT_ENDURANCE,
    writes_per_block_write: float = 1.0,
    intra_set_skew: float = 1.0,
) -> LifetimeResult:
    """Replay repeated execution with rotary remapping until the hottest
    physical superset crosses ``endurance``.

    writes_per_superset : logical write counts for one application epoch.
    epoch_cycles        : duration of that epoch in CPU cycles.
    rotations_per_epoch : rotate signals fired during the epoch (0 = the
                          offsets never move; wear stays concentrated).
    intra_set_skew      : hottest-cell/mean factor INSIDE a superset
                          (1.0 = even; replacement-counter placement keeps
                          it near 1; pass >1 to bound tag-row hotspots).

    A cell in a block sees ~1 programming pulse per block write (row write
    pulses its full row once); ``writes_per_block_write`` scales this.
    """
    w_even = np.asarray(writes_per_superset, np.float64) * writes_per_block_write
    # intra-set skew raises the hottest CELL's rate, not the ideal (which
    # assumes perfectly even distribution inside supersets too).
    w = w_even * intra_set_skew
    n = len(w)
    epoch_seconds = epoch_cycles / CPU_HZ
    total = float(w.sum())
    mean_per_epoch = float(w_even.sum()) / n

    def years_from_epochs(epochs: float) -> float:
        return epochs * epoch_seconds / SECONDS_PER_YEAR

    ideal_years = (years_from_epochs(endurance / mean_per_epoch)
                   if mean_per_epoch > 0 else float("inf"))

    if total <= 0:
        return LifetimeResult(float("inf"), ideal_years, 0.0, float("inf"))

    if rotations_per_epoch <= 0:
        # No rotation: wear concentrates on the static mapping forever.
        mx = float(w.max())
        return LifetimeResult(
            years=years_from_epochs(endurance / mx),
            ideal_years=ideal_years,
            max_cell_writes_per_epoch=mx,
            epochs_to_death=endurance / mx,
        )

    # Cumulative-crossing replay: one chunk = one rotation period.
    n_steps = rotations_per_epoch
    per_rotation = w / n_steps
    shifts = _offsets_sequence(max(16 * n, 4 * n_steps))
    phys = np.zeros(n, np.float64)
    idx = np.arange(n)
    steps_done = 0
    # Pre-rotation first period uses the identity mapping.
    schedule = np.concatenate([[0], shifts])
    while phys.max() < endurance and steps_done < len(schedule):
        s = schedule[steps_done % len(schedule)]
        phys[(idx + s) % n] += per_rotation
        steps_done += 1
    if phys.max() >= endurance:
        # Interpolate within the final step.
        over = phys.max() - endurance
        last = per_rotation.max() if per_rotation.max() > 0 else 1.0
        frac = min(over / last, 1.0)
        steps = steps_done - frac
    else:
        # Schedule exhausted without death: extrapolate from the (near-
        # steady-state) accumulated maximum.
        steps = steps_done * endurance / phys.max()
    epochs = steps / n_steps
    mx_epoch = float(w.max())
    return LifetimeResult(
        years=years_from_epochs(epochs),
        ideal_years=ideal_years,
        max_cell_writes_per_epoch=mx_epoch,
        epochs_to_death=epochs,
    )
