"""Trace-driven memory-hierarchy simulator (paper §9-§10 methodology).

Models the path  L3 -> in-package cache (Monarch or baseline) -> DDR4  for a
stream of memory requests, as a single ``jax.lax.scan`` over the trace.  The
goal is the paper's *relative* performance study (Fig. 9/10): the timing
parameters are taken verbatim from Table 3, the cache organizations from §7,
and the durability machinery (t_MWW superset locking, D/R install filter,
rotary wear leveling) from §8.

Performance model
-----------------
Open-loop with bounded memory-level parallelism: request *i* may not issue
until request *i - MLP* has completed (a ring buffer of completions models
the cores' outstanding-miss budget).  Each access seizes a bank chosen by
address; banks serialize (``bank_free`` vector), so write-latency asymmetry
(RRAM tWR=162 vs DRAM tWR=4) and bank-count asymmetry (Monarch 64
banks/vault vs DRAM 8) emerge naturally instead of being hard-coded.

DRAM row-buffer discipline: per-bank open-row registers; a row hit costs
tCAS+tBL, a conflict tRP+tRCD+tCAS+tBL and re-opens the row.  Refresh is
charged as a bandwidth tax (Table 3 fraction).  Monarch/CMOS need neither
(resistive/SRAM stacks are refresh-free; no row buffer).

Tag check:
* D-Cache / RC-Unbound: tags live with data (Loh-Hill style) — a lookup is
  a tag READ followed, on hit, by the data read in the same bank.
* Monarch: the lookup is one SEARCH command in the vault's CAM bank followed,
  on hit, by a data read in a (different) RAM bank — so tag and data accesses
  pipeline across banks.
* S-Cache: SRAM+SCAM search, same flow as Monarch with CMOS timing.

Capacity scaling: cache state arrays are scaled down by ``cfg.scale`` with
all capacity *ratios* preserved (8GB Monarch : 4GB DRAM : 73MB CMOS); traces
are generated against the scaled footprint.  Timing is never scaled.

Batched multi-config engine
---------------------------
Everything that distinguishes one ``SimConfig`` from another is split into
two layers:

* **SimShape** — array-shape-determining statics (set/way counts, bank
  counts).  One XLA compilation per distinct shape.
* **DynParams** — everything else (Table 3 timing scalars, policy flags,
  §8 wear knobs) as a pytree of traced scalars.  Former Python branches
  (``if cfg.search_tags`` ...) are computed on both sides and selected with
  ``jnp.where``, so two configs differing only in DynParams run through the
  *same* compiled scan.

``simulate_grid`` stacks DynParams for every config in a shape family and
runs the whole config x trace grid as ONE ``jax.vmap``-ed scan per family
(for the paper's C1-C8 sweep: the Monarch M-sweep, both DRAM caches, etc.
each collapse into a single vmapped call instead of a serial Python loop).
When the host exposes multiple JAX devices the grid axis is sharded across
them via ``launch/mesh.py``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller, wear
from repro.core.timing import TECH_TIMING, TABLE1, InterfaceTiming

MLP = 16            # outstanding-miss budget (8 cores x 2 threads, §9.1)
L3_LATENCY = 42     # cycles; identical across systems
CPU_GAP = 4         # non-memory work between misses reaching the L3


@dataclasses.dataclass(frozen=True)
class SimConfig:
    name: str
    tech: str                    # key into TECH_TIMING
    inpkg_sets: int
    inpkg_ways: int
    search_tags: bool            # True: CAM search; False: tag read
    l3_sets: int = 64
    l3_ways: int = 16
    # Monarch durability knobs.
    wear_enabled: bool = False
    m_writes: int = 3
    dr_filter: bool = False      # D/R-flag selective install (§8)
    no_allocate: bool = True     # miss fills go to L3 only (§8)
    t_mww_cycles: int = 1 << 22  # scaled window for simulation
    dc_limit: int = 256          # scaled dirty-counter limit
    window_budget_blocks: int = 0  # t_MWW budget blocks (0 = inpkg_ways);
    # scaled down with capacity so the constraint binds at sim horizon
    energy_tech: str = "2R XAM"  # Table 1 row for per-op energy

    @property
    def inpkg_blocks(self) -> int:
        return self.inpkg_sets * self.inpkg_ways

    @property
    def timing(self) -> InterfaceTiming:
        return TECH_TIMING[self.tech]


def baseline_configs(scale_blocks: int = 4096) -> dict[str, SimConfig]:
    """The paper's §10.2 systems.  ``scale_blocks`` = number of 64B blocks
    the (scaled) 4GB DRAM stack holds; every other capacity keeps the paper's
    ratio to it (Monarch/RRAM 2x, CMOS 73/4096x)."""
    dram_blocks = scale_blocks
    monarch_blocks = scale_blocks * 2
    cmos_blocks = max(64, int(scale_blocks * 73 / 4096))
    mk = lambda **kw: SimConfig(**kw)
    # Baselines are standard allocate-on-miss caches (paper's D-Cache [3]);
    # ONLY Monarch uses the §8 no-allocate + D/R selective-install policy.
    cfgs = {
        "d_cache": mk(name="d_cache", tech="dram",
                      inpkg_sets=dram_blocks // 16, inpkg_ways=16,
                      search_tags=False, no_allocate=False,
                      energy_tech="DRAM"),
        "d_cache_ideal": mk(name="d_cache_ideal", tech="dram_ideal",
                            inpkg_sets=dram_blocks // 16, inpkg_ways=16,
                            search_tags=False, no_allocate=False,
                            energy_tech="DRAM"),
        "s_cache": mk(name="s_cache", tech="cmos",
                      inpkg_sets=max(cmos_blocks // 16, 1), inpkg_ways=16,
                      search_tags=True, no_allocate=False,
                      energy_tech="SRAM+SCAM"),
        "rc_unbound": mk(name="rc_unbound", tech="rram_1r",
                         inpkg_sets=monarch_blocks // 16, inpkg_ways=16,
                         search_tags=False, no_allocate=False,
                         energy_tech="1R RAM"),
        "monarch_unbound": mk(name="monarch_unbound", tech="monarch",
                              inpkg_sets=monarch_blocks // 512, inpkg_ways=512,
                              search_tags=True, dr_filter=True,
                              energy_tech="2R XAM"),
    }
    for m in (1, 2, 3, 4):
        cfgs[f"monarch_m{m}"] = mk(
            name=f"monarch_m{m}", tech="monarch",
            inpkg_sets=monarch_blocks // 512, inpkg_ways=512,
            search_tags=True, wear_enabled=True, m_writes=m, dr_filter=True,
            energy_tech="2R XAM")
    return cfgs


# ---------------------------------------------------------------------------
# Static shape family vs dynamic per-config parameters.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SimShape:
    """Array-shape statics: configs sharing a SimShape compile once and can
    run through one vmapped scan."""
    l3_sets: int
    l3_ways: int
    inpkg_sets: int
    inpkg_ways: int
    n_banks: int        # in-package banks (vaults x banks/vault)


def shape_of(cfg: SimConfig) -> SimShape:
    t = cfg.timing
    return SimShape(
        l3_sets=cfg.l3_sets, l3_ways=cfg.l3_ways,
        inpkg_sets=cfg.inpkg_sets, inpkg_ways=cfg.inpkg_ways,
        n_banks=t.n_vaults * t.banks_per_vault,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DynTiming:
    """The Table 3 scalars the scan body reads, as traced values (the DDR4
    side stays a static ``InterfaceTiming`` — main memory is common to all
    configs).  ``_access`` accepts either representation."""
    tRCD: jnp.ndarray
    tCAS: jnp.ndarray
    tCCD: jnp.ndarray
    tWR: jnp.ndarray
    tBL: jnp.ndarray
    tCWD: jnp.ndarray
    tRP: jnp.ndarray
    tRC: jnp.ndarray
    needs_precharge: jnp.ndarray   # scalar bool


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DynParams:
    """Per-config dynamic parameters: one pytree leafset per grid lane."""
    timing: DynTiming
    search_tags: jnp.ndarray       # scalar bool
    allocate_on_miss: jnp.ndarray  # scalar bool (= not cfg.no_allocate)
    dr_filter: jnp.ndarray         # scalar bool
    wear_enabled: jnp.ndarray      # scalar bool
    wear: wear.WearDyn


def dyn_params(cfg: SimConfig) -> DynParams:
    t = cfg.timing
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    b = lambda v: jnp.asarray(v, bool)
    return DynParams(
        timing=DynTiming(
            tRCD=i32(t.tRCD), tCAS=i32(t.tCAS), tCCD=i32(t.tCCD),
            tWR=i32(t.tWR), tBL=i32(t.tBL), tCWD=i32(t.tCWD),
            tRP=i32(t.tRP), tRC=i32(t.tRC),
            needs_precharge=b(t.needs_precharge)),
        search_tags=b(cfg.search_tags),
        allocate_on_miss=b(not cfg.no_allocate),
        dr_filter=b(cfg.dr_filter),
        wear_enabled=b(cfg.wear_enabled),
        wear=wear.dyn_of(wear.WearConfig(
            n_supersets=cfg.inpkg_sets, m_writes=cfg.m_writes,
            dc_limit=cfg.dc_limit, t_mww_cycles=cfg.t_mww_cycles,
            blocks_per_superset=cfg.window_budget_blocks or cfg.inpkg_ways)),
    )


# ---------------------------------------------------------------------------
# Scan state.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    # L3 (functional, LRU) + per-line Dirty/Read flags for the §8 filter.
    l3_tags: jnp.ndarray     # (sets, ways) int64
    l3_valid: jnp.ndarray    # (sets, ways) int8
    l3_dirty: jnp.ndarray
    l3_read: jnp.ndarray
    l3_age: jnp.ndarray      # (sets, ways) int32
    # In-package cache.
    cache: controller.CacheState
    # Bank/row-buffer timing state.
    inpkg_bank_free: jnp.ndarray   # (n_banks,) int64
    inpkg_open_row: jnp.ndarray    # (n_banks,) int64 (-1 = closed)
    ddr_bank_free: jnp.ndarray     # (ddr_banks,) int64
    ddr_open_row: jnp.ndarray
    # MLP ring + clock.
    completions: jnp.ndarray       # (MLP,) int64
    arrival: jnp.ndarray           # scalar int64
    # Durability.
    wear: wear.WearState
    # Per-set install-write counts (lifetime estimation, Fig. 11).
    set_writes: jnp.ndarray        # (n_sets,) int32
    # Per-(set, way) install counts: within-superset wear skew (Fig. 11).
    set_way_writes: jnp.ndarray    # (n_sets, ways) int32
    # Stats.
    stats: jnp.ndarray             # (NSTATS,) int64


STAT_NAMES = [
    "l3_hits", "l3_misses", "inpkg_hits", "inpkg_misses", "inpkg_reads",
    "inpkg_writes", "inpkg_searches", "ddr_reads", "ddr_writes",
    "installs_skipped", "writes_filtered", "locked_bypass", "rotates",
    "flushed_dirty", "evict_writebacks", "l3_evictions",
]
NSTATS = len(STAT_NAMES)
SIDX = {n: i for i, n in enumerate(STAT_NAMES)}


def init_state(cfg: SimConfig | SimShape) -> SimState:
    shape = cfg if isinstance(cfg, SimShape) else shape_of(cfg)
    dt = TECH_TIMING["ddr4"]
    ddr_banks = dt.n_vaults * dt.banks_per_vault
    return SimState(
        l3_tags=jnp.zeros((shape.l3_sets, shape.l3_ways), jnp.int32),
        l3_valid=jnp.zeros((shape.l3_sets, shape.l3_ways), jnp.int8),
        l3_dirty=jnp.zeros((shape.l3_sets, shape.l3_ways), jnp.int8),
        l3_read=jnp.zeros((shape.l3_sets, shape.l3_ways), jnp.int8),
        l3_age=jnp.zeros((shape.l3_sets, shape.l3_ways), jnp.int32),
        cache=controller.init_cache(shape.inpkg_sets, shape.inpkg_ways),
        inpkg_bank_free=jnp.zeros((shape.n_banks,), jnp.int32),
        inpkg_open_row=-jnp.ones((shape.n_banks,), jnp.int32),
        ddr_bank_free=jnp.zeros((ddr_banks,), jnp.int32),
        ddr_open_row=-jnp.ones((ddr_banks,), jnp.int32),
        completions=jnp.zeros((MLP,), jnp.int32),
        arrival=jnp.zeros((), jnp.int32),
        wear=wear.init_state(wear.WearConfig(n_supersets=shape.inpkg_sets)),
        set_writes=jnp.zeros((shape.inpkg_sets,), jnp.int32),
        set_way_writes=jnp.zeros((shape.inpkg_sets, shape.inpkg_ways),
                                 jnp.int32),
        stats=jnp.zeros((NSTATS,), jnp.int32),
    )


# --------------------------- bank access helpers ---------------------------

def _access(bank_free, open_row, bank, row, when, t, is_write):
    """Seize ``bank`` at >= ``when``; returns (bank_free', open_row', done).

    ``t`` is either a static ``InterfaceTiming`` (DDR4 path) or a traced
    ``DynTiming``; both row-buffer disciplines are computed and selected on
    ``needs_precharge`` so the choice can be per-lane data under vmap.
    """
    start = jnp.maximum(when, bank_free[bank])
    row_hit = open_row[bank] == row
    lat_pre = jnp.where(row_hit, t.tCAS + t.tBL,
                        t.tRP + t.tRCD + t.tCAS + t.tBL)
    occ_pre = jnp.where(row_hit, t.tCCD, t.tRC)
    lat_nopre = jnp.asarray(t.tRCD + t.tCAS + t.tBL)
    occ_nopre = jnp.asarray(t.tCCD)
    pre = t.needs_precharge
    lat_r = jnp.where(pre, lat_pre, lat_nopre)
    occ_r = jnp.where(pre, occ_pre, occ_nopre)
    open_row = jnp.where(pre, open_row.at[bank].set(row), open_row)
    lat_w = t.tCWD + t.tWR + t.tBL
    occ_w = jnp.maximum(t.tCCD, t.tWR)
    lat = jnp.where(is_write, lat_w, lat_r).astype(jnp.int32)
    occ = jnp.where(is_write, occ_w, occ_r).astype(jnp.int32)
    done = start + lat
    bank_free = bank_free.at[bank].set(start + occ)
    return bank_free, open_row, done


# ------------------------------- step fn -----------------------------------

def make_step(shape: SimShape, dyn: DynParams, wear_on: bool = True):
    """Build the scan body.  ``shape`` is static (array sizes); every other
    per-config parameter comes in through ``dyn`` as traced scalars, so the
    same compiled step serves a whole stacked family of configs.

    ``wear_on`` is a static escape hatch: when the caller knows NO config in
    the batch has wear enabled (e.g. the DRAM-cache family), the §8 wear
    accounting and the O(sets x ways) rotation-flush computation are elided
    from the compiled step instead of computed-and-discarded per request."""
    t = dyn.timing
    dt = TECH_TIMING["ddr4"]
    n_banks = shape.n_banks
    ddr_banks = dt.n_vaults * dt.banks_per_vault
    wdyn = dyn.wear

    def bump(stats, name, amount=1):
        return stats.at[SIDX[name]].add(amount)

    def step(state: SimState, req):
        addr, is_write = req["addr"].astype(jnp.int32), req["is_write"]
        stats = state.stats

        # ---- issue gating: bounded MLP ---------------------------------
        slot = state.stats[SIDX["l3_misses"]] % MLP  # reuse miss count as idx
        arrival = jnp.maximum(state.arrival + CPU_GAP,
                              state.completions[slot.astype(jnp.int32)])

        # ---- L3 ---------------------------------------------------------
        l3_set = (addr % shape.l3_sets).astype(jnp.int32)
        l3_tag = addr // shape.l3_sets
        line = (state.l3_tags[l3_set] == l3_tag) & (state.l3_valid[l3_set] == 1)
        l3_hit = jnp.any(line)
        l3_way = jnp.argmax(line).astype(jnp.int32)

        # LRU bookkeeping.
        age = state.l3_age.at[l3_set].add(1)
        victim = jnp.argmax(jnp.where(state.l3_valid[l3_set] == 1,
                                      age[l3_set],
                                      jnp.iinfo(jnp.int32).max)).astype(jnp.int32)
        way = jnp.where(l3_hit, l3_way, victim)
        ev_valid = (~l3_hit) & (state.l3_valid[l3_set, way] == 1)
        ev_tag = state.l3_tags[l3_set, way]
        ev_dirty = state.l3_dirty[l3_set, way] == 1
        ev_read = state.l3_read[l3_set, way] == 1
        ev_addr = ev_tag * shape.l3_sets + l3_set

        l3_tags = state.l3_tags.at[l3_set, way].set(l3_tag)
        l3_valid = state.l3_valid.at[l3_set, way].set(1)
        l3_dirty = state.l3_dirty.at[l3_set, way].set(
            jnp.where(l3_hit, state.l3_dirty[l3_set, way] | is_write.astype(jnp.int8),
                      is_write.astype(jnp.int8)))
        # R flag = read AFTER installation (§8): the installing access itself
        # does not count, so a fill starts with R=0; later read hits set it.
        l3_read = state.l3_read.at[l3_set, way].set(
            jnp.where(l3_hit,
                      state.l3_read[l3_set, way] | (~is_write).astype(jnp.int8),
                      jnp.int8(0)))
        age = age.at[l3_set, way].set(0)

        stats = bump(stats, "l3_hits", l3_hit.astype(jnp.int32))
        stats = bump(stats, "l3_misses", (~l3_hit).astype(jnp.int32))
        stats = bump(stats, "l3_evictions", ev_valid.astype(jnp.int32))

        # =================================================================
        # MISS PATH — in-package lookup.  Everything below is predicated on
        # ~l3_hit (charged times multiplied to zero on hits).
        # =================================================================
        miss = ~l3_hit
        set_id_log = (addr % shape.inpkg_sets).astype(jnp.int32)
        # Rotary offset remap (wear leveling): logical set -> physical set.
        off = (state.wear.offsets.superset + state.wear.offsets.set_ +
               state.wear.offsets.bank + state.wear.offsets.vault)
        set_id = ((set_id_log + off) % shape.inpkg_sets).astype(jnp.int32)
        tag = addr // shape.inpkg_sets
        hit, hway = controller.cache_lookup(state.cache, set_id, tag)
        hit = hit & miss

        locked = wear.is_locked(state.wear, set_id, arrival) & dyn.wear_enabled
        hit = hit & ~locked  # locked superset: bypass to main memory
        stats = bump(stats, "locked_bypass", (miss & locked).astype(jnp.int32))

        # Bank mapping: CAM lookup bank and RAM data bank (different banks,
        # §7 decoupled tags/data) vs single-bank tag+data for DRAM-style.
        cam_bank = (set_id % max(n_banks // 8, 1)).astype(jnp.int32)
        ram_bank = ((addr // shape.inpkg_sets + set_id) % n_banks).astype(jnp.int32)
        inpkg_row = (addr // (shape.inpkg_sets * 8)) % 1024

        bank_free, open_row = state.inpkg_bank_free, state.inpkg_open_row

        # Tag check: both flavors are computed from the same pre-access
        # state and selected on dyn.search_tags.
        # (a) SEARCH in CAM bank: occupancy tCCD, latency tRCD+tCAS+tBL.
        s_start = jnp.maximum(arrival, bank_free[cam_bank])
        s_done = s_start + (t.tRCD + t.tCAS + t.tBL)
        bf_search = bank_free.at[cam_bank].set(
            jnp.where(miss, s_start + t.tCCD, bank_free[cam_bank]))
        # (b) Tag READ in the data bank (Loh-Hill compound access).
        bf_tr, or_tr, tag_done_r = _access(bank_free, open_row, ram_bank,
                                           inpkg_row, arrival, t, False)
        bf_tag = jnp.where(miss, bf_tr, bank_free)
        or_tag = jnp.where(miss, or_tr, open_row)

        bank_free = jnp.where(dyn.search_tags, bf_search, bf_tag)
        open_row = jnp.where(dyn.search_tags, open_row, or_tag)
        tag_done = jnp.where(miss,
                             jnp.where(dyn.search_tags, s_done, tag_done_r),
                             arrival)
        stats = bump(stats, "inpkg_searches",
                     (miss & dyn.search_tags).astype(jnp.int32))
        stats = bump(stats, "inpkg_reads",
                     (miss & ~dyn.search_tags).astype(jnp.int32))

        # Data read on hit.
        bf3, or3, data_done = _access(bank_free, open_row, ram_bank,
                                      inpkg_row, tag_done, t, False)
        bank_free = jnp.where(hit, bf3, bank_free)
        open_row = jnp.where(hit, or3, open_row)
        stats = bump(stats, "inpkg_hits", hit.astype(jnp.int32))
        stats = bump(stats, "inpkg_reads", hit.astype(jnp.int32))

        # DDR access on in-package miss.
        inpkg_miss = miss & ~hit
        stats = bump(stats, "inpkg_misses", inpkg_miss.astype(jnp.int32))
        ddr_bank = (addr % ddr_banks).astype(jnp.int32)
        ddr_row = (addr // ddr_banks) % 65536
        dbf, dor, ddr_done = _access(state.ddr_bank_free, state.ddr_open_row,
                                     ddr_bank, ddr_row, tag_done, dt, False)
        ddr_bank_free = jnp.where(inpkg_miss, dbf, state.ddr_bank_free)
        ddr_open_row = jnp.where(inpkg_miss, dor, state.ddr_open_row)
        stats = bump(stats, "ddr_reads", inpkg_miss.astype(jnp.int32))

        completion = jnp.where(
            l3_hit, arrival + L3_LATENCY,
            jnp.where(hit, data_done, ddr_done) + L3_LATENCY)

        # ---- fill policy -------------------------------------------------
        # no-allocate: in-package miss fills only L3 (already done above).
        # The legacy allocate-on-miss path (baselines) installs now.
        cache = state.cache
        wstate = state.wear
        do_install_miss = inpkg_miss & dyn.allocate_on_miss

        # ---- L3 eviction handling (install / forward / drop, §8) ---------
        inst_dr, fwd_dr = wear.install_decision(ev_dirty, ev_read)
        # plain writeback cache (no D/R filter): dirty evictions update the
        # in-package copy; clean evictions are dropped (fills on miss).
        inst = jnp.where(dyn.dr_filter, inst_dr, ev_dirty)
        fwd = jnp.where(dyn.dr_filter, fwd_dr, False)
        ev_install = ev_valid & inst & ~locked
        ev_forward = ev_valid & (fwd | locked) & ev_dirty
        # Write traffic removed from the in-package memory by the D/R rules:
        # D&R̄ (forwarded to DRAM) + D̄&R̄ (dropped) — every eviction NOT
        # installed is one avoided XAM write (paper: ~31% reduction).
        stats = bump(stats, "writes_filtered",
                     (ev_valid & ~inst).astype(jnp.int32))

        ev_set_log = (ev_addr % shape.inpkg_sets).astype(jnp.int32)
        ev_set = ((ev_set_log + off) % shape.inpkg_sets).astype(jnp.int32)
        ev_tag_c = ev_addr // shape.inpkg_sets
        # Install into in-package cache (a XAM/DRAM write).
        install_any = ev_install | do_install_miss
        inst_set = jnp.where(ev_install, ev_set, set_id)
        inst_tag = jnp.where(ev_install, ev_tag_c, tag)
        inst_dirty = jnp.where(ev_install, ev_dirty, is_write)
        cache2, evicted_dirty, inst_way = controller.cache_install(
            cache, inst_set, inst_tag, inst_dirty)
        cache = jax.tree.map(
            lambda a, b: jnp.where(install_any, b, a), cache, cache2)
        stats = bump(stats, "inpkg_writes", install_any.astype(jnp.int32))
        stats = bump(stats, "evict_writebacks",
                     (install_any & evicted_dirty).astype(jnp.int32))

        # Charge the write on the RAM bank (occupancy tWR — the RRAM pain).
        w_bank = ((inst_tag + inst_set) % n_banks).astype(jnp.int32)
        w_start = jnp.maximum(arrival, bank_free[w_bank])
        w_occ = jnp.maximum(t.tCCD, t.tWR).astype(jnp.int32)
        bank_free = bank_free.at[w_bank].set(
            jnp.where(install_any, w_start + w_occ, bank_free[w_bank]))

        # Forwarded dirty evictions + in-package dirty evictions go to DDR4.
        ddr_w = ev_forward | (install_any & evicted_dirty)
        dwb = ((ev_addr) % ddr_banks).astype(jnp.int32)
        dw_start = jnp.maximum(arrival, ddr_bank_free[dwb])
        ddr_bank_free = ddr_bank_free.at[dwb].set(
            jnp.where(ddr_w, dw_start + max(dt.tCCD, dt.tWR), ddr_bank_free[dwb]))
        stats = bump(stats, "ddr_writes", ddr_w.astype(jnp.int32))

        # ---- wear accounting + rotation ----------------------------------
        # Computed for every lane, applied only when (install_any &
        # wear_enabled) — matching the former Python-level branch; a
        # statically wear-free batch skips the whole block.
        if wear_on:
            wstate2, rotated, flushed = wear.record_write(
                wstate, wdyn, inst_set, inst_dirty, arrival)
            wear_apply = install_any & dyn.wear_enabled
            wstate = jax.tree.map(
                lambda a, b: jnp.where(wear_apply, b, a), wstate, wstate2)
            rot_now = wear_apply & rotated
            # On rotation: invalidate dirty sets (flush); charge writebacks.
            set_mask = controller.dirty_set_mask(state.cache)
            cache3, n_flush = controller.cache_invalidate_sets(cache, set_mask)
            cache = jax.tree.map(
                lambda a, b: jnp.where(rot_now, b, a), cache, cache3)
            stats = bump(stats, "rotates", rot_now.astype(jnp.int32))
            stats = bump(stats, "flushed_dirty",
                         jnp.where(rot_now, n_flush, 0).astype(jnp.int32))

        set_writes = state.set_writes.at[inst_set].add(
            install_any.astype(jnp.int32))
        set_way_writes = state.set_way_writes.at[inst_set, inst_way].add(
            install_any.astype(jnp.int32))

        # ---- retire -------------------------------------------------------
        completions = state.completions.at[slot.astype(jnp.int32)].set(
            jnp.where(miss, completion, state.completions[slot.astype(jnp.int32)]))

        new = SimState(
            l3_tags=l3_tags, l3_valid=l3_valid, l3_dirty=l3_dirty,
            l3_read=l3_read, l3_age=age,
            cache=cache,
            inpkg_bank_free=bank_free, inpkg_open_row=open_row,
            ddr_bank_free=ddr_bank_free, ddr_open_row=ddr_open_row,
            completions=completions,
            arrival=jnp.maximum(arrival, state.arrival),
            wear=wstate, set_writes=set_writes,
            set_way_writes=set_way_writes, stats=stats,
        )
        return new, completion

    return step


def _scan(shape: SimShape, wear_on: bool, dyn: DynParams, addrs, is_write):
    state = init_state(shape)
    step = make_step(shape, dyn, wear_on)
    return jax.lax.scan(step, state, {"addr": addrs, "is_write": is_write})


@partial(jax.jit, static_argnames=("shape", "wear_on"))
def _run_dyn(shape: SimShape, wear_on: bool, dyn: DynParams, addrs, is_write):
    return _scan(shape, wear_on, dyn, addrs, is_write)


@partial(jax.jit, static_argnames=("shape", "wear_on"))
def _run_grid(shape: SimShape, wear_on: bool, dyn_stack: DynParams,
              addrs, is_write):
    """One vmapped scan over a whole (config x trace) grid: ``dyn_stack``
    leaves and the trace arrays all carry a leading grid axis."""
    return jax.vmap(partial(_scan, shape, wear_on))(dyn_stack, addrs, is_write)


@dataclasses.dataclass
class SimResult:
    name: str
    total_cycles: float
    stats: dict[str, int]
    energy_nj: float

    @property
    def inpkg_hit_rate(self) -> float:
        h, m = self.stats["inpkg_hits"], self.stats["inpkg_misses"]
        return h / max(h + m, 1)


def _finish(cfg: SimConfig, max_completion, stats_row) -> SimResult:
    """Shared post-processing: refresh bandwidth tax + Table 1 energy."""
    total = float(max_completion)
    total *= 1.0 / (1.0 - cfg.timing.refresh_overhead)
    stats = {n: int(stats_row[i]) for i, n in enumerate(STAT_NAMES)}
    e = TABLE1[cfg.energy_tech]
    ddr_e = TABLE1["DRAM"]
    energy = (
        stats["inpkg_reads"] * e.read_nj
        + stats["inpkg_writes"] * e.write_nj
        + stats["inpkg_searches"] * e.search_nj
        + (stats["ddr_reads"] * ddr_e.read_nj + stats["ddr_writes"] * ddr_e.write_nj) * 4.0
    )
    # DRAM static/refresh energy tax (per §10.2's energy trends).
    if cfg.timing.needs_refresh:
        energy *= 1.30
    return SimResult(cfg.name, total, stats, energy)


def simulate_trace(cfg: SimConfig, addrs, is_write,
                   return_state: bool = False):
    addrs = jnp.asarray(addrs, jnp.int32)
    is_write = jnp.asarray(is_write, bool)
    final, completions = _run_dyn(shape_of(cfg), cfg.wear_enabled,
                                  dyn_params(cfg), addrs, is_write)
    result = _finish(cfg, jnp.max(completions), final.stats)
    if return_state:
        return result, final
    return result


# ---------------------------------------------------------------------------
# Batched multi-config grid.
# ---------------------------------------------------------------------------

def _shard_grid(tree, grid_size: int):
    """Shard the leading grid axis across this host's JAX devices (no-op on
    a single device or when the grid does not divide)."""
    from repro.launch import mesh as mesh_mod
    mesh = mesh_mod.make_grid_mesh(grid_size)
    if mesh is None:
        return tree
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.device_put(tree, NamedSharding(mesh, P("grid")))


def simulate_grid(cfgs, trace_list, *, return_state: bool = False,
                  shard: bool = True):
    """Run every (config, trace) pair through vmapped scans.

    ``cfgs``: dict name -> SimConfig, or an iterable of SimConfigs (their
    ``.name`` is used).  ``trace_list``: iterable of (name, addrs, is_write);
    all traces must share one length.  Configs are grouped into shape
    families (identical array shapes); each family's whole config x trace
    sub-grid runs as ONE vmapped ``lax.scan`` — no per-config Python loop.

    Returns dict[(cfg_name, trace_name)] -> SimResult, plus a dict of final
    SimStates (same keys) when ``return_state``.
    """
    named = list(cfgs.items()) if isinstance(cfgs, dict) \
        else [(c.name, c) for c in cfgs]
    tr = [(n, jnp.asarray(a, jnp.int32), jnp.asarray(w, bool))
          for n, a, w in trace_list]
    if not named or not tr:
        return ({}, {}) if return_state else {}
    n_req = int(tr[0][1].shape[0])
    for n, a, _ in tr:
        if int(a.shape[0]) != n_req:
            raise ValueError(f"trace {n!r} length {a.shape[0]} != {n_req}; "
                             "grid traces must share one length")
    addrs_all = jnp.stack([a for _, a, _ in tr])      # (n_traces, T)
    wr_all = jnp.stack([w for _, _, w in tr])
    n_traces = len(tr)

    families: dict[SimShape, list[tuple[str, SimConfig]]] = {}
    for cname, cfg in named:
        families.setdefault(shape_of(cfg), []).append((cname, cfg))

    results: dict[tuple[str, str], SimResult] = {}
    states: dict[tuple[str, str], SimState] = {}
    for shape, fam in families.items():
        # Grid layout is config-major: lane i*n_traces + j = (cfg i, trace j).
        dyn_stack = jax.tree.map(lambda *xs: jnp.stack(xs),
                                 *[dyn_params(cfg) for _, cfg in fam])
        dyn_stack = jax.tree.map(
            lambda x: jnp.repeat(x, n_traces, axis=0), dyn_stack)
        a_g = jnp.tile(addrs_all, (len(fam), 1))
        w_g = jnp.tile(wr_all, (len(fam), 1))
        if shard:
            dyn_stack, a_g, w_g = _shard_grid(
                (dyn_stack, a_g, w_g), len(fam) * n_traces)
        wear_on = any(cfg.wear_enabled for _, cfg in fam)
        finals, completions = _run_grid(shape, wear_on, dyn_stack, a_g, w_g)
        max_comp = np.asarray(jnp.max(completions, axis=1))
        stats_np = np.asarray(finals.stats)
        for i, (cname, cfg) in enumerate(fam):
            for j, (tname, _, _) in enumerate(tr):
                g = i * n_traces + j
                results[(cname, tname)] = _finish(cfg, max_comp[g],
                                                  stats_np[g])
                if return_state:
                    states[(cname, tname)] = jax.tree.map(
                        lambda x: x[g], finals)
    if return_state:
        return results, states
    return results


def n_shape_families(cfgs) -> int:
    """How many compiled scans a ``simulate_grid`` over ``cfgs`` needs."""
    named = cfgs.values() if isinstance(cfgs, dict) else cfgs
    return len({shape_of(c) for c in named})
