"""Trace-driven memory-hierarchy simulator (paper §9-§10 methodology).

Models the path  L3 -> in-package cache (Monarch or baseline) -> DDR4  for a
stream of memory requests, as a single ``jax.lax.scan`` over the trace.  The
goal is the paper's *relative* performance study (Fig. 9/10): the timing
parameters are taken verbatim from Table 3, the cache organizations from §7,
and the durability machinery (t_MWW superset locking, D/R install filter,
rotary wear leveling) from §8.

Performance model
-----------------
Open-loop with bounded memory-level parallelism: request *i* may not issue
until request *i - MLP* has completed (a ring buffer of completions models
the cores' outstanding-miss budget).  Each access seizes a bank chosen by
address; banks serialize (``bank_free`` vector), so write-latency asymmetry
(RRAM tWR=162 vs DRAM tWR=4) and bank-count asymmetry (Monarch 64
banks/vault vs DRAM 8) emerge naturally instead of being hard-coded.

DRAM row-buffer discipline: per-bank open-row registers; a row hit costs
tCAS+tBL, a conflict tRP+tRCD+tCAS+tBL and re-opens the row.  Refresh is
charged as a bandwidth tax (Table 3 fraction).  Monarch/CMOS need neither
(resistive/SRAM stacks are refresh-free; no row buffer).

Tag check:
* D-Cache / RC-Unbound: tags live with data (Loh-Hill style) — a lookup is
  a tag READ followed, on hit, by the data read in the same bank.
* Monarch: the lookup is one SEARCH command in the vault's CAM bank followed,
  on hit, by a data read in a (different) RAM bank — so tag and data accesses
  pipeline across banks.
* S-Cache: SRAM+SCAM search, same flow as Monarch with CMOS timing.

Capacity scaling: cache state arrays are scaled down by ``cfg.scale`` with
all capacity *ratios* preserved (8GB Monarch : 4GB DRAM : 73MB CMOS); traces
are generated against the scaled footprint.  Timing is never scaled.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import controller, wear
from repro.core.timing import TECH_TIMING, TABLE1, InterfaceTiming

MLP = 16            # outstanding-miss budget (8 cores x 2 threads, §9.1)
L3_LATENCY = 42     # cycles; identical across systems
CPU_GAP = 4         # non-memory work between misses reaching the L3


@dataclasses.dataclass(frozen=True)
class SimConfig:
    name: str
    tech: str                    # key into TECH_TIMING
    inpkg_sets: int
    inpkg_ways: int
    search_tags: bool            # True: CAM search; False: tag read
    l3_sets: int = 64
    l3_ways: int = 16
    # Monarch durability knobs.
    wear_enabled: bool = False
    m_writes: int = 3
    dr_filter: bool = False      # D/R-flag selective install (§8)
    no_allocate: bool = True     # miss fills go to L3 only (§8)
    t_mww_cycles: int = 1 << 22  # scaled window for simulation
    dc_limit: int = 256          # scaled dirty-counter limit
    window_budget_blocks: int = 0  # t_MWW budget blocks (0 = inpkg_ways);
    # scaled down with capacity so the constraint binds at sim horizon
    energy_tech: str = "2R XAM"  # Table 1 row for per-op energy

    @property
    def inpkg_blocks(self) -> int:
        return self.inpkg_sets * self.inpkg_ways

    @property
    def timing(self) -> InterfaceTiming:
        return TECH_TIMING[self.tech]


def baseline_configs(scale_blocks: int = 4096) -> dict[str, SimConfig]:
    """The paper's §10.2 systems.  ``scale_blocks`` = number of 64B blocks
    the (scaled) 4GB DRAM stack holds; every other capacity keeps the paper's
    ratio to it (Monarch/RRAM 2x, CMOS 73/4096x)."""
    dram_blocks = scale_blocks
    monarch_blocks = scale_blocks * 2
    cmos_blocks = max(64, int(scale_blocks * 73 / 4096))
    mk = lambda **kw: SimConfig(**kw)
    # Baselines are standard allocate-on-miss caches (paper's D-Cache [3]);
    # ONLY Monarch uses the §8 no-allocate + D/R selective-install policy.
    cfgs = {
        "d_cache": mk(name="d_cache", tech="dram",
                      inpkg_sets=dram_blocks // 16, inpkg_ways=16,
                      search_tags=False, no_allocate=False,
                      energy_tech="DRAM"),
        "d_cache_ideal": mk(name="d_cache_ideal", tech="dram_ideal",
                            inpkg_sets=dram_blocks // 16, inpkg_ways=16,
                            search_tags=False, no_allocate=False,
                            energy_tech="DRAM"),
        "s_cache": mk(name="s_cache", tech="cmos",
                      inpkg_sets=max(cmos_blocks // 16, 1), inpkg_ways=16,
                      search_tags=True, no_allocate=False,
                      energy_tech="SRAM+SCAM"),
        "rc_unbound": mk(name="rc_unbound", tech="rram_1r",
                         inpkg_sets=monarch_blocks // 16, inpkg_ways=16,
                         search_tags=False, no_allocate=False,
                         energy_tech="1R RAM"),
        "monarch_unbound": mk(name="monarch_unbound", tech="monarch",
                              inpkg_sets=monarch_blocks // 512, inpkg_ways=512,
                              search_tags=True, dr_filter=True,
                              energy_tech="2R XAM"),
    }
    for m in (1, 2, 3, 4):
        cfgs[f"monarch_m{m}"] = mk(
            name=f"monarch_m{m}", tech="monarch",
            inpkg_sets=monarch_blocks // 512, inpkg_ways=512,
            search_tags=True, wear_enabled=True, m_writes=m, dr_filter=True,
            energy_tech="2R XAM")
    return cfgs


# ---------------------------------------------------------------------------
# Scan state.
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimState:
    # L3 (functional, LRU) + per-line Dirty/Read flags for the §8 filter.
    l3_tags: jnp.ndarray     # (sets, ways) int64
    l3_valid: jnp.ndarray    # (sets, ways) int8
    l3_dirty: jnp.ndarray
    l3_read: jnp.ndarray
    l3_age: jnp.ndarray      # (sets, ways) int32
    # In-package cache.
    cache: controller.CacheState
    # Bank/row-buffer timing state.
    inpkg_bank_free: jnp.ndarray   # (n_banks,) int64
    inpkg_open_row: jnp.ndarray    # (n_banks,) int64 (-1 = closed)
    ddr_bank_free: jnp.ndarray     # (ddr_banks,) int64
    ddr_open_row: jnp.ndarray
    # MLP ring + clock.
    completions: jnp.ndarray       # (MLP,) int64
    arrival: jnp.ndarray           # scalar int64
    # Durability.
    wear: wear.WearState
    # Per-set install-write counts (lifetime estimation, Fig. 11).
    set_writes: jnp.ndarray        # (n_sets,) int32
    # Per-(set, way) install counts: within-superset wear skew (Fig. 11).
    set_way_writes: jnp.ndarray    # (n_sets, ways) int32
    # Stats.
    stats: jnp.ndarray             # (NSTATS,) int64


STAT_NAMES = [
    "l3_hits", "l3_misses", "inpkg_hits", "inpkg_misses", "inpkg_reads",
    "inpkg_writes", "inpkg_searches", "ddr_reads", "ddr_writes",
    "installs_skipped", "writes_filtered", "locked_bypass", "rotates",
    "flushed_dirty", "evict_writebacks", "l3_evictions",
]
NSTATS = len(STAT_NAMES)
SIDX = {n: i for i, n in enumerate(STAT_NAMES)}


def init_state(cfg: SimConfig) -> SimState:
    t = cfg.timing
    n_banks = t.n_vaults * t.banks_per_vault
    dt = TECH_TIMING["ddr4"]
    ddr_banks = dt.n_vaults * dt.banks_per_vault
    return SimState(
        l3_tags=jnp.zeros((cfg.l3_sets, cfg.l3_ways), jnp.int32),
        l3_valid=jnp.zeros((cfg.l3_sets, cfg.l3_ways), jnp.int8),
        l3_dirty=jnp.zeros((cfg.l3_sets, cfg.l3_ways), jnp.int8),
        l3_read=jnp.zeros((cfg.l3_sets, cfg.l3_ways), jnp.int8),
        l3_age=jnp.zeros((cfg.l3_sets, cfg.l3_ways), jnp.int32),
        cache=controller.init_cache(cfg.inpkg_sets, cfg.inpkg_ways),
        inpkg_bank_free=jnp.zeros((n_banks,), jnp.int32),
        inpkg_open_row=-jnp.ones((n_banks,), jnp.int32),
        ddr_bank_free=jnp.zeros((ddr_banks,), jnp.int32),
        ddr_open_row=-jnp.ones((ddr_banks,), jnp.int32),
        completions=jnp.zeros((MLP,), jnp.int32),
        arrival=jnp.zeros((), jnp.int32),
        wear=wear.init_state(wear.WearConfig(
            n_supersets=cfg.inpkg_sets, m_writes=cfg.m_writes,
            dc_limit=cfg.dc_limit, t_mww_cycles=cfg.t_mww_cycles)),
        set_writes=jnp.zeros((cfg.inpkg_sets,), jnp.int32),
        set_way_writes=jnp.zeros((cfg.inpkg_sets, cfg.inpkg_ways), jnp.int32),
        stats=jnp.zeros((NSTATS,), jnp.int32),
    )


# --------------------------- bank access helpers ---------------------------

def _access(bank_free, open_row, bank, row, when, t: InterfaceTiming,
            is_write: bool):
    """Seize ``bank`` at >= ``when``; returns (bank_free', open_row', done)."""
    start = jnp.maximum(when, bank_free[bank])
    if t.needs_precharge:
        row_hit = open_row[bank] == row
        lat_r = jnp.where(row_hit, t.tCAS + t.tBL,
                          t.tRP + t.tRCD + t.tCAS + t.tBL)
        occ_r = jnp.where(row_hit, t.tCCD, t.tRC)
        open_row = open_row.at[bank].set(row)
    else:
        lat_r = jnp.asarray(t.tRCD + t.tCAS + t.tBL)
        occ_r = jnp.asarray(t.tCCD)
    lat_w = t.tCWD + t.tWR + t.tBL
    occ_w = max(t.tCCD, t.tWR)
    lat = jnp.where(is_write, lat_w, lat_r).astype(jnp.int32)
    occ = jnp.where(is_write, occ_w, occ_r).astype(jnp.int32)
    done = start + lat
    bank_free = bank_free.at[bank].set(start + occ)
    return bank_free, open_row, done


# ------------------------------- step fn -----------------------------------

def make_step(cfg: SimConfig):
    t = cfg.timing
    dt = TECH_TIMING["ddr4"]
    n_banks = t.n_vaults * t.banks_per_vault
    ddr_banks = dt.n_vaults * dt.banks_per_vault
    wcfg = wear.WearConfig(
        n_supersets=cfg.inpkg_sets, m_writes=cfg.m_writes,
        dc_limit=cfg.dc_limit, t_mww_cycles=cfg.t_mww_cycles,
        # Scaled sim: budget per (scaled) superset window.
        blocks_per_superset=cfg.window_budget_blocks or cfg.inpkg_ways)

    def bump(stats, name, amount=1):
        return stats.at[SIDX[name]].add(amount)

    def step(state: SimState, req):
        addr, is_write = req["addr"].astype(jnp.int32), req["is_write"]
        stats = state.stats

        # ---- issue gating: bounded MLP ---------------------------------
        slot = state.stats[SIDX["l3_misses"]] % MLP  # reuse miss count as idx
        arrival = jnp.maximum(state.arrival + CPU_GAP,
                              state.completions[slot.astype(jnp.int32)])

        # ---- L3 ---------------------------------------------------------
        l3_set = (addr % cfg.l3_sets).astype(jnp.int32)
        l3_tag = addr // cfg.l3_sets
        line = (state.l3_tags[l3_set] == l3_tag) & (state.l3_valid[l3_set] == 1)
        l3_hit = jnp.any(line)
        l3_way = jnp.argmax(line).astype(jnp.int32)

        # LRU bookkeeping.
        age = state.l3_age.at[l3_set].add(1)
        victim = jnp.argmax(jnp.where(state.l3_valid[l3_set] == 1,
                                      age[l3_set],
                                      jnp.iinfo(jnp.int32).max)).astype(jnp.int32)
        way = jnp.where(l3_hit, l3_way, victim)
        ev_valid = (~l3_hit) & (state.l3_valid[l3_set, way] == 1)
        ev_tag = state.l3_tags[l3_set, way]
        ev_dirty = state.l3_dirty[l3_set, way] == 1
        ev_read = state.l3_read[l3_set, way] == 1
        ev_addr = ev_tag * cfg.l3_sets + l3_set

        l3_tags = state.l3_tags.at[l3_set, way].set(l3_tag)
        l3_valid = state.l3_valid.at[l3_set, way].set(1)
        l3_dirty = state.l3_dirty.at[l3_set, way].set(
            jnp.where(l3_hit, state.l3_dirty[l3_set, way] | is_write.astype(jnp.int8),
                      is_write.astype(jnp.int8)))
        # R flag = read AFTER installation (§8): the installing access itself
        # does not count, so a fill starts with R=0; later read hits set it.
        l3_read = state.l3_read.at[l3_set, way].set(
            jnp.where(l3_hit,
                      state.l3_read[l3_set, way] | (~is_write).astype(jnp.int8),
                      jnp.int8(0)))
        age = age.at[l3_set, way].set(0)

        stats = bump(stats, "l3_hits", l3_hit.astype(jnp.int32))
        stats = bump(stats, "l3_misses", (~l3_hit).astype(jnp.int32))
        stats = bump(stats, "l3_evictions", ev_valid.astype(jnp.int32))

        # =================================================================
        # MISS PATH — in-package lookup.  Everything below is predicated on
        # ~l3_hit (charged times multiplied to zero on hits).
        # =================================================================
        miss = ~l3_hit
        set_id_log = (addr % cfg.inpkg_sets).astype(jnp.int32)
        # Rotary offset remap (wear leveling): logical set -> physical set.
        off = (state.wear.offsets.superset + state.wear.offsets.set_ +
               state.wear.offsets.bank + state.wear.offsets.vault)
        set_id = ((set_id_log + off) % cfg.inpkg_sets).astype(jnp.int32)
        tag = addr // cfg.inpkg_sets
        hit, hway = controller.cache_lookup(state.cache, set_id, tag)
        hit = hit & miss

        locked = wear.is_locked(state.wear, set_id, arrival) & cfg.wear_enabled
        hit = hit & ~locked  # locked superset: bypass to main memory
        stats = bump(stats, "locked_bypass", (miss & locked).astype(jnp.int32))

        # Bank mapping: CAM lookup bank and RAM data bank (different banks,
        # §7 decoupled tags/data) vs single-bank tag+data for DRAM-style.
        cam_bank = (set_id % max(n_banks // 8, 1)).astype(jnp.int32)
        ram_bank = ((addr // cfg.inpkg_sets + set_id) % n_banks).astype(jnp.int32)
        inpkg_row = (addr // (cfg.inpkg_sets * 8)) % 1024

        bank_free, open_row = state.inpkg_bank_free, state.inpkg_open_row

        if cfg.search_tags:
            # SEARCH in CAM bank: occupancy tCCD, latency tRCD+tCAS+tBL.
            s_start = jnp.maximum(arrival, bank_free[cam_bank])
            s_done = s_start + (t.tRCD + t.tCAS + t.tBL)
            bank_free = bank_free.at[cam_bank].set(
                jnp.where(miss, s_start + t.tCCD, bank_free[cam_bank]))
            tag_done = jnp.where(miss, s_done, arrival)
            stats = bump(stats, "inpkg_searches", miss.astype(jnp.int32))
        else:
            # Tag READ in the data bank (Loh-Hill compound access).
            bf2, or2, tag_done_r = _access(bank_free, open_row, ram_bank,
                                           inpkg_row, arrival, t, False)
            bank_free = jnp.where(miss, bf2, bank_free)
            open_row = jnp.where(miss, or2, open_row)
            tag_done = jnp.where(miss, tag_done_r, arrival)
            stats = bump(stats, "inpkg_reads", miss.astype(jnp.int32))

        # Data read on hit.
        bf3, or3, data_done = _access(bank_free, open_row, ram_bank,
                                      inpkg_row, tag_done, t, False)
        bank_free = jnp.where(hit, bf3, bank_free)
        open_row = jnp.where(hit, or3, open_row)
        stats = bump(stats, "inpkg_hits", hit.astype(jnp.int32))
        stats = bump(stats, "inpkg_reads", hit.astype(jnp.int32))

        # DDR access on in-package miss.
        inpkg_miss = miss & ~hit
        stats = bump(stats, "inpkg_misses", inpkg_miss.astype(jnp.int32))
        ddr_bank = (addr % ddr_banks).astype(jnp.int32)
        ddr_row = (addr // ddr_banks) % 65536
        dbf, dor, ddr_done = _access(state.ddr_bank_free, state.ddr_open_row,
                                     ddr_bank, ddr_row, tag_done, dt, False)
        ddr_bank_free = jnp.where(inpkg_miss, dbf, state.ddr_bank_free)
        ddr_open_row = jnp.where(inpkg_miss, dor, state.ddr_open_row)
        stats = bump(stats, "ddr_reads", inpkg_miss.astype(jnp.int32))

        completion = jnp.where(
            l3_hit, arrival + L3_LATENCY,
            jnp.where(hit, data_done, ddr_done) + L3_LATENCY)

        # ---- fill policy -------------------------------------------------
        # no-allocate: in-package miss fills only L3 (already done above).
        # The legacy allocate-on-miss path (baselines) installs now.
        cache = state.cache
        wstate = state.wear
        do_install_miss = inpkg_miss & (not cfg.no_allocate)

        # ---- L3 eviction handling (install / forward / drop, §8) ---------
        if cfg.dr_filter:
            inst, fwd = wear.install_decision(ev_dirty, ev_read)
        else:
            # plain writeback cache: dirty evictions update the in-package
            # copy; clean evictions are dropped (fills happened on miss).
            inst, fwd = ev_dirty, jnp.asarray(False)
        ev_install = ev_valid & inst & ~locked
        ev_forward = ev_valid & (fwd | locked) & ev_dirty
        # Write traffic removed from the in-package memory by the D/R rules:
        # D&R̄ (forwarded to DRAM) + D̄&R̄ (dropped) — every eviction NOT
        # installed is one avoided XAM write (paper: ~31% reduction).
        stats = bump(stats, "writes_filtered",
                     (ev_valid & ~inst).astype(jnp.int32))

        ev_set_log = (ev_addr % cfg.inpkg_sets).astype(jnp.int32)
        ev_set = ((ev_set_log + off) % cfg.inpkg_sets).astype(jnp.int32)
        ev_tag_c = ev_addr // cfg.inpkg_sets
        # Install into in-package cache (a XAM/DRAM write).
        install_any = ev_install | do_install_miss
        inst_set = jnp.where(ev_install, ev_set, set_id)
        inst_tag = jnp.where(ev_install, ev_tag_c, tag)
        inst_dirty = jnp.where(ev_install, ev_dirty, is_write)
        cache2, evicted_dirty, inst_way = controller.cache_install(
            cache, inst_set, inst_tag, inst_dirty)
        cache = jax.tree.map(
            lambda a, b: jnp.where(install_any, b, a), cache, cache2)
        stats = bump(stats, "inpkg_writes", install_any.astype(jnp.int32))
        stats = bump(stats, "evict_writebacks",
                     (install_any & evicted_dirty).astype(jnp.int32))

        # Charge the write on the RAM bank (occupancy tWR — the RRAM pain).
        w_bank = ((inst_tag + inst_set) % n_banks).astype(jnp.int32)
        w_start = jnp.maximum(arrival, bank_free[w_bank])
        w_occ = jnp.int32(max(t.tCCD, t.tWR))
        bank_free = bank_free.at[w_bank].set(
            jnp.where(install_any, w_start + w_occ, bank_free[w_bank]))

        # Forwarded dirty evictions + in-package dirty evictions go to DDR4.
        ddr_w = ev_forward | (install_any & evicted_dirty)
        dwb = ((ev_addr) % ddr_banks).astype(jnp.int32)
        dw_start = jnp.maximum(arrival, ddr_bank_free[dwb])
        ddr_bank_free = ddr_bank_free.at[dwb].set(
            jnp.where(ddr_w, dw_start + max(dt.tCCD, dt.tWR), ddr_bank_free[dwb]))
        stats = bump(stats, "ddr_writes", ddr_w.astype(jnp.int32))

        # ---- wear accounting + rotation ----------------------------------
        if cfg.wear_enabled:
            wstate2, rotated, flushed = wear.record_write(
                wstate, wcfg, inst_set, inst_dirty, arrival)
            wstate = jax.tree.map(
                lambda a, b: jnp.where(install_any, b, a), wstate, wstate2)
            rot_now = install_any & rotated
            # On rotation: invalidate dirty sets (flush); charge writebacks.
            set_mask = (state.cache.dirty.sum(axis=1) > 0)
            cache3, n_flush = controller.cache_invalidate_sets(cache, set_mask)
            cache = jax.tree.map(
                lambda a, b: jnp.where(rot_now, b, a), cache, cache3)
            stats = bump(stats, "rotates", rot_now.astype(jnp.int32))
            stats = bump(stats, "flushed_dirty",
                         jnp.where(rot_now, n_flush, 0).astype(jnp.int32))

        set_writes = state.set_writes.at[inst_set].add(
            install_any.astype(jnp.int32))
        set_way_writes = state.set_way_writes.at[inst_set, inst_way].add(
            install_any.astype(jnp.int32))

        # ---- retire -------------------------------------------------------
        completions = state.completions.at[slot.astype(jnp.int32)].set(
            jnp.where(miss, completion, state.completions[slot.astype(jnp.int32)]))

        new = SimState(
            l3_tags=l3_tags, l3_valid=l3_valid, l3_dirty=l3_dirty,
            l3_read=l3_read, l3_age=age,
            cache=cache,
            inpkg_bank_free=bank_free, inpkg_open_row=open_row,
            ddr_bank_free=ddr_bank_free, ddr_open_row=ddr_open_row,
            completions=completions,
            arrival=jnp.maximum(arrival, state.arrival),
            wear=wstate, set_writes=set_writes,
            set_way_writes=set_way_writes, stats=stats,
        )
        return new, completion

    return step


@partial(jax.jit, static_argnames=("cfg",))
def _run(cfg: SimConfig, addrs: jnp.ndarray, is_write: jnp.ndarray):
    state = init_state(cfg)
    step = make_step(cfg)
    final, completions = jax.lax.scan(
        step, state, {"addr": addrs, "is_write": is_write})
    return final, completions


@dataclasses.dataclass
class SimResult:
    name: str
    total_cycles: float
    stats: dict[str, int]
    energy_nj: float

    @property
    def inpkg_hit_rate(self) -> float:
        h, m = self.stats["inpkg_hits"], self.stats["inpkg_misses"]
        return h / max(h + m, 1)


def simulate_trace(cfg: SimConfig, addrs, is_write,
                   return_state: bool = False):
    addrs = jnp.asarray(addrs, jnp.int32)
    is_write = jnp.asarray(is_write, bool)
    final, completions = _run(cfg, addrs, is_write)
    total = float(jnp.max(completions))
    # Refresh tax: DRAM loses a bandwidth fraction.
    total *= 1.0 / (1.0 - cfg.timing.refresh_overhead)
    stats = {n: int(final.stats[i]) for i, n in enumerate(STAT_NAMES)}
    e = TABLE1[cfg.energy_tech]
    ddr_e = TABLE1["DRAM"]
    energy = (
        stats["inpkg_reads"] * e.read_nj
        + stats["inpkg_writes"] * e.write_nj
        + stats["inpkg_searches"] * e.search_nj
        + (stats["ddr_reads"] * ddr_e.read_nj + stats["ddr_writes"] * ddr_e.write_nj) * 4.0
    )
    # DRAM static/refresh energy tax (per §10.2's energy trends).
    if cfg.timing.needs_refresh:
        energy *= 1.30
    result = SimResult(cfg.name, total, stats, energy)
    if return_state:
        return result, final
    return result
