"""Wear-leveling and t_MWW enforcement (paper §8, Fig. 8).

Pure-functional state machine over JAX arrays so it composes into the
``lax.scan`` trace simulator AND is unit/property-testable in isolation.

Components reproduced:

* Superset Write Table (SWT): W (written) and D (dirty) flags per superset.
* write / superset / dirty counters.
* WR approximation WITHOUT a divider: WR = 1 when the most significant
  non-zero bit of the write counter is >= 9 binary orders (512x) above the
  superset counter's MSB.
* rotate = WR | WC | DC  (WC/DC = saturation limits of the counters;
  the paper sets DC = 8192 to bound flush cost).
* On rotate: dirty supersets flushed (returned as a count + mask for the
  simulator to charge writeback traffic), SWT and counters reset, rotary
  offsets bumped by unique primes (geometry.apply_rotate).
* t_MWW: per-superset write budget of 512*M per window (t_MWW enforced at
  superset granularity = 512 blocks, §8 "Tracking Writes"); a superset
  exceeding the budget is locked (cache mode: bypass to main memory) until
  the window rolls over.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import geometry
from repro.core.timing import CPU_HZ, t_mww_seconds


#: Cycle resolution of the ``clock="wall"`` domain: one cycle per
#: microsecond of host wall time.  Chosen so realistic t_MWW windows fit
#: the int32 cycle domain the predicates operate in — the serving rebase
#: (``CLOCK_REBASE_AT``) folds the clock every ~17.9 wall-minutes, which
#: also bounds the longest expressible window.
WALL_HZ = 1_000_000

#: Legal values of the ``clock`` knob.
CLOCKS = ("ops", "wall")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearConfig:
    n_supersets: int = dataclasses.field(metadata=dict(static=True))
    m_writes: int = dataclasses.field(metadata=dict(static=True), default=3)
    dc_limit: int = dataclasses.field(metadata=dict(static=True), default=8192)
    wc_limit: int = dataclasses.field(metadata=dict(static=True), default=1 << 22)
    wr_shift: int = dataclasses.field(metadata=dict(static=True), default=9)
    t_mww_cycles: int = dataclasses.field(metadata=dict(static=True), default=0)
    blocks_per_superset: int = dataclasses.field(metadata=dict(static=True), default=512)
    #: Cycle DOMAIN of every stamp fed to the window predicates:
    #: ``"ops"`` — the caller's op/request counter stands in for cycles
    #: (the simulator and the serving default; PRE-EXISTING semantics,
    #: bit-identical); ``"wall"`` — stamps are host wall-clock
    #: microseconds (``WALL_HZ``), so ``t_mww_cycles`` expresses a
    #: LATENCY-ERA time budget.  The predicates themselves are
    #: clock-agnostic (pure int32 difference arithmetic, see
    #: ``_window_now``); this field records which domain the caller must
    #: stamp in and steers ``make_config``'s window derivation.
    clock: str = dataclasses.field(metadata=dict(static=True), default="ops")

    def __post_init__(self):
        if self.clock not in CLOCKS:
            raise ValueError(
                f"WearConfig.clock={self.clock!r}: expected one of {CLOCKS}")

    @property
    def window_write_budget(self) -> int:
        # M writes per BLOCK per window, tracked at superset granularity:
        # budget = 512 * M writes per superset per window (§8).
        return self.blocks_per_superset * self.m_writes


def make_config(n_supersets: int, m_writes: int = 3,
                t_life_years: float = 10.0, endurance: float = 1e8,
                clock: str = "ops", **kw) -> WearConfig:
    """WearConfig with the t_MWW window derived from a lifetime target.

    ``clock="ops"`` (default) keeps the historic CPU-cycle proxy:
    ``t_mww_cycles = t_MWW_seconds * CPU_HZ``.  ``clock="wall"`` expresses
    the window in wall microseconds (``t_MWW_seconds * WALL_HZ``) so
    callers stamping wall time get a true latency-era window."""
    t_mww_s = t_mww_seconds(m_writes, t_life_years * 365.25 * 24 * 3600, endurance)
    hz = CPU_HZ if clock == "ops" else WALL_HZ
    return WearConfig(
        n_supersets=n_supersets, m_writes=m_writes,
        t_mww_cycles=int(t_mww_s * hz), clock=clock, **kw,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearDyn:
    """Dynamic (traced) wear knobs — the batched simulator stacks one of
    these per config and ``jax.vmap``s over them, so the durability
    parameters (M, counter limits, window length) become data rather than
    compile-time constants.  Field names mirror the ``WearConfig``
    attributes ``record_write``/``rotate_signal``/``wr_signal`` read, so
    either can be passed as ``cfg``; only ``n_supersets`` (an array shape)
    must stay static."""
    window_write_budget: jnp.ndarray   # scalar int32 = blocks/superset * M
    dc_limit: jnp.ndarray              # scalar int32
    wc_limit: jnp.ndarray              # scalar int32
    wr_shift: jnp.ndarray              # scalar int32
    t_mww_cycles: jnp.ndarray          # scalar int32


def dyn_of(cfg: WearConfig) -> WearDyn:
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    return WearDyn(
        window_write_budget=i32(cfg.window_write_budget),
        dc_limit=i32(cfg.dc_limit), wc_limit=i32(cfg.wc_limit),
        wr_shift=i32(cfg.wr_shift), t_mww_cycles=i32(cfg.t_mww_cycles),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearState:
    swt_w: jnp.ndarray          # (S,) int8 — written flag
    swt_d: jnp.ndarray          # (S,) int8 — dirty flag
    write_counter: jnp.ndarray  # scalar int32
    superset_counter: jnp.ndarray
    dirty_counter: jnp.ndarray
    offsets: geometry.RotaryOffsets
    # t_MWW window tracking, per superset.
    window_writes: jnp.ndarray  # (S,) int32 writes in current window
    window_start: jnp.ndarray   # (S,) int64 cycle the window opened
    locked_until: jnp.ndarray   # (S,) int64 cycle until which superset is locked
    total_rotates: jnp.ndarray  # scalar int32
    total_flushed: jnp.ndarray  # scalar int32 — dirty supersets flushed


def init_state(cfg: WearConfig) -> WearState:
    s = cfg.n_supersets
    return WearState(
        swt_w=jnp.zeros((s,), jnp.int8),
        swt_d=jnp.zeros((s,), jnp.int8),
        write_counter=jnp.zeros((), jnp.int32),
        superset_counter=jnp.zeros((), jnp.int32),
        dirty_counter=jnp.zeros((), jnp.int32),
        offsets=geometry.zero_offsets(),
        window_writes=jnp.zeros((s,), jnp.int32),
        window_start=jnp.zeros((s,), jnp.int32),
        locked_until=jnp.zeros((s,), jnp.int32),
        total_rotates=jnp.zeros((), jnp.int32),
        total_flushed=jnp.zeros((), jnp.int32),
    )


def msb_index(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the most-significant non-zero bit; -1 for zero (Fig. 8's
    divider-free ratio detector operates on these)."""
    x32 = x.astype(jnp.uint32)
    clz = jax.lax.clz(x32)
    return jnp.where(x32 == 0, jnp.int32(-1), (31 - clz).astype(jnp.int32))


def wr_signal(state: WearState, cfg: WearConfig) -> jnp.ndarray:
    """WR=1 when msb(write_counter) - msb(superset_counter) >= wr_shift
    (the divider-free 512x ratio detector, Fig. 8)."""
    wmsb = msb_index(state.write_counter)
    smsb = msb_index(state.superset_counter)
    return ((wmsb - smsb) >= cfg.wr_shift) & (state.superset_counter > 0)


def rotate_signal(state: WearState, cfg: WearConfig) -> jnp.ndarray:
    wc = state.write_counter >= cfg.wc_limit
    dc = state.dirty_counter >= cfg.dc_limit
    return wr_signal(state, cfg) | wc | dc


def is_locked(state: WearState, superset: jnp.ndarray, cycle: jnp.ndarray) -> jnp.ndarray:
    return cycle < state.locked_until[superset]


def record_write(state: WearState, cfg: WearConfig, superset: jnp.ndarray,
                 makes_dirty: jnp.ndarray, cycle: jnp.ndarray):
    """Account one XAM write to ``superset`` at ``cycle``.

    Returns (new_state, rotated:bool, flushed_count:int32).
    Handles, in order: t_MWW window rollover, budget accounting + lock,
    SWT/counter updates, rotate detection + offset bump + SWT reset.
    """
    s = superset
    cycle = cycle.astype(jnp.int32)

    # --- t_MWW window (rollover arithmetic shared with the reject-
    # before-write predicate, see _window_now) ----------------------------
    win, expired, w_writes = _window_now(state, cfg, s, cycle)
    w_start = jnp.where(expired, cycle, state.window_start[s])
    w_writes = w_writes + 1
    over = w_writes > cfg.window_write_budget
    locked_until = jnp.where(over, w_start + win, state.locked_until[s])

    window_writes = state.window_writes.at[s].set(w_writes)
    window_start = state.window_start.at[s].set(w_start)
    locked = state.locked_until.at[s].set(locked_until)

    # --- SWT + counters (Fig. 8) ------------------------------------------
    first_write = state.swt_w[s] == 0
    superset_counter = state.superset_counter + jnp.where(first_write, 1, 0).astype(jnp.int32)
    swt_w = state.swt_w.at[s].set(1)
    newly_dirty = (state.swt_d[s] == 0) & makes_dirty
    dirty_counter = state.dirty_counter + jnp.where(newly_dirty, 1, 0).astype(jnp.int32)
    swt_d = state.swt_d.at[s].max(makes_dirty.astype(jnp.int8))
    write_counter = state.write_counter + 1

    mid = WearState(
        swt_w=swt_w, swt_d=swt_d,
        write_counter=write_counter, superset_counter=superset_counter,
        dirty_counter=dirty_counter, offsets=state.offsets,
        window_writes=window_writes, window_start=window_start,
        locked_until=locked,
        total_rotates=state.total_rotates, total_flushed=state.total_flushed,
    )

    rot = rotate_signal(mid, cfg)
    flushed = jnp.where(rot, jnp.sum(swt_d.astype(jnp.int32)), 0)

    def do_rotate(st: WearState) -> WearState:
        return WearState(
            swt_w=jnp.zeros_like(st.swt_w),
            swt_d=jnp.zeros_like(st.swt_d),
            write_counter=jnp.zeros_like(st.write_counter),
            superset_counter=jnp.zeros_like(st.superset_counter),
            dirty_counter=jnp.zeros_like(st.dirty_counter),
            offsets=geometry.apply_rotate(st.offsets),
            window_writes=st.window_writes,
            window_start=st.window_start,
            locked_until=st.locked_until,
            total_rotates=st.total_rotates + 1,
            total_flushed=st.total_flushed + flushed,
        )

    new_state = jax.lax.cond(rot, do_rotate, lambda st: st, mid)
    return new_state, rot, flushed


# ---------------------------------------------------------------------------
# Batched device ops.  The serving path (serve/kv_index.py), the hashtable
# app, and the differential tests all consume the SAME per-write semantics as
# the simulator — there is exactly one implementation of §8, this module —
# but amortize dispatch by applying a whole write trace per device call:
# ``record_writes`` is a ``lax.scan`` over ``record_write``, so it is
# step-for-step identical to the host loop while costing one dispatch.
# ---------------------------------------------------------------------------

def _window_now(state: WearState, cfg, superset, cycle):
    """THE t_MWW window-rollover arithmetic (one implementation, shared by
    ``record_write`` and ``window_would_exceed``): returns
    ``(win, expired, writes_now)`` for ``superset`` at ``cycle``.

    Clock-agnostic by construction: only int32 DIFFERENCES of ``cycle``
    against stored stamps are compared, so the same predicate serves the
    op-counter proxy (``clock="ops"``) and wall-microsecond stamps
    (``clock="wall"``) — the caller just has to stamp consistently in
    one domain (``WearConfig.clock`` records which)."""
    win = jnp.maximum(jnp.asarray(cfg.t_mww_cycles, jnp.int32), 1)
    expired = (cycle - state.window_start[superset]) >= win
    writes_now = jnp.where(expired, 0, state.window_writes[superset])
    return win, expired, writes_now


def window_would_exceed(state: WearState, cfg, superset: jnp.ndarray,
                        cycle: jnp.ndarray) -> jnp.ndarray:
    """Reject-before-write t_MWW predicate (§6.2 lifetime throttle).

    Parameters
    ----------
    state : WearState
        Current wear state (host or device resident).
    cfg : WearConfig | WearDyn
        Source of ``window_write_budget`` / ``t_mww_cycles`` — static
        config and traced dynamic knobs are interchangeable here.
    superset : jnp.ndarray, int32 (scalar or (N,))
        Superset id(s) the prospective write targets.
    cycle : jnp.ndarray, int32
        Current cycle in the config's clock domain (serving stamps its
        op counter under ``clock="ops"``, wall microseconds under
        ``clock="wall"``).

    Returns
    -------
    jnp.ndarray, bool (same shape as ``superset``)
        True when ONE more write at ``cycle`` would blow the t_MWW window
        budget.  Admission controllers (cache-mode serving) consult this
        BEFORE spending the XAM write, unlike the simulator's
        lock-after-overflow accounting in :func:`record_write` — both use
        the same ``_window_now`` rollover arithmetic.
    """
    cycle = jnp.asarray(cycle, jnp.int32)
    _, _, writes_now = _window_now(state, cfg, superset, cycle)
    return (writes_now + 1) > cfg.window_write_budget


def shard_states(cfg: WearConfig, n_shards: int) -> list[WearState]:
    """Per-shard §8 wear states for a set-sharded serving index.

    Each shard tracks its own contiguous block of
    ``cfg.n_supersets // n_shards`` supersets; because every t_MWW
    decision reads only per-superset rows (``window_writes`` /
    ``window_start`` / ``locked_until``), splitting the state this way is
    decision-equivalent to one global state — only the global SWT scalars
    (write/superset/dirty counters) become shard-local, and the serving
    index disables the rotate signals those feed.

    Returns a list of ``n_shards`` fresh :func:`init_state` states, each
    sized ``n_supersets // n_shards`` (which must divide evenly).
    """
    if n_shards < 1 or cfg.n_supersets % n_shards != 0:
        raise ValueError(
            f"n_shards={n_shards} must divide n_supersets={cfg.n_supersets}")
    sub = dataclasses.replace(cfg, n_supersets=cfg.n_supersets // n_shards)
    return [init_state(sub) for _ in range(n_shards)]


def concat_states(states: list[WearState]) -> WearState:
    """Global read-only view over per-shard wear states.

    Per-superset fields are concatenated in shard order (shard k's rows
    land at global supersets ``[k * s_local, (k + 1) * s_local)`` —
    matching ``geometry.shard_of_set`` ownership); scalar counters are
    summed; the rotary offsets are taken from shard 0 (the serving index
    never consumes them).  Used for reporting only — never write through
    the result.
    """
    if len(states) == 1:
        return states[0]
    # Shard states may live on different mesh devices: gather through the
    # host (this is a reporting path, never a compute path).
    cat = lambda f: jnp.asarray(
        np.concatenate([np.asarray(getattr(s, f)) for s in states]))
    tot = lambda f: jnp.asarray(
        sum(np.asarray(getattr(s, f)) for s in states))
    return WearState(
        swt_w=cat("swt_w"), swt_d=cat("swt_d"),
        write_counter=tot("write_counter"),
        superset_counter=tot("superset_counter"),
        dirty_counter=tot("dirty_counter"),
        offsets=states[0].offsets,
        window_writes=cat("window_writes"),
        window_start=cat("window_start"),
        locked_until=cat("locked_until"),
        total_rotates=tot("total_rotates"),
        total_flushed=tot("total_flushed"),
    )


def record_writes(state: WearState, cfg, supersets, makes_dirty, cycles,
                  active=None):
    """Batched :func:`record_write`: apply a trace of writes in order.

    Parameters
    ----------
    state : WearState
        State the trace starts from.
    cfg : WearConfig | WearDyn
        Durability knobs (static or traced).
    supersets : (B,) int32
        Target superset per write, in trace order.
    makes_dirty : (B,) bool
        Whether each write dirties its superset (drives the DC counter).
    cycles : (B,) int32
        Cycle stamp per write (monotone within the trace).
    active : (B,) bool, optional
        Masks padding lanes (pow2-bucketed callers) — an inactive lane is
        a no-op on state AND outputs.

    Returns
    -------
    (state, rotated, flushed)
        New state, per-step rotate flags ``(B,) bool`` and flushed-superset
        counts ``(B,) int32``.  The per-step outputs match a Python loop
        over :func:`record_write` exactly (pinned by tests/test_wear.py's
        differential trace tests).
    """
    supersets = jnp.asarray(supersets, jnp.int32)
    makes_dirty = jnp.asarray(makes_dirty, bool)
    cycles = jnp.asarray(cycles, jnp.int32)
    act = (jnp.ones(supersets.shape, bool) if active is None
           else jnp.asarray(active, bool))

    def step(st, x):
        s, d, c, a = x
        st2, rot, fl = record_write(st, cfg, s, d, c)
        st = jax.tree.map(lambda o, n: jnp.where(a, n, o), st, st2)
        return st, (rot & a, jnp.where(a, fl, 0))

    state, (rots, fls) = jax.lax.scan(
        step, state, (supersets, makes_dirty, cycles, act))
    return state, rots, fls


#: Device entry point for :func:`record_writes`: the state argument is
#: DONATED (the caller's reference is invalid after the call — rebind to
#: the returned state), so a long-lived serving/app loop costs one device
#: dispatch and zero state copies per write batch.
record_writes_device = functools.partial(
    jax.jit, donate_argnums=(0,))(record_writes)


def record_write_rows(state: WearState, cfg, supersets, cycles, active,
                      makes_dirty=None) -> WearState:
    """Vectorized :func:`record_write` over DISTINCT supersets — one fully
    parallel row update instead of a scan.

    Bit-identical to folding :func:`record_write` over the lanes in any
    order, PROVIDED two contract conditions hold (the caller's to keep):

    * the active supersets are pairwise distinct — every per-superset row
      (window fields, SWT flags) is touched by at most one lane, so the
      row scatters commute and the scalar counters become order-free sums;
    * the rotate signals are disabled (``wr_shift >= 32`` and huge
      WC/DC limits, the serving index's configuration) — ``record_write``'s
      rotate branch is then the identity, so offsets / ``total_rotates`` /
      ``total_flushed`` are invariants and are passed through untouched.

    The single-dispatch admission path (serve/kv_index.py) calls this once
    per admission round; its round grid holds distinct sets per round by
    construction.  Inactive lanes are full no-ops (gathers are clipped,
    scatters dropped via an out-of-bounds sentinel index).

    Parameters
    ----------
    state : WearState
    cfg : WearConfig | WearDyn
        Durability knobs (static or traced).
    supersets : (K,) int32
        Target superset per lane; active lanes must be pairwise distinct.
    cycles : (K,) int32
        Cycle stamp per lane.
    active : (K,) bool
        Lane mask; an inactive lane changes nothing.
    makes_dirty : (K,) bool, optional
        Defaults to all-dirty (the serving install path).

    Returns
    -------
    WearState

    Examples
    --------
    >>> import numpy as np
    >>> cfg = WearConfig(n_supersets=4, t_mww_cycles=100,
    ...                  blocks_per_superset=2, wr_shift=32)
    >>> st = record_write_rows(
    ...     init_state(cfg), cfg, np.array([0, 2, 1], np.int32),
    ...     np.array([5, 6, 7], np.int32), np.array([True, True, False]))
    >>> np.asarray(st.window_writes).tolist(), int(st.write_counter)
    ([1, 0, 1, 0], 2)
    """
    s = jnp.asarray(supersets, jnp.int32)
    cycle = jnp.asarray(cycles, jnp.int32)
    act = jnp.asarray(active, bool)
    dirty = (jnp.ones(s.shape, bool) if makes_dirty is None
             else jnp.asarray(makes_dirty, bool))
    n = state.swt_w.shape[0]
    sc = jnp.clip(s, 0, n - 1)          # gather-safe row index
    ii = jnp.where(act, sc, n)          # scatter index: OOB drop when inactive

    # t_MWW window accounting — the same _window_now rollover arithmetic,
    # one lane per (distinct) superset row.
    win, expired, w_writes = _window_now(state, cfg, sc, cycle)
    w_start = jnp.where(expired, cycle, state.window_start[sc])
    w_writes = w_writes + 1
    over = w_writes > cfg.window_write_budget
    locked_until = jnp.where(over, w_start + win, state.locked_until[sc])

    window_writes = state.window_writes.at[ii].set(w_writes, mode="drop")
    window_start = state.window_start.at[ii].set(w_start, mode="drop")
    locked = state.locked_until.at[ii].set(locked_until, mode="drop")

    # SWT + counters: per-row flags scatter (disjoint rows), scalar
    # counters as sums over the lanes (order-free because each lane's
    # first_write/newly_dirty depends only on its own pre-batch row).
    first_write = (state.swt_w[sc] == 0) & act
    superset_counter = (state.superset_counter
                        + jnp.sum(first_write.astype(jnp.int32)))
    swt_w = state.swt_w.at[ii].set(jnp.int8(1), mode="drop")
    newly_dirty = (state.swt_d[sc] == 0) & dirty & act
    dirty_counter = (state.dirty_counter
                     + jnp.sum(newly_dirty.astype(jnp.int32)))
    swt_d = state.swt_d.at[ii].max(dirty.astype(jnp.int8), mode="drop")
    write_counter = state.write_counter + jnp.sum(act.astype(jnp.int32))

    return WearState(
        swt_w=swt_w, swt_d=swt_d,
        write_counter=write_counter, superset_counter=superset_counter,
        dirty_counter=dirty_counter, offsets=state.offsets,
        window_writes=window_writes, window_start=window_start,
        locked_until=locked,
        total_rotates=state.total_rotates, total_flushed=state.total_flushed,
    )


#: Serving clock re-base threshold.  The cycle domain is int32 (JAX's
#: default integer width); a long-lived op-counter clock must be folded
#: back before it wraps.  Every window comparison is difference-based, so
#: shifting the clock AND every stored timestamp by the same delta is an
#: exact no-op semantically.
CLOCK_REBASE_AT = 1 << 30


def maybe_rebase(state: WearState, op_counter: int):
    """The serving wrap policy in one place: fold ``op_counter`` (and the
    state's timestamps, via :func:`rebase_clock`) once it reaches
    CLOCK_REBASE_AT.  Returns ``(state, op_counter)``."""
    if op_counter >= CLOCK_REBASE_AT:
        state = rebase_clock(state, CLOCK_REBASE_AT)
        op_counter -= CLOCK_REBASE_AT
    return state, op_counter


def rebase_clock(state: WearState, delta) -> WearState:
    """Shift all stored timestamps down by ``delta`` (callers shift their
    op counter in lockstep).  Timestamps are floored at -CLOCK_REBASE_AT so
    repeated rebases cannot underflow int32: an entry at the floor is, and
    behaves as, long-expired/unlocked (exact as long as window lengths are
    <= CLOCK_REBASE_AT, which the int32 ``t_mww_cycles`` domain and callers
    guarantee)."""
    d = jnp.asarray(delta, jnp.int32)
    floor = jnp.int32(-CLOCK_REBASE_AT)
    return dataclasses.replace(
        state,
        window_start=jnp.maximum(state.window_start - d, floor),
        locked_until=jnp.maximum(state.locked_until - d, floor),
    )


# ---------------------------------------------------------------------------
# L3-eviction write-mitigation filter (§8 "Mitigating Writes").
# D (dirty) and R (read-since-install) flags decide the fate of an evicted
# block:  D&R -> install/update in Monarch;  D&!R -> forward to main memory;
# !D&R -> install as read-only;  !D&!R -> drop.
# ---------------------------------------------------------------------------

def install_decision(dirty: jnp.ndarray, read: jnp.ndarray):
    """Fate of an L3-evicted block from its D (dirty) / R (read) flags.

    Returns ``(install_in_monarch, forward_to_dram)`` — read blocks
    install, dirty-never-read blocks are forwarded, clean-never-read
    blocks are dropped:

    >>> import numpy as np
    >>> inst, fwd = install_decision(np.array([1, 1, 0, 0]),
    ...                              np.array([1, 0, 1, 0]))
    >>> np.asarray(inst).tolist(), np.asarray(fwd).tolist()
    ([True, False, True, False], [False, True, False, False])
    """
    dirty = dirty.astype(bool)
    read = read.astype(bool)
    install = read  # D&R and !D&R install
    forward = dirty & ~read  # D&!R forwarded to DRAM
    return install, forward
