"""Wear-leveling and t_MWW enforcement (paper §8, Fig. 8).

Pure-functional state machine over JAX arrays so it composes into the
``lax.scan`` trace simulator AND is unit/property-testable in isolation.

Components reproduced:

* Superset Write Table (SWT): W (written) and D (dirty) flags per superset.
* write / superset / dirty counters.
* WR approximation WITHOUT a divider: WR = 1 when the most significant
  non-zero bit of the write counter is >= 9 binary orders (512x) above the
  superset counter's MSB.
* rotate = WR | WC | DC  (WC/DC = saturation limits of the counters;
  the paper sets DC = 8192 to bound flush cost).
* On rotate: dirty supersets flushed (returned as a count + mask for the
  simulator to charge writeback traffic), SWT and counters reset, rotary
  offsets bumped by unique primes (geometry.apply_rotate).
* t_MWW: per-superset write budget of 512*M per window (t_MWW enforced at
  superset granularity = 512 blocks, §8 "Tracking Writes"); a superset
  exceeding the budget is locked (cache mode: bypass to main memory) until
  the window rolls over.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import geometry
from repro.core.timing import CPU_HZ, t_mww_seconds


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearConfig:
    n_supersets: int = dataclasses.field(metadata=dict(static=True))
    m_writes: int = dataclasses.field(metadata=dict(static=True), default=3)
    dc_limit: int = dataclasses.field(metadata=dict(static=True), default=8192)
    wc_limit: int = dataclasses.field(metadata=dict(static=True), default=1 << 22)
    wr_shift: int = dataclasses.field(metadata=dict(static=True), default=9)
    t_mww_cycles: int = dataclasses.field(metadata=dict(static=True), default=0)
    blocks_per_superset: int = dataclasses.field(metadata=dict(static=True), default=512)

    @property
    def window_write_budget(self) -> int:
        # M writes per BLOCK per window, tracked at superset granularity:
        # budget = 512 * M writes per superset per window (§8).
        return self.blocks_per_superset * self.m_writes


def make_config(n_supersets: int, m_writes: int = 3,
                t_life_years: float = 10.0, endurance: float = 1e8,
                **kw) -> WearConfig:
    t_mww_s = t_mww_seconds(m_writes, t_life_years * 365.25 * 24 * 3600, endurance)
    return WearConfig(
        n_supersets=n_supersets, m_writes=m_writes,
        t_mww_cycles=int(t_mww_s * CPU_HZ), **kw,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearDyn:
    """Dynamic (traced) wear knobs — the batched simulator stacks one of
    these per config and ``jax.vmap``s over them, so the durability
    parameters (M, counter limits, window length) become data rather than
    compile-time constants.  Field names mirror the ``WearConfig``
    attributes ``record_write``/``rotate_signal``/``wr_signal`` read, so
    either can be passed as ``cfg``; only ``n_supersets`` (an array shape)
    must stay static."""
    window_write_budget: jnp.ndarray   # scalar int32 = blocks/superset * M
    dc_limit: jnp.ndarray              # scalar int32
    wc_limit: jnp.ndarray              # scalar int32
    wr_shift: jnp.ndarray              # scalar int32
    t_mww_cycles: jnp.ndarray          # scalar int32


def dyn_of(cfg: WearConfig) -> WearDyn:
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    return WearDyn(
        window_write_budget=i32(cfg.window_write_budget),
        dc_limit=i32(cfg.dc_limit), wc_limit=i32(cfg.wc_limit),
        wr_shift=i32(cfg.wr_shift), t_mww_cycles=i32(cfg.t_mww_cycles),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearState:
    swt_w: jnp.ndarray          # (S,) int8 — written flag
    swt_d: jnp.ndarray          # (S,) int8 — dirty flag
    write_counter: jnp.ndarray  # scalar int32
    superset_counter: jnp.ndarray
    dirty_counter: jnp.ndarray
    offsets: geometry.RotaryOffsets
    # t_MWW window tracking, per superset.
    window_writes: jnp.ndarray  # (S,) int32 writes in current window
    window_start: jnp.ndarray   # (S,) int64 cycle the window opened
    locked_until: jnp.ndarray   # (S,) int64 cycle until which superset is locked
    total_rotates: jnp.ndarray  # scalar int32
    total_flushed: jnp.ndarray  # scalar int32 — dirty supersets flushed


def init_state(cfg: WearConfig) -> WearState:
    s = cfg.n_supersets
    return WearState(
        swt_w=jnp.zeros((s,), jnp.int8),
        swt_d=jnp.zeros((s,), jnp.int8),
        write_counter=jnp.zeros((), jnp.int32),
        superset_counter=jnp.zeros((), jnp.int32),
        dirty_counter=jnp.zeros((), jnp.int32),
        offsets=geometry.zero_offsets(),
        window_writes=jnp.zeros((s,), jnp.int32),
        window_start=jnp.zeros((s,), jnp.int32),
        locked_until=jnp.zeros((s,), jnp.int32),
        total_rotates=jnp.zeros((), jnp.int32),
        total_flushed=jnp.zeros((), jnp.int32),
    )


def msb_index(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the most-significant non-zero bit; -1 for zero (Fig. 8's
    divider-free ratio detector operates on these)."""
    x32 = x.astype(jnp.uint32)
    clz = jax.lax.clz(x32)
    return jnp.where(x32 == 0, jnp.int32(-1), (31 - clz).astype(jnp.int32))


def wr_signal(state: WearState, cfg: WearConfig) -> jnp.ndarray:
    """WR=1 when msb(write_counter) - msb(superset_counter) >= wr_shift
    (the divider-free 512x ratio detector, Fig. 8)."""
    wmsb = msb_index(state.write_counter)
    smsb = msb_index(state.superset_counter)
    return ((wmsb - smsb) >= cfg.wr_shift) & (state.superset_counter > 0)


def rotate_signal(state: WearState, cfg: WearConfig) -> jnp.ndarray:
    wc = state.write_counter >= cfg.wc_limit
    dc = state.dirty_counter >= cfg.dc_limit
    return wr_signal(state, cfg) | wc | dc


def is_locked(state: WearState, superset: jnp.ndarray, cycle: jnp.ndarray) -> jnp.ndarray:
    return cycle < state.locked_until[superset]


def record_write(state: WearState, cfg: WearConfig, superset: jnp.ndarray,
                 makes_dirty: jnp.ndarray, cycle: jnp.ndarray):
    """Account one XAM write to ``superset`` at ``cycle``.

    Returns (new_state, rotated:bool, flushed_count:int32).
    Handles, in order: t_MWW window rollover, budget accounting + lock,
    SWT/counter updates, rotate detection + offset bump + SWT reset.
    """
    s = superset
    cycle = cycle.astype(jnp.int32)

    # --- t_MWW window ----------------------------------------------------
    # jnp.maximum (not Python max): t_mww_cycles may be a traced scalar
    # when the batched simulator passes a WearDyn.
    win = jnp.maximum(jnp.asarray(cfg.t_mww_cycles, jnp.int32), 1)
    expired = (cycle - state.window_start[s]) >= win
    w_writes = jnp.where(expired, 0, state.window_writes[s])
    w_start = jnp.where(expired, cycle, state.window_start[s])
    w_writes = w_writes + 1
    over = w_writes > cfg.window_write_budget
    locked_until = jnp.where(over, w_start + win, state.locked_until[s])

    window_writes = state.window_writes.at[s].set(w_writes)
    window_start = state.window_start.at[s].set(w_start)
    locked = state.locked_until.at[s].set(locked_until)

    # --- SWT + counters (Fig. 8) ------------------------------------------
    first_write = state.swt_w[s] == 0
    superset_counter = state.superset_counter + jnp.where(first_write, 1, 0).astype(jnp.int32)
    swt_w = state.swt_w.at[s].set(1)
    newly_dirty = (state.swt_d[s] == 0) & makes_dirty
    dirty_counter = state.dirty_counter + jnp.where(newly_dirty, 1, 0).astype(jnp.int32)
    swt_d = state.swt_d.at[s].max(makes_dirty.astype(jnp.int8))
    write_counter = state.write_counter + 1

    mid = WearState(
        swt_w=swt_w, swt_d=swt_d,
        write_counter=write_counter, superset_counter=superset_counter,
        dirty_counter=dirty_counter, offsets=state.offsets,
        window_writes=window_writes, window_start=window_start,
        locked_until=locked,
        total_rotates=state.total_rotates, total_flushed=state.total_flushed,
    )

    rot = rotate_signal(mid, cfg)
    flushed = jnp.where(rot, jnp.sum(swt_d.astype(jnp.int32)), 0)

    def do_rotate(st: WearState) -> WearState:
        return WearState(
            swt_w=jnp.zeros_like(st.swt_w),
            swt_d=jnp.zeros_like(st.swt_d),
            write_counter=jnp.zeros_like(st.write_counter),
            superset_counter=jnp.zeros_like(st.superset_counter),
            dirty_counter=jnp.zeros_like(st.dirty_counter),
            offsets=geometry.apply_rotate(st.offsets),
            window_writes=st.window_writes,
            window_start=st.window_start,
            locked_until=st.locked_until,
            total_rotates=st.total_rotates + 1,
            total_flushed=st.total_flushed + flushed,
        )

    new_state = jax.lax.cond(rot, do_rotate, lambda st: st, mid)
    return new_state, rot, flushed


# ---------------------------------------------------------------------------
# L3-eviction write-mitigation filter (§8 "Mitigating Writes").
# D (dirty) and R (read-since-install) flags decide the fate of an evicted
# block:  D&R -> install/update in Monarch;  D&!R -> forward to main memory;
# !D&R -> install as read-only;  !D&!R -> drop.
# ---------------------------------------------------------------------------

def install_decision(dirty: jnp.ndarray, read: jnp.ndarray):
    """Returns (install_in_monarch, forward_to_dram)."""
    dirty = dirty.astype(bool)
    read = read.astype(bool)
    install = read  # D&R and !D&R install
    forward = dirty & ~read  # D&!R forwarded to DRAM
    return install, forward
