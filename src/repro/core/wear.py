"""Wear-leveling and t_MWW enforcement (paper §8, Fig. 8).

Pure-functional state machine over JAX arrays so it composes into the
``lax.scan`` trace simulator AND is unit/property-testable in isolation.

Components reproduced:

* Superset Write Table (SWT): W (written) and D (dirty) flags per superset.
* write / superset / dirty counters.
* WR approximation WITHOUT a divider: WR = 1 when the most significant
  non-zero bit of the write counter is >= 9 binary orders (512x) above the
  superset counter's MSB.
* rotate = WR | WC | DC  (WC/DC = saturation limits of the counters;
  the paper sets DC = 8192 to bound flush cost).
* On rotate: dirty supersets flushed (returned as a count + mask for the
  simulator to charge writeback traffic), SWT and counters reset, rotary
  offsets bumped by unique primes (geometry.apply_rotate).
* t_MWW: per-superset write budget of 512*M per window (t_MWW enforced at
  superset granularity = 512 blocks, §8 "Tracking Writes"); a superset
  exceeding the budget is locked (cache mode: bypass to main memory) until
  the window rolls over.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import geometry
from repro.core.timing import CPU_HZ, t_mww_seconds


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearConfig:
    n_supersets: int = dataclasses.field(metadata=dict(static=True))
    m_writes: int = dataclasses.field(metadata=dict(static=True), default=3)
    dc_limit: int = dataclasses.field(metadata=dict(static=True), default=8192)
    wc_limit: int = dataclasses.field(metadata=dict(static=True), default=1 << 22)
    wr_shift: int = dataclasses.field(metadata=dict(static=True), default=9)
    t_mww_cycles: int = dataclasses.field(metadata=dict(static=True), default=0)
    blocks_per_superset: int = dataclasses.field(metadata=dict(static=True), default=512)

    @property
    def window_write_budget(self) -> int:
        # M writes per BLOCK per window, tracked at superset granularity:
        # budget = 512 * M writes per superset per window (§8).
        return self.blocks_per_superset * self.m_writes


def make_config(n_supersets: int, m_writes: int = 3,
                t_life_years: float = 10.0, endurance: float = 1e8,
                **kw) -> WearConfig:
    t_mww_s = t_mww_seconds(m_writes, t_life_years * 365.25 * 24 * 3600, endurance)
    return WearConfig(
        n_supersets=n_supersets, m_writes=m_writes,
        t_mww_cycles=int(t_mww_s * CPU_HZ), **kw,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearDyn:
    """Dynamic (traced) wear knobs — the batched simulator stacks one of
    these per config and ``jax.vmap``s over them, so the durability
    parameters (M, counter limits, window length) become data rather than
    compile-time constants.  Field names mirror the ``WearConfig``
    attributes ``record_write``/``rotate_signal``/``wr_signal`` read, so
    either can be passed as ``cfg``; only ``n_supersets`` (an array shape)
    must stay static."""
    window_write_budget: jnp.ndarray   # scalar int32 = blocks/superset * M
    dc_limit: jnp.ndarray              # scalar int32
    wc_limit: jnp.ndarray              # scalar int32
    wr_shift: jnp.ndarray              # scalar int32
    t_mww_cycles: jnp.ndarray          # scalar int32


def dyn_of(cfg: WearConfig) -> WearDyn:
    i32 = lambda v: jnp.asarray(v, jnp.int32)
    return WearDyn(
        window_write_budget=i32(cfg.window_write_budget),
        dc_limit=i32(cfg.dc_limit), wc_limit=i32(cfg.wc_limit),
        wr_shift=i32(cfg.wr_shift), t_mww_cycles=i32(cfg.t_mww_cycles),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class WearState:
    swt_w: jnp.ndarray          # (S,) int8 — written flag
    swt_d: jnp.ndarray          # (S,) int8 — dirty flag
    write_counter: jnp.ndarray  # scalar int32
    superset_counter: jnp.ndarray
    dirty_counter: jnp.ndarray
    offsets: geometry.RotaryOffsets
    # t_MWW window tracking, per superset.
    window_writes: jnp.ndarray  # (S,) int32 writes in current window
    window_start: jnp.ndarray   # (S,) int64 cycle the window opened
    locked_until: jnp.ndarray   # (S,) int64 cycle until which superset is locked
    total_rotates: jnp.ndarray  # scalar int32
    total_flushed: jnp.ndarray  # scalar int32 — dirty supersets flushed


def init_state(cfg: WearConfig) -> WearState:
    s = cfg.n_supersets
    return WearState(
        swt_w=jnp.zeros((s,), jnp.int8),
        swt_d=jnp.zeros((s,), jnp.int8),
        write_counter=jnp.zeros((), jnp.int32),
        superset_counter=jnp.zeros((), jnp.int32),
        dirty_counter=jnp.zeros((), jnp.int32),
        offsets=geometry.zero_offsets(),
        window_writes=jnp.zeros((s,), jnp.int32),
        window_start=jnp.zeros((s,), jnp.int32),
        locked_until=jnp.zeros((s,), jnp.int32),
        total_rotates=jnp.zeros((), jnp.int32),
        total_flushed=jnp.zeros((), jnp.int32),
    )


def msb_index(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the most-significant non-zero bit; -1 for zero (Fig. 8's
    divider-free ratio detector operates on these)."""
    x32 = x.astype(jnp.uint32)
    clz = jax.lax.clz(x32)
    return jnp.where(x32 == 0, jnp.int32(-1), (31 - clz).astype(jnp.int32))


def wr_signal(state: WearState, cfg: WearConfig) -> jnp.ndarray:
    """WR=1 when msb(write_counter) - msb(superset_counter) >= wr_shift
    (the divider-free 512x ratio detector, Fig. 8)."""
    wmsb = msb_index(state.write_counter)
    smsb = msb_index(state.superset_counter)
    return ((wmsb - smsb) >= cfg.wr_shift) & (state.superset_counter > 0)


def rotate_signal(state: WearState, cfg: WearConfig) -> jnp.ndarray:
    wc = state.write_counter >= cfg.wc_limit
    dc = state.dirty_counter >= cfg.dc_limit
    return wr_signal(state, cfg) | wc | dc


def is_locked(state: WearState, superset: jnp.ndarray, cycle: jnp.ndarray) -> jnp.ndarray:
    return cycle < state.locked_until[superset]


def record_write(state: WearState, cfg: WearConfig, superset: jnp.ndarray,
                 makes_dirty: jnp.ndarray, cycle: jnp.ndarray):
    """Account one XAM write to ``superset`` at ``cycle``.

    Returns (new_state, rotated:bool, flushed_count:int32).
    Handles, in order: t_MWW window rollover, budget accounting + lock,
    SWT/counter updates, rotate detection + offset bump + SWT reset.
    """
    s = superset
    cycle = cycle.astype(jnp.int32)

    # --- t_MWW window (rollover arithmetic shared with the reject-
    # before-write predicate, see _window_now) ----------------------------
    win, expired, w_writes = _window_now(state, cfg, s, cycle)
    w_start = jnp.where(expired, cycle, state.window_start[s])
    w_writes = w_writes + 1
    over = w_writes > cfg.window_write_budget
    locked_until = jnp.where(over, w_start + win, state.locked_until[s])

    window_writes = state.window_writes.at[s].set(w_writes)
    window_start = state.window_start.at[s].set(w_start)
    locked = state.locked_until.at[s].set(locked_until)

    # --- SWT + counters (Fig. 8) ------------------------------------------
    first_write = state.swt_w[s] == 0
    superset_counter = state.superset_counter + jnp.where(first_write, 1, 0).astype(jnp.int32)
    swt_w = state.swt_w.at[s].set(1)
    newly_dirty = (state.swt_d[s] == 0) & makes_dirty
    dirty_counter = state.dirty_counter + jnp.where(newly_dirty, 1, 0).astype(jnp.int32)
    swt_d = state.swt_d.at[s].max(makes_dirty.astype(jnp.int8))
    write_counter = state.write_counter + 1

    mid = WearState(
        swt_w=swt_w, swt_d=swt_d,
        write_counter=write_counter, superset_counter=superset_counter,
        dirty_counter=dirty_counter, offsets=state.offsets,
        window_writes=window_writes, window_start=window_start,
        locked_until=locked,
        total_rotates=state.total_rotates, total_flushed=state.total_flushed,
    )

    rot = rotate_signal(mid, cfg)
    flushed = jnp.where(rot, jnp.sum(swt_d.astype(jnp.int32)), 0)

    def do_rotate(st: WearState) -> WearState:
        return WearState(
            swt_w=jnp.zeros_like(st.swt_w),
            swt_d=jnp.zeros_like(st.swt_d),
            write_counter=jnp.zeros_like(st.write_counter),
            superset_counter=jnp.zeros_like(st.superset_counter),
            dirty_counter=jnp.zeros_like(st.dirty_counter),
            offsets=geometry.apply_rotate(st.offsets),
            window_writes=st.window_writes,
            window_start=st.window_start,
            locked_until=st.locked_until,
            total_rotates=st.total_rotates + 1,
            total_flushed=st.total_flushed + flushed,
        )

    new_state = jax.lax.cond(rot, do_rotate, lambda st: st, mid)
    return new_state, rot, flushed


# ---------------------------------------------------------------------------
# Batched device ops.  The serving path (serve/kv_index.py), the hashtable
# app, and the differential tests all consume the SAME per-write semantics as
# the simulator — there is exactly one implementation of §8, this module —
# but amortize dispatch by applying a whole write trace per device call:
# ``record_writes`` is a ``lax.scan`` over ``record_write``, so it is
# step-for-step identical to the host loop while costing one dispatch.
# ---------------------------------------------------------------------------

def _window_now(state: WearState, cfg, superset, cycle):
    """THE t_MWW window-rollover arithmetic (one implementation, shared by
    ``record_write`` and ``window_would_exceed``): returns
    ``(win, expired, writes_now)`` for ``superset`` at ``cycle``."""
    win = jnp.maximum(jnp.asarray(cfg.t_mww_cycles, jnp.int32), 1)
    expired = (cycle - state.window_start[superset]) >= win
    writes_now = jnp.where(expired, 0, state.window_writes[superset])
    return win, expired, writes_now


def window_would_exceed(state: WearState, cfg, superset: jnp.ndarray,
                        cycle: jnp.ndarray) -> jnp.ndarray:
    """True when one more write to ``superset`` at ``cycle`` would blow the
    t_MWW window budget.  Admission controllers (cache mode serving) consult
    this BEFORE spending the XAM write — the §6.2 lifetime throttle as a
    reject-before-write predicate rather than the simulator's lock-after-
    overflow accounting.  ``cfg`` may be a WearConfig or a WearDyn."""
    cycle = jnp.asarray(cycle, jnp.int32)
    _, _, writes_now = _window_now(state, cfg, superset, cycle)
    return (writes_now + 1) > cfg.window_write_budget


def record_writes(state: WearState, cfg, supersets, makes_dirty, cycles,
                  active=None):
    """Batched :func:`record_write`: apply a trace of writes in order.

    supersets/makes_dirty/cycles : (B,) arrays; ``active`` (B,) bool masks
    padding lanes (pow2-bucketed callers) — an inactive lane is a no-op.
    Returns ``(state, rotated (B,) bool, flushed (B,) int32)``; the per-step
    outputs match a Python loop over ``record_write`` exactly (pinned by
    tests/test_wear.py's differential trace tests).
    """
    supersets = jnp.asarray(supersets, jnp.int32)
    makes_dirty = jnp.asarray(makes_dirty, bool)
    cycles = jnp.asarray(cycles, jnp.int32)
    act = (jnp.ones(supersets.shape, bool) if active is None
           else jnp.asarray(active, bool))

    def step(st, x):
        s, d, c, a = x
        st2, rot, fl = record_write(st, cfg, s, d, c)
        st = jax.tree.map(lambda o, n: jnp.where(a, n, o), st, st2)
        return st, (rot & a, jnp.where(a, fl, 0))

    state, (rots, fls) = jax.lax.scan(
        step, state, (supersets, makes_dirty, cycles, act))
    return state, rots, fls


#: Device entry point: donated state, one dispatch per write batch.
record_writes_device = functools.partial(
    jax.jit, donate_argnums=(0,))(record_writes)


#: Serving clock re-base threshold.  The cycle domain is int32 (JAX's
#: default integer width); a long-lived op-counter clock must be folded
#: back before it wraps.  Every window comparison is difference-based, so
#: shifting the clock AND every stored timestamp by the same delta is an
#: exact no-op semantically.
CLOCK_REBASE_AT = 1 << 30


def maybe_rebase(state: WearState, op_counter: int):
    """The serving wrap policy in one place: fold ``op_counter`` (and the
    state's timestamps, via :func:`rebase_clock`) once it reaches
    CLOCK_REBASE_AT.  Returns ``(state, op_counter)``."""
    if op_counter >= CLOCK_REBASE_AT:
        state = rebase_clock(state, CLOCK_REBASE_AT)
        op_counter -= CLOCK_REBASE_AT
    return state, op_counter


def rebase_clock(state: WearState, delta) -> WearState:
    """Shift all stored timestamps down by ``delta`` (callers shift their
    op counter in lockstep).  Timestamps are floored at -CLOCK_REBASE_AT so
    repeated rebases cannot underflow int32: an entry at the floor is, and
    behaves as, long-expired/unlocked (exact as long as window lengths are
    <= CLOCK_REBASE_AT, which the int32 ``t_mww_cycles`` domain and callers
    guarantee)."""
    d = jnp.asarray(delta, jnp.int32)
    floor = jnp.int32(-CLOCK_REBASE_AT)
    return dataclasses.replace(
        state,
        window_start=jnp.maximum(state.window_start - d, floor),
        locked_until=jnp.maximum(state.locked_until - d, floor),
    )


# ---------------------------------------------------------------------------
# L3-eviction write-mitigation filter (§8 "Mitigating Writes").
# D (dirty) and R (read-since-install) flags decide the fate of an evicted
# block:  D&R -> install/update in Monarch;  D&!R -> forward to main memory;
# !D&R -> install as read-only;  !D&!R -> drop.
# ---------------------------------------------------------------------------

def install_decision(dirty: jnp.ndarray, read: jnp.ndarray):
    """Returns (install_in_monarch, forward_to_dram)."""
    dirty = dirty.astype(bool)
    read = read.astype(bool)
    install = read  # D&R and !D&R install
    forward = dirty & ~read  # D&!R forwarded to DRAM
    return install, forward
