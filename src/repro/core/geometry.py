"""Monarch address geometry (paper §6, Figures 4 and 7).

Hierarchy (Table 3, 8 GB Monarch):

    8 vaults x 64 banks/vault x 256 supersets/bank x 8 sets/superset
      x 64 rows/set, one row = one 64 B block (512 bits across 8 subarrays).

Supersets are 8x8 grids of 64x64 XAM subarrays; the subarray at (i, j)
belongs to set k = (j - i) % 8 (diagonal arrangement, Fig. 4), which lets a
single 3-to-8 decoder + mode latch select the 8 subarrays of any set for
either row (RowIn) or column (ColumnIn) access.

The rotary wear-leveling offsets (§8) are applied here: vault/bank/superset/
set IDs are rotated by running offsets that the wear controller bumps by
distinct primes on every rotate signal.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

BLOCK_BYTES = 64
SET_WAYS = 512  # columns searchable per set == cache associativity


@dataclasses.dataclass(frozen=True)
class MonarchGeometry:
    """NOTE on Table 3 fidelity: the paper states an 8 GB stack but its
    listed fields (8 vaults x 64 banks x 256 supersets x 8 sets x 64 rows x
    64 B) multiply to 4 GB, and the same table lists both "64 banks/vault"
    and "32 banks/vault".  We keep the STATED capacity (8 GB) — it drives
    the iso-capacity comparisons — by using 512 supersets/bank, and record
    the discrepancy here and in DESIGN.md."""
    n_vaults: int = 8
    banks_per_vault: int = 64
    supersets_per_bank: int = 512
    sets_per_superset: int = 8
    rows_per_set: int = 64
    subarray_rows: int = 64
    subarray_cols: int = 64
    superset_grid: int = 8  # 8x8 subarrays

    @property
    def blocks_per_set(self) -> int:
        return self.rows_per_set

    @property
    def blocks_per_superset(self) -> int:
        return self.sets_per_superset * self.rows_per_set  # 512

    @property
    def total_supersets(self) -> int:
        return self.n_vaults * self.banks_per_vault * self.supersets_per_bank

    @property
    def total_blocks(self) -> int:
        return self.total_supersets * self.blocks_per_superset

    @property
    def capacity_bytes(self) -> int:
        return self.total_blocks * BLOCK_BYTES

    def scaled(self, factor: int) -> "MonarchGeometry":
        """Uniformly scale down vault*bank*superset counts for simulation
        (ratios preserved; per-set geometry untouched)."""
        assert factor >= 1
        ss = max(self.supersets_per_bank // factor, 1)
        return dataclasses.replace(self, supersets_per_bank=ss)


GEOM_8GB = MonarchGeometry()
assert GEOM_8GB.capacity_bytes == 8 * 1024 ** 3


# ---------------------------------------------------------------------------
# Diagonal set selection (Fig. 4).
# ---------------------------------------------------------------------------

def set_of_subarray(i: int | jnp.ndarray, j: int | jnp.ndarray, grid: int = 8):
    """Set id of the subarray at superset grid position (row i, col j)."""
    return (j - i) % grid


def subarrays_of_set(k: int, grid: int = 8):
    """The 8 (i, j) positions selected for set k — one per grid row."""
    return [(i, (i + k) % grid) for i in range(grid)]


def port_select(k: int, mode_column_in: bool, grid: int = 8):
    """Which port (row/column) each selected subarray drives, per the port
    selector's mode latch.  Returns [(i, j, port)] with port in
    {"col", "row"}."""
    port = "col" if mode_column_in else "row"
    return [(i, j, port) for (i, j) in subarrays_of_set(k, grid)]


# ---------------------------------------------------------------------------
# Set-axis sharding (serving): contiguous-block ownership of the set planes.
# The serving index (serve/kv_index.py) splits its n_sets CAM sets across
# n_shards mesh devices; these helpers are THE shard-address arithmetic, so
# host grouping, admission fan-out and the rotation remap all agree on which
# shard owns which physical set.
# ---------------------------------------------------------------------------


def sets_per_shard(n_sets: int, n_shards: int) -> int:
    """Sets owned by each shard under contiguous-block ownership.

    Parameters
    ----------
    n_sets : int
        Total (global) CAM set count.
    n_shards : int
        Shard count; must divide ``n_sets`` evenly so every shard's plane
        arrays share one compiled shape.

    Returns
    -------
    int
        ``n_sets // n_shards``.

    Examples
    --------
    >>> sets_per_shard(8, 4)
    2
    """
    if n_shards < 1 or n_sets % n_shards != 0:
        raise ValueError(
            f"n_shards={n_shards} must be >=1 and divide n_sets={n_sets}")
    return n_sets // n_shards


def shard_of_set(set_ids, n_sets: int, n_shards: int):
    """Decompose global physical set ids into ``(shard, local_set)``.

    Shard ``k`` owns the contiguous block of global sets
    ``[k * sets_per_shard, (k + 1) * sets_per_shard)`` — a pure relabeling,
    so the fingerprint -> physical-set mapping (and therefore every hit,
    install and wear decision) is independent of the shard count.

    Parameters
    ----------
    set_ids : array_like of int
        Global physical set ids in ``[0, n_sets)``.
    n_sets, n_shards : int
        Global set count and shard count (``n_shards`` divides ``n_sets``).

    Returns
    -------
    (shard, local) : tuple of arrays
        ``shard[i]`` owns query i's set; ``local[i]`` is the row inside
        that shard's ``(sets_per_shard, ...)`` plane arrays.
    """
    s_local = sets_per_shard(n_sets, n_shards)
    return set_ids // s_local, set_ids % s_local


def shard_set_slice(shard: int, n_sets: int, n_shards: int) -> slice:
    """Global-set slice owned by ``shard`` (contiguous-block ownership)."""
    s_local = sets_per_shard(n_sets, n_shards)
    return slice(shard * s_local, (shard + 1) * s_local)


def shard_roll_plan(shift: int, n_sets: int, n_parts: int):
    """Decompose a GLOBAL cyclic set roll into per-shard collectives.

    The serving index's rotary remap is ``new[g] = old[(g - shift) mod
    n_sets]`` over the whole set axis.  With contiguous-block sharding
    (``s_loc = n_sets // n_parts`` sets per shard) the same permutation
    factors into shard-local arithmetic: write ``shift = q * s_loc + r``
    with ``0 <= r < s_loc``.  Then destination shard ``k`` assembles its
    new plane from exactly TWO sources —

    * rows ``[r, s_loc)``  <- shard ``(k - q) mod n_parts``, rows
      ``[0, s_loc - r)`` (the bulk that stays block-aligned), and
    * rows ``[0, r)``      <- shard ``(k - q - 1) mod n_parts``, rows
      ``[s_loc - r, s_loc)`` (the ``r`` boundary sets that cross a shard
      edge under the global permutation)

    — i.e. each source shard ``j`` ppermutes its low ``s_loc - r`` rows
    to shard ``j + q`` and its high ``r`` rows to shard ``j + q + 1``.
    A slab whose shard permutation is the identity never leaves its
    device: the common small-stride case (``q == 0``) is a pure local
    roll plus a boundary exchange of only the ``r`` edge sets.

    Parameters
    ----------
    shift : int
        Global roll amount in sets (the serving index uses the prime
        stride 7 mod ``n_sets``).
    n_sets, n_parts : int
        Global set count and shard count (``n_parts`` divides
        ``n_sets``).

    Returns
    -------
    (q, r, low_perm, high_perm) : tuple
        ``q``/``r`` as above; ``low_perm``/``high_perm`` are the
        ``(source, destination)`` pair lists for ``jax.lax.ppermute`` of
        the low/high slabs, or ``None`` when that slab stays device-local
        (identity permutation, or — for ``high_perm`` — when ``r == 0``
        and there is no boundary slab at all).

    Examples
    --------
    >>> shard_roll_plan(7, 8, 4)    # stride 7, 2/shard: boundary is local
    (3, 1, [(0, 3), (1, 0), (2, 1), (3, 2)], None)
    >>> shard_roll_plan(1, 8, 4)    # pure boundary exchange
    (0, 1, None, [(0, 1), (1, 2), (2, 3), (3, 0)])
    >>> shard_roll_plan(2, 8, 4)    # whole-block permutation
    (1, 0, [(0, 1), (1, 2), (2, 3), (3, 0)], None)
    """
    s_loc = sets_per_shard(n_sets, n_parts)
    if not 0 < shift < n_sets:
        raise ValueError(f"shift={shift} must be in (0, {n_sets})")
    q, r = divmod(shift, s_loc)
    low_perm = ([(j, (j + q) % n_parts) for j in range(n_parts)]
                if q % n_parts != 0 else None)
    high_perm = ([(j, (j + q + 1) % n_parts) for j in range(n_parts)]
                 if r != 0 and (q + 1) % n_parts != 0 else None)
    return q, r, low_perm, high_perm


# ---------------------------------------------------------------------------
# Rotary offsets (§8): primes per level, vault bumped every 8th rotate.
# ---------------------------------------------------------------------------

ROTATE_PRIMES = {"bank": 1, "set": 3, "vault": 5, "superset": 7}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RotaryOffsets:
    vault: jnp.ndarray  # scalar int32
    bank: jnp.ndarray
    superset: jnp.ndarray
    set_: jnp.ndarray
    rotate_count: jnp.ndarray


def zero_offsets() -> RotaryOffsets:
    # Five DISTINCT zero buffers: sharing one array across fields makes any
    # donated-state op over a fresh state an XLA double-donation error.
    z = lambda: jnp.zeros((), jnp.int32)
    return RotaryOffsets(z(), z(), z(), z(), z())


def apply_rotate(off: RotaryOffsets) -> RotaryOffsets:
    """Bump offsets by the unique primes; vault only every 8 rotates."""
    rc = off.rotate_count + 1
    vault = off.vault + jnp.where(rc % 8 == 0, ROTATE_PRIMES["vault"], 0)
    return RotaryOffsets(
        vault=vault.astype(jnp.int32),
        bank=(off.bank + ROTATE_PRIMES["bank"]).astype(jnp.int32),
        superset=(off.superset + ROTATE_PRIMES["superset"]).astype(jnp.int32),
        set_=(off.set_ + ROTATE_PRIMES["set"]).astype(jnp.int32),
        rotate_count=rc.astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Address decomposition.  Linear block address -> physical coordinates.
# Bit layout (low to high): set-row | set | superset | bank | vault, so that
# consecutive blocks stride rows first (good spatial locality within a set),
# matching the paper's row-major block packing.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockCoord:
    vault: jnp.ndarray
    bank: jnp.ndarray
    superset: jnp.ndarray
    set_: jnp.ndarray
    row: jnp.ndarray

    def flat_superset(self, g: MonarchGeometry) -> jnp.ndarray:
        return (
            self.vault * g.banks_per_vault + self.bank
        ) * g.supersets_per_bank + self.superset


def decompose(block_addr: jnp.ndarray, g: MonarchGeometry,
              off: RotaryOffsets | None = None) -> BlockCoord:
    a = block_addr.astype(jnp.int32) if hasattr(block_addr, "astype") else jnp.asarray(block_addr, jnp.int32)
    row = a % g.rows_per_set
    a = a // g.rows_per_set
    set_ = a % g.sets_per_superset
    a = a // g.sets_per_superset
    superset = a % g.supersets_per_bank
    a = a // g.supersets_per_bank
    bank = a % g.banks_per_vault
    a = a // g.banks_per_vault
    vault = a % g.n_vaults
    if off is not None:
        vault = (vault + off.vault) % g.n_vaults
        bank = (bank + off.bank) % g.banks_per_vault
        superset = (superset + off.superset) % g.supersets_per_bank
        set_ = (set_ + off.set_) % g.sets_per_superset
    to32 = lambda x: x.astype(jnp.int32)
    return BlockCoord(to32(vault), to32(bank), to32(superset), to32(set_), to32(row))


def compose(c: BlockCoord, g: MonarchGeometry) -> jnp.ndarray:
    """Inverse of decompose (without offsets)."""
    a = c.vault.astype(jnp.int32)
    a = a * g.banks_per_vault + c.bank
    a = a * g.supersets_per_bank + c.superset
    a = a * g.sets_per_superset + c.set_
    a = a * g.rows_per_set + c.row
    return a


# ---------------------------------------------------------------------------
# Fig. 7: coordinated RAM <-> CAM mapping for cache mode.  Data blocks live
# in RAM banks; their tags live in CAM banks of the SAME vault with the same
# superset ID.  Every RAM superset (512 blocks) corresponds to one CAM set
# (512 tag columns); the RAM bank ID supplies the CAM set / key / bank bits.
# With 32b tags, each 64-bit column stores two tags; key_id selects which.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CamCoord:
    bank: jnp.ndarray     # CAM bank within the vault's CAM partition
    set_: jnp.ndarray     # set within the CAM superset
    key_id: jnp.ndarray   # which tag of the column (0: low half, 1: high)


def ram_to_cam(ram_bank: jnp.ndarray, g: MonarchGeometry,
               n_cam_banks: int = 2) -> CamCoord:
    """Map a RAM bank id to the (cam_bank, set, key_id) holding its tags.

    The RAM partition has g.banks_per_vault - n_cam_banks banks; each CAM
    set serves one RAM superset; more-significant bits become the key ID to
    minimize mask-register updates (paper §7).
    """
    b = ram_bank.astype(jnp.int32)
    sets_per_cam_bank = g.sets_per_superset * g.supersets_per_bank
    cam_bank = b // (sets_per_cam_bank // max(1, 1))  # folded below
    # Interleave: low bits pick the set, next bit the cam bank, top the key.
    set_ = b % g.sets_per_superset
    rest = b // g.sets_per_superset
    cam_bank = rest % n_cam_banks
    key_id = rest // n_cam_banks
    return CamCoord(cam_bank.astype(jnp.int32), set_.astype(jnp.int32),
                    key_id.astype(jnp.int32))
