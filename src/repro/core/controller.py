"""Monarch vault controllers (paper §7, Fig. 5/6/7).

Three control modes:

* ``flat-RAM``  — software scratchpad; read/write only; controller tracks
  per-bank mode flags and issues prepare/activate toggles as needed.
* ``flat-CAM``  — software associative scratchpad; recognizes data write,
  key/mask write (RowIn CAM, odd row -> mask, even row -> key), search
  (read of the match pointer), and data read.  Key/mask live in global vault
  registers and are pushed to supersets lazily; searches are elided when the
  match register already holds a fresh result.
* ``cache``     — hardware-managed 512-way set-associative cache; CAM banks
  hold tags (two 32-bit tags per 64-bit column), RAM banks hold data, with
  the Fig. 7 coordinated address mapping, no-allocate fills, D/R-flag
  selective installation, and random-counter replacement.

The controllers are written as explicit-state step functions: every request
returns (new_state, CommandTrace) where the trace records which interface
commands (P/A/R/W/S) were issued — that is what the timing model consumes,
and what the tests assert on (e.g. "consecutive searches on the same
superset do not re-send key/mask").

Bank modes: RAM=0, CAM=1 (prepare toggles).  Superset datapath: RowIn=0,
ColumnIn=1 (activate toggles).  Initial mode of every bank is RAM (paper
§6.2), default datapath RowIn.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import wear, xam

RAM, CAM = 0, 1
ROW_IN, COL_IN = 0, 1


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CommandCounts:
    """Interface commands issued while serving one request."""
    prepares: jnp.ndarray
    activates: jnp.ndarray
    reads: jnp.ndarray
    writes: jnp.ndarray
    searches: jnp.ndarray

    @staticmethod
    def zero() -> "CommandCounts":
        z = jnp.zeros((), jnp.int32)
        return CommandCounts(z, z, z, z, z)

    def __add__(self, o: "CommandCounts") -> "CommandCounts":
        return CommandCounts(
            self.prepares + o.prepares, self.activates + o.activates,
            self.reads + o.reads, self.writes + o.writes,
            self.searches + o.searches,
        )


def _count(prepares=0, activates=0, reads=0, writes=0, searches=0) -> CommandCounts:
    a = lambda v: jnp.asarray(v, jnp.int32)
    return CommandCounts(a(prepares), a(activates), a(reads), a(writes), a(searches))


# ===========================================================================
# flat-CAM controller over a single superset's worth of sets.
# ===========================================================================

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlatCamState:
    """One vault's flat-CAM control state plus the XAM contents of a
    superset (8 sets x 64 rows x 512 cols logical planes)."""
    sets_bits: jnp.ndarray        # (n_sets, 64, 512) int8 — XAM planes
    key_reg: jnp.ndarray          # (64,) int8 — global key register
    mask_reg: jnp.ndarray         # (64,) int8 — global mask register
    match_reg: jnp.ndarray        # scalar int32 — match pointer (-1 = NULL)
    match_fresh: jnp.ndarray      # scalar bool — result valid for cur key/mask
    superset_has_latest_km: jnp.ndarray  # scalar bool — key/mask pushed down
    bank_mode: jnp.ndarray        # scalar int32 RAM/CAM
    datapath: jnp.ndarray         # scalar int32 RowIn/ColumnIn


def init_flat_cam(n_sets: int = 8, rows: int = 64, cols: int = 512) -> FlatCamState:
    return FlatCamState(
        sets_bits=jnp.zeros((n_sets, rows, cols), jnp.int8),
        key_reg=jnp.zeros((rows,), jnp.int8),
        mask_reg=jnp.ones((rows,), jnp.int8),
        match_reg=jnp.asarray(-1, jnp.int32),
        match_fresh=jnp.asarray(False),
        superset_has_latest_km=jnp.asarray(False),
        bank_mode=jnp.asarray(RAM, jnp.int32),
        datapath=jnp.asarray(ROW_IN, jnp.int32),
    )


def _transition(state: FlatCamState, want_mode, want_path):
    """Issue prepare/activate as needed to reach (mode, datapath)."""
    p = (state.bank_mode != want_mode).astype(jnp.int32)
    a = (state.datapath != want_path).astype(jnp.int32)
    st = dataclasses.replace(
        state,
        bank_mode=jnp.asarray(want_mode, jnp.int32),
        datapath=jnp.asarray(want_path, jnp.int32),
    )
    return st, _count(prepares=p, activates=a)


def cam_data_write(state: FlatCamState, set_id, col, key_bits) -> tuple[FlatCamState, CommandCounts]:
    """Store a key down a column of a set (ColumnIn CAM, §7)."""
    state, c0 = _transition(state, CAM, COL_IN)
    bits = state.sets_bits
    col_onehot = (jnp.arange(bits.shape[2]) == col)
    new_plane = jnp.where(col_onehot[None, :], key_bits.astype(jnp.int8)[:, None],
                          bits[set_id])
    bits = bits.at[set_id].set(new_plane)
    st = dataclasses.replace(state, sets_bits=bits,
                             match_fresh=jnp.asarray(False))
    return st, c0 + _count(writes=1)


def key_mask_write(state: FlatCamState, row_addr, value_bits) -> tuple[FlatCamState, CommandCounts]:
    """Software write to the key/mask pointers.  RowIn CAM mode: even row
    address -> key register, odd -> mask register (§6.2)."""
    state, c0 = _transition(state, CAM, ROW_IN)
    is_mask = (row_addr % 2).astype(bool)
    key = jnp.where(is_mask, state.key_reg, value_bits.astype(jnp.int8))
    mask = jnp.where(is_mask, value_bits.astype(jnp.int8), state.mask_reg)
    st = dataclasses.replace(
        state, key_reg=key, mask_reg=mask,
        match_fresh=jnp.asarray(False),
        superset_has_latest_km=jnp.asarray(False),
    )
    return st, c0 + _count(writes=1)


def search_read(state: FlatCamState, set_id) -> tuple[FlatCamState, jnp.ndarray, CommandCounts]:
    """Software read of the match pointer: triggers key/mask push + search
    only when the match register does not already hold a fresh result
    (§7 'the controller will issue a search ... if the results of previous
    search is not present')."""

    def fresh(st: FlatCamState):
        return st, st.match_reg, CommandCounts.zero()

    def stale(st: FlatCamState):
        # Push key/mask if the superset copy is out of date (1 write burst).
        km_writes = jnp.where(st.superset_has_latest_km, 0, 1)
        st, c_t = _transition(st, CAM, COL_IN)
        plane = st.sets_bits[set_id]
        arr = xam.XamArray(plane, jnp.zeros_like(plane, jnp.int32))
        _, idx = xam.set_search(arr, st.key_reg, st.mask_reg)
        st = dataclasses.replace(
            st, match_reg=idx.astype(jnp.int32),
            match_fresh=jnp.asarray(True),
            superset_has_latest_km=jnp.asarray(True),
        )
        return st, idx.astype(jnp.int32), c_t + _count(searches=1, writes=km_writes)

    return jax.lax.cond(state.match_fresh, fresh, stale, state)


def cam_data_write_tracked(state: FlatCamState, wstate: wear.WearState,
                           wcfg, set_id, col, key_bits, superset, cycle):
    """flat-CAM data write with §8 wear accounting fused into the command
    trace: the write command charged by the controller is the SAME event
    the wear state records (one implementation — ``wear.record_write`` —
    shared with the cache-mode simulator and the serving index).

    Returns ``(state, wstate, rotated, counts)``; ``rotated`` is the §8
    rotate signal so the caller can remap placement.
    """
    state, counts = cam_data_write(state, set_id, col, key_bits)
    wstate, rotated, _flushed = wear.record_write(
        wstate, wcfg, jnp.asarray(superset, jnp.int32),
        jnp.asarray(True), jnp.asarray(cycle, jnp.int32))
    return state, wstate, rotated, counts


def cam_row_read(state: FlatCamState, set_id, row) -> tuple[FlatCamState, jnp.ndarray, CommandCounts]:
    """Read stored keys back out (footnote 1: row-mode read)."""
    state, c0 = _transition(state, CAM, ROW_IN)
    data = state.sets_bits[set_id][row]
    return state, data, c0 + _count(reads=1)


# ===========================================================================
# Cache-mode controller (functional hit/miss engine).
#
# The timing simulator uses this vectorized tag engine; a bit-level
# equivalence test pins it to the XAM search semantics on small sizes.
# Layout per Fig. 7: one CAM set (512 tag columns) serves one RAM superset
# (512 data blocks).  Replacement: shared free-running 9-bit counter.
# ===========================================================================

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CacheState:
    tags: jnp.ndarray       # (n_sets, ways) int64 — stored tags
    valid: jnp.ndarray      # (n_sets, ways) int8
    dirty: jnp.ndarray      # (n_sets, ways) int8
    counter: jnp.ndarray    # scalar int32 — free-running replacement counter


def init_cache(n_sets: int, ways: int = 512) -> CacheState:
    return CacheState(
        tags=jnp.zeros((n_sets, ways), jnp.int32),
        valid=jnp.zeros((n_sets, ways), jnp.int8),
        dirty=jnp.zeros((n_sets, ways), jnp.int8),
        counter=jnp.zeros((), jnp.int32),
    )


def cache_lookup(state: CacheState, set_id, tag):
    """One CAM search: returns (hit, way)."""
    line = (state.tags[set_id] == tag) & (state.valid[set_id] == 1)
    hit = jnp.any(line)
    way = jnp.argmax(line)
    return hit, way.astype(jnp.int32)


def cache_install(state: CacheState, set_id, tag, make_dirty):
    """Install per §7: prefer an invalid way (found by a RAM-mode row read of
    the valid bits); else prefer a clean way near the rotating counter; else
    evict dirty at the counter.  Returns (state, evicted_dirty, way)."""
    ways = state.tags.shape[1]
    valid_row = state.valid[set_id]
    dirty_row = state.dirty[set_id]

    # All way choices walk from the shared free-running counter (paper §8):
    # this spaces two installs at a physical location by >= `ways`
    # evictions, which is what levels wear WITHIN a superset.
    start = state.counter % ways
    order = (jnp.arange(ways) + start) % ways
    invalid = (valid_row[order] == 0)
    has_invalid = jnp.any(invalid)
    inv_way = order[jnp.argmax(invalid)]
    clean = (dirty_row[order] == 0)
    has_clean = jnp.any(clean)
    clean_way = order[jnp.argmax(clean)]
    ctr_way = order[0]

    way = jnp.where(has_invalid, inv_way,
                    jnp.where(has_clean, clean_way, ctr_way)).astype(jnp.int32)
    evicted_dirty = (~has_invalid) & (~has_clean) & (dirty_row[ctr_way] == 1)

    new = CacheState(
        tags=state.tags.at[set_id, way].set(tag),
        valid=state.valid.at[set_id, way].set(1),
        dirty=state.dirty.at[set_id, way].set(make_dirty.astype(jnp.int8)),
        counter=state.counter + 1,
    )
    return new, evicted_dirty, way


def dirty_set_mask(state: CacheState) -> jnp.ndarray:
    """(n_sets,) bool — sets holding at least one dirty line; the rotation
    flush in the simulator invalidates exactly these."""
    return state.dirty.sum(axis=1) > 0


def cache_invalidate_sets(state: CacheState, set_mask: jnp.ndarray):
    """Flush whole sets (rotation): returns (state, n_dirty_written_back)."""
    dirty_per_set = jnp.sum(state.dirty * state.valid, axis=1)
    flushed = jnp.sum(jnp.where(set_mask, dirty_per_set, 0))
    keep = (~set_mask)[:, None]
    return CacheState(
        tags=state.tags,
        valid=jnp.where(keep, state.valid, 0).astype(jnp.int8),
        dirty=jnp.where(keep, state.dirty, 0).astype(jnp.int8),
        counter=state.counter,
    ), flushed.astype(jnp.int32)
