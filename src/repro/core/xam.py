"""Bit-accurate functional model of the XAM reconfigurable RAM/CAM array.

The XAM array (paper §4) is a crosspoint of differential 2R memristive
cells.  Each cell stores one bit as a (R, R̄) resistance pair.  The array
supports four data-plane operations:

* ``write_row``    — two-step row write (0s first, then 1s), §4.1.1
* ``write_col``    — two-step column write, §4.1.2 (enabled by the 2R cell)
* ``read_row``     — voltage-divider row read against Ref_R, §4.2.1
* ``search``       — masked parallel match of a key against ALL columns
                     (in-situ XNOR + analog column sum vs Ref_S), §4.2.2

Everything here is pure-functional JAX on {0,1} int8 bit planes so it can
run under ``jax.jit`` / ``lax.scan`` and serve as the oracle for the Pallas
kernels in ``repro.kernels``.

Wear model: per the paper's evaluation assumption ("the write voltage is
constant for every write across both resistors"), every cell on an active
row/column receives a programming pulse on each write regardless of whether
its value changes — so wear increments for the full written line.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# Canonical XAM array geometry (paper §6 / Table 3): 64 x 64 bit subarrays.
N_ROWS = 64
N_COLS = 64


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class XamArray:
    """State of one XAM subarray.

    bits        : (n_rows, n_cols) int8 in {0,1} — logical cell contents.
    cell_writes : (n_rows, n_cols) int32 — cumulative programming pulses
                  (wear), used by the lifetime model.
    """

    bits: jnp.ndarray
    cell_writes: jnp.ndarray

    @property
    def n_rows(self) -> int:
        return self.bits.shape[0]

    @property
    def n_cols(self) -> int:
        return self.bits.shape[1]


def make_array(n_rows: int = N_ROWS, n_cols: int = N_COLS) -> XamArray:
    return XamArray(
        bits=jnp.zeros((n_rows, n_cols), jnp.int8),
        cell_writes=jnp.zeros((n_rows, n_cols), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Writes (two-step: 0s then 1s).  The two steps are modeled explicitly so the
# tests can check the voltage-discipline invariant: step-1 touches exactly the
# cells receiving a 0, step-2 exactly the cells receiving a 1, and cells on
# inactive lines are never disturbed (V/2 half-select).
# ---------------------------------------------------------------------------

def write_row_steps(arr: XamArray, row: jnp.ndarray, data: jnp.ndarray):
    """Return (new_array, step0_mask, step1_mask) for writing ``data`` into
    row ``row``.  data: (n_cols,) bits."""
    data = data.astype(jnp.int8)
    row_onehot = (jnp.arange(arr.n_rows) == row).astype(jnp.int8)  # (R,)
    # Step 1: active h_line at G, v_lines of input-0 at V  -> program 0s.
    step0 = row_onehot[:, None] * (1 - data)[None, :]
    # Step 2: active h_line switched to V -> program 1s.
    step1 = row_onehot[:, None] * data[None, :]
    new_bits = jnp.where(row_onehot[:, None] == 1, data[None, :], arr.bits)
    # Full-line programming pulse (constant write voltage assumption).
    new_wear = arr.cell_writes + row_onehot[:, None].astype(jnp.int32)
    return XamArray(new_bits.astype(jnp.int8), new_wear), step0, step1


def write_row(arr: XamArray, row: jnp.ndarray, data: jnp.ndarray) -> XamArray:
    new_arr, _, _ = write_row_steps(arr, row, data)
    return new_arr


def write_col_steps(arr: XamArray, col: jnp.ndarray, data: jnp.ndarray):
    """Column write (§4.1.2): data fed through the ROW drivers; one column
    active, others half-selected at V/2.  data: (n_rows,) bits."""
    data = data.astype(jnp.int8)
    col_onehot = (jnp.arange(arr.n_cols) == col).astype(jnp.int8)  # (C,)
    step0 = (1 - data)[:, None] * col_onehot[None, :]
    step1 = data[:, None] * col_onehot[None, :]
    new_bits = jnp.where(col_onehot[None, :] == 1, data[:, None], arr.bits)
    new_wear = arr.cell_writes + col_onehot[None, :].astype(jnp.int32)
    return XamArray(new_bits.astype(jnp.int8), new_wear), step0, step1


def write_col(arr: XamArray, col: jnp.ndarray, data: jnp.ndarray) -> XamArray:
    new_arr, _, _ = write_col_steps(arr, col, data)
    return new_arr


# ---------------------------------------------------------------------------
# Reads and searches.
# ---------------------------------------------------------------------------

def read_row(arr: XamArray, row: jnp.ndarray) -> jnp.ndarray:
    """Row read (§4.2.1).  The voltage divider develops ~G for a stored 0 and
    ~V_R for a stored 1; sensing against Ref_R = V_R/2 recovers the bit."""
    return jnp.take(arr.bits, row, axis=0)


def search_voltages(
    bits: jnp.ndarray, key: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Analog model of the CAM search (§4.2.2): returns the normalized
    column line voltage in [0, 1] (fraction of V_R).

    A cell whose low-resistance element is pulled to ground (bit mismatch)
    pulls its column voltage down.  With H >> L, the column voltage is
    approximately V_R * H*n_match_paths/(...); the discriminating quantity is
    simply whether ANY selected cell mismatches.  We model the normalized
    voltage as 1 - (#mismatches)/(#selected) scaled into the sensing range so
    Ref_S sits between "all match" and "one mismatch".
    """
    key = key.astype(jnp.int8)
    mask = mask.astype(jnp.int8)
    # XNOR per selected cell: 1 where cell bit == key bit.
    xnor = (bits == key[:, None]).astype(jnp.int32)
    mism = jnp.sum(mask[:, None].astype(jnp.int32) * (1 - xnor), axis=0)
    n_sel = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)
    return 1.0 - mism.astype(jnp.float32) / n_sel.astype(jnp.float32)


def ref_s(n_selected: jnp.ndarray) -> jnp.ndarray:
    """Sensing reference between all-match (1.0) and single-mismatch
    (1 - 1/n) normalized voltages."""
    n = jnp.maximum(n_selected, 1).astype(jnp.float32)
    return 1.0 - 0.5 / n


def search(arr: XamArray, key: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Masked parallel search.  key, mask: (n_rows,) bits.  Returns
    (n_cols,) int8 match vector: 1 iff every unmasked key bit equals the
    stored column bit."""
    v = search_voltages(arr.bits, key, mask)
    n_sel = jnp.sum(mask.astype(jnp.int32))
    return (v > ref_s(n_sel)).astype(jnp.int8)


def search_digital(arr: XamArray, key, mask) -> jnp.ndarray:
    """Digital oracle for search (no analog model) — used in property tests
    to pin the analog threshold model to the boolean semantics."""
    key = key.astype(jnp.int8)
    mask = mask.astype(jnp.int8)
    eq = (arr.bits == key[:, None]) | (mask[:, None] == 0)
    return jnp.all(eq, axis=0).astype(jnp.int8)


# ---------------------------------------------------------------------------
# Set-level helpers.  One Monarch *set* spans 8 subarrays of 64x64 selected
# diagonally inside a superset, i.e. a logical 64-row x 512-column XAM plane.
# Blocks (64B = 512 bits) are written row-wise across the 8 subarrays; tags /
# keys are stored column-wise (two 32-bit tags per 64-bit column, §7).
# ---------------------------------------------------------------------------

SET_COLS = 8 * N_COLS  # 512 columns searchable in one command


def make_set(n_rows: int = N_ROWS, n_cols: int = SET_COLS) -> XamArray:
    return make_array(n_rows, n_cols)


@partial(jax.jit, static_argnames=())
def set_search(arr: XamArray, key: jnp.ndarray, mask: jnp.ndarray):
    """Search a whole set; returns (match_vector, match_index) where
    match_index is the lowest matching column or -1 (the paper's match
    register resets to NULL on no-match)."""
    matches = search(arr, key, mask)
    any_match = jnp.any(matches == 1)
    idx = jnp.argmax(matches)  # lowest index with a 1
    return matches, jnp.where(any_match, idx, -1)


def pack_block_rowwise(arr: XamArray, row: jnp.ndarray, block_bits: jnp.ndarray) -> XamArray:
    """Write one 512-bit block across a set's row (RowIn RAM mode)."""
    return write_row(arr, row, block_bits)


def store_key_colwise(arr: XamArray, col: jnp.ndarray, key_bits: jnp.ndarray) -> XamArray:
    """Store a key/tag down a column (ColumnIn CAM mode)."""
    return write_col(arr, col, key_bits)


# ---------------------------------------------------------------------------
# Packed plane views.  The functional model keeps one logical bit per int8
# cell (the physical picture: one differential 2R cell per bit), but the
# serving kernels may STORE a plane packed 8 bits per uint8 word along the
# row axis (``plane_format="packed8"`` — kernels/common.py).  The search is
# bit-serial in the paper's sense, so the packed view is a pure re-layout:
# these twins pin the layout contract at the model level.
# ---------------------------------------------------------------------------

def packed_view(bits: jnp.ndarray) -> jnp.ndarray:
    """Row-packed view of a {0,1} bit plane: logical row ``r`` lands in
    packed word ``r // 8`` at bit position ``r % 8`` (LSB-first — the
    same convention as ``words_to_bits``).  Rows must be a multiple of 8.

    >>> import numpy as np
    >>> plane = jnp.zeros((8, 2), jnp.int8).at[0, 0].set(1).at[2, 0].set(1)
    >>> np.asarray(packed_view(plane)).tolist()   # bit0 + bit2 = 5
    [[5, 0]]
    >>> bool((unpacked_view(packed_view(plane)) == plane).all())
    True
    """
    r, c = bits.shape
    if r % 8 != 0:
        raise ValueError(
            f"row count {r} is not a multiple of 8; pad with zero rows "
            "before packing")
    words = bits.astype(jnp.uint8).reshape(r // 8, 8, c)
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    return jnp.sum(words << shifts, axis=1).astype(jnp.uint8)


def unpacked_view(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`packed_view`: (R//8, C) uint8 words back to the
    (R, C) int8 bit plane the functional model operates on."""
    rp, c = packed.shape
    shifts = jnp.arange(8, dtype=jnp.uint8)[None, :, None]
    bits = (packed[:, None, :] >> shifts) & 1
    return bits.reshape(rp * 8, c).astype(jnp.int8)
