"""Timing, energy, and lifetime parameters (paper Tables 1-3, §6.2, §8).

All interface timings are in CPU cycles at 3.2 GHz, exactly as listed in
Table 3.  Table 1 gives per-operation latency/energy/area for a 32 KB
building block in each candidate technology; we carry the full table so the
technology-selection study (benchmark `table1_tech`) reproduces §5.
"""
from __future__ import annotations

import dataclasses

CPU_HZ = 3.2e9
SECONDS_PER_CYCLE = 1.0 / CPU_HZ


# ---------------------------------------------------------------------------
# Table 1 — 32KB building block per technology.
# latency ns, energy nJ, area mm^2.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Table1Row:
    read_ns: float
    write_ns: float
    search_ns: float
    read_nj: float
    write_nj: float
    search_nj: float
    area_mm2: float


TABLE1 = {
    "SRAM":      Table1Row(0.2334, 0.1892, 14.9395, 0.015, 0.0196, 0.9627, 0.0331),
    "SCAM":      Table1Row(32.2385, 0.2167, 0.5037, 0.2329, 0.0139, 0.1273, 0.111),
    "SRAM+SCAM": Table1Row(0.2334, 0.2167, 0.5037, 0.015, 0.0335, 0.1273, 0.144),
    "DRAM":      Table1Row(2.5945, 2.1874, 166.0499, 0.0657, 0.058, 4.4544, 0.0169),
    "1R RAM":    Table1Row(1.654, 20.258, 105.856, 0.0214, 0.325, 1.623, 0.0104),
    "2T2R CAM":  Table1Row(122.048, 20.825, 3.36, 2.7156, 1.29, 0.0472, 0.0153),
    "1R+2T2R":   Table1Row(1.654, 20.825, 3.36, 0.0214, 1.61, 0.0472, 0.0258),
    "2R XAM":    Table1Row(1.7734, 20.323, 3.2264, 0.0215, 0.652, 0.0263, 0.0124),
}


# ---------------------------------------------------------------------------
# Table 3 — interface timing per memory system (CPU cycles @ 3.2 GHz).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InterfaceTiming:
    tRCD: int
    tCAS: int
    tCCD: int
    tWTR: int
    tWR: int
    tRTP: int
    tBL: int
    tCWD: int
    tRP: int
    tRRD: int
    tRAS: int
    tRC: int
    tFAW: int
    # Structural properties of the stack.
    n_vaults: int = 8
    banks_per_vault: int = 8
    needs_precharge: bool = True      # DRAM row-buffer discipline
    needs_refresh: bool = True
    refresh_overhead: float = 0.05    # fraction of time unavailable
    capacity_mb: int = 4096

    # Derived service latencies for the queuing model -------------------
    def read_latency(self, row_hit: bool = False) -> int:
        base = self.tCAS + self.tBL
        if self.needs_precharge and not row_hit:
            return self.tRP + self.tRCD + base
        if not self.needs_precharge:
            return self.tRCD + base
        return base  # open-row hit

    def write_latency(self) -> int:
        return self.tCWD + self.tWR + self.tBL

    def search_latency(self) -> int:
        # Search = read with Ref_S (same datapath); technologies without
        # parallel search must stream the whole set -> modeled by caller.
        return self.tRCD + self.tCAS + self.tBL

    def bank_occupancy_read(self) -> int:
        return max(self.tCCD, self.tRC if self.needs_precharge else self.tCCD)

    def bank_occupancy_write(self) -> int:
        return max(self.tCCD, self.tWR)


# In-package DRAM (Wide I/O 2) — Table 3.
DRAM_HBM = InterfaceTiming(
    tRCD=44, tCAS=44, tCCD=16, tWTR=31, tWR=4, tRTP=46, tBL=4,
    tCWD=61, tRP=44, tRRD=16, tRAS=112, tRC=271, tFAW=181,
    n_vaults=8, banks_per_vault=8, needs_precharge=True, needs_refresh=True,
    refresh_overhead=0.05, capacity_mb=4096,
)

# Ideal DRAM cache: zero refresh / precharge / activate overheads (paper §9).
DRAM_IDEAL = dataclasses.replace(
    DRAM_HBM, needs_precharge=False, needs_refresh=False, refresh_overhead=0.0,
    tRP=0, tRCD=0, tRAS=0, tRC=16,
)

# In-package Monarch / RRAM — Table 3 (8GB, 64 banks/vault).
MONARCH = InterfaceTiming(
    tRCD=4, tCAS=4, tCCD=1, tWTR=31, tWR=162, tRTP=1, tBL=4,
    tCWD=4, tRP=8, tRRD=1, tRAS=4, tRC=12, tFAW=181,
    n_vaults=8, banks_per_vault=64, needs_precharge=False, needs_refresh=False,
    refresh_overhead=0.0, capacity_mb=8192,
)

# 1R RRAM baseline: same interface, but no parallel search capability and
# (per Table 1) slightly better read, similar write.
RRAM_1R = dataclasses.replace(MONARCH, capacity_mb=8192)

# In-package CMOS SRAM(+SCAM) — Table 3 (73.28 MB iso-area).
CMOS_SRAM = InterfaceTiming(
    tRCD=4, tCAS=4, tCCD=1, tWTR=31, tWR=3, tRTP=1, tBL=4,
    tCWD=4, tRP=8, tRRD=1, tRAS=4, tRC=12, tFAW=181,
    n_vaults=8, banks_per_vault=8, needs_precharge=False, needs_refresh=False,
    refresh_overhead=0.0, capacity_mb=73,
)

# Off-chip DDR4 main memory — Table 3.
DDR4 = InterfaceTiming(
    tRCD=44, tCAS=44, tCCD=16, tWTR=31, tWR=4, tRTP=46, tBL=10,
    tCWD=61, tRP=44, tRRD=16, tRAS=112, tRC=271, tFAW=181,
    n_vaults=2, banks_per_vault=8,  # 2 channels x 8 banks
    needs_precharge=True, needs_refresh=True, refresh_overhead=0.05,
    capacity_mb=32768,
)

TECH_TIMING = {
    "monarch": MONARCH,
    "rram_1r": RRAM_1R,
    "dram": DRAM_HBM,
    "dram_ideal": DRAM_IDEAL,
    "cmos": CMOS_SRAM,
    "ddr4": DDR4,
}


# ---------------------------------------------------------------------------
# Lifetime math (§6.2 "Constraining Block Writes", §8).
# ---------------------------------------------------------------------------

SECONDS_PER_YEAR = 365.25 * 24 * 3600

# Paper example: 3-year lifetime = 94.6e6 s, endurance 1e8 -> t_MWW = 0.94*M s
PAPER_3Y_SECONDS = 94.6e6


def t_mww_seconds(m_writes: int, t_life_seconds: float, endurance: float) -> float:
    """t_MWW = M * T_Life / n_W  — window length allowing M writes per block
    region while guaranteeing T_Life."""
    return m_writes * t_life_seconds / endurance


def t_mww_cycles(m_writes: int, t_life_seconds: float, endurance: float) -> int:
    return int(round(t_mww_seconds(m_writes, t_life_seconds, endurance) * CPU_HZ))


def lifetime_years(endurance: float, max_writes_per_second: float) -> float:
    """Years until the hottest cell reaches its endurance."""
    if max_writes_per_second <= 0:
        return float("inf")
    return endurance / max_writes_per_second / SECONDS_PER_YEAR


DEFAULT_ENDURANCE = 1e8   # §8: evaluations use 1e8 cell writes
DEFAULT_TARGET_LIFE_YEARS = 10.0  # §10.2 target lifetime
