"""User-space Monarch API (paper §7 "OS Support", Fig. 6).

Mirrors the memkind-extension programming model: ``flat_ram_malloc`` /
``flat_cam_malloc`` allocate from vault-backed RAM/CAM address spaces, and
the returned :class:`MonarchDevice` pointers expose the key / mask / match
registers that the vault controller maps onto ordinary loads and stores.

This is the layer the examples (kv_store, string_search) and the framework
integration (MonarchKVIndex dedup) program against.  Data-plane search uses
the Pallas XAM kernel; control-plane semantics (lazy key/mask push, fresh
match-register reuse, mode toggling) follow ``repro.core.controller``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import controller
from repro.kernels.xam_search import ops as xam_ops


@dataclasses.dataclass
class Allocation:
    base: int
    n_elems: int
    space: str  # "ram" | "cam"


class MonarchDevice:
    """An 8-vault Monarch stack with per-vault mode configuration.

    Vaults configured "cache" are hardware-managed and invisible here; the
    flat vaults expose scratchpad address spaces.  For the software API we
    model one flat-RAM region and one flat-CAM region (sets of 64-bit words
    stored column-wise, 512 columns per set).
    """

    def __init__(self, n_sets: int = 64, key_bits: int = 64,
                 set_cols: int = 512):
        self.key_bits = key_bits
        self.set_cols = set_cols
        self.n_sets = n_sets
        # CAM planes: (n_sets, key_bits rows, set_cols columns) of bits.
        self.cam_bits = jnp.zeros((n_sets, key_bits, set_cols), jnp.int8)
        # RAM scratchpad (word-addressed).
        self.ram = jnp.zeros((n_sets * set_cols,), jnp.uint32)
        self.ram_hi = jnp.zeros((n_sets * set_cols,), jnp.uint32)
        # Vault-controller registers.
        self.key_reg = jnp.zeros((key_bits,), jnp.int8)
        self.mask_reg = jnp.ones((key_bits,), jnp.int8)
        self.match_reg = -1
        self._match_fresh = False
        self._km_pushed = set()  # supersets holding the latest key/mask
        self._ram_ptr = 0
        self._cam_ptr = 0
        self.command_log: list[str] = []

    # ---- memkind-style allocation ------------------------------------
    def flat_ram_malloc(self, n_elems: int) -> Allocation:
        a = Allocation(self._ram_ptr, n_elems, "ram")
        self._ram_ptr += n_elems
        if self._ram_ptr > self.ram.shape[0]:
            raise MemoryError("flat-RAM vault exhausted")
        return a

    def flat_cam_malloc(self, n_elems: int) -> Allocation:
        a = Allocation(self._cam_ptr, n_elems, "cam")
        self._cam_ptr += n_elems
        if self._cam_ptr > self.n_sets * self.set_cols:
            raise MemoryError("flat-CAM vault exhausted")
        return a

    # ---- data plane ----------------------------------------------------
    @staticmethod
    def _to_bits(word: int, n: int) -> jnp.ndarray:
        return jnp.asarray([(int(word) >> i) & 1 for i in range(n)], jnp.int8)

    def cam_write(self, alloc: Allocation, index: int, key: int) -> None:
        """Fig. 6: myDATA-style write — store ``key`` column-wise in CAM."""
        pos = alloc.base + index
        set_id, col = divmod(pos, self.set_cols)
        bits = self._to_bits(key, self.key_bits)
        plane = self.cam_bits[set_id]
        col_onehot = jnp.arange(self.set_cols) == col
        self.cam_bits = self.cam_bits.at[set_id].set(
            jnp.where(col_onehot[None, :], bits[:, None], plane))
        self._match_fresh = False
        self.command_log.append(f"W cam set={set_id} col={col}")

    def ram_write(self, alloc: Allocation, index: int, value: int) -> None:
        pos = alloc.base + index
        self.ram = self.ram.at[pos].set(np.uint32(value & 0xFFFFFFFF))
        self.ram_hi = self.ram_hi.at[pos].set(np.uint32((value >> 32) & 0xFFFFFFFF))
        self.command_log.append(f"W ram {pos}")

    def ram_read(self, alloc: Allocation, index: int) -> int:
        pos = alloc.base + index
        self.command_log.append(f"R ram {pos}")
        return int(self.ram[pos]) | (int(self.ram_hi[pos]) << 32)

    # ---- key/mask/match registers (§6.2 fine-grained access) ----------
    def write_key(self, key: int) -> None:
        self.key_reg = self._to_bits(key, self.key_bits)
        self._match_fresh = False
        self._km_pushed.clear()
        self.command_log.append("W key_reg")

    def write_mask(self, mask: int) -> None:
        self.mask_reg = self._to_bits(mask, self.key_bits)
        self._match_fresh = False
        self._km_pushed.clear()
        self.command_log.append("W mask_reg")

    def read_match(self, alloc: Allocation, set_index: int = 0) -> int:
        """A read of the match pointer triggers (at most) one search."""
        if self._match_fresh:
            self.command_log.append("R match (fresh)")
            return self.match_reg
        set_id = alloc.base // self.set_cols + set_index
        if set_id not in self._km_pushed:
            self.command_log.append(f"W key/mask -> superset {set_id}")
            self._km_pushed.add(set_id)
        matches = xam_ops.xam_search(
            self.key_reg[None, :], self.cam_bits[set_id], self.mask_reg[None, :])
        hit = bool(jnp.any(matches[0] == 1))
        idx = int(jnp.argmax(matches[0])) if hit else -1
        self.match_reg = -1 if not hit else set_id * self.set_cols + idx
        self._match_fresh = True
        self.command_log.append(f"S set={set_id}")
        return self.match_reg

    # ---- convenience: Fig. 6 key-value store flow -----------------------
    def kv_lookup(self, keys_alloc: Allocation, data_alloc: Allocation,
                  key: int, mask: int = ~0) -> int | None:
        self.write_key(key)
        self.write_mask(mask & ((1 << self.key_bits) - 1))
        n_sets_used = (keys_alloc.n_elems + self.set_cols - 1) // self.set_cols
        for s in range(n_sets_used):
            m = self.read_match(keys_alloc, s)
            if m >= 0:
                return self.ram_read(data_alloc, m - keys_alloc.base)
            self._match_fresh = False  # advance to next set
        return None
