"""Pure-jnp oracle for the hopscotch window lookup.

Monarch semantics (paper §9.2.2): a hash-table lookup probes the H buckets
of the key's hopscotch window.  The baseline issues up to H serial reads;
Monarch issues ONE search covering the window.  The oracle returns, per
query, the offset (0..H-1) of the first bucket whose stored key equals the
query key, or -1.

Table layout: ``table_lo/hi`` are (n_slots,) uint32 planes of 64-bit keys
(slot 0 .. n_slots-1); the table is allocated with H-1 trailing pad slots so
windows never wrap.  Empty slots hold the key 0 sentinel.
"""
from __future__ import annotations

import jax.numpy as jnp


def hopscotch_lookup_ref(table_lo, table_hi, homes, q_lo, q_hi,
                         window: int) -> jnp.ndarray:
    homes = homes.astype(jnp.int32)
    idx = homes[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    w_lo = table_lo[idx]           # (Q, H)
    w_hi = table_hi[idx]
    match = (w_lo == q_lo[:, None]) & (w_hi == q_hi[:, None])
    any_m = jnp.any(match, axis=1)
    off = jnp.argmax(match, axis=1).astype(jnp.int32)
    return jnp.where(any_m, off, -1)
