"""Jit'd wrappers for the hopscotch window-lookup kernel and the
device-resident insert/delete path (windowed scatter with the hop-chain
displacement as a bounded ``lax.while_loop``)."""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.common import bucket_pow2
from repro.kernels.hopscotch.kernel import BLOCK_Q, hopscotch_lookup_pallas
from repro.kernels.hopscotch.ref import hopscotch_lookup_ref

_ON_TPU = jax.default_backend() == "tpu"


def hopscotch_lookup(table_lo, table_hi, homes, q_lo, q_hi, *, window: int,
                     block_q: int | None = None,
                     use_kernel: bool = True,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Batched hopscotch window probe over a packed 64-bit key table.

    Parameters
    ----------
    table_lo, table_hi : (N,) uint32
        Low/high halves of the table's 64-bit keys (0 = EMPTY sentinel).
    homes : (Q,) int32
        Home slot of each query (bucket base the H-window starts at).
    q_lo, q_hi : (Q,) uint32
        Low/high halves of the 64-bit query keys.
    window : int
        Hopscotch neighborhood size H (entries scanned per query).
    block_q : int, optional
        Queries per kernel grid step (default 8); each step gather-DMAs
        all its queries' window tiles together.
    use_kernel, interpret
        Reference-path switch and Pallas interpret-mode flag (defaults
        to True off-TPU).

    Returns
    -------
    jnp.ndarray, shape (Q,), int32
        First-match offset within each query's window; ``-1`` = miss.

    Notes
    -----
    The query count is bucketed to a power of two HERE, on the host, so
    ragged batches reuse a handful of compiled shapes (the jitted kernel
    specializes on its input shapes).
    """
    table_lo = jnp.asarray(table_lo, jnp.uint32)
    table_hi = jnp.asarray(table_hi, jnp.uint32)
    homes = jnp.asarray(homes, jnp.int32)
    q_lo = jnp.asarray(q_lo, jnp.uint32)
    q_hi = jnp.asarray(q_hi, jnp.uint32)
    if not use_kernel:
        return hopscotch_lookup_ref(table_lo, table_hi, homes, q_lo, q_hi, window)
    if interpret is None:
        interpret = not _ON_TPU
    if block_q is None:
        block_q = BLOCK_Q
    q = homes.shape[0]
    qp = bucket_pow2(q, block_q)
    if qp != q:
        # pad rows carry home 0 / key 0 and are sliced off below
        pad = np.zeros(qp - q, np.int32)
        homes = jnp.concatenate([homes, jnp.asarray(pad)])
        q_lo = jnp.concatenate([q_lo, jnp.asarray(pad.view(np.uint32))])
        q_hi = jnp.concatenate([q_hi, jnp.asarray(pad.view(np.uint32))])
    out = hopscotch_lookup_pallas(
        table_lo, table_hi, homes, q_lo, q_hi,
        window=window, block_q=block_q, interpret=interpret)
    return out[:q]


# ---------------------------------------------------------------------------
# Device-resident mutation path (apps/hashtable.py "device" backend).
# ---------------------------------------------------------------------------

def _murmur3_u32(x: jnp.ndarray) -> jnp.ndarray:
    """jnp twin of ``repro.data.pipeline.murmur3_np`` (32-bit finalizer);
    uint32 multiplies wrap, which is the point."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


@functools.partial(jax.jit, static_argnames=("window",),
                   donate_argnums=(0, 1, 2, 3))
def hopscotch_insert_device(k_lo, k_hi, v_lo, v_hi, home, q_lo, q_hi,
                            nv_lo, nv_hi, *, window: int):
    """One hopscotch insert, entirely on device (donated planes).

    Bit-for-bit replica of ``HopscotchTable.insert``'s host algorithm over
    the split uint32 key/value planes (length ``n + 2*window``; 0/0 =
    EMPTY): resident-key value update, first-free-window install, else
    forward walk to the first free bucket (vectorized mask scan, capped at
    ``min(n + w, home + 64w)``) and hop-chain displacement back into the
    window as a bounded ``lax.while_loop`` — each hop moves the FIRST
    window-compatible key forward (its home recomputed on device with
    ``_murmur3_u32``, matching the host hash low-word-exactly), exactly
    like the host's inner ``for`` scan, and a failed chain leaves partial
    moves in place for the host-orchestrated rehash.

    Returns
    -------
    (k_lo, k_hi, v_lo, v_hi, status, probes, swaps, log, n_log)
        Updated planes; ``status`` 0 = resident value update, 1 =
        installed, 2 = needs rehash; ``probes`` the ``insert_probes``
        delta; ``swaps`` the hop count; ``log[:n_log]`` the touched
        bucket indices in the host's exact ``_record_write`` order
        (j, k per hop, then the final install slot) so wear accounting
        replays identically.
    """
    w = window
    n_pad = k_lo.shape[0]
    n = n_pad - 2 * w
    h = home.astype(jnp.int32)
    iota = jnp.arange(n_pad, dtype=jnp.int32)
    log_cap = 128 * w            # > 2 * 63w hop writes + 1 final install

    wk_lo = lax.dynamic_slice(k_lo, (h,), (w,))
    wk_hi = lax.dynamic_slice(k_hi, (h,), (w,))
    hit = (wk_lo == q_lo) & (wk_hi == q_hi)
    is_res = jnp.any(hit)
    res_off = jnp.argmax(hit).astype(jnp.int32)

    empty_w = (wk_lo == 0) & (wk_hi == 0)
    has_free = jnp.any(empty_w)
    free_off = jnp.argmax(empty_w).astype(jnp.int32)
    do_freewin = ~is_res & has_free
    need_hop = ~is_res & ~has_free

    # Forward walk: first free bucket past the window, as one mask scan.
    occ = (k_lo != 0) | (k_hi != 0)
    limit = jnp.minimum(jnp.int32(n + w), h + 64 * w)
    cand = ~occ & (iota >= h + w) & (iota < limit)
    fwd_found = jnp.any(cand)
    j0 = jnp.argmax(cand).astype(jnp.int32)
    advances = jnp.where(fwd_found, j0, limit) - (h + w)
    probes = jnp.where(
        is_res, 0, jnp.where(do_freewin, free_off + 1, w + advances))
    hop_ok = need_hop & fwd_found

    log = jnp.full((log_cap,), -1, jnp.int32)
    n_log = jnp.int32(0)

    def cond(c):
        _, _, _, _, _, _, j, failed = c
        return hop_ok & ~failed & (j >= h + w)

    def body(c):
        k_lo, k_hi, v_lo, v_hi, log, nl, j, failed = c
        c_lo = lax.dynamic_slice(k_lo, (j - w + 1,), (w - 1,))
        c_hi = lax.dynamic_slice(k_hi, (j - w + 1,), (w - 1,))
        occ_k = (c_lo != 0) | (c_hi != 0)
        homes_k = (_murmur3_u32(c_lo) % jnp.uint32(n)).astype(jnp.int32)
        movable = occ_k & (j < homes_k + w)
        any_mv = jnp.any(movable)
        k = j - w + 1 + jnp.argmax(movable).astype(jnp.int32)
        jj = jnp.where(any_mv, j, n_pad)      # sentinel: drop when no move
        kk = jnp.where(any_mv, k, n_pad)
        # move k -> j: keys clear at k, values keep the host's stale copy
        k_lo = k_lo.at[jj].set(k_lo[k], mode="drop").at[kk].set(
            jnp.uint32(0), mode="drop")
        k_hi = k_hi.at[jj].set(k_hi[k], mode="drop").at[kk].set(
            jnp.uint32(0), mode="drop")
        v_lo = v_lo.at[jj].set(v_lo[k], mode="drop")
        v_hi = v_hi.at[jj].set(v_hi[k], mode="drop")
        log = log.at[jnp.where(any_mv, nl, log_cap)].set(j, mode="drop")
        log = log.at[jnp.where(any_mv, nl + 1, log_cap)].set(k, mode="drop")
        nl = nl + jnp.where(any_mv, 2, 0)
        j = jnp.where(any_mv, k, j)
        return (k_lo, k_hi, v_lo, v_hi, log, nl, j, failed | ~any_mv)

    if w > 1:
        (k_lo, k_hi, v_lo, v_hi, log, n_log, j_fin, failed) = lax.while_loop(
            cond, body, (k_lo, k_hi, v_lo, v_hi, log, n_log, j0, False))
    else:   # degenerate window: no hop candidates exist, chain always fails
        j_fin, failed = j0, hop_ok
    swaps = n_log // 2
    installed_hop = hop_ok & ~failed

    slot = jnp.where(is_res, h + res_off,
                     jnp.where(do_freewin, h + free_off, j_fin))
    put_key = do_freewin | installed_hop
    put_val = put_key | is_res
    ki = jnp.where(put_key, slot, n_pad)
    vi = jnp.where(put_val, slot, n_pad)
    k_lo = k_lo.at[ki].set(q_lo, mode="drop")
    k_hi = k_hi.at[ki].set(q_hi, mode="drop")
    v_lo = v_lo.at[vi].set(nv_lo, mode="drop")
    v_hi = v_hi.at[vi].set(nv_hi, mode="drop")
    log = log.at[jnp.where(put_val, n_log, log_cap)].set(slot, mode="drop")
    n_log = n_log + put_val.astype(jnp.int32)

    status = jnp.where(
        is_res, 0, jnp.where(do_freewin | installed_hop, 1, 2)
    ).astype(jnp.int32)
    return k_lo, k_hi, v_lo, v_hi, status, probes, swaps, log, n_log


@jax.jit
def hopscotch_delete_device(k_lo, k_hi, v_lo, v_hi, idx):
    """Clear one resolved bucket (key AND value planes) on device.

    The caller resolves ``idx`` via the window lookup; donation is left
    OFF so a miss path can reuse the planes untouched."""
    return (k_lo.at[idx].set(jnp.uint32(0)),
            k_hi.at[idx].set(jnp.uint32(0)),
            v_lo.at[idx].set(jnp.uint32(0)),
            v_hi.at[idx].set(jnp.uint32(0)))
