"""Jit'd wrappers for the hopscotch window-lookup kernel."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.common import bucket_pow2
from repro.kernels.hopscotch.kernel import BLOCK_Q, hopscotch_lookup_pallas
from repro.kernels.hopscotch.ref import hopscotch_lookup_ref

_ON_TPU = jax.default_backend() == "tpu"


def hopscotch_lookup(table_lo, table_hi, homes, q_lo, q_hi, *, window: int,
                     block_q: int | None = None,
                     use_kernel: bool = True,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Batched hopscotch window probe over a packed 64-bit key table.

    Parameters
    ----------
    table_lo, table_hi : (N,) uint32
        Low/high halves of the table's 64-bit keys (0 = EMPTY sentinel).
    homes : (Q,) int32
        Home slot of each query (bucket base the H-window starts at).
    q_lo, q_hi : (Q,) uint32
        Low/high halves of the 64-bit query keys.
    window : int
        Hopscotch neighborhood size H (entries scanned per query).
    block_q : int, optional
        Queries per kernel grid step (default 8); each step gather-DMAs
        all its queries' window tiles together.
    use_kernel, interpret
        Reference-path switch and Pallas interpret-mode flag (defaults
        to True off-TPU).

    Returns
    -------
    jnp.ndarray, shape (Q,), int32
        First-match offset within each query's window; ``-1`` = miss.

    Notes
    -----
    The query count is bucketed to a power of two HERE, on the host, so
    ragged batches reuse a handful of compiled shapes (the jitted kernel
    specializes on its input shapes).
    """
    table_lo = jnp.asarray(table_lo, jnp.uint32)
    table_hi = jnp.asarray(table_hi, jnp.uint32)
    homes = jnp.asarray(homes, jnp.int32)
    q_lo = jnp.asarray(q_lo, jnp.uint32)
    q_hi = jnp.asarray(q_hi, jnp.uint32)
    if not use_kernel:
        return hopscotch_lookup_ref(table_lo, table_hi, homes, q_lo, q_hi, window)
    if interpret is None:
        interpret = not _ON_TPU
    if block_q is None:
        block_q = BLOCK_Q
    q = homes.shape[0]
    qp = bucket_pow2(q, block_q)
    if qp != q:
        # pad rows carry home 0 / key 0 and are sliced off below
        pad = np.zeros(qp - q, np.int32)
        homes = jnp.concatenate([homes, jnp.asarray(pad)])
        q_lo = jnp.concatenate([q_lo, jnp.asarray(pad.view(np.uint32))])
        q_hi = jnp.concatenate([q_hi, jnp.asarray(pad.view(np.uint32))])
    out = hopscotch_lookup_pallas(
        table_lo, table_hi, homes, q_lo, q_hi,
        window=window, block_q=block_q, interpret=interpret)
    return out[:q]
