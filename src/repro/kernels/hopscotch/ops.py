"""Jit'd wrappers for the hopscotch window-lookup kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.hopscotch.kernel import hopscotch_lookup_pallas
from repro.kernels.hopscotch.ref import hopscotch_lookup_ref

_ON_TPU = jax.default_backend() == "tpu"


def hopscotch_lookup(table_lo, table_hi, homes, q_lo, q_hi, *, window: int,
                     use_kernel: bool = True,
                     interpret: bool | None = None) -> jnp.ndarray:
    """First-match offset within each query's H-bucket window (-1 = miss)."""
    table_lo = jnp.asarray(table_lo, jnp.uint32)
    table_hi = jnp.asarray(table_hi, jnp.uint32)
    homes = jnp.asarray(homes, jnp.int32)
    q_lo = jnp.asarray(q_lo, jnp.uint32)
    q_hi = jnp.asarray(q_hi, jnp.uint32)
    if not use_kernel:
        return hopscotch_lookup_ref(table_lo, table_hi, homes, q_lo, q_hi, window)
    if interpret is None:
        interpret = not _ON_TPU
    return hopscotch_lookup_pallas(
        table_lo, table_hi, homes, q_lo, q_hi,
        window=window, interpret=interpret)
