"""Pallas TPU kernel: fused hopscotch-window lookup (Monarch flat-CAM flow).

Monarch turns "probe up to H buckets serially" into one CAM search per
window.  The TPU-native analogue is a *scalar-prefetch gather kernel* in the
style of paged attention block tables: the per-query home indices ride in
SMEM (scalar prefetch), and the BlockSpec index_map uses them to DMA exactly
the two H-aligned table tiles that cover the query's window from HBM into
VMEM — one fused gather+match instead of H scalar loads.

Layout: the key table is reshaped (n_slots/H, H); query q's window
[home, home+H) spans aligned tiles  home//H  and  home//H + 1.  Both tiles
are fetched (two in_specs over the same array), concatenated, shifted by
home % H, and compared against the query key (64-bit keys as two uint32
planes).  Output: first-match offset within the window, or -1.

Grid = one query per step — each step's DMA target depends on that query's
home, exactly like one search command per window on Monarch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lookup_kernel(scalars_ref,             # (3, Q) int32: homes, q_lo, q_hi
                   lo0_ref, lo1_ref, hi0_ref, hi1_ref,  # (1, H) table tiles
                   out_ref):                # (1, 1) int32
    q = pl.program_id(0)
    window = lo0_ref.shape[1]
    home = scalars_ref[0, q]
    q_lo = scalars_ref[1, q]
    q_hi = scalars_ref[2, q]
    off = home % window

    # Keep everything 2D (1, 2H) — lane-shaped for the VPU.
    lo = jnp.concatenate([lo0_ref[...], lo1_ref[...]], axis=1)   # (1, 2H)
    hi = jnp.concatenate([hi0_ref[...], hi1_ref[...]], axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 2 * window), 1)
    in_win = (pos >= off) & (pos < off + window)
    match = in_win & (lo == q_lo) & (hi == q_hi)
    big = jnp.int32(2 * window)
    first = jnp.min(jnp.where(match, pos, big))
    out_ref[0, 0] = jnp.where(first < big, first - off, -1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def hopscotch_lookup_pallas(table_lo, table_hi, homes, q_lo, q_hi,
                            *, window: int, interpret: bool = True):
    """table_lo/hi: (n_slots,) uint32 (n_slots % window == 0, with >= window
    pad slots so home+2H never overruns); homes: (Q,) int32; q_lo/hi: (Q,)
    uint32.  Returns (Q,) int32 first-match offsets (-1 = miss)."""
    n_slots = table_lo.shape[0]
    assert n_slots % window == 0
    n_tiles = n_slots // window
    q = homes.shape[0]

    t_lo = table_lo.reshape(n_tiles, window)
    t_hi = table_hi.reshape(n_tiles, window)
    scalars = jnp.stack([
        homes.astype(jnp.int32),
        q_lo.astype(jnp.uint32).view(jnp.int32),
        q_hi.astype(jnp.uint32).view(jnp.int32),
    ])

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q,),
        in_specs=[
            pl.BlockSpec((1, window), lambda i, s: (s[0, i] // window, 0)),
            pl.BlockSpec((1, window), lambda i, s: (s[0, i] // window + 1, 0)),
            pl.BlockSpec((1, window), lambda i, s: (s[0, i] // window, 0)),
            pl.BlockSpec((1, window), lambda i, s: (s[0, i] // window + 1, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, s: (i, 0)),
    )
    out = pl.pallas_call(
        _lookup_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.int32),
        interpret=interpret,
    )(scalars, t_lo.view(jnp.int32), t_lo.view(jnp.int32),
      t_hi.view(jnp.int32), t_hi.view(jnp.int32))
    return out[:, 0]
