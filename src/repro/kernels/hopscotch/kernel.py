"""Pallas TPU kernel: fused hopscotch-window lookup (Monarch flat-CAM flow).

Monarch turns "probe up to H buckets serially" into one CAM search per
window.  The TPU-native analogue is a *scalar-prefetch gather kernel* in the
style of paged attention block tables: the per-query home indices ride in
SMEM (scalar prefetch), and the BlockSpec index_map uses them to DMA exactly
the two H-aligned table tiles that cover each query's window from HBM into
VMEM — one fused gather+match instead of H scalar loads.

Layout: the two uint32 key planes (64-bit keys as lo/hi words) are packed
into one (n_slots/H, 2, H) array so a single gathered block carries both
planes of a tile; query q's window [home, home+H) spans aligned tiles
home//H and home//H + 1.  Both tiles are fetched (two in_specs over the
same packed array), the block's rows are laid side by side as (bq, 2H)
lanes, shifted by home % H, and compared against the query keys.  Output:
first-match offset within each window, or -1.

Grid = BLOCK_Q queries per step.  The seed kernel ran ONE query per grid
step — one DMA round-trip (and, in interpret mode, one Python kernel-body
dispatch) per query.  Here each step owns a block of 8+ queries whose
2*BLOCK_Q window tiles are scalar-prefetch-gathered together and resolved
by ONE vectorized compare+reduce, amortizing per-step overhead the same
way one wide Monarch search command amortizes the command bus.  Query
counts are bucketed to powers of two so ragged batches reuse a handful of
compiled shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 8   # queries per grid step (acceptance floor: >= 8)


def _lookup_kernel(scalars_ref,   # (3, Q) int32 prefetch (index maps only)
                   qvec_ref,      # (3, bq) int32: homes, q_lo, q_hi
                   *refs,         # 2*bq packed tiles (1, 2, H) ... + out_ref
                   block_q: int):
    del scalars_ref               # consumed by the index maps
    out_ref = refs[-1]            # (bq, 1) int32
    tiles = refs[:2 * block_q]    # [tile_t, tile_t1] per query

    window = tiles[0].shape[2]
    big = jnp.int32(2 * window)

    qv = qvec_ref[...]
    homes = qv[0:1, :].T          # (bq, 1)
    q_lo = qv[1:2, :].T
    q_hi = qv[2:3, :].T
    off = homes % window

    # Lay each query's two window tiles side by side as one (bq, 2H) lane
    # row per plane, then resolve the whole block with ONE vectorized
    # compare + reduce.
    lo = jnp.concatenate([
        jnp.concatenate([tiles[2 * j][0, 0:1, :], tiles[2 * j + 1][0, 0:1, :]],
                        axis=1)
        for j in range(block_q)], axis=0)             # (bq, 2H)
    hi = jnp.concatenate([
        jnp.concatenate([tiles[2 * j][0, 1:2, :], tiles[2 * j + 1][0, 1:2, :]],
                        axis=1)
        for j in range(block_q)], axis=0)
    pos = jax.lax.broadcasted_iota(jnp.int32, lo.shape, 1)
    in_win = (pos >= off) & (pos < off + window)
    match = in_win & (lo == q_lo) & (hi == q_hi)
    first = jnp.min(jnp.where(match, pos, big), axis=1, keepdims=True)
    out_ref[...] = jnp.where(first < big, first - off, -1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "interpret"))
def hopscotch_lookup_pallas(table_lo, table_hi, homes, q_lo, q_hi,
                            *, window: int, block_q: int = BLOCK_Q,
                            interpret: bool = True):
    """table_lo/hi: (n_slots,) uint32 (n_slots % window == 0, with >= window
    pad slots so home+2H never overruns); homes: (Q,) int32; q_lo/hi: (Q,)
    uint32.  Returns (Q,) int32 first-match offsets (-1 = miss)."""
    n_slots = table_lo.shape[0]
    assert n_slots % window == 0
    n_tiles = n_slots // window
    qp = homes.shape[0]
    # Query-count bucketing happens in ops.hopscotch_lookup BEFORE this jit
    # boundary (jit specializes on input shapes, so padding here would not
    # prevent per-batch-size recompiles).
    assert qp % block_q == 0, "pad the query count to block_q multiples"
    scalars = jnp.stack([
        homes.astype(jnp.int32),
        q_lo.astype(jnp.uint32).view(jnp.int32),
        q_hi.astype(jnp.uint32).view(jnp.int32)])

    # Pack both key planes tile-wise: (n_tiles, 2, H), one gather per tile.
    packed = jnp.stack(
        [table_lo.reshape(n_tiles, window).view(jnp.int32),
         table_hi.reshape(n_tiles, window).view(jnp.int32)], axis=1)

    def _tile0(j):
        return pl.BlockSpec(
            (1, 2, window),
            lambda i, s, j=j: (s[0, i * block_q + j] // window, 0, 0))

    def _tile1(j):
        return pl.BlockSpec(
            (1, 2, window),
            lambda i, s, j=j: (s[0, i * block_q + j] // window + 1, 0, 0))

    tile_specs = [s for j in range(block_q) for s in (_tile0(j), _tile1(j))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qp // block_q,),
        in_specs=[pl.BlockSpec((3, block_q), lambda i, s: (0, i))]
        + tile_specs,
        out_specs=pl.BlockSpec((block_q, 1), lambda i, s: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_lookup_kernel, block_q=block_q),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((qp, 1), jnp.int32),
        interpret=interpret,
    )(scalars, scalars, *([packed] * (2 * block_q)))
    return out[:, 0]
