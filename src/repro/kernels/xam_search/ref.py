"""Pure-jnp oracle for the XAM CAM search.

Semantics (paper §4.2.2): a stored column matches a (key, mask) pair iff
every *unmasked* key bit equals the stored bit in that row of the column.

    match[q, c] = AND_r ( mask[q, r] == 0  OR  key[q, r] == data[r, c] )

Shapes:
    keys  : (Q, R)   int8 bits in {0, 1}
    data  : (R, C)   int8 bits in {0, 1}   (one logical XAM set plane)
    masks : (Q, R)   int8 bits in {0, 1};  1 = bit participates
Returns:
    match : (Q, C)   int8 in {0, 1}
"""
from __future__ import annotations

import jax.numpy as jnp


def xam_search_ref(keys: jnp.ndarray, data: jnp.ndarray,
                   masks: jnp.ndarray) -> jnp.ndarray:
    keys = keys.astype(jnp.int8)
    data = data.astype(jnp.int8)
    masks = masks.astype(jnp.int8)
    # (Q, R, C): bit equality or masked-out.
    eq = (keys[:, :, None] == data[None, :, :]) | (masks[:, :, None] == 0)
    return jnp.all(eq, axis=1).astype(jnp.int8)


def xam_match_index_ref(keys, data, masks) -> jnp.ndarray:
    """First matching column per query, -1 when none (match register)."""
    m = xam_search_ref(keys, data, masks)
    any_m = jnp.any(m == 1, axis=1)
    return jnp.where(any_m, jnp.argmax(m, axis=1), -1).astype(jnp.int32)


def xam_search_multiset_ref(keys, masks, set_ids, planes,
                            valid) -> jnp.ndarray:
    """Oracle for the fused multi-set search: per query q, the first column
    of plane ``set_ids[q]`` that is valid and matches under the mask, else
    -1.  keys/masks (Q, R), planes (n_sets, R, C), valid (n_sets, C)."""
    keys = keys.astype(jnp.int8)
    masks = masks.astype(jnp.int8)
    set_ids = set_ids.astype(jnp.int32)
    d = planes.astype(jnp.int8)[set_ids]                # (Q, R, C)
    eq = (keys[:, :, None] == d) | (masks[:, :, None] == 0)
    m = jnp.all(eq, axis=1) & (valid.astype(jnp.int8)[set_ids] == 1)
    any_m = jnp.any(m, axis=1)
    return jnp.where(any_m, jnp.argmax(m, axis=1), -1).astype(jnp.int32)
