"""Pallas TPU kernel for the XAM CAM search — the paper's core primitive
re-thought for the MXU.

Hardware mapping (DESIGN.md §2b): the XAM crossbar answers a search by
summing per-cell XNOR currents down each column and sensing against Ref_S.
On TPU the same inner product is a systolic matmul: encode stored bits and
key bits as ±1, zero out masked key rows, then

    score[q, c] = sum_r K[q, r] * D[r, c]
                = (#matching unmasked bits) - (#mismatching unmasked bits)

so a column matches  iff  score == n_selected[q]  (the integer Ref_S).
One kernel invocation searches a whole superset tile: a (block_q x R) key
block is broadcast against (R x block_c) stored columns entirely in VMEM —
the same "one key vs 512 columns per command" granularity as the paper.

Block shapes are MXU-aligned: block_q multiple of 8 (sublanes), block_c a
multiple of 128 (lanes); R (key bits, 64 for a Monarch set) rides in one
block — 64..512 bit keys fit VMEM trivially.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_C = 512


def _xam_search_kernel(keys_ref, data_ref, masks_ref, out_ref):
    """keys/masks: (bq, R) int8; data: (R, bc) int8; out: (bq, bc) int8."""
    keys = keys_ref[...].astype(jnp.float32)
    masks = masks_ref[...].astype(jnp.float32)
    data = data_ref[...].astype(jnp.float32)

    # ±1 encoding; masked-out key rows contribute 0 current.
    k_enc = (2.0 * keys - 1.0) * masks          # (bq, R)
    d_enc = 2.0 * data - 1.0                    # (R, bc)
    n_sel = jnp.sum(masks, axis=1, keepdims=True)  # (bq, 1) — integer Ref_S

    score = jax.lax.dot_general(
        k_enc, d_enc,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (bq, bc) on the MXU
    # All-match  <=>  score == n_sel  (sense amp threshold).  0.5 guard band
    # = half the two-unit gap to a single-mismatch column (analog margin).
    out_ref[...] = (score >= n_sel - 0.5).astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_c", "interpret"))
def xam_search_pallas(
    keys: jnp.ndarray,
    data: jnp.ndarray,
    masks: jnp.ndarray,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched masked CAM search.  keys/masks (Q, R), data (R, C) ->
    match bitmap (Q, C) int8.  Q and C are padded to block multiples here;
    callers see exact shapes."""
    q, r = keys.shape
    r2, c = data.shape
    assert r == r2 and masks.shape == keys.shape

    bq = min(block_q, _round_up(q, 8))
    bc = min(block_c, _round_up(c, 128))
    qp, cp = _round_up(q, bq), _round_up(c, bc)

    keys_p = jnp.zeros((qp, r), jnp.int8).at[:q].set(keys.astype(jnp.int8))
    # Padded queries: mask all-zero -> they match everything; sliced off.
    masks_p = jnp.zeros((qp, r), jnp.int8).at[:q].set(masks.astype(jnp.int8))
    # Padded columns: stored bits 0; harmless, sliced off.
    data_p = jnp.zeros((r, cp), jnp.int8).at[:, :c].set(data.astype(jnp.int8))

    out = pl.pallas_call(
        _xam_search_kernel,
        grid=(qp // bq, cp // bc),
        in_specs=[
            pl.BlockSpec((bq, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bc), lambda i, j: (0, j)),
            pl.BlockSpec((bq, r), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.int8),
        interpret=interpret,
    )(keys_p, data_p, masks_p)
    return out[:q, :c]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
