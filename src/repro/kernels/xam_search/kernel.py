"""Pallas TPU kernels for the XAM CAM search — the paper's core primitive
re-thought for the MXU.

Hardware mapping (DESIGN.md §2b): the XAM crossbar answers a search by
summing per-cell XNOR currents down each column and sensing against Ref_S.
On TPU the same inner product is a systolic matmul: encode stored bits and
key bits as ±1, zero out masked key rows, then

    score[q, c] = sum_r K[q, r] * D[r, c]
                = (#matching unmasked bits) - (#mismatching unmasked bits)

so a column matches  iff  score == n_selected[q]  (the integer Ref_S).
One kernel invocation searches a whole superset tile: a (block_q x R) key
block is broadcast against (R x block_c) stored columns entirely in VMEM —
the same "one key vs 512 columns per command" granularity as the paper.

Two scoring paths share the encoding:

* ``int8`` (default): ±1 operands stay int8 and the MXU accumulates into
  int32 (``preferred_element_type=jnp.int32``) — native int8 MXU rate,
  exact integer sense-amp compare, no guard band needed.
* ``f32``: the original float32 path, kept as a fallback flag and pinned
  bit-identical to int8 by tests/test_kernels.py.

Block shapes are MXU-aligned: block_q multiple of 8 (sublanes), block_c a
multiple of 128 (lanes); R (key bits, 64 for a Monarch set) rides in one
block — 64..512 bit keys fit VMEM trivially.

``xam_search_multiset_pallas`` is the device-resident fast path: stored
bits for ALL sets live on device as one (n_sets, R, C) array, and a whole
query batch — each query addressed to its own set — is answered by ONE
``pallas_call``.  Queries are grouped into per-set blocks on the host; the
per-block set ids ride in SMEM (scalar prefetch) and the BlockSpec
index_map uses them to DMA exactly the one stored-bit plane and validity
row each block needs, paged-attention-block-table style.  Validity masking
and the first-match reduction are fused, so the kernel returns a compact
(Q, 1) way index (-1 = miss) instead of a (Q, C) bitmap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_C = 512
MULTISET_BLOCK_Q = 16  # queries per grid step in the fused multi-set kernel


def _unpack_rows(packed):
    """(rp, c) uint8 packed words -> (rp*8, c) int8 {0,1} bits, LSB-first.

    The VMEM-side inverse of ``common.pack_bits_np(..., axis=-2)``:
    logical row ``r`` comes from packed word ``r // 8`` at bit position
    ``r % 8``, so the unpacked plane drops into the existing ±1 encoding
    and the MXU matmul / first-match reduce run unchanged.  Pure VPU
    shift-and-mask — the 8x narrower packed operand is what crossed
    HBM->VMEM."""
    rp, c = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 8, 1), 1)
    bits = (packed.astype(jnp.int32)[:, None, :] >> shifts) & 1
    return bits.reshape(rp * 8, c).astype(jnp.int8)


def _check_scoring(scoring: str):
    if scoring not in ("int8", "f32"):
        raise ValueError(
            f"scoring must be one of ('int8', 'f32'), got {scoring!r} "
            "(set via the REPRO_XAM_SCORING env knob or the scoring "
            "argument)")


def _match_bitmap(keys, masks, data, scoring: str):
    """±1-encoded XNOR-current matmul -> (bq, bc) int8 match bitmap."""
    if scoring == "int8":
        k_enc = ((2 * keys - 1) * masks).astype(jnp.int8)      # {-1, 0, 1}
        d_enc = (2 * data - 1).astype(jnp.int8)                # {-1, 1}
        n_sel = jnp.sum(masks.astype(jnp.int32), axis=1, keepdims=True)
        score = jax.lax.dot_general(
            k_enc, d_enc,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )                                        # int8 x int8 -> int32 MXU
        # Integer sense amp: all-match <=> score == n_sel exactly.
        return (score >= n_sel).astype(jnp.int8)
    keys = keys.astype(jnp.float32)
    masks = masks.astype(jnp.float32)
    data = data.astype(jnp.float32)
    k_enc = (2.0 * keys - 1.0) * masks
    d_enc = 2.0 * data - 1.0
    n_sel = jnp.sum(masks, axis=1, keepdims=True)
    score = jax.lax.dot_general(
        k_enc, d_enc,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # All-match  <=>  score == n_sel  (sense amp threshold).  0.5 guard band
    # = half the two-unit gap to a single-mismatch column (analog margin).
    return (score >= n_sel - 0.5).astype(jnp.int8)


def _xam_search_kernel(keys_ref, data_ref, masks_ref, out_ref, *,
                       scoring: str):
    """keys/masks: (bq, R) int8; data: (R, bc) int8 — or (R//8, bc) uint8
    packed words, unpacked here in VMEM; out: (bq, bc) int8."""
    data = data_ref[...]
    if data.dtype == jnp.uint8:
        data = _unpack_rows(data)
    out_ref[...] = _match_bitmap(keys_ref[...], masks_ref[...], data, scoring)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_c", "scoring", "interpret"))
def xam_search_pallas(
    keys: jnp.ndarray,
    data: jnp.ndarray,
    masks: jnp.ndarray,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_c: int = DEFAULT_BLOCK_C,
    scoring: str = "int8",
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched masked CAM search.  keys/masks (Q, R), data (R, C) int8 —
    or (ceil(R/8), C) uint8 packed words (``plane_format="packed8"``; the
    kernel unpacks in VMEM) -> match bitmap (Q, C) int8.  Q and C are
    padded to block multiples here; callers see exact shapes."""
    q, r = keys.shape
    _check_scoring(scoring)
    packed = data.dtype == jnp.uint8
    if packed:
        rp, c = data.shape
        r_eff = rp * 8
        if r > r_eff:
            raise ValueError(
                f"packed data holds {r_eff} bit rows but keys have {r}")
    else:
        r2, c = data.shape
        assert r == r2
        rp, r_eff = r, r
    assert masks.shape == keys.shape

    bq = min(block_q, _round_up(q, 8))
    bc = min(block_c, _round_up(c, 128))
    qp, cp = _round_up(q, bq), _round_up(c, bc)

    # Keys/masks padded to the unpacked row count: the pad rows carry
    # mask 0, so they never select a bit.
    keys_p = jnp.zeros((qp, r_eff), jnp.int8).at[:q, :r].set(
        keys.astype(jnp.int8))
    # Padded queries: mask all-zero -> they match everything; sliced off.
    masks_p = jnp.zeros((qp, r_eff), jnp.int8).at[:q, :r].set(
        masks.astype(jnp.int8))
    # Padded columns: stored bits 0; harmless, sliced off.
    ddt = jnp.uint8 if packed else jnp.int8
    data_p = jnp.zeros((rp, cp), ddt).at[:, :c].set(data.astype(ddt))

    out = pl.pallas_call(
        functools.partial(_xam_search_kernel, scoring=scoring),
        grid=(qp // bq, cp // bc),
        in_specs=[
            pl.BlockSpec((bq, r_eff), lambda i, j: (i, 0)),
            pl.BlockSpec((rp, bc), lambda i, j: (0, j)),
            pl.BlockSpec((bq, r_eff), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.int8),
        interpret=interpret,
    )(keys_p, data_p, masks_p)
    return out[:q, :c]


# ---------------------------------------------------------------------------
# Fused multi-set search: one launch serves a query batch spanning sets.
# ---------------------------------------------------------------------------

def _xam_multiset_kernel(block_sets_ref,       # (n_qb,) int32 in SMEM
                         live_blocks_ref,      # (n_qb,) int32 in SMEM
                         keys_ref, masks_ref,  # (bq, R) int8
                         plane_ref,            # (1, R, C) int8 — this block's set
                         valid_ref,            # (1, C) int8
                         out_ref,              # (bq, 1) int32
                         *, scoring: str):
    del block_sets_ref  # consumed by the index maps

    # Padding blocks — the pow2 bucket tail, and in the stacked sharded
    # layout every block a shard pads up to the common Qmax (a per-shard
    # PREFIX of real blocks, so flattened layouts interleave pad runs) —
    # SKIP the matmul entirely and emit the NULL match register.  The
    # scalar-prefetched per-block liveness flags are what make bucket
    # padding nearly free: grid steps still run, compute doesn't.
    blk_live = live_blocks_ref[pl.program_id(0)] != 0

    @pl.when(jnp.logical_not(blk_live))
    def _pad_block():
        out_ref[...] = jnp.full(out_ref.shape, -1, jnp.int32)

    @pl.when(blk_live)
    def _live_block():
        plane = plane_ref[0]
        if plane.dtype == jnp.uint8:          # packed8: unpack in VMEM
            plane = _unpack_rows(plane)
        match = _match_bitmap(
            keys_ref[...], masks_ref[...], plane, scoring)      # (bq, C)
        live = match * valid_ref[...]                       # fused validity
        bq, c = live.shape
        pos = jax.lax.broadcasted_iota(jnp.int32, (bq, c), 1)
        big = jnp.int32(c)
        first = jnp.min(jnp.where(live == 1, pos, big), axis=1,
                        keepdims=True)
        # Ragged block tails (all-zero mask rows) also report -1, so the
        # (Q,) result is deterministic end-to-end, not
        # garbage-where-discarded.
        row_live = jnp.any(masks_ref[...] != 0, axis=1)[:, None]
        first = jnp.where(row_live, first, big)
        out_ref[...] = jnp.where(first < big, first, -1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_q", "scoring", "interpret"))
def xam_search_multiset_pallas(
    keys: jnp.ndarray,        # (Q, R) int8 — block_q-grouped by set (host)
    masks: jnp.ndarray,       # (Q, R) int8 — all-zero rows = padding
    planes: jnp.ndarray,      # (n_sets, R, C) int8 device-resident bits
    valid: jnp.ndarray,       # (n_sets, C) int8 device-resident validity
    block_sets: jnp.ndarray,  # (Q // block_q,) int32 set id per query block
    live_blocks: jnp.ndarray | None = None,  # (Q // block_q,) int32 0 = pad
    *,
    block_q: int = MULTISET_BLOCK_Q,
    scoring: str = "int8",
    interpret: bool = True,
) -> jnp.ndarray:
    """One fused launch over a set-grouped query batch.  Returns (Q,) int32
    first matching *valid* way per query, -1 = miss.  Q must be a multiple
    of ``block_q`` and every query in block b must belong to set
    ``block_sets[b]``.  ``live_blocks`` (scalar-prefetched alongside the
    block set ids) flags the non-padding blocks: blocks flagged 0 skip
    the matmul and report -1 (as do all-zero-mask rows inside live
    blocks), so both the flat pow2 bucket tail and the stacked sharded
    layout — per-shard prefixes of real blocks, interleaved with pad runs
    when flattened — get a deterministic result at no compute cost for
    the padding.  None = every block live.

    ``planes`` may instead be ``(n_sets, R // 8, C)`` uint8 packed words
    (``plane_format="packed8"``, R a multiple of 8): the kernel unpacks
    each set's plane tile in VMEM, so the HBM->VMEM traffic of the
    dominant plane operand is ~8x lower and the result is bit-identical.
    """
    q, r = keys.shape
    _check_scoring(scoring)
    packed = planes.dtype == jnp.uint8
    n_sets, rp, c = planes.shape
    if packed:
        if r != rp * 8:
            raise ValueError(
                f"packed planes hold {rp * 8} bit rows but keys have {r}; "
                "plane_format='packed8' needs key bits padded to a "
                "multiple of 8")
    else:
        assert r == rp
    assert masks.shape == keys.shape
    assert valid.shape == (n_sets, c)
    assert q % block_q == 0 and block_sets.shape == (q // block_q,)
    if live_blocks is None:
        live_blocks = jnp.ones(q // block_q, jnp.int32)
    assert live_blocks.shape == (q // block_q,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(q // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, r), lambda i, s, nb: (i, 0)),
            pl.BlockSpec((block_q, r), lambda i, s, nb: (i, 0)),
            pl.BlockSpec((1, rp, c), lambda i, s, nb: (s[i], 0, 0)),
            pl.BlockSpec((1, c), lambda i, s, nb: (s[i], 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1), lambda i, s, nb: (i, 0)),
    )
    pdt = jnp.uint8 if packed else jnp.int8
    out = pl.pallas_call(
        functools.partial(_xam_multiset_kernel, scoring=scoring),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((q, 1), jnp.int32),
        interpret=interpret,
    )(block_sets.astype(jnp.int32), live_blocks.astype(jnp.int32),
      keys.astype(jnp.int8), masks.astype(jnp.int8),
      planes.astype(pdt), valid.astype(jnp.int8))
    return out[:, 0]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
