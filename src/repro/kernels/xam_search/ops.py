"""Jit'd public wrappers for the XAM search kernels.

``interpret`` defaults to True on CPU (this rig) and should be False on real
TPUs; the flag is threaded, never hard-coded in callers.  ``scoring``
selects the MXU arithmetic: ``"int8"`` (default — int8 x int8 -> int32
accumulate) or ``"f32"`` (the original float32 path); the default can be
flipped rig-wide via ``REPRO_XAM_SCORING=f32``.
"""
from __future__ import annotations

import functools
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import autotune
from repro.kernels.common import (
    bucket_pow2, pack_bits_np, plane_format_of, resolve_plane_format)
from repro.kernels.xam_search.kernel import (
    MULTISET_BLOCK_Q, xam_search_multiset_pallas, xam_search_pallas)
from repro.kernels.xam_search.ref import xam_search_ref

_ON_TPU = jax.default_backend() == "tpu"

#: Host-side fused-search launches since import (every device dispatch of a
#: multi-set search bumps it exactly once — the unsharded single call, each
#: per-shard call of the host fan-out, and the ONE shard_map dispatch of the
#: stacked path).  The dispatch-count tests read and reset it.
LAUNCH_COUNT = 0

#: Host-side ADMISSION launches since import — the write-path twin of
#: ``LAUNCH_COUNT``.  ``MonarchKVIndex`` bumps it once per device admission
#: dispatch: exactly once per batch on the stacked single-dispatch path
#: (``admit_dispatch="auto"``), once per partition holding candidates on
#: the kept per-partition fan-out oracle (``admit_dispatch="fanout"``).
ADMIT_LAUNCH_COUNT = 0

#: Adaptive query-block policy, now MEASURED: ``kernels/autotune.py``
#: answers with the committed per-(shape-bucket, backend, plane-format)
#: winner, falling back deterministically to the original two-point
#: heuristic (16 below 256 queries, 64 at/above) when a family is
#: uncached.  Search results are layout-independent (first-valid-way per
#: query), so the width never changes an answer — pinned by the parity
#: matrix and the cold-cache test.
WIDE_BLOCK_AT = autotune.WIDE_BLOCK_AT
WIDE_BLOCK_Q = autotune.WIDE_BLOCK_Q


def _pick_block_q(n_queries: int, block_q: int | None,
                  plane_format: str = "int8") -> int:
    if block_q is not None:
        return block_q
    return autotune.multiset_block_q(n_queries, plane_format)


def _resolve_scoring(scoring: str | None) -> str:
    if scoring is None:
        scoring = os.environ.get("REPRO_XAM_SCORING", "int8")
    if scoring not in ("int8", "f32"):
        raise ValueError(
            f"scoring must be one of ('int8', 'f32'), got {scoring!r} "
            "(set via the REPRO_XAM_SCORING env knob or the scoring "
            "argument)")
    return scoring


def xam_search(keys, data, masks=None, *, use_kernel: bool = True,
               scoring: str | None = None,
               plane_format: str | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Masked CAM search: (Q,R) keys x (R,C) stored bits -> (Q,C) matches.

    ``plane_format`` (None = the ``REPRO_PLANE_FORMAT`` env knob,
    default ``"int8"``) selects the stored-bit layout: ``"packed8"``
    packs ``data`` 8 bits per uint8 word along R on the host (R padded
    to a multiple of 8 with zero bits) and the kernel unpacks in VMEM —
    bit-identical results, ~8x less plane traffic.  Block shapes come
    from the autotune cache (``kernels/autotune.py``)."""
    keys = jnp.asarray(keys, jnp.int8)
    data = jnp.asarray(data, jnp.int8)
    if masks is None:
        masks = jnp.ones_like(keys)
    masks = jnp.asarray(masks, jnp.int8)
    if not use_kernel:
        return xam_search_ref(keys, data, masks)
    plane_format = resolve_plane_format(plane_format)
    if plane_format == "packed8":
        bits = np.asarray(data, np.int8)
        r, c = bits.shape
        rp8 = -(-r // 8) * 8
        if rp8 != r:
            bits = np.concatenate(
                [bits, np.zeros((rp8 - r, c), np.int8)], axis=0)
        data = jnp.asarray(pack_bits_np(bits, axis=0))
    if interpret is None:
        interpret = not _ON_TPU
    block_q, block_c = autotune.search_blocks(plane_format)
    return xam_search_pallas(keys, data, masks,
                             block_q=block_q, block_c=block_c,
                             scoring=_resolve_scoring(scoring),
                             interpret=interpret)


def xam_match_index(keys, data, masks=None, **kw) -> jnp.ndarray:
    """First matching column per query; -1 = NULL match register."""
    m = xam_search(keys, data, masks, **kw)
    any_m = jnp.any(m == 1, axis=1)
    return jnp.where(any_m, jnp.argmax(m, axis=1), -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused multi-set fast path (device-resident planes, one launch per batch).
# ---------------------------------------------------------------------------

def _group_one(set_ids: np.ndarray, n_sets: int, block_q: int):
    """Unbucketed per-set block packing (one shard's level-2 grouping).

    Returns ``(slot, block_sets, total_blocks)`` with ``block_sets`` of
    exact length ``total_blocks`` — callers bucket/pad to their own
    compiled-shape policy."""
    set_ids = np.asarray(set_ids, np.int64)
    q = set_ids.shape[0]
    counts = np.bincount(set_ids, minlength=n_sets)
    blocks_per_set = -(-counts // block_q)          # ceil
    total_blocks = int(blocks_per_set.sum())

    block_start = np.zeros(n_sets + 1, np.int64)
    np.cumsum(blocks_per_set, out=block_start[1:])
    set_start = np.zeros(n_sets + 1, np.int64)
    np.cumsum(counts, out=set_start[1:])

    order = np.argsort(set_ids, kind="stable")
    sorted_sets = set_ids[order]
    rank_in_set = np.arange(q, dtype=np.int64) - set_start[sorted_sets]
    slot = np.empty(q, np.int64)
    slot[order] = block_start[sorted_sets] * block_q + rank_in_set

    block_sets = np.repeat(
        np.arange(n_sets, dtype=np.int32), blocks_per_set)
    return slot, block_sets, total_blocks


def group_queries_by_set(set_ids: np.ndarray, n_sets: int,
                         block_q: int = MULTISET_BLOCK_Q):
    """Host-side layout for the fused kernel: pack queries into per-set
    blocks of ``block_q`` and bucket the block count to a power of two (so
    varying batch sizes hit a handful of compiled shapes, not one each).

    Returns ``(slot, block_sets, padded_q, n_blocks)``: query i goes to
    padded row ``slot[i]``; grid block b searches set ``block_sets[b]``;
    only the first ``n_blocks`` blocks are real — the kernel skips the
    matmul for the bucket-padding tail via the scalar-prefetched count.
    """
    slot, block_sets, total_blocks = _group_one(set_ids, n_sets, block_q)
    n_qb = bucket_pow2(max(total_blocks, 1), lo=4)
    padded = np.zeros(n_qb, np.int32)
    padded[:total_blocks] = block_sets
    return slot, padded, n_qb * block_q, total_blocks


def group_queries_by_set_stacked(set_ids: np.ndarray, n_sets: int,
                                 n_parts: int,
                                 block_q: int = MULTISET_BLOCK_Q):
    """Two-level stacked layout for the single-dispatch sharded search.

    Level 1 splits queries by owning shard (``set_id // (n_sets //
    n_parts)`` — contiguous-block ownership, ``geometry.shard_of_set``);
    level 2 packs each shard's queries into per-(local-)set blocks of
    ``block_q`` exactly as :func:`group_queries_by_set` does.  Every
    shard is then padded to ONE common block count — the pow2 bucket of
    the largest shard's block count — so the query operand is a dense
    ``(n_parts, Qmax, R)`` array that shards ``P("sets")`` over the
    mesh, and the jit cache grows with the pow2 bucket count instead of
    one entry per ragged shape.

    Layout contract (consumed by ``xam_search_multiset_stacked``):

    * query i lives at row ``slot[i]`` of shard ``part_of[i]``'s slice;
    * grid block b of shard p searches that shard's LOCAL set
      ``block_sets[p, b]``;
    * only the first ``n_blocks[p]`` blocks of shard p are real — the
      kernel gets ``n_blocks`` via scalar prefetch and reports -1 for
      every padding block/row.

    Returns ``(part_of, slot, block_sets, n_blocks, padded_q)`` with
    ``block_sets`` of shape ``(n_parts, padded_q // block_q)`` and
    ``n_blocks`` of shape ``(n_parts,)``.

    Examples
    --------
    8 global sets over 2 shards, block width 4: set 5 is shard 1's local
    set 1, and the empty shard 0 still occupies its padded slice (zero
    real blocks):

    >>> part_of, slot, block_sets, n_blocks, padded_q = (
    ...     group_queries_by_set_stacked([5, 5, 4], 8, 2, block_q=4))
    >>> part_of.tolist(), slot.tolist()
    ([1, 1, 1], [4, 5, 0])
    >>> block_sets.tolist(), n_blocks.tolist(), padded_q
    ([[0, 0, 0, 0], [0, 1, 0, 0]], [0, 2], 16)
    """
    set_ids = np.asarray(set_ids, np.int64)
    if n_sets % n_parts != 0:
        raise ValueError(f"n_parts={n_parts} must divide n_sets={n_sets}")
    s_part = n_sets // n_parts
    part_of = set_ids // s_part
    grouped = []
    for p in range(n_parts):
        sel = np.nonzero(part_of == p)[0]
        sl, bs, tb = _group_one(set_ids[sel] - p * s_part, s_part, block_q)
        grouped.append((sel, sl, bs, tb))
    n_qb = bucket_pow2(max(max(g[3] for g in grouped), 1), lo=4)
    slot = np.empty(set_ids.shape[0], np.int64)
    block_sets = np.zeros((n_parts, n_qb), np.int32)
    n_blocks = np.zeros(n_parts, np.int32)
    for p, (sel, sl, bs, tb) in enumerate(grouped):
        slot[sel] = sl
        block_sets[p, :tb] = bs
        n_blocks[p] = tb
    return part_of, slot, block_sets, n_blocks, n_qb * block_q


def group_admits_stacked(set_ids: np.ndarray, n_sets: int, n_parts: int,
                         lo: int = 8):
    """Round-grid stacked layout for the single-dispatch admission.

    The admission scan couples candidates ONLY through per-set state
    (residency, window budget, the per-set replacement counter), so two
    candidates targeting different sets commute — only intra-set
    collisions need the sequential tie-break.  This grouping turns that
    into a dense grid: candidate i gets

    * ``part_of[i]`` — its owning storage partition (contiguous-block
      ownership, ``geometry.shard_of_set``);
    * ``row[i]`` — its PER-SET PREFIX RANK (how many earlier candidates
      in the batch target the same set), and
    * ``col[i]`` — its batch-order position among partition
      ``part_of[i]``'s rank-``row[i]`` candidates.

    Packed as a ``(n_parts, n_rounds, round_width)`` operand this is the
    segmented-parallel schedule: round r of a partition holds only
    rank-r candidates, whose sets are pairwise DISTINCT by construction
    (two same-set candidates differ in rank), so a whole round admits
    vectorized while a ``lax.scan`` over rounds replays intra-set
    collisions in exact batch order — bit-equal to the sequential scan.
    Both grid axes are pow2-bucketed (``n_rounds`` from 1, ``round_width``
    from ``lo``) so ragged batches reuse a handful of compiled shapes,
    mirroring :func:`group_queries_by_set_stacked`'s Qmax bucketing.

    Returns ``(part_of, row, col, n_rounds, round_width)``.

    Examples
    --------
    8 global sets over 2 partitions: two set-5 candidates split across
    rounds 0 and 1, the set-4 candidate shares round 0 (distinct set),
    and the set-1 candidate opens partition 0's round 0:

    >>> part_of, row, col, n_rounds, round_width = group_admits_stacked(
    ...     [5, 5, 4, 1], 8, 2)
    >>> part_of.tolist(), row.tolist(), col.tolist()
    ([1, 1, 1, 0], [0, 1, 0, 0], [0, 0, 1, 0])
    >>> n_rounds, round_width
    (2, 8)
    """
    set_ids = np.asarray(set_ids, np.int64)
    if n_sets % n_parts != 0:
        raise ValueError(f"n_parts={n_parts} must divide n_sets={n_sets}")
    s_part = n_sets // n_parts
    part_of = set_ids // s_part
    b = set_ids.shape[0]
    if b == 0:
        return part_of, set_ids.copy(), set_ids.copy(), 1, max(lo, 1)
    # Per-set prefix rank: batch position among same-set candidates.
    set_start = np.zeros(n_sets + 1, np.int64)
    np.cumsum(np.bincount(set_ids, minlength=n_sets), out=set_start[1:])
    order = np.argsort(set_ids, kind="stable")
    row = np.empty(b, np.int64)
    row[order] = np.arange(b) - set_start[set_ids[order]]
    # Column: batch position among the (partition, rank) group's members.
    n_rounds_real = int(row.max()) + 1
    gid = part_of * n_rounds_real + row
    g_start = np.zeros(n_parts * n_rounds_real + 1, np.int64)
    np.cumsum(np.bincount(gid, minlength=n_parts * n_rounds_real),
              out=g_start[1:])
    gorder = np.argsort(gid, kind="stable")
    col = np.empty(b, np.int64)
    col[gorder] = np.arange(b) - g_start[gid[gorder]]
    n_rounds = bucket_pow2(n_rounds_real, lo=1)
    round_width = bucket_pow2(int(col.max()) + 1, lo=lo)
    return part_of, row, col, n_rounds, round_width


def _multiset_dispatch(key_bits: np.ndarray, set_ids: np.ndarray,
                       planes: jnp.ndarray, valid: jnp.ndarray, *,
                       block_q: int, scoring: str, interpret: bool):
    """Group + pad + LAUNCH one fused multi-set search; defer the sync.

    Returns ``(out, slot)``: ``out`` is the in-flight (padded_q,) device
    result, ``slot`` the padded row of each input query.  Callers that fan
    out over shards dispatch every shard's kernel before materializing any
    result, so the launches overlap under jax async dispatch."""
    global LAUNCH_COUNT
    LAUNCH_COUNT += 1
    key_bits = np.asarray(key_bits, np.int8)
    _, r = key_bits.shape
    n_sets = planes.shape[0]
    slot, block_sets, padded_q, n_blocks = group_queries_by_set(
        set_ids, n_sets, block_q)
    keys_p = np.zeros((padded_q, r), np.int8)
    masks_p = np.zeros((padded_q, r), np.int8)
    keys_p[slot] = key_bits
    masks_p[slot] = 1
    # Query-side operands follow the planes' placement, so shard-local
    # calls run on the shard's own mesh device.
    put = lambda x: jax.device_put(jnp.asarray(x), planes.sharding)
    live = (np.arange(len(block_sets)) < n_blocks).astype(np.int32)
    out = xam_search_multiset_pallas(
        put(keys_p), put(masks_p), planes, valid,
        put(block_sets), put(live),
        block_q=block_q, scoring=scoring, interpret=interpret)
    return out, slot


def xam_search_multiset(key_bits: np.ndarray, set_ids: np.ndarray,
                        planes: jnp.ndarray, valid: jnp.ndarray, *,
                        block_q: int | None = None,
                        scoring: str | None = None,
                        interpret: bool | None = None) -> np.ndarray:
    """Batched CAM search across sets in ONE kernel launch.

    Parameters
    ----------
    key_bits : np.ndarray, shape (Q, R), {0, 1}
        Host-side query bit rows (one row per fingerprint/key).
    set_ids : np.ndarray, shape (Q,), int
        Which of the device-resident stored-bit planes each query
        searches; values in ``[0, n_sets)``.
    planes : jnp.ndarray, shape (n_sets, R, C) int8 — or (n_sets, R//8,
        C) uint8 packed words (``plane_format="packed8"``; the kernel
        unpacks per tile in VMEM, bit-identical results).  The dtype IS
        the format tag.
    valid : jnp.ndarray, shape (n_sets, C), int8
        Per-way validity; dead ways are masked inside the kernel so they
        never produce hits.
    block_q, scoring, interpret
        Kernel tile width (None = the autotune-cache winner for this
        batch's shape bucket and plane format, falling back to the
        16/64 two-point heuristic — the answer is width-independent),
        MXU arithmetic ("int8" default / "f32"), and Pallas
        interpret-mode flag (defaults to True off-TPU).

    Returns
    -------
    np.ndarray, shape (Q,), int32
        First matching *valid* way per query; ``-1`` = miss.
    """
    if interpret is None:
        interpret = not _ON_TPU
    out, slot = _multiset_dispatch(
        key_bits, set_ids, planes, valid,
        block_q=_pick_block_q(len(set_ids), block_q,
                              plane_format_of(planes)),
        scoring=_resolve_scoring(scoring), interpret=interpret)
    return np.asarray(out)[slot]


@functools.lru_cache(maxsize=None)
def _stacked_shardmap_fn(mesh: Mesh, block_q: int, scoring: str,
                         interpret: bool):
    """Jitted shard_map wrapper placing every shard's fused search from
    ONE dispatch.  Each mesh device receives its (1, Qmax, R) query slice,
    its scalar-prefetch row of block set ids + valid block count, and its
    resident (sets_per_shard, R, C) plane block; XLA runs the per-shard
    pallas_calls concurrently inside the single program."""
    def per_shard(keys, masks, block_sets, n_blocks, planes, valid):
        live = (jnp.arange(block_sets.shape[1]) < n_blocks[0]
                ).astype(jnp.int32)
        out = xam_search_multiset_pallas(
            keys[0], masks[0], planes, valid, block_sets[0], live,
            block_q=block_q, scoring=scoring, interpret=interpret)
        return out[None]

    spec = (P("sets"),) * 6
    return jax.jit(shard_map(per_shard, mesh=mesh, in_specs=spec,
                             out_specs=P("sets"), check_rep=False))


def xam_search_multiset_stacked(key_bits: np.ndarray, set_ids: np.ndarray,
                                planes: jnp.ndarray, valid: jnp.ndarray, *,
                                mesh: Mesh | None = None,
                                n_parts: int | None = None,
                                block_q: int | None = None,
                                scoring: str | None = None,
                                interpret: bool | None = None) -> np.ndarray:
    """Sharded CAM search in ONE device dispatch (the shard_map fast path).

    The two-level stacked layout of
    :func:`group_queries_by_set_stacked` turns the whole query batch into
    a dense ``(n_parts, Qmax, R)`` operand; with a ``("sets",)`` ``mesh``
    the search is ONE jitted ``shard_map`` call — XLA places every
    shard's fused kernel from a single program, replacing the
    one-``pallas_call``-per-shard host fan-out of
    :func:`xam_search_multiset_sharded`.  Without a mesh (co-located
    shards) the same stacked layout flattens into ONE plain fused launch
    over the global planes.

    Parameters
    ----------
    key_bits : np.ndarray, shape (Q, R), {0, 1}
        Host-side query bit rows.
    set_ids : np.ndarray, shape (Q,), int
        GLOBAL physical set ids in ``[0, n_sets)``.
    planes : jnp.ndarray, shape (n_sets, R, C) int8 (or packed uint8 —
        see :func:`xam_search_multiset`)
        Stored bits for ALL sets.  With ``mesh`` this must be sharded
        ``P("sets")`` over it (contiguous blocks, shard k's sets on mesh
        device k — the layout ``MonarchKVIndex`` assembles zero-copy from
        its per-shard planes); without a mesh any single-device array.
    valid : jnp.ndarray, shape (n_sets, C), int8
        Validity planes, sharded like ``planes``.
    mesh : Mesh | None
        The ``("sets",)`` mesh (``launch/mesh.make_set_mesh``).  None =
        single-device host: one flattened fused launch.
    n_parts : int | None
        Shard count of the stacked layout; defaults to the mesh size
        (must equal it when a mesh is given).

    Returns
    -------
    np.ndarray, shape (Q,), int32
        First matching valid way per query (set-local), -1 = miss — same
        contract as :func:`xam_search_multiset`.

    Notes
    -----
    With ``n_parts == 1`` and no mesh this is EXACTLY
    :func:`xam_search_multiset` — same grouping, same kernel — keeping
    the unsharded serving path bit-identical.
    """
    if n_parts is None:
        n_parts = mesh.shape["sets"] if mesh is not None else 1
    if mesh is not None and n_parts != mesh.shape["sets"]:
        raise ValueError(
            f"n_parts={n_parts} must equal the mesh size {mesh.shape['sets']}")
    if n_parts == 1 and mesh is None:
        return xam_search_multiset(key_bits, set_ids, planes, valid,
                                   block_q=block_q, scoring=scoring,
                                   interpret=interpret)
    if interpret is None:
        interpret = not _ON_TPU
    scoring = _resolve_scoring(scoring)
    block_q = _pick_block_q(len(set_ids), block_q, plane_format_of(planes))
    key_bits = np.asarray(key_bits, np.int8)
    n_sets = planes.shape[0]
    r = key_bits.shape[1]
    part_of, slot, block_sets, n_blocks, padded_q = (
        group_queries_by_set_stacked(set_ids, n_sets, n_parts, block_q))
    keys_p = np.zeros((n_parts, padded_q, r), np.int8)
    masks_p = np.zeros((n_parts, padded_q, r), np.int8)
    keys_p[part_of, slot] = key_bits
    masks_p[part_of, slot] = 1

    global LAUNCH_COUNT
    LAUNCH_COUNT += 1
    if mesh is None:
        # Co-located shards: the stacked layout IS a valid flat grouping
        # once block set ids are globalized — one plain fused launch.
        # Each shard's pad run (blocks past its prefix of real ones, up
        # to the common Qmax) stays flagged dead, so the kernel skips
        # its matmuls exactly like the shard_map path does.
        s_part = n_sets // n_parts
        bs_global = (block_sets
                     + (np.arange(n_parts, dtype=np.int32) * s_part)[:, None])
        n_qb = block_sets.shape[1]
        live = (np.arange(n_qb) < n_blocks[:, None]).astype(np.int32)
        out = xam_search_multiset_pallas(
            jnp.asarray(keys_p.reshape(-1, r)),
            jnp.asarray(masks_p.reshape(-1, r)),
            planes, valid, jnp.asarray(bs_global.reshape(-1)),
            jnp.asarray(live.reshape(-1)),
            block_q=block_q, scoring=scoring, interpret=interpret)
        out = np.asarray(out).reshape(n_parts, padded_q)
    else:
        put = lambda x: jax.device_put(
            jnp.asarray(x), NamedSharding(mesh, P("sets")))
        fn = _stacked_shardmap_fn(mesh, block_q, scoring, interpret)
        out = np.asarray(fn(put(keys_p), put(masks_p), put(block_sets),
                            put(n_blocks), planes, valid))
    return out[part_of, slot].astype(np.int32)


def xam_search_multiset_sharded(key_bits: np.ndarray, set_ids: np.ndarray,
                                planes_by_shard, valid_by_shard, *,
                                block_q: int | None = None,
                                scoring: str | None = None,
                                interpret: bool | None = None) -> np.ndarray:
    """Fan a query batch out over set-sharded CAM planes.

    Two-level extension of :func:`group_queries_by_set`'s pow2 bucketing:
    queries are first split by owning shard (``set_id // sets_per_shard``,
    contiguous-block ownership — ``geometry.shard_of_set``), then each
    shard's sub-batch is grouped into per-set blocks and searched by ONE
    shard-local :func:`xam_search_multiset` launch against that shard's
    ``(sets_per_shard, R, C)`` planes.  All shard kernels are dispatched
    before any result is materialized, so on a multi-device ``("sets",)``
    mesh the searches run concurrently.

    Parameters
    ----------
    key_bits : np.ndarray, shape (Q, R), {0, 1}
        Host-side query bit rows.
    set_ids : np.ndarray, shape (Q,), int
        GLOBAL physical set ids in ``[0, n_shards * sets_per_shard)``.
    planes_by_shard : sequence of jnp.ndarray, (sets_per_shard, R, C) int8
        Shard-local stored-bit planes (shard k owns global sets
        ``[k * sets_per_shard, (k + 1) * sets_per_shard)``).
    valid_by_shard : sequence of jnp.ndarray, (sets_per_shard, C) int8
        Shard-local validity planes.

    Returns
    -------
    np.ndarray, shape (Q,), int32
        First matching valid way per query (way index is set-local, as in
        the unsharded path); ``-1`` = miss.

    Notes
    -----
    With one shard this is EXACTLY :func:`xam_search_multiset` — same
    grouping, same kernel, same inputs — which pins the single-shard
    serving path bit-identical to the unsharded implementation.

    This host fan-out is the DIFFERENTIAL REFERENCE for the
    single-dispatch path: :func:`xam_search_multiset_stacked` answers the
    same ``(key_bits, set_ids)`` batch from one ``shard_map`` dispatch
    over the stacked ``(n_parts, Qmax, R)`` layout (contract in
    :func:`group_queries_by_set_stacked` — per-shard blocks padded to a
    common pow2 ``Qmax``, per-shard valid block counts scalar-prefetched)
    and must return bit-identical ways; ``tests/test_kv_index_differential
    .py`` replays randomized schedules through both after every op.
    """
    n_shards = len(planes_by_shard)
    if n_shards == 1:
        return xam_search_multiset(
            key_bits, set_ids, planes_by_shard[0], valid_by_shard[0],
            block_q=block_q, scoring=scoring, interpret=interpret)
    if interpret is None:
        interpret = not _ON_TPU
    scoring = _resolve_scoring(scoring)
    key_bits = np.asarray(key_bits, np.int8)
    set_ids = np.asarray(set_ids, np.int64)
    s_local = planes_by_shard[0].shape[0]
    shard_ids = set_ids // s_local
    # Dispatch every shard's fused search before syncing any of them.
    pending = []
    for k in np.unique(shard_ids):
        sel = np.nonzero(shard_ids == k)[0]
        out, slot = _multiset_dispatch(
            key_bits[sel], set_ids[sel] - int(k) * s_local,
            planes_by_shard[int(k)], valid_by_shard[int(k)],
            block_q=_pick_block_q(sel.size, block_q,
                                  plane_format_of(planes_by_shard[0])),
            scoring=scoring, interpret=interpret)
        pending.append((sel, slot, out))
    ways = np.empty(set_ids.shape[0], np.int32)
    for sel, slot, out in pending:
        ways[sel] = np.asarray(out)[slot]
    return ways


# ---------------------------------------------------------------------------
# Bit-plane packing helpers.
# ---------------------------------------------------------------------------

def words_to_bits(words: jnp.ndarray, n_bits: int = 32) -> jnp.ndarray:
    """(...,) uint words -> (..., n_bits) int8 bit planes (LSB first).
    ``n_bits`` must not exceed the word dtype's width."""
    words = jnp.asarray(words)
    assert n_bits <= jnp.iinfo(words.dtype).bits, "n_bits exceeds word width"
    shifts = jnp.arange(n_bits, dtype=words.dtype)
    return ((words[..., None] >> shifts) & 1).astype(jnp.int8)


def words_to_bits_np(words: np.ndarray, n_bits: int = 32) -> np.ndarray:
    """Host-side twin of :func:`words_to_bits` (no device round-trip).

    >>> words_to_bits_np(np.asarray([5], np.uint32), 4).tolist()
    [[1, 0, 1, 0]]
    """
    words = np.asarray(words)
    assert n_bits <= np.iinfo(words.dtype).bits, "n_bits exceeds word width"
    shifts = np.arange(n_bits, dtype=words.dtype)
    return ((words[..., None] >> shifts) & 1).astype(np.int8)


def bits_to_words(bits: jnp.ndarray) -> jnp.ndarray:
    n_bits = bits.shape[-1]
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1)
