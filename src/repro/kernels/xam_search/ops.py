"""Jit'd public wrappers for the XAM search kernel.

``interpret`` defaults to True on CPU (this rig) and should be False on real
TPUs; the flag is threaded, never hard-coded in callers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.xam_search.kernel import xam_search_pallas
from repro.kernels.xam_search.ref import xam_search_ref

_ON_TPU = jax.default_backend() == "tpu"


def xam_search(keys, data, masks=None, *, use_kernel: bool = True,
               interpret: bool | None = None) -> jnp.ndarray:
    """Masked CAM search: (Q,R) keys x (R,C) stored bits -> (Q,C) matches."""
    keys = jnp.asarray(keys, jnp.int8)
    data = jnp.asarray(data, jnp.int8)
    if masks is None:
        masks = jnp.ones_like(keys)
    masks = jnp.asarray(masks, jnp.int8)
    if not use_kernel:
        return xam_search_ref(keys, data, masks)
    if interpret is None:
        interpret = not _ON_TPU
    return xam_search_pallas(keys, data, masks, interpret=interpret)


def xam_match_index(keys, data, masks=None, **kw) -> jnp.ndarray:
    """First matching column per query; -1 = NULL match register."""
    m = xam_search(keys, data, masks, **kw)
    any_m = jnp.any(m == 1, axis=1)
    return jnp.where(any_m, jnp.argmax(m, axis=1), -1).astype(jnp.int32)


def words_to_bits(words: jnp.ndarray, n_bits: int = 32) -> jnp.ndarray:
    """(...,) uint words -> (..., n_bits) int8 bit planes (LSB first).
    ``n_bits`` must not exceed the word dtype's width."""
    words = jnp.asarray(words)
    assert n_bits <= jnp.iinfo(words.dtype).bits, "n_bits exceeds word width"
    shifts = jnp.arange(n_bits, dtype=words.dtype)
    return ((words[..., None] >> shifts) & 1).astype(jnp.int8)


def bits_to_words(bits: jnp.ndarray) -> jnp.ndarray:
    n_bits = bits.shape[-1]
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1)
