"""Jit'd public wrappers for the XAM search kernels.

``interpret`` defaults to True on CPU (this rig) and should be False on real
TPUs; the flag is threaded, never hard-coded in callers.  ``scoring``
selects the MXU arithmetic: ``"int8"`` (default — int8 x int8 -> int32
accumulate) or ``"f32"`` (the original float32 path); the default can be
flipped rig-wide via ``REPRO_XAM_SCORING=f32``.
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.common import bucket_pow2
from repro.kernels.xam_search.kernel import (
    MULTISET_BLOCK_Q, xam_search_multiset_pallas, xam_search_pallas)
from repro.kernels.xam_search.ref import xam_search_ref

_ON_TPU = jax.default_backend() == "tpu"


def _resolve_scoring(scoring: str | None) -> str:
    if scoring is None:
        scoring = os.environ.get("REPRO_XAM_SCORING", "int8")
    assert scoring in ("int8", "f32"), scoring
    return scoring


def xam_search(keys, data, masks=None, *, use_kernel: bool = True,
               scoring: str | None = None,
               interpret: bool | None = None) -> jnp.ndarray:
    """Masked CAM search: (Q,R) keys x (R,C) stored bits -> (Q,C) matches."""
    keys = jnp.asarray(keys, jnp.int8)
    data = jnp.asarray(data, jnp.int8)
    if masks is None:
        masks = jnp.ones_like(keys)
    masks = jnp.asarray(masks, jnp.int8)
    if not use_kernel:
        return xam_search_ref(keys, data, masks)
    if interpret is None:
        interpret = not _ON_TPU
    return xam_search_pallas(keys, data, masks,
                             scoring=_resolve_scoring(scoring),
                             interpret=interpret)


def xam_match_index(keys, data, masks=None, **kw) -> jnp.ndarray:
    """First matching column per query; -1 = NULL match register."""
    m = xam_search(keys, data, masks, **kw)
    any_m = jnp.any(m == 1, axis=1)
    return jnp.where(any_m, jnp.argmax(m, axis=1), -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused multi-set fast path (device-resident planes, one launch per batch).
# ---------------------------------------------------------------------------

def group_queries_by_set(set_ids: np.ndarray, n_sets: int,
                         block_q: int = MULTISET_BLOCK_Q):
    """Host-side layout for the fused kernel: pack queries into per-set
    blocks of ``block_q`` and bucket the block count to a power of two (so
    varying batch sizes hit a handful of compiled shapes, not one each).

    Returns ``(slot, block_sets, padded_q)``: query i goes to padded row
    ``slot[i]``; grid block b searches set ``block_sets[b]``.
    """
    set_ids = np.asarray(set_ids, np.int64)
    q = set_ids.shape[0]
    counts = np.bincount(set_ids, minlength=n_sets)
    blocks_per_set = -(-counts // block_q)          # ceil
    total_blocks = max(int(blocks_per_set.sum()), 1)
    n_qb = bucket_pow2(total_blocks, lo=4)

    block_start = np.zeros(n_sets + 1, np.int64)
    np.cumsum(blocks_per_set, out=block_start[1:])
    set_start = np.zeros(n_sets + 1, np.int64)
    np.cumsum(counts, out=set_start[1:])

    order = np.argsort(set_ids, kind="stable")
    sorted_sets = set_ids[order]
    rank_in_set = np.arange(q, dtype=np.int64) - set_start[sorted_sets]
    slot = np.empty(q, np.int64)
    slot[order] = block_start[sorted_sets] * block_q + rank_in_set

    block_sets = np.zeros(n_qb, np.int32)
    block_sets[:total_blocks] = np.repeat(
        np.arange(n_sets, dtype=np.int32), blocks_per_set)
    return slot, block_sets, n_qb * block_q


def _multiset_dispatch(key_bits: np.ndarray, set_ids: np.ndarray,
                       planes: jnp.ndarray, valid: jnp.ndarray, *,
                       block_q: int, scoring: str, interpret: bool):
    """Group + pad + LAUNCH one fused multi-set search; defer the sync.

    Returns ``(out, slot)``: ``out`` is the in-flight (padded_q,) device
    result, ``slot`` the padded row of each input query.  Callers that fan
    out over shards dispatch every shard's kernel before materializing any
    result, so the launches overlap under jax async dispatch."""
    key_bits = np.asarray(key_bits, np.int8)
    _, r = key_bits.shape
    n_sets = planes.shape[0]
    slot, block_sets, padded_q = group_queries_by_set(
        set_ids, n_sets, block_q)
    keys_p = np.zeros((padded_q, r), np.int8)
    masks_p = np.zeros((padded_q, r), np.int8)
    keys_p[slot] = key_bits
    masks_p[slot] = 1
    # Query-side operands follow the planes' placement, so shard-local
    # calls run on the shard's own mesh device.
    put = lambda x: jax.device_put(jnp.asarray(x), planes.sharding)
    out = xam_search_multiset_pallas(
        put(keys_p), put(masks_p), planes, valid,
        put(block_sets), block_q=block_q,
        scoring=scoring, interpret=interpret)
    return out, slot


def xam_search_multiset(key_bits: np.ndarray, set_ids: np.ndarray,
                        planes: jnp.ndarray, valid: jnp.ndarray, *,
                        block_q: int = MULTISET_BLOCK_Q,
                        scoring: str | None = None,
                        interpret: bool | None = None) -> np.ndarray:
    """Batched CAM search across sets in ONE kernel launch.

    Parameters
    ----------
    key_bits : np.ndarray, shape (Q, R), {0, 1}
        Host-side query bit rows (one row per fingerprint/key).
    set_ids : np.ndarray, shape (Q,), int
        Which of the device-resident stored-bit planes each query
        searches; values in ``[0, n_sets)``.
    planes : jnp.ndarray, shape (n_sets, R, C), int8
        Device-resident stored bits, one (R, C) plane per CAM set.
    valid : jnp.ndarray, shape (n_sets, C), int8
        Per-way validity; dead ways are masked inside the kernel so they
        never produce hits.
    block_q, scoring, interpret
        Kernel tile width, MXU arithmetic ("int8" default / "f32"), and
        Pallas interpret-mode flag (defaults to True off-TPU).

    Returns
    -------
    np.ndarray, shape (Q,), int32
        First matching *valid* way per query; ``-1`` = miss.
    """
    if interpret is None:
        interpret = not _ON_TPU
    out, slot = _multiset_dispatch(
        key_bits, set_ids, planes, valid, block_q=block_q,
        scoring=_resolve_scoring(scoring), interpret=interpret)
    return np.asarray(out)[slot]


def xam_search_multiset_sharded(key_bits: np.ndarray, set_ids: np.ndarray,
                                planes_by_shard, valid_by_shard, *,
                                block_q: int = MULTISET_BLOCK_Q,
                                scoring: str | None = None,
                                interpret: bool | None = None) -> np.ndarray:
    """Fan a query batch out over set-sharded CAM planes.

    Two-level extension of :func:`group_queries_by_set`'s pow2 bucketing:
    queries are first split by owning shard (``set_id // sets_per_shard``,
    contiguous-block ownership — ``geometry.shard_of_set``), then each
    shard's sub-batch is grouped into per-set blocks and searched by ONE
    shard-local :func:`xam_search_multiset` launch against that shard's
    ``(sets_per_shard, R, C)`` planes.  All shard kernels are dispatched
    before any result is materialized, so on a multi-device ``("sets",)``
    mesh the searches run concurrently.

    Parameters
    ----------
    key_bits : np.ndarray, shape (Q, R), {0, 1}
        Host-side query bit rows.
    set_ids : np.ndarray, shape (Q,), int
        GLOBAL physical set ids in ``[0, n_shards * sets_per_shard)``.
    planes_by_shard : sequence of jnp.ndarray, (sets_per_shard, R, C) int8
        Shard-local stored-bit planes (shard k owns global sets
        ``[k * sets_per_shard, (k + 1) * sets_per_shard)``).
    valid_by_shard : sequence of jnp.ndarray, (sets_per_shard, C) int8
        Shard-local validity planes.

    Returns
    -------
    np.ndarray, shape (Q,), int32
        First matching valid way per query (way index is set-local, as in
        the unsharded path); ``-1`` = miss.

    Notes
    -----
    With one shard this is EXACTLY :func:`xam_search_multiset` — same
    grouping, same kernel, same inputs — which pins the single-shard
    serving path bit-identical to the unsharded implementation.
    """
    n_shards = len(planes_by_shard)
    if n_shards == 1:
        return xam_search_multiset(
            key_bits, set_ids, planes_by_shard[0], valid_by_shard[0],
            block_q=block_q, scoring=scoring, interpret=interpret)
    if interpret is None:
        interpret = not _ON_TPU
    scoring = _resolve_scoring(scoring)
    key_bits = np.asarray(key_bits, np.int8)
    set_ids = np.asarray(set_ids, np.int64)
    s_local = planes_by_shard[0].shape[0]
    shard_ids = set_ids // s_local
    # Dispatch every shard's fused search before syncing any of them.
    pending = []
    for k in np.unique(shard_ids):
        sel = np.nonzero(shard_ids == k)[0]
        out, slot = _multiset_dispatch(
            key_bits[sel], set_ids[sel] - int(k) * s_local,
            planes_by_shard[int(k)], valid_by_shard[int(k)],
            block_q=block_q, scoring=scoring, interpret=interpret)
        pending.append((sel, slot, out))
    ways = np.empty(set_ids.shape[0], np.int32)
    for sel, slot, out in pending:
        ways[sel] = np.asarray(out)[slot]
    return ways


# ---------------------------------------------------------------------------
# Bit-plane packing helpers.
# ---------------------------------------------------------------------------

def words_to_bits(words: jnp.ndarray, n_bits: int = 32) -> jnp.ndarray:
    """(...,) uint words -> (..., n_bits) int8 bit planes (LSB first).
    ``n_bits`` must not exceed the word dtype's width."""
    words = jnp.asarray(words)
    assert n_bits <= jnp.iinfo(words.dtype).bits, "n_bits exceeds word width"
    shifts = jnp.arange(n_bits, dtype=words.dtype)
    return ((words[..., None] >> shifts) & 1).astype(jnp.int8)


def words_to_bits_np(words: np.ndarray, n_bits: int = 32) -> np.ndarray:
    """Host-side twin of :func:`words_to_bits` (no device round-trip).

    >>> words_to_bits_np(np.asarray([5], np.uint32), 4).tolist()
    [[1, 0, 1, 0]]
    """
    words = np.asarray(words)
    assert n_bits <= np.iinfo(words.dtype).bits, "n_bits exceeds word width"
    shifts = np.arange(n_bits, dtype=words.dtype)
    return ((words[..., None] >> shifts) & 1).astype(np.int8)


def bits_to_words(bits: jnp.ndarray) -> jnp.ndarray:
    n_bits = bits.shape[-1]
    shifts = jnp.arange(n_bits, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) << shifts, axis=-1)
