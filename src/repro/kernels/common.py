"""Helpers shared by the kernel packages."""
from __future__ import annotations


def bucket_pow2(n: int, lo: int) -> int:
    """Next power of two >= max(n, lo) — the recompile-killing bucket
    policy for ragged query counts (one compiled shape per bucket)."""
    b = lo
    while b < n:
        b <<= 1
    return b
