"""Helpers shared by the kernel packages."""
from __future__ import annotations

import os

import numpy as np

#: Storage formats for device-resident stored-bit planes.
#:
#: * ``"int8"`` — one logical bit per int8 byte (the original layout).
#: * ``"packed8"`` — 8 logical bits per uint8 word along the bit axis
#:   (LSB-first), cutting HBM->VMEM traffic for the plane operand ~8x;
#:   kernels unpack per tile in VMEM, so results are bit-identical.
PLANE_FORMATS = ("int8", "packed8")

#: Env knob that picks the default plane format rig-wide.
PLANE_FORMAT_ENV = "REPRO_PLANE_FORMAT"


def bucket_pow2(n: int, lo: int) -> int:
    """Next power of two >= max(n, lo) — the recompile-killing bucket
    policy for ragged query counts (one compiled shape per bucket)."""
    b = lo
    while b < n:
        b <<= 1
    return b


def resolve_plane_format(fmt: str | None = None) -> str:
    """Validate a plane format; ``None`` reads the ``REPRO_PLANE_FORMAT``
    env knob (default ``"int8"``).  Raises ``ValueError`` naming the knob
    and the valid values — never an assert (which ``python -O`` elides).
    """
    if fmt is None:
        fmt = os.environ.get(PLANE_FORMAT_ENV, "int8")
    if fmt not in PLANE_FORMATS:
        raise ValueError(
            f"plane_format must be one of {PLANE_FORMATS}, got {fmt!r} "
            f"(set via the {PLANE_FORMAT_ENV} env knob or the plane_format "
            "argument)")
    return fmt


def plane_format_of(planes) -> str:
    """Infer the storage format of a stored-bit plane array from its
    dtype: uint8 planes hold packed words, int8 planes hold one bit per
    byte.  The dtype IS the format tag — jit caches already specialize on
    it, so no extra static argument is threaded."""
    dt = np.dtype(planes.dtype)
    if dt == np.uint8:
        return "packed8"
    if dt == np.int8:
        return "int8"
    raise ValueError(
        f"stored-bit planes must be int8 (unpacked) or uint8 (packed8); "
        f"got dtype {dt}")


def pack_bits_np(bits: np.ndarray, axis: int = -1) -> np.ndarray:
    """Pack {0,1} bit planes 8-per-uint8-word along ``axis``, LSB-first.

    The layout contract (shared with the in-kernel unpack and
    ``words_to_bits``): logical bit ``r`` of a column lives in packed
    word ``r // 8`` at bit position ``r % 8``.  The bit-axis length must
    be a multiple of 8 — pad with zero bits first if it is not (all-zero
    mask rows are inert in the search).

    >>> pack_bits_np(np.asarray([[1, 0, 1, 0, 0, 0, 0, 0]], np.int8)
    ...              ).tolist()
    [[5]]
    >>> cols = np.asarray([[1, 1, 0, 0, 0, 0, 0, 1] * 2], np.int8)
    >>> unpack_bits_np(pack_bits_np(cols), 16).tolist() == cols.tolist()
    True
    """
    bits = np.asarray(bits)
    axis = axis % bits.ndim
    r = bits.shape[axis]
    if r % 8 != 0:
        raise ValueError(
            f"bit-axis length {r} is not a multiple of 8; pad with zero "
            "bits before packing (plane_format='packed8' stores 8 bits "
            "per uint8 word)")
    moved = np.moveaxis(bits, axis, -1).astype(np.uint8)
    words = moved.reshape(moved.shape[:-1] + (r // 8, 8))
    shifts = np.arange(8, dtype=np.uint8)
    packed = np.bitwise_or.reduce(words << shifts, axis=-1).astype(np.uint8)
    return np.moveaxis(packed, -1, axis)


def unpack_bits_np(packed: np.ndarray, n_bits: int | None = None,
                   axis: int = -1) -> np.ndarray:
    """Inverse of :func:`pack_bits_np`: uint8 packed words -> {0,1} int8
    bit planes along ``axis`` (LSB-first).  ``n_bits`` trims the unpacked
    axis (default: 8x the packed length)."""
    packed = np.asarray(packed, np.uint8)
    axis = axis % packed.ndim
    moved = np.moveaxis(packed, axis, -1)
    shifts = np.arange(8, dtype=np.uint8)
    bits = ((moved[..., None] >> shifts) & 1).astype(np.int8)
    bits = bits.reshape(moved.shape[:-1] + (moved.shape[-1] * 8,))
    if n_bits is not None:
        bits = bits[..., :n_bits]
    return np.moveaxis(bits, -1, axis)
