"""Measured block-shape selection for the XAM kernels.

The block shapes used to be a hard-coded two-point heuristic (the 16/64
``_pick_block_q`` switch plus ``DEFAULT_BLOCK_Q``/``DEFAULT_BLOCK_C``).
This module replaces the constants with MEASURED winners: a small sweep
(`autotune()` — ``python -m benchmarks.run --autotune`` or ``python -m
repro.kernels.autotune``) times MXU-aligned candidates per family and
commits the winners to ``autotune_cache.json`` next to this file.

A *family* is ``{kernel}/{backend}/{plane_format}/{shape_bucket}``:

* ``kernel`` — ``xam_multiset`` (the fused serving kernel; tunes
  ``block_q``) or ``xam_search`` (the flat bitmap kernel; tunes
  ``(block_q, block_c)``);
* ``backend`` — ``jax.default_backend()`` at sweep time (``cpu``
  interpret-mode numbers must never steer a TPU run and vice versa);
* ``plane_format`` — ``int8`` / ``packed8`` (``kernels/common.py``):
  packed planes shift the bandwidth/compute balance, so they tune
  separately;
* ``shape_bucket`` — for ``xam_multiset`` the SAME two-point structure
  the old switch had (``narrow`` below ``WIDE_BLOCK_AT`` queries,
  ``wide`` at/above), for ``xam_search`` a single ``default`` bucket.
  Keeping the bucket structure is what caps jit-cache growth at the
  existing pow2 buckets: every batch in a bucket maps to ONE
  deterministic block shape, cache hit or not.

Misses fall back DETERMINISTICALLY to today's constants, so a cold cache
(deleted file, fresh machine, unknown backend) produces bit-identical
kernel *results* — block shapes never change an answer, only its speed —
and the same compiled-shape count.  ``REPRO_AUTOTUNE_CACHE`` points the
loader at an alternate cache file (CI uses it to prove the cold path).
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import pathlib

from repro.kernels.common import resolve_plane_format
from repro.kernels.xam_search.kernel import (
    DEFAULT_BLOCK_C, DEFAULT_BLOCK_Q, MULTISET_BLOCK_Q)

#: Committed winners; regenerate with ``python -m benchmarks.run --autotune``.
DEFAULT_CACHE_PATH = pathlib.Path(__file__).with_name("autotune_cache.json")

#: Env knob pointing the loader at an alternate cache file (cold-cache CI).
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

#: Adaptive query-block threshold — the shape-bucket split of the
#: ``xam_multiset`` families AND the deterministic fallback's switch
#: point (the pre-autotune heuristic: ``MULTISET_BLOCK_Q`` below it,
#: ``WIDE_BLOCK_Q`` at/above).  Search results are layout-independent
#: (first-valid-way per query), so the width never changes an answer.
WIDE_BLOCK_AT = 256
WIDE_BLOCK_Q = 64

#: MXU-aligned sweep candidates: block_q multiples of 8 (sublanes, floor
#: 8), block_c multiples of 128 (lanes).
BLOCK_Q_CANDIDATES = (8, 16, 32, 64, 128)
BLOCK_C_CANDIDATES = (128, 256, 512)


def cache_path() -> pathlib.Path:
    override = os.environ.get(CACHE_ENV)
    return pathlib.Path(override) if override else DEFAULT_CACHE_PATH


@functools.lru_cache(maxsize=None)
def _load(path_str: str) -> dict:
    """Family table from the cache file; {} when cold/unreadable (the
    deterministic fallback then answers every query)."""
    path = pathlib.Path(path_str)
    if not path.exists():
        return {}
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return {}
    fams = data.get("families", {})
    return fams if isinstance(fams, dict) else {}


def _families() -> dict:
    return _load(str(cache_path()))


def reset_cache() -> None:
    """Drop the in-process loader cache (tests repoint REPRO_AUTOTUNE_CACHE
    and need the next consult to re-read)."""
    _load.cache_clear()


def _backend() -> str:
    import jax
    return jax.default_backend()


def family_key(kernel: str, plane_format: str, shape_bucket: str) -> str:
    return f"{kernel}/{_backend()}/{plane_format}/{shape_bucket}"


def multiset_block_q(n_queries: int, plane_format: str = "int8") -> int:
    """Measured ``block_q`` for the fused multiset kernel, deterministic
    per (shape bucket, plane format): the committed winner when the
    family is cached, else the pre-autotune two-point heuristic."""
    plane_format = resolve_plane_format(plane_format)
    wide = n_queries >= WIDE_BLOCK_AT
    fam = _families().get(
        family_key("xam_multiset", plane_format, "wide" if wide else "narrow"))
    if fam is not None:
        return int(fam["block_q"])
    return WIDE_BLOCK_Q if wide else MULTISET_BLOCK_Q


def search_blocks(plane_format: str = "int8") -> tuple[int, int]:
    """Measured ``(block_q, block_c)`` for the flat bitmap kernel, with a
    deterministic fallback to the module defaults."""
    plane_format = resolve_plane_format(plane_format)
    fam = _families().get(family_key("xam_search", plane_format, "default"))
    if fam is not None:
        return int(fam["block_q"]), int(fam["block_c"])
    return DEFAULT_BLOCK_Q, DEFAULT_BLOCK_C


def cache_fingerprint() -> str:
    """Short content hash of the active cache file — stamped into every
    ``BENCH_*.json`` so cross-run comparisons can't silently mix tuned
    and untuned (or differently tuned) configurations.  ``"cold"`` when
    the file is absent."""
    path = cache_path()
    if not path.exists():
        return "cold"
    return hashlib.sha256(path.read_bytes()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# The sweep.
# ---------------------------------------------------------------------------

def _time_multiset(n_q: int, block_q: int, plane_format: str,
                   reps: int) -> float:
    """Median us for one synthetic multiset workload at a candidate
    block_q (the bench's own shape family: 8 sets, 32-bit keys, 512
    ways)."""
    import numpy as np

    import jax

    from repro.bench.harness import time_callable
    from repro.kernels.common import pack_bits_np
    from repro.kernels.xam_search import ops as xam_ops
    from repro.kernels.xam_search.kernel import xam_search_multiset_pallas

    rng = np.random.default_rng(0)
    n_sets, r, c = 8, 32, 512
    planes_np = rng.integers(0, 2, (n_sets, r, c)).astype(np.int8)
    if plane_format == "packed8":
        planes = jax.numpy.asarray(pack_bits_np(planes_np, axis=1))
    else:
        planes = jax.numpy.asarray(planes_np)
    valid = jax.numpy.asarray(
        rng.integers(0, 2, (n_sets, c)).astype(np.int8))
    set_ids = rng.integers(0, n_sets, n_q)
    key_bits = xam_ops.words_to_bits_np(
        rng.integers(0, 2 ** 32, n_q, dtype=np.uint32), r)
    slot, block_sets, padded_q, n_blocks = xam_ops.group_queries_by_set(
        set_ids, n_sets, block_q)
    keys_p = np.zeros((padded_q, r), np.int8)
    masks_p = np.zeros((padded_q, r), np.int8)
    keys_p[slot] = key_bits
    masks_p[slot] = 1
    live = (np.arange(len(block_sets)) < n_blocks).astype(np.int32)
    args = tuple(jax.numpy.asarray(a)
                 for a in (keys_p, masks_p, block_sets, live))
    interpret = jax.default_backend() != "tpu"

    def run():
        return xam_search_multiset_pallas(
            args[0], args[1], planes, valid, args[2], args[3],
            block_q=block_q, interpret=interpret).block_until_ready()

    return time_callable(run, reps=reps).median_us


def _time_search(block_q: int, block_c: int, plane_format: str,
                 reps: int) -> float:
    """Median us for the flat bitmap kernel at a candidate block pair."""
    import numpy as np

    import jax

    from repro.bench.harness import time_callable
    from repro.kernels.common import pack_bits_np
    from repro.kernels.xam_search.kernel import xam_search_pallas

    rng = np.random.default_rng(0)
    q, r, c = 64, 64, 512
    keys = jax.numpy.asarray(rng.integers(0, 2, (q, r)).astype(np.int8))
    masks = jax.numpy.ones((q, r), jax.numpy.int8)
    data_np = rng.integers(0, 2, (r, c)).astype(np.int8)
    if plane_format == "packed8":
        data = jax.numpy.asarray(pack_bits_np(data_np, axis=0))
    else:
        data = jax.numpy.asarray(data_np)
    interpret = jax.default_backend() != "tpu"

    def run():
        return xam_search_pallas(
            keys, data, masks, block_q=block_q, block_c=block_c,
            interpret=interpret).block_until_ready()

    return time_callable(run, reps=reps).median_us


def autotune(out_path: pathlib.Path | str | None = None,
             quick: bool = False) -> dict:
    """Sweep every family on THIS backend and write the winners.

    Returns the full cache payload (also written to ``out_path``, default
    the committed ``autotune_cache.json``).  Winners are medians via
    ``bench/harness.time_callable``; re-running on the same rig
    reproduces the same table up to timing noise on near-tied candidates.
    """
    from repro.bench.harness import time_callable  # noqa: F401 (doc anchor)

    reps = 3 if quick else 5
    backend = _backend()
    families: dict[str, dict] = {}
    # xam_multiset: one representative batch size per shape bucket — the
    # bucket's winner must be deterministic across every size in it, so
    # one size per bucket is the contract, not a shortcut.
    bucket_sizes = {"narrow": 128, "wide": 512}
    for plane_format in ("int8", "packed8"):
        for bucket, n_q in bucket_sizes.items():
            timings = {
                bq: _time_multiset(n_q, bq, plane_format, reps)
                for bq in BLOCK_Q_CANDIDATES}
            best = min(timings, key=timings.get)
            families[f"xam_multiset/{backend}/{plane_format}/{bucket}"] = {
                "block_q": best,
                "median_us": round(timings[best], 3),
                "swept": {str(k): round(v, 3) for k, v in timings.items()},
            }
        timings = {
            (bq, bc): _time_search(bq, bc, plane_format, reps)
            for bq in BLOCK_Q_CANDIDATES for bc in BLOCK_C_CANDIDATES}
        best = min(timings, key=timings.get)
        families[f"xam_search/{backend}/{plane_format}/default"] = {
            "block_q": best[0], "block_c": best[1],
            "median_us": round(timings[best], 3),
            "swept": {f"{k[0]}x{k[1]}": round(v, 3)
                      for k, v in timings.items()},
        }
    payload = {
        "version": 1,
        "backend": backend,
        "block_q_candidates": list(BLOCK_Q_CANDIDATES),
        "block_c_candidates": list(BLOCK_C_CANDIDATES),
        "families": families,
    }
    path = pathlib.Path(out_path) if out_path else DEFAULT_CACHE_PATH
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    reset_cache()
    return payload


def main() -> int:
    import argparse
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--quick", action="store_true", help="3 reps instead of 5")
    p.add_argument("--out", default=None,
                   help="cache file to write (default: the committed one)")
    args = p.parse_args()
    payload = autotune(args.out, quick=args.quick)
    for key in sorted(payload["families"]):
        fam = payload["families"][key]
        shape = f"block_q={fam['block_q']}"
        if "block_c" in fam:
            shape += f" block_c={fam['block_c']}"
        print(f"[autotune] {key}: {shape} ({fam['median_us']} us)")
    print(f"[autotune] wrote {args.out or DEFAULT_CACHE_PATH} "
          f"(fingerprint {cache_fingerprint()})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
