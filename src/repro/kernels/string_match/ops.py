"""Jit'd wrappers for the string-match kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.string_match.kernel import string_match_pallas
from repro.kernels.string_match.ref import string_match_ref

_ON_TPU = jax.default_backend() == "tpu"


def string_match(text, pattern, *, use_kernel: bool = True,
                 tile: int = 4096, interpret: bool | None = None):
    """Exact-match start positions of ``pattern`` in ``text``.

    Parameters
    ----------
    text : (N,) uint8
        Haystack bytes.
    pattern : (P,) uint8
        Needle bytes (``P`` becomes a static kernel parameter).
    use_kernel : bool
        False = numpy-style reference path.
    tile : int
        Text bytes per kernel grid step (int8 compares, no upcast).
    interpret : bool, optional
        Pallas interpret-mode flag (defaults to True off-TPU).

    Returns
    -------
    jnp.ndarray, shape (N,), int8
        1 at every position where ``text[i : i + P] == pattern``.
    """
    text = jnp.asarray(text, jnp.uint8)
    pattern = jnp.asarray(pattern, jnp.uint8)
    if not use_kernel:
        return string_match_ref(text, pattern)
    if interpret is None:
        interpret = not _ON_TPU
    return string_match_pallas(
        text, pattern, pattern_len=int(pattern.shape[0]), tile=tile,
        interpret=interpret)


def count_matches(text, pattern, **kw) -> jnp.ndarray:
    return jnp.sum(string_match(text, pattern, **kw).astype(jnp.int32))
