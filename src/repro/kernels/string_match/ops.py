"""Jit'd wrappers for the string-match kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.string_match.kernel import string_match_pallas
from repro.kernels.string_match.ref import string_match_ref

_ON_TPU = jax.default_backend() == "tpu"


def string_match(text, pattern, *, use_kernel: bool = True,
                 tile: int = 4096, interpret: bool | None = None):
    """Exact-match start positions of ``pattern`` in ``text``."""
    text = jnp.asarray(text, jnp.uint8)
    pattern = jnp.asarray(pattern, jnp.uint8)
    if not use_kernel:
        return string_match_ref(text, pattern)
    if interpret is None:
        interpret = not _ON_TPU
    return string_match_pallas(
        text, pattern, pattern_len=int(pattern.shape[0]), tile=tile,
        interpret=interpret)


def count_matches(text, pattern, **kw) -> jnp.ndarray:
    return jnp.sum(string_match(text, pattern, **kw).astype(jnp.int32))
