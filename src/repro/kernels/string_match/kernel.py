"""Pallas TPU kernel: broadcast string match (Monarch flat-CAM §10.5).

Monarch broadcasts one search across the whole dataset span, each command
covering up to 4 KB.  TPU mapping: each grid step owns one text tile in VMEM
plus its right halo (the next tile), and slides the pattern across it with P
static vectorized compares on the VPU — one "search command" per tile.

Tile size defaults to 4096 bytes = the paper's per-command search coverage.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 4096  # bytes per command (paper: "each search covering upto 4KB")


def _make_kernel(pattern_len: int, tile: int):
    def kernel(text_ref, halo_ref, pattern_ref, out_ref):
        # (1, tile) current tile, (1, tile) next tile, (1, P_pad) pattern.
        # Compare in int8 (uint8 -> int8 is a bijection, so equality is
        # preserved): 8-bit lanes pack 4x denser on the VPU than the old
        # int32 upcast.
        window = jnp.concatenate([text_ref[...], halo_ref[...]], axis=1)
        window = window.astype(jnp.int8)
        pat = pattern_ref[...].astype(jnp.int8)
        acc = jnp.ones((1, tile), bool)
        for k in range(pattern_len):  # static unroll: P vector compares
            acc = acc & (window[:, k:k + tile] == pat[0, k])
        out_ref[...] = acc.astype(jnp.int8)
    return kernel


@functools.partial(jax.jit, static_argnames=("pattern_len", "tile", "interpret"))
def string_match_pallas(text: jnp.ndarray, pattern: jnp.ndarray, *,
                        pattern_len: int, tile: int = TILE,
                        interpret: bool = True) -> jnp.ndarray:
    """text: (N,) uint8, pattern: (P,) uint8 (P == pattern_len <= tile).
    Returns (N,) int8 match-start flags."""
    n = text.shape[0]
    assert pattern_len <= tile
    n_tiles = (n + tile - 1) // tile
    padded = (n_tiles + 1) * tile  # one extra tile: halo for the last tile
    text_p = jnp.zeros((1, padded), jnp.uint8).at[0, :n].set(text)
    p_pad = max(_round_up(pattern_len, 128), 128)
    pat_p = jnp.zeros((1, p_pad), jnp.uint8).at[0, :pattern_len].set(pattern)

    out = pl.pallas_call(
        _make_kernel(pattern_len, tile),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i + 1)),
            pl.BlockSpec((1, p_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_tiles * tile), jnp.int8),
        interpret=interpret,
    )(text_p, text_p, pat_p)
    res = out[0, :n]
    valid = jnp.arange(n) <= (n - pattern_len)
    return (res.astype(bool) & valid).astype(jnp.int8)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
