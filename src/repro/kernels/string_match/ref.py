"""Pure-jnp oracle for sliding-window exact string match.

match[i] = 1 iff text[i : i+P] == pattern, for i in [0, N-P].
Positions i > N-P are 0 by definition.
"""
from __future__ import annotations

import jax.numpy as jnp


def string_match_ref(text: jnp.ndarray, pattern: jnp.ndarray) -> jnp.ndarray:
    text = text.astype(jnp.int32)
    pattern = pattern.astype(jnp.int32)
    n, p = text.shape[0], pattern.shape[0]
    if p > n:
        return jnp.zeros((n,), jnp.int8)
    acc = jnp.ones((n,), bool)
    for k in range(p):
        shifted = jnp.roll(text, -k)
        acc = acc & (shifted == pattern[k])
    valid = jnp.arange(n) <= (n - p)
    return (acc & valid).astype(jnp.int8)
