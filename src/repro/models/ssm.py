"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD, chunked).

Both are written chunk-parallel over the sequence with rematerialized chunk
bodies: the (B, L, d_inner, N) discretized tensors exist only per-chunk, so
32k/500k sequences never materialize full scan residuals (this is the
sub-quadratic long-context path for falcon-mamba / zamba2 / long_500k).

Sharding intent (see repro.dist.sharding): d_inner (mamba1) and heads
(mamba2) shard over the `model` mesh axis; batch over (`pod`, `data`).
The SSM recurrence itself is purely local to those shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers

DTYPE = layers.DTYPE


# ---------------------------------------------------------------------------
# Mamba-1 (falcon-mamba-7b: d_state=16, expand=2, conv=4, dt_rank=D/16).
# ---------------------------------------------------------------------------

def dt_rank(cfg: ArchConfig) -> int:
    return max(cfg.d_model // 16, 1)


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba1(key, cfg: ArchConfig):
    di, n, r = d_inner(cfg), cfg.ssm_state, dt_rank(cfg)
    k = layers.split_keys(key, 7)
    # S4D-real initialization for A.
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "wx": layers.dense_init(k[0], (cfg.d_model, di)),
        "wz": layers.dense_init(k[5], (cfg.d_model, di)),
        "conv_w": layers.dense_init(k[1], (cfg.ssm_conv, di), scale=0.5),
        "conv_b": jnp.zeros((di,), DTYPE),
        "x_proj": layers.dense_init(k[2], (di, r + 2 * n)),
        "dt_w": layers.dense_init(k[3], (r, di)),
        "dt_b": (jnp.log(jnp.expm1(jnp.full((di,), 0.01)))).astype(DTYPE),
        "a_log": jnp.log(a_init),                    # (di, n) fp32
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": layers.dense_init(k[4], (di, cfg.d_model)),
    }


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, C); w: (K, C) — causal per-channel conv, unrolled taps."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        acc = acc + xp[:, i:i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (acc + b.astype(jnp.float32)).astype(x.dtype)


def mamba1_block(params, x, cfg: ArchConfig, *, chunk: int = 64,
                 return_state: bool = False):
    """x: (B, S, D) -> (B, S, D) via chunked selective scan.
    With ``return_state``: also returns (h_final, conv_tail) for prefill."""
    b, s, _ = x.shape
    di, n, r = d_inner(cfg), cfg.ssm_state, dt_rank(cfg)
    xh_raw = x @ params["wx"]                        # (B, S, di)
    z = x @ params["wz"]
    xh = xh_raw
    xh = jax.nn.silu(_causal_depthwise_conv(xh, params["conv_w"], params["conv_b"]))

    dbc = xh @ params["x_proj"]                      # (B, S, r + 2n)
    dt_in, b_in, c_in = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_w"] +
                         params["dt_b"].astype(jnp.float32))  # (B,S,di) fp32
    a = -jnp.exp(params["a_log"])                    # (di, n)

    chunk = min(chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))

    def chunk_body(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)
        xc, dtc = sl(xh), sl(dt)
        bc, cc = sl(b_in).astype(jnp.float32), sl(c_in).astype(jnp.float32)
        # per-step discretization, sequential within chunk.
        dA = jnp.exp(dtc[..., None] * a[None, None])           # (B,C,di,n)
        dBx = (dtc * xc.astype(jnp.float32))[..., None] * bc[:, :, None, :]

        def step(hc, t):
            hc = hc * dA[:, t] + dBx[:, t]                     # (B, di, n)
            y_t = jnp.einsum("bdn,bn->bd", hc, cc[:, t])
            return hc, y_t

        h, ys = jax.lax.scan(step, h, jnp.arange(chunk))
        return h, jnp.moveaxis(ys, 0, 1)                       # (B, C, di)

    h0 = jnp.zeros((b, di, n), jnp.float32)
    h_final, y = jax.lax.scan(jax.checkpoint(chunk_body), h0, jnp.arange(n_chunks))
    y = y.transpose(1, 0, 2, 3).reshape(b, n_chunks * chunk, di)[:, :s]
    y = y + xh[:, :s].astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        k = cfg.ssm_conv
        tail = jnp.pad(xh_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, s:s + k - 1]
        return out, h_final, tail.astype(DTYPE)
    return out


def _conv_step(x_t, conv_buf, w, b):
    """One causal depthwise-conv step with a (B, K-1, C) ring buffer.
    Returns (conv_out (B, C), new_buf)."""
    ext = jnp.concatenate([conv_buf, x_t[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", ext.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out, ext[:, 1:, :]


def mamba1_decode(params, x, cfg: ArchConfig, h, conv_buf):
    """Single-token step.  x: (B, 1, D); h: (B, di, n) fp32 state;
    conv_buf: (B, K-1, di) tap ring buffer.
    Returns (out (B,1,D), new_h, new_conv_buf)."""
    b = x.shape[0]
    di, n, r = d_inner(cfg), cfg.ssm_state, dt_rank(cfg)
    xh = x[:, 0] @ params["wx"]
    z = x[:, 0] @ params["wz"]
    xh_c, conv_buf = _conv_step(xh, conv_buf, params["conv_w"], params["conv_b"])
    xh = jax.nn.silu(xh_c).astype(x.dtype)
    dbc = xh @ params["x_proj"]
    dt_in, b_in, c_in = jnp.split(dbc, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_w"] + params["dt_b"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"])
    dA = jnp.exp(dt[..., None] * a[None])                     # (B, di, n)
    dBx = (dt * xh.astype(jnp.float32))[..., None] * b_in.astype(jnp.float32)[:, None, :]
    h = h * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, c_in.astype(jnp.float32))
    y = y + xh.astype(jnp.float32) * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return (y @ params["out_proj"])[:, None, :], h, conv_buf


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2: d_state=64, headdim=64, scalar A per head).
# ---------------------------------------------------------------------------

def m2_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(key, cfg: ArchConfig):
    di, n, h = d_inner(cfg), cfg.ssm_state, m2_heads(cfg)
    k = layers.split_keys(key, 4)
    # separate projections (clean tensor-parallel sharding; a fused
    # in_proj would put split boundaries mid-shard):
    return {
        "wz": layers.dense_init(k[0], (cfg.d_model, di)),
        "wxbc": layers.dense_init(k[3], (cfg.d_model, di + 2 * n)),
        "wdt": layers.dense_init(k[1], (cfg.d_model, h), scale=0.02),
        "conv_w": layers.dense_init(k[1], (cfg.ssm_conv, di + 2 * n), scale=0.5),
        "conv_b": jnp.zeros((di + 2 * n,), DTYPE),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_b": (jnp.log(jnp.expm1(jnp.full((h,), 0.01)))).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_w": jnp.zeros((di,), DTYPE),
        "out_proj": layers.dense_init(k[2], (di, cfg.d_model)),
    }


def mamba2_block(params, x, cfg: ArchConfig, *, chunk: int = 256,
                 return_state: bool = False):
    """SSD forward, chunked (Mamba-2 minimal algorithm).  x: (B,S,D).
    With ``return_state``: also returns (h_final, conv_tail) for prefill."""
    bsz, s, _ = x.shape
    di, n, h = d_inner(cfg), cfg.ssm_state, m2_heads(cfg)
    p = cfg.ssm_head_dim

    z = x @ params["wz"]
    xbc_raw = x @ params["wxbc"]
    dt_in = x @ params["wdt"]
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc_raw, params["conv_w"],
                                             params["conv_b"]))
    xh, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_b"])   # (B,S,H)
    a = -jnp.exp(params["a_log"])                                      # (H,)
    log_a = dt * a[None, None, :]                                      # (B,S,H) <= 0

    chunk = min(chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))

    xhh = xh.reshape(bsz, n_chunks, chunk, h, p)
    dtc = dt.reshape(bsz, n_chunks, chunk, h)
    la = log_a.reshape(bsz, n_chunks, chunk, h)
    bb = b_in.reshape(bsz, n_chunks, chunk, n).astype(jnp.float32)
    cc = c_in.reshape(bsz, n_chunks, chunk, n).astype(jnp.float32)

    def chunk_body(hstate, idx):
        # hstate: (B, H, P, N) fp32 carried across chunks.
        xc = xhh[:, idx].astype(jnp.float32)       # (B,L,H,P)
        d = dtc[:, idx]                            # (B,L,H)
        l = la[:, idx]                             # (B,L,H)
        bc, ccc = bb[:, idx], cc[:, idx]           # (B,L,N)
        cs = jnp.cumsum(l, axis=1)                 # (B,L,H) inclusive
        # intra-chunk (attention-like) term.
        seg = cs[:, :, None, :] - cs[:, None, :, :]        # (B,L,L,H) log decay i<-j
        iota = jnp.arange(chunk)
        causal = (iota[:, None] >= iota[None, :])
        decay = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bin,bjn->bij", ccc, bc)            # (B,L,L)
        w = cb[:, :, :, None] * decay                       # (B,L,L,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", w, xc * d[..., None])
        # inter-chunk: contribution of carried state.
        state_decay = jnp.exp(cs)                           # (B,L,H)
        y_off = jnp.einsum("bln,bhpn,blh->blhp", ccc, hstate, state_decay)
        y = y_diag + y_off + xc * params["d_skip"][None, None, :, None]
        # update carried state.
        tail = jnp.exp(cs[:, -1:, :] - cs)                  # (B,L,H) decay to end
        new_state = hstate * jnp.exp(cs[:, -1])[..., None, None]  # (B,H,P,N)
        chunk_state = jnp.einsum("blh,bln,blhp->bhpn", tail * d, bc, xc)
        return new_state + chunk_state, y

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, jnp.arange(n_chunks))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, n_chunks * chunk, di)[:, :s]
    # gated RMSNorm then out-projection.
    y = layers.rms_norm(y.astype(DTYPE) * jax.nn.silu(z.astype(jnp.float32)).astype(DTYPE),
                        params["norm_w"])
    out = y @ params["out_proj"]
    if return_state:
        k = cfg.ssm_conv
        tail = jnp.pad(xbc_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, s:s + k - 1]
        return out, h_final, tail.astype(DTYPE)
    return out


def mamba2_decode(params, x, cfg: ArchConfig, hstate, conv_buf):
    """Single-token SSD step.  hstate: (B, H, P, N) fp32;
    conv_buf: (B, K-1, di + 2n)."""
    bsz = x.shape[0]
    di, n, h = d_inner(cfg), cfg.ssm_state, m2_heads(cfg)
    p = cfg.ssm_head_dim
    z = x[:, 0] @ params["wz"]
    xbc = x[:, 0] @ params["wxbc"]
    dt_in = x[:, 0] @ params["wdt"]
    xbc_c, conv_buf = _conv_step(xbc, conv_buf, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc_c).astype(x.dtype)
    xh, b_in, c_in = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + params["dt_b"])   # (B,H)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt * a[None, :])                                      # (B,H)
    xhp = xh.reshape(bsz, h, p).astype(jnp.float32)
    bcf = b_in.astype(jnp.float32)
    hstate = hstate * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xhp, bcf)
    y = jnp.einsum("bhpn,bn->bhp", hstate, c_in.astype(jnp.float32))
    y = y + xhp * params["d_skip"][None, :, None]
    y = y.reshape(bsz, di)
    y = layers.rms_norm((y[:, None, :].astype(DTYPE)
                         * jax.nn.silu(z.astype(jnp.float32))[:, None, :].astype(DTYPE)),
                        params["norm_w"])
    return y @ params["out_proj"], hstate, conv_buf
