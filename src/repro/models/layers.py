"""Core transformer layers: norms, RoPE, GQA attention (chunked/flash),
gated MLP, embeddings.  Pure functions over parameter dicts.

Conventions:
* params are nested dicts of jnp arrays; a parallel tree of
  ``jax.sharding.PartitionSpec`` is built by ``repro.dist.sharding``.
* compute dtype bf16, accumulations fp32 (``preferred_element_type``).
* attention is chunked over KV (online softmax) so the 32k/500k shapes
  never materialize (Q, K) score planes; the chunk body is rematted.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

DTYPE = jnp.bfloat16
NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Init helpers.
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None, dtype=DTYPE):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh); positions: (B, S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig):
    k = split_keys(key, 4)
    return {
        "wq": dense_init(k[0], (cfg.d_model, cfg.n_heads * cfg.d_head)),
        "wk": dense_init(k[1], (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
        "wv": dense_init(k[2], (cfg.d_model, cfg.n_kv_heads * cfg.d_head)),
        "wo": dense_init(k[3], (cfg.n_heads * cfg.d_head, cfg.d_model)),
    }


def _qkv(params, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (x @ params["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = (x @ params["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _soft_cap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits


def chunked_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                      q_offset, kv_chunk: int = 1024):
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Sq, H, dh); k/v: (B, Skv, KV, dh).  ``q_offset`` = absolute
    position of q[0] relative to k[0] (0 for self-attn; >0 for decode).
    window > 0 applies sliding-window masking (local attention).
    Returns (B, Sq, H, dh).
    """
    b, sq, h, dh = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = dh ** -0.5
    kv_chunk = min(kv_chunk, skv)
    n_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # GQA grouping WITHOUT repeating KV: q (B, Sq, KVH, rep, dh).
    qg = (q * scale).astype(DTYPE).reshape(b, sq, kvh, rep, dh)
    q_pos = q_offset + jnp.arange(sq)                        # (Sq,)

    def body(carry, chunk_idx):
        m, l, acc = carry
        start = chunk_idx * kv_chunk
        kc = jax.lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
        k_pos = start + jnp.arange(kv_chunk)                 # (C,)
        # (B, KVH, rep, Sq, C) logits — KV heads broadcast, never repeated.
        logits = jnp.einsum("bqgrd,bcgd->bgrqc", qg, kc,
                            preferred_element_type=jnp.float32)
        logits = _soft_cap(logits, softcap)
        mask = (k_pos[None, :] < skv)                        # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window and window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, None, None, :, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqc,bcgd->bgrqd", p.astype(DTYPE), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, kvh, rep, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-20)[..., None]              # (B,KVH,rep,Sq,dh)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)
    return out.astype(q.dtype)


def _seq_shard(t, cfg: ArchConfig):
    """§Perf: pin (B, S, ...) activations to (dp, model, None...) so the
    attention einsums contract UNsharded head dims (no fp32-logits
    all-reduce) at the cost of gathering KV chunks over `model`."""
    axes = tuple(getattr(cfg, "attn_seq_shard", ()) or ())
    if not axes:
        return t
    from jax.sharding import PartitionSpec as P
    spec = P(axes, "model", *([None] * (t.ndim - 2)))
    return jax.lax.with_sharding_constraint(t, spec)


def attention_block(params, x, cfg: ArchConfig, positions, *, local: bool,
                    kv_chunk: int = 1024):
    """Self-attention over x (B, S, D)."""
    q, k, v = _qkv(params, x, cfg, positions)
    q, k, v = _seq_shard(q, cfg), _seq_shard(k, cfg), _seq_shard(v, cfg)
    window = cfg.sliding_window if local else 0
    out = chunked_attention(
        q, k, v, causal=cfg.causal and not cfg.encoder_only,
        window=window, softcap=cfg.logit_softcap, q_offset=0,
        kv_chunk=kv_chunk)
    b, s, _, _ = out.shape
    return _seq_shard(out.reshape(b, s, -1) @ params["wo"], cfg)


def decode_attention(params, x, cfg: ArchConfig, cache_k, cache_v, pos,
                     *, local: bool):
    """Single-token decode: x (B, 1, D); cache_k/v (B, S_max, KV, dh);
    ``pos`` scalar int32 — index of the new token.  Returns
    (out (B,1,D), new_k, new_v)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = (x @ params["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k_new = (x @ params["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v_new = (x @ params["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), pos, axis=1)

    s_max = cache_k.shape[1]
    kvh = cfg.n_kv_heads
    rep = cfg.n_heads // kvh
    scale = cfg.d_head ** -0.5
    qg = (q * scale).astype(DTYPE).reshape(b, 1, kvh, rep, cfg.d_head)
    logits = jnp.einsum("bqgrd,bcgd->bgrqc", qg, cache_k.astype(DTYPE),
                        preferred_element_type=jnp.float32)
    logits = _soft_cap(logits, cfg.logit_softcap)
    k_pos = jnp.arange(s_max)
    mask = k_pos <= pos
    if local:
        mask = mask & (k_pos > pos - cfg.sliding_window)
    logits = jnp.where(mask[None, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(DTYPE)
    out = jnp.einsum("bgrqc,bcgd->bqgrd", p, cache_v.astype(DTYPE),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(b, 1, -1) @ params["wo"]
    return out, cache_k, cache_v


def decode_attention_ring(params, x, cfg: ArchConfig, cache_k, cache_v, pos,
                          slot):
    """Sliding-window decode with a ring-buffer cache of size W: slot =
    pos % W.  Keys are stored post-RoPE (absolute positions), so slot s
    holds absolute position  p_s = pos - ((pos - s) mod W)  — always inside
    the window; only p_s >= 0 entries are valid.  Cache memory is O(W)
    instead of O(S_max): the 500k-context local layers cost 1024 slots."""
    b = x.shape[0]
    w = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = (x @ params["wq"]).reshape(b, 1, cfg.n_heads, cfg.d_head)
    k_new = (x @ params["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    v_new = (x @ params["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.d_head)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), slot, axis=1)

    kvh = cfg.n_kv_heads
    rep = cfg.n_heads // kvh
    scale = cfg.d_head ** -0.5
    qg = (q * scale).astype(DTYPE).reshape(b, 1, kvh, rep, cfg.d_head)
    logits = jnp.einsum("bqgrd,bcgd->bgrqc", qg, cache_k.astype(DTYPE),
                        preferred_element_type=jnp.float32)
    logits = _soft_cap(logits, cfg.logit_softcap)
    s_ix = jnp.arange(w)
    abs_pos = pos - ((pos - s_ix) % w)
    mask = abs_pos >= 0
    logits = jnp.where(mask[None, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(DTYPE)
    out = jnp.einsum("bgrqc,bcgd->bqgrd", p, cache_v.astype(DTYPE),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(b, 1, -1) @ params["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    k = split_keys(key, 3)
    p = {
        "w_up": dense_init(k[0], (cfg.d_model, d_ff)),
        "w_down": dense_init(k[1], (d_ff, cfg.d_model)),
    }
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(k[2], (cfg.d_model, d_ff))
    return p


def mlp_block(params, x, cfg: ArchConfig):
    up = x @ params["w_up"]
    if cfg.mlp_gated:
        up = jax.nn.silu(x @ params["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding with seq-chunked loss.
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ArchConfig):
    k = split_keys(key, 2)
    p = {"embed": dense_init(k[0], (cfg.vocab_size, cfg.d_model), scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k[1], (cfg.d_model, cfg.vocab_size))
    return p


def embed(params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed_logits(params, x):
    w = params.get("unembed")
    if w is None:
        w = params["embed"].T
    return jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)


def chunked_ce_loss(params, x, labels, *, chunk: int = 512):
    """Cross-entropy over the vocab, scanning sequence chunks so the full
    (B, S, V) logits plane is never resident (rematted chunk body)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    pad = n_chunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)

    def body(total, idx):
        xc = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = unembed_logits(params, xc)                   # (B, C, V) fp32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = (lc >= 0)
        nll = jnp.where(valid, logz - gold, 0.0)
        return (total[0] + nll.sum(), total[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        jnp.arange(n_chunks))
    return tot / jnp.maximum(cnt, 1).astype(jnp.float32)
