"""Mixture-of-Experts block: group-local top-k dispatch (GShard-style).

Tokens are dispatched WITHIN their group (group = one sequence's tokens),
so the sort/rank machinery never crosses a data-parallel shard — no
collectives are induced by dispatch; experts are sharded over the `model`
mesh axis (expert parallelism) so each device computes its resident experts
on the (group, expert, capacity) batch that lands there.

Dispatch algorithm (static shapes, TPU-friendly, autodiff-safe):
  1. router logits -> softmax -> top-k (expert ids + gate weights)
  2. per group: stable-argsort the (token*k) expert ids
  3. rank-in-expert = position - first-position-of-that-expert
  4. entries with rank >= capacity are dropped (scattered to a trash slot)
  5. gather tokens into (G, E, C, D), batched expert FFN, weighted
     scatter-add back.

Arctic-style ``dense_residual`` adds a normal MLP in parallel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers


def init_moe(key, cfg: ArchConfig):
    k = layers.split_keys(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff or cfg.d_ff
    p = {
        "router": layers.dense_init(k[0], (d, e), scale=0.02),
        "w_up": layers.dense_init(k[1], (e, d, f)),
        "w_gate": layers.dense_init(k[2], (e, d, f)),
        "w_down": layers.dense_init(k[3], (e, f, d)),
    }
    if cfg.dense_residual:
        p["dense"] = layers.init_mlp(k[4], cfg)
    return p


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(c, 1)


def moe_block(params, x, cfg: ArchConfig):
    """x: (B, S, D) -> (B, S, D).  Groups = sequences (B groups of S).

    Two dispatch paths (cfg.moe_dispatch):
    * "gather"  — argsort + take_along_axis/scatter-add (the original);
      integer gathers partition badly under GSPMD (involuntary full
      rematerialization: the token batch is replicated across the expert
      axis), which makes large-expert configs collective-bound.
    * "einsum"  — GShard-style one-hot dispatch/combine matmuls; GSPMD
      partitions them as all-to-alls (beyond-paper §Perf optimization;
      costs ~N*EC*D extra MXU flops, wins back ~40x collective bytes).
    """
    if getattr(cfg, "moe_dispatch", "gather") == "einsum":
        return _moe_block_einsum(params, x, cfg)
    return _moe_block_gather(params, x, cfg)


def _moe_block_einsum(params, x, cfg: ArchConfig):
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)

    logits = jnp.einsum("gnd,de->gne", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_ix = jax.lax.top_k(probs, k)               # (G, N, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Position-in-expert via cumsums (GShard §3.2): entries of earlier
    # tokens (any k-slot) rank first, then earlier k-slots of this token.
    eo = jax.nn.one_hot(expert_ix, e, dtype=jnp.float32)      # (G, N, K, E)
    tok_e = eo.sum(axis=2)                                    # (G, N, E)
    excl_n = jnp.cumsum(tok_e, axis=1) - tok_e                # before token n
    within = jnp.cumsum(eo, axis=2) - eo                      # earlier k-slots
    pos = excl_n[:, :, None, :] + within                      # (G, N, K, E)
    pos_in_e = jnp.sum(pos * eo, axis=-1)                     # (G, N, K)
    keep = pos_in_e < c
    gate_w = gate_w * keep.astype(gate_w.dtype)

    slot = jax.nn.one_hot(jnp.where(keep, pos_in_e, c), c,
                          dtype=jnp.float32)                  # (G, N, K, C)
    # combine[g,n,e,c] = sum_k gate * onehot_e * onehot_c
    combine = jnp.einsum("gnk,gnke,gnkc->gnec", gate_w.astype(jnp.float32),
                         eo, slot)
    dispatch = (combine > 0).astype(x.dtype)                  # (G, N, E, C)

    xe = jnp.einsum("gnd,gnec->gecd", x, dispatch)            # all-to-all-able
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    h = jax.nn.silu(gate) * up
    out_e = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    y = jnp.einsum("gecd,gnec->gnd", out_e, combine.astype(out_e.dtype))
    y = y.astype(x.dtype)
    if cfg.dense_residual:
        y = y + layers.mlp_block(params["dense"], x, cfg)
    return y


def _moe_block_gather(params, x, cfg: ArchConfig):
    """x: (B, S, D) -> (B, S, D).  Groups = sequences (B groups of S)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, s)
    xg = x  # (G=b, N=s, D)

    # 1. Routing (fp32 for numerics).
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, expert_ix = jax.lax.top_k(probs, k)               # (G, N, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # 2-4. Group-local rank-in-expert with capacity C.
    flat_e = expert_ix.reshape(b, s * k)                      # (G, NK)
    order = jnp.argsort(flat_e, axis=-1, stable=True)         # (G, NK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # first position of each expert in the sorted list, per group.
    first = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(sorted_e)
    rank = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        first, sorted_e, axis=-1)                             # (G, NK)
    keep = rank < c
    dest = jnp.where(keep, sorted_e * c + rank, e * c)        # trash slot e*c

    # token index (0..N-1) of each sorted entry.
    tok_of_entry = order // k                                  # (G, NK)
    w_of_entry = jnp.take_along_axis(
        gate_w.reshape(b, s * k), order, axis=-1)

    # 5. Gather into (G, E*C+1) slots.
    slot_tok = jnp.full((b, e * c + 1), 0, jnp.int32)
    slot_tok = jax.vmap(lambda st, de, te: st.at[de].set(te))(
        slot_tok, dest, tok_of_entry.astype(jnp.int32))
    slot_w = jnp.zeros((b, e * c + 1), gate_w.dtype)
    slot_w = jax.vmap(lambda sw, de, we: sw.at[de].set(we))(
        slot_w, dest, jnp.where(keep, w_of_entry, 0.0))
    slot_tok = slot_tok[:, : e * c].reshape(b, e, c)
    slot_w = slot_w[:, : e * c].reshape(b, e, c)

    xe = jnp.take_along_axis(
        xg[:, :, None, :].reshape(b, s, d)[:, :, :],           # (G, N, D)
        slot_tok.reshape(b, e * c)[:, :, None], axis=1,
    ).reshape(b, e, c, d)

    # Batched expert FFN; experts sharded over `model`.
    up = jnp.einsum("gecd,edf->gecf", xe, params["w_up"])
    gate = jnp.einsum("gecd,edf->gecf", xe, params["w_gate"])
    h = jax.nn.silu(gate) * up
    out_e = jnp.einsum("gecf,efd->gecd", h, params["w_down"])

    # Weighted scatter-add back to tokens.
    out_e = out_e * slot_w[..., None].astype(out_e.dtype)
    flat_out = out_e.reshape(b, e * c, d)
    flat_tok = slot_tok.reshape(b, e * c)
    y = jnp.zeros((b, s, d), out_e.dtype)
    y = jax.vmap(lambda yy, ti, oo: yy.at[ti].add(oo))(y, flat_tok, flat_out)
    y = y.astype(x.dtype)

    if cfg.dense_residual:
        y = y + layers.mlp_block(params["dense"], x, cfg)
    return y


def aux_load_balance_loss(params, x, cfg: ArchConfig):
    """Switch-style load-balance auxiliary (fraction * probability)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    imp = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * imp)
