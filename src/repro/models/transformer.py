"""Model assembly: composable blocks -> scan-over-layer-groups stack.

The per-layer pattern of each architecture (dense / 5:1 local:global /
MoE / Mamba / hybrid-with-shared-attention) is factored into a repeating
*group* that is scanned with stacked parameters (plus an unscanned
remainder), so HLO size and compile time are independent of depth — a 62
layer model lowers as one group body.

Public entry points (used by train/serve/launch):

    init_params(key, cfg)                      -> params pytree
    forward(params, cfg, batch)                -> final hidden states
    train_loss(params, cfg, batch)             -> scalar CE loss
    init_cache(cfg, batch, max_seq)            -> decode cache pytree
    prefill(params, cfg, batch, cache)         -> (last-token logits, cache)
    decode_step(params, cfg, tokens, cache, pos) -> (logits, cache)

Batch dict keys: "tokens" (B, S) int32 and/or "embeds" (B, P, D) bf16
(VLM patch / audio frame stubs), "labels" (B, S) int32 (-1 = masked).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, MAMBA1, MAMBA2,
                                SHARED_ATTN, ArchConfig)
from repro.models import layers, moe, ssm

DTYPE = layers.DTYPE


# ---------------------------------------------------------------------------
# Per-block init / apply.
# ---------------------------------------------------------------------------

def _is_attn(kind: str) -> bool:
    return kind in (ATTN_GLOBAL, ATTN_LOCAL, SHARED_ATTN)


def init_block(key, kind: str, cfg: ArchConfig):
    k = layers.split_keys(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), DTYPE)}
    if _is_attn(kind):
        p["attn"] = layers.init_attention(k[0], cfg)
        p["ln2"] = jnp.zeros((cfg.d_model,), DTYPE)
        if cfg.n_experts and kind != SHARED_ATTN:
            p["moe"] = moe.init_moe(k[1], cfg)
        else:
            p["mlp"] = layers.init_mlp(k[1], cfg)
    elif kind == MAMBA1:
        p["ssm"] = ssm.init_mamba1(k[0], cfg)
    elif kind == MAMBA2:
        p["ssm"] = ssm.init_mamba2(k[0], cfg)
    else:
        raise ValueError(kind)
    return p


def apply_block(p, kind: str, x, cfg: ArchConfig, positions):
    h = layers.rms_norm(x, p["ln1"])
    if _is_attn(kind):
        h = layers.attention_block(p["attn"], h, cfg, positions,
                                   local=(kind == ATTN_LOCAL))
        x = x + h
        h2 = layers.rms_norm(x, p["ln2"])
        if "moe" in p:
            h2 = moe.moe_block(p["moe"], h2, cfg)
        else:
            h2 = layers.mlp_block(p["mlp"], h2, cfg)
        return x + h2
    else:
        fn = ssm.mamba1_block if kind == MAMBA1 else ssm.mamba2_block
        return x + fn(p["ssm"], h, cfg)


# ---------------------------------------------------------------------------
# Parameter tree.
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig):
    group, n_groups, rem = cfg.scan_groups()
    keys = layers.split_keys(key, 4 + len(rem))
    params = {"embed": layers.init_embed(keys[0], cfg),
              "final_ln": jnp.zeros((cfg.d_model,), DTYPE)}

    if n_groups > 0:
        def init_one_group(gkey):
            ks = layers.split_keys(gkey, len(group))
            return {f"b{i}": init_block(ks[i], kind, cfg)
                    for i, kind in enumerate(group)
                    if kind != SHARED_ATTN}
        gkeys = jnp.stack(layers.split_keys(keys[1], n_groups))
        params["groups"] = jax.vmap(init_one_group)(gkeys)
    if any(k == SHARED_ATTN for k in group + rem):
        params["shared"] = init_block(keys[2], SHARED_ATTN, cfg)
    for i, kind in enumerate(rem):
        if kind != SHARED_ATTN:
            params[f"rem{i}"] = init_block(keys[4 + i], kind, cfg)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Forward (training / encoder / prefill trunk).
# ---------------------------------------------------------------------------

def _input_embeds(params, cfg: ArchConfig, batch):
    parts = []
    if "embeds" in batch:
        parts.append(batch["embeds"].astype(DTYPE))
    if "tokens" in batch:
        scale = jnp.asarray(cfg.d_model ** 0.5, DTYPE)
        parts.append(layers.embed(params["embed"], batch["tokens"]) * scale)
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, positions


def forward(params, cfg: ArchConfig, batch):
    x, positions = _input_embeds(params, cfg, batch)
    group, n_groups, rem = cfg.scan_groups()
    shared = params.get("shared")

    if n_groups > 0:
        def body(xc, gp):
            for i, kind in enumerate(group):
                p = shared if kind == SHARED_ATTN else gp[f"b{i}"]
                xc = apply_block(p, kind, xc, cfg, positions)
            return xc, None
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["groups"])
    for i, kind in enumerate(rem):
        p = shared if kind == SHARED_ATTN else params[f"rem{i}"]
        x = apply_block(p, kind, x, cfg, positions)
    return layers.rms_norm(x, params["final_ln"])


def train_loss(params, cfg: ArchConfig, batch):
    x = forward(params, cfg, batch)
    labels = batch["labels"]
    if "embeds" in batch and "tokens" in batch:
        # VLM: loss only over the text tail (prefix embeds carry no labels).
        x = x[:, batch["embeds"].shape[1]:]
    loss = layers.chunked_ce_loss(params["embed"], x, labels)
    if cfg.n_experts:
        # aux load-balance term over the last hidden states (cheap proxy;
        # the per-layer routers see rebalanced inputs anyway).
        pass
    return loss


# ---------------------------------------------------------------------------
# Decode caches.
# ---------------------------------------------------------------------------

def _block_cache(kind: str, cfg: ArchConfig, b: int, max_seq: int):
    if _is_attn(kind):
        s = min(max_seq, cfg.sliding_window) if kind == ATTN_LOCAL else max_seq
        shape = (b, s, cfg.n_kv_heads, cfg.d_head)
        return {"k": jnp.zeros(shape, DTYPE), "v": jnp.zeros(shape, DTYPE)}
    if kind == MAMBA1:
        di = ssm.d_inner(cfg)
        return {"h": jnp.zeros((b, di, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((b, cfg.ssm_conv - 1, di), DTYPE)}
    if kind == MAMBA2:
        di = ssm.d_inner(cfg)
        return {"h": jnp.zeros((b, ssm.m2_heads(cfg), cfg.ssm_head_dim,
                                cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((b, cfg.ssm_conv - 1, di + 2 * cfg.ssm_state),
                                  DTYPE)}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int):
    group, n_groups, rem = cfg.scan_groups()
    cache = {}
    if n_groups > 0:
        def one(_):
            return {f"b{i}": _block_cache(kind, cfg, batch, max_seq)
                    for i, kind in enumerate(group)}
        cache["groups"] = jax.vmap(one)(jnp.arange(n_groups))
    for i, kind in enumerate(rem):
        cache[f"rem{i}"] = _block_cache(kind, cfg, batch, max_seq)
    return cache


def _decode_block(p, kind: str, x, cfg: ArchConfig, bcache, pos):
    h = layers.rms_norm(x, p["ln1"])
    if _is_attn(kind):
        local = kind == ATTN_LOCAL
        if local:
            # ring-buffer cache: slot = pos % window (absolute-RoPE keys).
            w = bcache["k"].shape[1]
            slot = pos % w
            out, ck, cv = layers.decode_attention_ring(
                p["attn"], h, cfg, bcache["k"], bcache["v"], pos, slot)
        else:
            out, ck, cv = layers.decode_attention(
                p["attn"], h, cfg, bcache["k"], bcache["v"], pos, local=False)
        x = x + out
        h2 = layers.rms_norm(x, p["ln2"])
        if "moe" in p:
            h2 = moe.moe_block(p["moe"], h2, cfg)
        else:
            h2 = layers.mlp_block(p["mlp"], h2, cfg)
        return x + h2, {"k": ck, "v": cv}
    if kind == MAMBA1:
        out, hh, conv = ssm.mamba1_decode(p["ssm"], h, cfg, bcache["h"],
                                          bcache["conv"])
        return x + out, {"h": hh, "conv": conv}
    out, hh, conv = ssm.mamba2_decode(p["ssm"], h, cfg, bcache["h"],
                                      bcache["conv"])
    return x + out, {"h": hh, "conv": conv}


def decode_step(params, cfg: ArchConfig, tokens, cache, pos):
    """tokens: (B, 1) int32; pos: scalar int32 (next position).
    Returns (logits (B, V) fp32, new cache)."""
    scale = jnp.asarray(cfg.d_model ** 0.5, DTYPE)
    x = layers.embed(params["embed"], tokens) * scale
    group, n_groups, rem = cfg.scan_groups()
    shared = params.get("shared")

    if n_groups > 0:
        def body(xc, gp_and_cache):
            gp, gc = gp_and_cache
            new_gc = {}
            for i, kind in enumerate(group):
                p = shared if kind == SHARED_ATTN else gp[f"b{i}"]
                xc, new_gc[f"b{i}"] = _decode_block(p, kind, xc, cfg,
                                                    gc[f"b{i}"], pos)
            return xc, new_gc
        x, new_groups = jax.lax.scan(
            body, x, (params["groups"], cache["groups"]))
        new_cache = {"groups": new_groups}
    else:
        new_cache = {}
    for i, kind in enumerate(rem):
        p = shared if kind == SHARED_ATTN else params[f"rem{i}"]
        x, new_cache[f"rem{i}"] = _decode_block(p, kind, x, cfg,
                                                cache[f"rem{i}"], pos)
    x = layers.rms_norm(x, params["final_ln"])
    logits = layers.unembed_logits(params["embed"], x)[:, 0]
    return logits, new_cache


def resume_supported(cfg: ArchConfig) -> bool:
    """True when the prefix-cache resume path can serve this arch: every
    layer's decode state must be reconstructible from per-position KV
    (attention only).  SSM/hybrid recurrent states fold the whole prefix
    into one vector and cannot be restored from chunk slabs."""
    return all(k in (ATTN_GLOBAL, ATTN_LOCAL) for k in cfg.layer_pattern())


def prefix_length(prefix_kv) -> int:
    """Token length P of a ``prefix_kv`` pytree (as returned by
    ``prefill(..., return_kv=True)``: the sequence axis is always the
    third-from-last — (..., S, KV_heads, d_head))."""
    leaf = jax.tree.leaves(prefix_kv)[0]
    return leaf.shape[leaf.ndim - 3]


def prefill(params, cfg: ArchConfig, batch, max_seq: int, *,
            prefix_kv=None, return_kv: bool = False):
    """Run the trunk over a prompt and build the decode cache.
    Returns (last-token logits (B, V), cache) — plus a per-layer KV
    pytree for the tokens of THIS call when ``return_kv=True``.

    ``prefix_kv`` resumes from a cached prefix: a pytree mirroring the
    cache layout with post-RoPE k/v of the first P prompt tokens (seq
    axis third-from-last).  ``batch`` then holds only the suffix; its
    positions start at P (RoPE offset contract: resumed tokens attend at
    their original absolute positions), attention runs over
    concat(prefix, suffix) with ``q_offset=P``, and the cache is built
    over the combined sequence — bit-identical to a full prefill of the
    whole prompt, since the slabs hold exactly the k/v a full prefill
    would compute."""
    if prefix_kv is not None and not resume_supported(cfg):
        raise NotImplementedError(
            f"prefix resume needs attention-only layers; {cfg.name} "
            "has recurrent (SSM) state that chunk slabs cannot restore")
    x, positions = _input_embeds(params, cfg, batch)
    b, s, _ = x.shape
    p_len = 0
    if prefix_kv is not None:
        p_len = prefix_length(prefix_kv)
        positions = positions + jnp.int32(p_len)
    group, n_groups, rem = cfg.scan_groups()
    shared = params.get("shared")

    def fill_block(p, kind, xc, bcache, pk):
        h = layers.rms_norm(xc, p["ln1"])
        if _is_attn(kind):
            local = kind == ATTN_LOCAL
            q, k, v = layers._qkv(p["attn"], h, cfg, positions)
            q = layers._seq_shard(q, cfg)
            k = layers._seq_shard(k, cfg)
            v = layers._seq_shard(v, cfg)
            if pk is not None:
                # k/v over the COMBINED sequence: cached prefix ++ new.
                k_all = jnp.concatenate([pk["k"].astype(k.dtype), k], axis=1)
                v_all = jnp.concatenate([pk["v"].astype(v.dtype), v], axis=1)
            else:
                k_all, v_all = k, v
            s_tot = k_all.shape[1]
            out = layers.chunked_attention(
                q, k_all, v_all, causal=cfg.causal and not cfg.encoder_only,
                window=cfg.sliding_window if local else 0,
                softcap=cfg.logit_softcap, q_offset=p_len)
            out = out.reshape(b, s, -1) @ p["attn"]["wo"]
            xc = xc + out
            h2 = layers.rms_norm(xc, p["ln2"])
            h2 = (moe.moe_block(p["moe"], h2, cfg) if "moe" in p
                  else layers.mlp_block(p["mlp"], h2, cfg))
            xc = xc + h2
            # write cache (ring layout for local, plain for global) over
            # the combined sequence — same formulas as a full prefill of
            # s_tot tokens.
            cw = bcache["k"].shape[1]
            if local:
                take = min(cw, s_tot)
                ks, vs = k_all[:, -take:], v_all[:, -take:]
                slots = (jnp.arange(s_tot - take, s_tot) % cw).astype(jnp.int32)
                ck = bcache["k"].at[:, slots].set(ks.astype(DTYPE))
                cv = bcache["v"].at[:, slots].set(vs.astype(DTYPE))
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    bcache["k"], k_all.astype(DTYPE), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    bcache["v"], v_all.astype(DTYPE), 0, axis=1)
            kv = {"k": k.astype(DTYPE), "v": v.astype(DTYPE)}
            return xc, {"k": ck, "v": cv}, kv
        # SSM prefill: the chunked block already carries the recurrent state
        # across chunks; return_state hands back (h_final, conv tail) to
        # seed decode exactly.
        fn = ssm.mamba1_block if kind == MAMBA1 else ssm.mamba2_block
        out, h_final, conv_tail = fn(p["ssm"], h, cfg, return_state=True)
        return xc + out, {"h": h_final, "conv": conv_tail}, None

    cache = init_cache(cfg, b, max_seq)
    kv_out = {}
    if n_groups > 0:
        pk_groups = None if prefix_kv is None else prefix_kv["groups"]
        def body(xc, scanned):
            gp, gc, gpk = scanned
            new_gc, new_kv = {}, {}
            for i, kind in enumerate(group):
                p = shared if kind == SHARED_ATTN else gp[f"b{i}"]
                bpk = None if gpk is None else gpk[f"b{i}"]
                xc, new_gc[f"b{i}"], new_kv[f"b{i}"] = fill_block(
                    p, kind, xc, gc[f"b{i}"], bpk)
            return xc, (new_gc, new_kv)
        x, (new_groups, kv_groups) = jax.lax.scan(
            jax.checkpoint(body), x,
            (params["groups"], cache["groups"], pk_groups))
        cache = dict(cache, groups=new_groups)
        kv_out["groups"] = kv_groups
    for i, kind in enumerate(rem):
        p = shared if kind == SHARED_ATTN else params[f"rem{i}"]
        rpk = None if prefix_kv is None else prefix_kv.get(f"rem{i}")
        x, cache[f"rem{i}"], kv_out[f"rem{i}"] = fill_block(
            p, kind, x, cache[f"rem{i}"], rpk)
    x = layers.rms_norm(x, params["final_ln"])
    logits = layers.unembed_logits(params["embed"], x[:, -1:])[:, 0]
    if return_kv:
        return logits, cache, kv_out
    return logits, cache
