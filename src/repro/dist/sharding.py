"""Name-based partition-spec rules for params / batches / decode caches.

One rule engine, three entry points:

* ``param_specs``  — tensor-parallel layout by leaf name: column-parallel
  projections shard their output dim on ``model``; row-parallel ones
  (``wo``, ``w_down``) and the vocab embedding shard the reduction/vocab
  dim; norms replicate.  Leaves stacked under the scanned ``groups`` axis
  keep that leading axis unsharded.
* ``batch_specs``  — leading (batch) dim over the data axes.
* ``cache_specs``  — batch over data; KV heads over ``model`` by default,
  or the sequence dim over ``model`` with ``seq_shard=True`` (§Perf
  sequence-sharded decode).

Every emitted spec passes through ``_guard``: an axis that does not evenly
divide its dim is dropped to ``None`` (replicated) instead of producing an
XLA error — this is what lets the same rules serve a 1-device host mesh
and the 16x16 production mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# Column-parallel (shard the output-feature dim, last axis) vs
# row-parallel (shard the reduction/vocab dim, second-to-last axis).
_COL_PARALLEL = {"wq", "wk", "wv", "w_up", "w_gate", "wx", "wz", "unembed"}
_ROW_PARALLEL = {"wo", "w_down", "embed"}


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh):
    """The data-parallel axis (or axes) of a mesh: ("pod", "data") on
    multi-pod meshes, "data" otherwise."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _guard(axes, shape, mesh) -> P:
    """Drop any mesh axis that does not evenly divide its dim.

    ``axes`` may be shorter than ``shape`` (missing entries replicate) and
    entries may be axis tuples.  Always returns a PartitionSpec of
    ``len(shape)`` entries.
    """
    sizes = _axis_sizes(mesh)
    out = []
    for i, dim in enumerate(shape):
        ax = axes[i] if i < len(axes) else None
        if ax is None:
            out.append(None)
            continue
        group = ax if isinstance(ax, tuple) else (ax,)
        denom = 1
        for a in group:
            denom *= sizes.get(a, 1)
        out.append(ax if denom > 1 and dim % denom == 0 else None)
    return P(*out)


def _leaf_keys(path) -> list[str]:
    return [getattr(p, "key", str(getattr(p, "idx", ""))) for p in path]


def _param_rule(keys: list[str], ndim: int, two_d_mlp: bool):
    """Pre-guard axis assignment for one parameter leaf."""
    name = keys[-1]
    axes = [None] * ndim
    # Leading stacked-scan axis (params["groups"][...]) stays unsharded.
    n_lead = 1 if "groups" in keys[:-1] else 0
    eff = ndim - n_lead
    if eff < 2:
        return axes        # norms / biases / scalars: replicate
    if name in _COL_PARALLEL:
        axes[-1] = "model"
        if two_d_mlp and name in ("w_up", "w_gate"):
            axes[-2] = "data"
    elif name in _ROW_PARALLEL:
        axes[-2] = "model"
        if two_d_mlp and name == "w_down":
            axes[-1] = "data"
    elif name == "router":
        pass               # tiny: replicate next to its experts
    else:
        # Unknown >=2-D weight: column-parallel default.
        axes[-1] = "model"
    return axes


def param_specs(shapes, mesh, two_d_mlp: bool = False):
    """PartitionSpec tree matching the structure of a params shape tree."""
    def one(path, leaf):
        keys = _leaf_keys(path)
        axes = _param_rule(keys, len(leaf.shape), two_d_mlp)
        return _guard(axes, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, shapes)


def batch_specs(batch, mesh):
    """Batch dim over the data axes; everything else replicated."""
    dp = dp_axes(mesh)

    def one(leaf):
        if not leaf.shape:
            return P()
        return _guard([dp], leaf.shape, mesh)
    return jax.tree.map(one, batch)


def cache_specs(cache, mesh, seq_shard: bool = False):
    """Decode-cache specs: KV layout (B, S, H, D) per attention leaf (one
    leading stacked axis under "groups"), SSM state (B, ...) otherwise."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        keys = _leaf_keys(path)
        ndim = len(leaf.shape)
        n_lead = 1 if "groups" in keys[:-1] else 0
        axes = [None] * ndim
        if ndim > n_lead:
            axes[n_lead] = dp                      # batch dim
        if keys[-1] in ("k", "v") and ndim - n_lead >= 4:
            if seq_shard:
                axes[n_lead + 1] = "model"         # sequence dim (§Perf)
            else:
                axes[n_lead + 2] = "model"         # KV-head dim
        return _guard(axes, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(one, cache)


def to_named(spec_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
