"""Block-wise int8 gradient compression with error feedback.

``quantize_int8`` scales each BLOCK-sized slice by its own max-abs (so one
outlier only costs its block, not the tensor) and rounds to int8;
round-tripping is bounded by half a quantization step per element.

``compressed_psum_leaf`` is the collective building block: the residual
from the previous round is folded in BEFORE quantization and the new
residual handed back, so the quantization error feeds forward instead of
biasing the sum — over repeated reductions the accumulated estimate stays
unbiased (the property ``test_compressed_psum_error_feedback`` pins).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(g: jnp.ndarray):
    """-> (q int8 (n_blocks, BLOCK), scale fp32 (n_blocks,), pad int)."""
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.round(blocks / scale[:, None]).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = flat.shape[0] - pad
    return flat[:n].reshape(shape)


def compressed_psum_leaf(g: jnp.ndarray, residual: jnp.ndarray,
                         axis_name: str):
    """int8-compressed cross-replica sum of one gradient leaf.

    Returns (summed dequantized gradient, new residual).  The residual is
    per-replica local state the caller threads through training steps.
    """
    target = g + residual
    q, scale, pad = quantize_int8(target)
    local = dequantize_int8(q, scale, pad, g.shape)
    new_residual = target - local
    return jax.lax.psum(local, axis_name), new_residual
