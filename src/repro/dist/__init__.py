"""Distribution layer: partition-spec rules, atomic checkpoints, gradient
compression, elastic restart, and straggler handling.

Every module is importable on a single-host CPU rig (tests run there); the
same code drives the 512-device dry-run meshes.
"""
