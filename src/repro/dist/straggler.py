"""Straggler watchdog: per-step wall-time anomaly policy.

A step is *slow* when it exceeds ``quantile(history) * slack``.  One slow
step is tolerated (RETRY — could be a GC pause, a preemption warning, a
checkpoint flush); ``escalate_after`` CONSECUTIVE slow steps escalate to
REJOIN (leave the job and re-enter through the elastic restart path).  Any
healthy step resets the suspicion counter, giving the hysteresis the tests
pin down.  Only healthy steps enter the history, so a stuck worker cannot
poison its own baseline into normality.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

OK = "ok"
RETRY = "retry"
REJOIN = "rejoin"


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    quantile: float = 0.5        # history quantile used as the baseline
    slack: float = 3.0           # slow = dt > baseline * slack
    escalate_after: int = 3      # consecutive slow steps before REJOIN
    min_history: int = 8         # observations before judging at all
    max_history: int = 256       # rolling window of healthy step times


class StragglerWatchdog:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self._history: deque[float] = deque(maxlen=cfg.max_history)
        self._slow_streak = 0

    @property
    def baseline(self) -> float | None:
        if len(self._history) < self.cfg.min_history:
            return None
        return float(np.quantile(np.asarray(self._history),
                                 self.cfg.quantile))

    def observe(self, step_seconds: float) -> str:
        base = self.baseline
        if base is not None and step_seconds > base * self.cfg.slack:
            self._slow_streak += 1
            if self._slow_streak >= self.cfg.escalate_after:
                self._slow_streak = 0
                return REJOIN
            return RETRY
        self._slow_streak = 0
        self._history.append(step_seconds)
        return OK
