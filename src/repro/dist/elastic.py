"""Elastic rescaling: resume the newest checkpoint onto whatever mesh the
current launch has, and re-split the global batch over the worker count.

The checkpoint format is topology-free (host numpy per leaf), so a run
killed on N devices restarts on M by restoring and letting GSPMD place the
arrays under the new mesh's shardings.  Every resume appends a record to
``scale_events.jsonl`` so rescale history is auditable.
"""
from __future__ import annotations

import json
import os
import time

from repro.dist import checkpoint


def elastic_batch(global_batch: int, n_workers: int) -> tuple[int, int]:
    """(per_worker, used_global): the largest even split not exceeding the
    requested global batch — never below 1 per worker, so a shrink-below-
    batch-size event rounds the effective batch UP to one per worker."""
    per = max(global_batch // n_workers, 1)
    return per, per * n_workers


def resume_elastic(ckpt_dir: str, template, mesh, run_dir: str | None = None):
    """(step, state-or-None) from the newest checkpoint, logging the
    rescale event.  ``mesh`` is the CURRENT launch topology."""
    step, restored = checkpoint.restore_latest(ckpt_dir, template)
    event = {
        "time_unix": round(time.time(), 3),
        "step": step,
        "restored": restored is not None,
        "n_devices": int(mesh.devices.size),
        "mesh_axes": dict(zip(mesh.axis_names,
                              [int(s) for s in mesh.devices.shape])),
    }
    log_dir = run_dir or ckpt_dir
    os.makedirs(log_dir, exist_ok=True)
    with open(os.path.join(log_dir, "scale_events.jsonl"), "a") as f:
        f.write(json.dumps(event) + "\n")
    return step, restored
