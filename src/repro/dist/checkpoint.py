"""Atomic-publish checkpoints: write to ``step_N.tmp``, fsync, rename.

A checkpoint directory holds ``step_<N>/`` dirs; each contains one
``leaf_<i>.npy`` per pytree leaf (template order) plus ``manifest.json``.
A step dir WITHOUT a manifest is an unfinished writer crash and is ignored
by readers and eventually garbage-collected by writers — that is the whole
crash-safety story: the rename is the publish.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

MANIFEST = "manifest.json"
_PREFIX = "step_"


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"{_PREFIX}{step}")


def published_steps(root: str) -> list[int]:
    """Sorted steps with a complete (manifest-bearing) checkpoint."""
    if not os.path.isdir(root):
        return []
    steps = []
    for name in os.listdir(root):
        if not name.startswith(_PREFIX) or name.endswith(".tmp"):
            continue
        try:
            step = int(name[len(_PREFIX):])
        except ValueError:
            continue
        if os.path.exists(os.path.join(root, name, MANIFEST)):
            steps.append(step)
    return sorted(steps)


def _gc(root: str, keep_last: int | None) -> None:
    """Remove crashed-writer droppings and over-retention checkpoints."""
    for name in os.listdir(root):
        if name.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, name), ignore_errors=True)
    if keep_last is not None:
        for step in published_steps(root)[:-keep_last]:
            shutil.rmtree(_step_dir(root, step), ignore_errors=True)


def save(root: str, step: int, state, keep_last: int | None = None,
         process_index: int | None = None) -> str:
    """Publish ``state`` at ``step``; returns the published directory.

    Only process 0 writes in a multi-process run (every process may call
    this; non-zero writers return the would-be path without touching disk).
    """
    if process_index is None:
        process_index = jax.process_index()
    final = _step_dir(root, step)
    if process_index != 0:
        return final
    os.makedirs(root, exist_ok=True)
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    leaves = jax.tree.leaves(state)
    for i, leaf in enumerate(leaves):
        with open(os.path.join(tmp, f"leaf_{i}.npy"), "wb") as f:
            np.save(f, np.asarray(leaf))
            f.flush()
            os.fsync(f.fileno())
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves)}, f)
        f.flush()
        os.fsync(f.fileno())
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)        # the atomic publish
    # Make the rename itself durable before gc deletes older steps —
    # otherwise a crash can surface the new dir with stale data blocks
    # while the previous complete checkpoint is already gone.
    dirfd = os.open(root, os.O_RDONLY)
    try:
        os.fsync(dirfd)
    finally:
        os.close(dirfd)
    _gc(root, keep_last)
    return final


def restore(root: str, step: int, template):
    """Load the checkpoint at ``step`` into ``template``'s structure."""
    d = _step_dir(root, step)
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    treedef = jax.tree.structure(template)
    n = treedef.num_leaves
    if manifest["n_leaves"] != n:
        raise ValueError(
            f"checkpoint at {d} has {manifest['n_leaves']} leaves; "
            f"template expects {n}")
    leaves = [np.load(os.path.join(d, f"leaf_{i}.npy")) for i in range(n)]
    return jax.tree.unflatten(treedef, leaves)


def restore_latest(root: str, template):
    """(step, state) of the newest published checkpoint, or (0, None)."""
    steps = published_steps(root)
    if not steps:
        return 0, None
    step = steps[-1]
    return step, restore(root, step, template)
