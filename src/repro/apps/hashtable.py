"""Hopscotch hash table (paper §9.2.2) with a Monarch-accelerated lookup.

Open addressing with windowed (neighborhood) probing:

* ``insert``: find home = hash(key) % n; if a free bucket exists within the
  H-window, store there; else walk forward for a free bucket and hop it
  backwards by swapping window-compatible keys; rehash to 2x on failure.
* ``lookup`` (baseline): probe up to H buckets serially — up to H memory
  reads.
* ``lookup`` (Monarch): ONE search command per window — the hopscotch
  window maps exactly onto a CAM set search (kernels/hopscotch).
  The per-bucket metadata bitmap (window_size/8 bytes per bucket) that the
  baseline needs for lookups becomes unnecessary — §10.4.2's observation —
  so Monarch stores it in main memory (we simply don't build it here).

The table also reports OPERATION COUNTS (probes, searches, writes, swaps,
rehashes) — the inputs to the §10.4 timing model in benchmarks/hashing.py.

Two storage backends share every code path above the bucket store:

* ``backend="host"`` — numpy bucket arrays, the original reference; the
  lookup kernel reads a device mirror rebuilt when inserts dirty it.
* ``backend="device"`` — the table LIVES on device as split uint32
  key/value planes; ``insert``/``delete`` run as ONE donated device call
  each (``kernels.hopscotch.ops.hopscotch_insert_device`` — windowed
  scatter with the hop-chain displacement as a bounded while-loop) and
  the host keeps only a lazy mirror for rehash/baseline paths.  Stats and
  §8 wear records are bit-identical to the host backend (the insert op
  returns the touched buckets in host ``_record_write`` order), pinned by
  ``tests/test_hashtable_device_differential.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import wear
from repro.data.pipeline import murmur3_np
from repro.kernels.hopscotch import ops as hop_ops

EMPTY = np.uint64(0)
WEAR_FLUSH_EVERY = 256      # bucket writes buffered per device wear call


@dataclasses.dataclass
class HashStats:
    lookups: int = 0
    probes: int = 0           # baseline bucket reads
    searches: int = 0         # Monarch window searches
    data_reads: int = 0
    inserts: int = 0
    insert_probes: int = 0
    swaps: int = 0
    rehashes: int = 0
    writes: int = 0
    deletes: int = 0


class HopscotchTable:
    def __init__(self, log2_size: int, window: int = 32, seed: int = 0,
                 wear_cfg: wear.WearConfig | None = None,
                 backend: str = "host", plane_format: str | None = None):
        """``wear_cfg``: optional §8 wear accounting over the table's
        backing store (a flat-CAM in the paper's deployment).  Bucket
        writes are charged to ``n_supersets`` equal superset stripes via
        the SAME ``wear.record_writes`` device op the simulator and the
        serving index use; writes are buffered and applied in batched
        device calls, not one dispatch per insert.

        ``backend``: ``"host"`` (numpy bucket store, the reference) or
        ``"device"`` (device-resident planes; insert/delete are single
        donated device calls, bit-identical results — see module
        docstring).

        ``plane_format``: accepted for serving-stack symmetry (``None`` =
        the ``REPRO_PLANE_FORMAT`` env knob) and VALIDATED, but both
        values store the same planes here: the hopscotch lo/hi tile
        planes are uint32 key words — already 8 logical bits per byte —
        so ``"packed8"`` is the documented identity for this kernel.
        The XAM planes (1 logical bit per byte at ``"int8"``) are where
        packing changes the stored layout."""
        if backend not in ("host", "device"):
            raise ValueError(
                f"backend must be one of ('host', 'device'), got "
                f"{backend!r}")
        from repro.kernels.common import resolve_plane_format
        self.plane_format = resolve_plane_format(plane_format)
        self.backend = backend
        self.window = window
        self.wear_cfg = wear_cfg
        if wear_cfg is not None:
            self.wear_state = wear.init_state(wear_cfg)
            self.wear_dyn = wear.dyn_of(wear_cfg)
            self.writes_per_superset = np.zeros(
                wear_cfg.n_supersets, np.int64)
            self._pending_ss: list[int] = []
            self._wear_rotates = 0
            self._wear_op = 0
        self._alloc(1 << log2_size)
        self.stats = HashStats()

    def _alloc(self, n: int):
        self.n = n
        # +2 windows of pad so windows never wrap (kernel contract too).
        self.keys = np.zeros(n + 2 * self.window, np.uint64)
        self.vals = np.zeros(n + 2 * self.window, np.uint64)
        self._table_version = getattr(self, "_table_version", 0) + 1
        self._dev_planes = None     # (version, t_lo, t_hi) device cache
        if self.backend == "device":
            # the authoritative store: split uint32 key/value planes
            # (four DISTINCT buffers — the insert op donates all four)
            shape = (n + 2 * self.window,)
            self._pk_lo, self._pk_hi, self._pv_lo, self._pv_hi = (
                jnp.zeros(shape, jnp.uint32) for _ in range(4))
            self._host_dirty = False   # keys/vals mirror is in sync
        if self.wear_cfg is not None:
            # superset stripe width over the (padded) bucket array
            self._ss_stripe = -(-len(self.keys) // self.wear_cfg.n_supersets)

    # ------------------------------------------------------------------
    # §8 wear accounting (shared core/wear.py machinery).
    # ------------------------------------------------------------------
    def _record_write(self, bucket: int):
        if self.wear_cfg is None:
            return
        ss = min(int(bucket) // self._ss_stripe, self.wear_cfg.n_supersets - 1)
        self.writes_per_superset[ss] += 1
        self._pending_ss.append(ss)
        if len(self._pending_ss) >= WEAR_FLUSH_EVERY:
            self.flush_wear()

    def flush_wear(self):
        """Apply buffered bucket writes to the device WearState in ONE
        ``wear.record_writes_device`` call (insert paths only buffer).
        The trace is pow2-bucketed with the op's ``active`` mask so ragged
        flush lengths reuse a handful of compiled scans."""
        if self.wear_cfg is None or not self._pending_ss:
            return
        from repro.kernels.common import bucket_pow2
        # fold the op clock before the int32 cycle domain wraps
        self.wear_state, self._wear_op = wear.maybe_rebase(
            self.wear_state, self._wear_op)
        n = len(self._pending_ss)
        nb = bucket_pow2(n, lo=32)
        ss = np.zeros(nb, np.int32)
        ss[:n] = self._pending_ss
        cycles = (self._wear_op + np.arange(nb)).astype(np.int32)
        active = np.zeros(nb, bool)
        active[:n] = True
        self.wear_state, rotated, _fl = wear.record_writes_device(
            self.wear_state, self.wear_dyn, ss,
            np.ones(nb, bool), cycles, active)
        self._wear_rotates += int(np.asarray(rotated).sum())
        self._wear_op += n
        self._pending_ss = []

    def _require_wear(self, what: str):
        if self.wear_cfg is None:
            raise ValueError(
                f"{what} requires wear tracking; construct the table with "
                "a wear_cfg (see repro.core.wear.WearConfig)")

    def wear_report(self) -> dict:
        """Wear summary for benchmarks/launchers (flushes first)."""
        self._require_wear("wear_report()")
        self.flush_wear()
        w = self.writes_per_superset.astype(np.float64)
        mean = float(w.mean()) if w.size else 0.0
        return {
            "writes_total": int(w.sum()),
            "writes_per_superset_max": float(w.max()) if w.size else 0.0,
            "skew_max_over_mean": float(w.max() / mean) if mean > 0 else 1.0,
            "rotates": self._wear_rotates,
            "locked_now": int(np.asarray(
                self.wear_state.locked_until > self._wear_op).sum()),
        }

    def lifetime_estimate(self, endurance: float = 1e8,
                          ops_per_second: float = 1e6):
        """Fig. 11-style lifetime projection for the table's write stream —
        the simulator's cumulative-crossing replay fed by app-level wear."""
        from repro.core import lifetime
        self._require_wear("lifetime_estimate()")
        self.flush_wear()
        return lifetime.estimate_from_ops(
            self.writes_per_superset, self._wear_op, self._wear_rotates,
            endurance=endurance, ops_per_second=ops_per_second)

    # ------------------------------------------------------------------
    def home(self, key) -> np.ndarray:
        return (murmur3_np(np.asarray(key, np.uint64).astype(np.uint32))
                % np.uint32(self.n)).astype(np.int64)

    @property
    def load(self) -> float:
        if self.backend == "device":
            occupied = int(jnp.sum((self._pk_lo != 0) | (self._pk_hi != 0)))
            return float(occupied) / self.n
        return float((self.keys != EMPTY).sum()) / self.n

    def _sync_host(self):
        """Refresh the host keys/vals mirror from the device planes (device
        backend only; one transfer per mutation epoch, rehash/baseline
        paths are the only consumers)."""
        if self.backend != "device" or not self._host_dirty:
            return
        klo, khi, vlo, vhi = jax.device_get(
            (self._pk_lo, self._pk_hi, self._pv_lo, self._pv_hi))
        self.keys = ((khi.astype(np.uint64) << np.uint64(32))
                     | klo.astype(np.uint64))
        self.vals = ((vhi.astype(np.uint64) << np.uint64(32))
                     | vlo.astype(np.uint64))
        self._host_dirty = False

    # ------------------------------------------------------------------
    def insert(self, key: int, val: int) -> bool:
        key = np.uint64(key)
        if key == EMPTY:
            raise ValueError("0 is the empty sentinel")
        self.stats.inserts += 1
        if self.backend == "device":
            return self._insert_device(key, np.uint64(val))
        return self._insert_host(key, np.uint64(val))

    def _insert_device(self, key: np.uint64, val: np.uint64) -> bool:
        """ONE donated device dispatch per insert; the returned write log
        replays the host backend's exact ``_record_write`` sequence."""
        h = np.int32(self.home(key))
        (self._pk_lo, self._pk_hi, self._pv_lo, self._pv_hi,
         status, probes, swaps, log, n_log) = hop_ops.hopscotch_insert_device(
            self._pk_lo, self._pk_hi, self._pv_lo, self._pv_hi, h,
            np.uint32(key & np.uint64(0xFFFFFFFF)),
            np.uint32(key >> np.uint64(32)),
            np.uint32(val & np.uint64(0xFFFFFFFF)),
            np.uint32(val >> np.uint64(32)),
            window=self.window)
        # the dispatch donated the old planes; drop any lookup cache that
        # might alias them (rebuilt device-side on the next lookup)
        self._dev_planes = None
        status, swaps, n_log = int(status), int(swaps), int(n_log)
        self.stats.insert_probes += int(probes)
        self.stats.swaps += swaps
        self.stats.writes += n_log
        if n_log:
            self._host_dirty = True
            for slot in np.asarray(log)[:n_log]:
                self._record_write(int(slot))
        if status == 1 or swaps:     # key planes changed (host parity:
            self._table_version += 1  # resident val update doesn't bump)
        if status == 2:
            self._rehash()
            return self.insert(int(key), int(val))
        return True

    def _insert_host(self, key: np.uint64, val: np.uint64) -> bool:
        h = int(self.home(key))
        w = self.window
        # already present? (one lookup)
        off = self._lookup_window(np.asarray([key]))[0]
        if off >= 0:
            self.vals[h + off] = np.uint64(val)
            self.stats.writes += 1
            self._record_write(h + off)
            return True
        # free bucket within window (probes up to the first free slot;
        # with the metadata bitmap this is 1 line read + the jump)
        win = self.keys[h:h + w]
        free = np.nonzero(win == EMPTY)[0]
        self.stats.insert_probes += int(free[0]) + 1 if free.size else w
        if free.size:
            self.keys[h + free[0]] = key
            self.vals[h + free[0]] = np.uint64(val)
            self.stats.writes += 1
            self._record_write(h + int(free[0]))
            self._table_version += 1
            return True
        # walk forward for a free bucket, then hop it back
        j = h + w
        limit = min(self.n + w, h + 64 * w)
        while j < limit and self.keys[j] != EMPTY:
            j += 1
            self.stats.insert_probes += 1
        if j >= limit:
            self._rehash()
            return self.insert(int(key), int(val))
        while j >= h + w:
            moved = False
            for k in range(j - w + 1, j):
                if k < 0:
                    continue
                kh = int(self.home(self.keys[k])) if self.keys[k] != EMPTY else -1
                if kh >= 0 and j < kh + w:
                    # key at k may legally move to j
                    self.keys[j] = self.keys[k]
                    self.vals[j] = self.vals[k]
                    self.keys[k] = EMPTY
                    self._table_version += 1
                    self.stats.swaps += 1
                    self.stats.writes += 2
                    self._record_write(j)
                    self._record_write(k)
                    j = k
                    moved = True
                    break
            if not moved:
                self._rehash()
                return self.insert(int(key), int(val))
        self.keys[j] = key
        self.vals[j] = np.uint64(val)
        self.stats.writes += 1
        self._record_write(j)
        self._table_version += 1
        return True

    def delete(self, key: int) -> bool:
        """Remove ``key`` (clears the bucket's key AND value).  Returns
        False on miss.  Safe for the Monarch lookup, which always scans
        the FULL home window; the serial baseline keeps its
        metadata-bitmap early-stop semantics (see ``lookup_baseline``)."""
        key = np.uint64(key)
        if key == EMPTY:
            raise ValueError("0 is the empty sentinel")
        self.stats.deletes += 1
        off = int(self._lookup_window(np.asarray([key]))[0])
        if off < 0:
            return False
        idx = int(self.home(key)) + off
        if self.backend == "device":
            (self._pk_lo, self._pk_hi, self._pv_lo,
             self._pv_hi) = hop_ops.hopscotch_delete_device(
                self._pk_lo, self._pk_hi, self._pv_lo, self._pv_hi,
                np.int32(idx))
            self._host_dirty = True
        else:
            self.keys[idx] = EMPTY
            self.vals[idx] = np.uint64(0)
        self.stats.writes += 1
        self._record_write(idx)
        self._table_version += 1
        return True

    def _rehash(self):
        self.stats.rehashes += 1
        self._sync_host()
        old_k, old_v = self.keys.copy(), self.vals.copy()
        self._alloc(self.n * 2)
        for k, v in zip(old_k, old_v):
            if k != EMPTY:
                self.insert(int(k), int(v))

    # ------------------------------------------------------------------
    def _table_planes(self):
        """Device-resident uint32 key planes, rebuilt only after inserts
        dirty the table (read-heavy phases skip the host->device upload;
        the device backend pads its resident planes in place — no host
        round trip at all)."""
        if (self._dev_planes is None
                or self._dev_planes[0] != self._table_version):
            if self.backend == "device":
                t_lo, t_hi = self._pk_lo, self._pk_hi
                pad = (-t_lo.shape[0]) % self.window
                if pad:
                    t_lo = jnp.pad(t_lo, (0, pad))
                    t_hi = jnp.pad(t_hi, (0, pad))
            else:
                t_lo = (self.keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
                t_hi = (self.keys >> np.uint64(32)).astype(np.uint32)
                pad = (-t_lo.shape[0]) % self.window
                if pad:
                    t_lo = np.pad(t_lo, (0, pad))
                    t_hi = np.pad(t_hi, (0, pad))
            self._dev_planes = (self._table_version, jnp.asarray(t_lo),
                                jnp.asarray(t_hi))
        return self._dev_planes[1], self._dev_planes[2]

    def _lookup_window(self, keys: np.ndarray) -> np.ndarray:
        homes = self.home(keys).astype(np.int32)
        lo = (keys & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        hi = (keys >> np.uint64(32)).astype(np.uint32)
        t_lo, t_hi = self._table_planes()
        out = hop_ops.hopscotch_lookup(
            t_lo, t_hi, homes, lo, hi, window=self.window)
        return np.asarray(out)

    def lookup_monarch(self, keys: np.ndarray):
        """Batched lookup via the fused window-search kernel: ONE search +
        (on hit) one data read per query."""
        keys = np.asarray(keys, np.uint64)
        offs = self._lookup_window(keys)
        self.stats.lookups += len(keys)
        self.stats.searches += len(keys)
        hits = offs >= 0
        self.stats.data_reads += int(hits.sum())
        idx = self.home(keys).astype(np.int64) + np.where(hits, offs, 0)
        if self.backend == "device":
            # value gather stays on device; only the (Q,) results land
            vlo, vhi = jax.device_get(
                (jnp.take(self._pv_lo, jnp.asarray(idx, jnp.int32)),
                 jnp.take(self._pv_hi, jnp.asarray(idx, jnp.int32))))
            got = ((vhi.astype(np.uint64) << np.uint64(32))
                   | vlo.astype(np.uint64))
            return np.where(hits, got, 0), hits
        vals = np.where(hits, self.vals[idx], 0)
        return vals, hits

    def lookup_baseline(self, keys: np.ndarray):
        """Serial window probing; counts the reads Monarch saves."""
        self._sync_host()
        keys = np.asarray(keys, np.uint64)
        self.stats.lookups += len(keys)
        vals = np.zeros(len(keys), np.uint64)
        hits = np.zeros(len(keys), bool)
        for i, key in enumerate(keys):
            h = int(self.home(key))
            for off in range(self.window):
                self.stats.probes += 1
                if self.keys[h + off] == key:
                    vals[i] = self.vals[h + off]
                    hits[i] = True
                    self.stats.data_reads += 1
                    break
                if self.keys[h + off] == EMPTY:
                    # hopscotch guarantee: key would have been within window
                    # of its home; empty home-window slot -> miss (with
                    # metadata bitmap the baseline stops here too)
                    break
        return vals, hits
