"""String-Match application (paper §9.2.3 / §10.5, Phoenix kernel).

Monarch flow: the dataset is copied from DDRx into CAM arrays with 64-bit
block boundaries as word delimiters — an 8x storage blow-up (bit-planes) +
a preprocessing pass, both charged in the benchmark — after which each
search command covers 4 KB of data.  The baseline streams the dataset
through the cache hierarchy in 64 B lines.

Op counts reported here feed benchmarks/string_match.py's timing model;
the actual matching runs on the Pallas kernel.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels.string_match import ops as sm_ops

SEARCH_COVERAGE = 4096      # bytes per Monarch search command
LINE = 64                   # baseline cache-line bytes
BLOWUP = 8                  # bit-plane storage expansion (paper §10.5)


@dataclasses.dataclass
class MatchReport:
    n_matches: int
    monarch_searches: int
    monarch_copy_bytes: int   # preprocessing writes into CAM (8x data)
    baseline_line_reads: int


def find(text: np.ndarray, pattern: bytes) -> MatchReport:
    text = np.asarray(text, np.uint8)
    pat = np.frombuffer(pattern, np.uint8)
    matches = int(np.asarray(sm_ops.count_matches(text, pat)))
    n = text.shape[0]
    return MatchReport(
        n_matches=matches,
        monarch_searches=(n + SEARCH_COVERAGE - 1) // SEARCH_COVERAGE,
        monarch_copy_bytes=n * BLOWUP,
        baseline_line_reads=(n + LINE - 1) // LINE,
    )


def make_corpus(n_bytes: int, seed: int = 0, alphabet: int = 16) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return (rng.integers(97, 97 + alphabet, n_bytes)).astype(np.uint8)
