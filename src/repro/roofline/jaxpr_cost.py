"""Trip-count-aware FLOP counting from the jaxpr.

XLA's HloCostAnalysis counts while/scan bodies ONCE (verified empirically —
a 10-iteration scanned matmul reports 1 matmul of FLOPs).  Our models scan
over layer groups / KV chunks / loss chunks, so compiled ``cost_analysis``
under-reports by ~the trip count.  This walker traverses the jaxpr instead:

* ``dot_general``: 2 x batch x M x N x K            (exact)
* ``conv_general_dilated``: 2 x out_spatial x flt   (exact)
* ``scan``: length x cost(body)                      (the fix)
* ``while``: cost(body) x assumed trips (unknown -> 1, flagged)
* ``remat/checkpoint/pjit/closed_call/custom_*``: recurse (each invocation
  of a remat body is real recompute and is counted at each call site —
  matching what actually executes after AD)
* ``cond``: max over branches

Reported alongside the compiled numbers in the dry-run JSON; the roofline
compute term uses these corrected FLOPs, and the memory term scales the
compiled bytes by the same body-repeat factor (loop bodies dominate both).
"""
from __future__ import annotations

import jax
import numpy as np


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    batch = float(np.prod([lhs.shape[i] for i in lb], dtype=np.float64)) if lb else 1.0
    contract = float(np.prod([lhs.shape[i] for i in lc], dtype=np.float64)) if lc else 1.0
    m = float(np.prod([d for i, d in enumerate(lhs.shape)
                       if i not in lc and i not in lb], dtype=np.float64))
    n = float(np.prod([d for i, d in enumerate(rhs.shape)
                       if i not in rc and i not in rb], dtype=np.float64))
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    out_elems = float(np.prod(out.shape, dtype=np.float64))
    # per output element: 2 * (filter spatial x in_channels / groups)
    k = float(np.prod(rhs.shape, dtype=np.float64)) / max(rhs.shape[-1], 1)
    return 2.0 * out_elems * k


def jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif prim == "scan":
            total += eqn.params["length"] * jaxpr_flops(eqn.params["jaxpr"].jaxpr)
        elif prim == "while":
            total += jaxpr_flops(eqn.params["body_jaxpr"].jaxpr)
        elif prim == "cond":
            total += max(jaxpr_flops(b.jaxpr) for b in eqn.params["branches"])
        else:
            for k in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
                sub = eqn.params.get(k)
                if sub is not None:
                    inner = getattr(sub, "jaxpr", sub)
                    total += jaxpr_flops(inner)
                    break
    return total


def step_flops(fn, *arg_shapes) -> float:
    """Global (unpartitioned) FLOPs of one step, trip counts applied."""
    closed = jax.make_jaxpr(fn)(*arg_shapes)
    return jaxpr_flops(closed.jaxpr)
