"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in SECONDS (per step, per device):

    compute    = HLO_FLOPs   / peak_FLOP/s          (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes   / HBM_bw               (819 GB/s)
    collective = coll_bytes  / ICI_bw               (~50 GB/s/link)

``cost_analysis()`` supplies FLOPs / bytes of the partitioned (per-device)
module.  Collective bytes are NOT in cost_analysis — we parse the compiled
HLO text and sum the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import os
import re

# v5e hardware constants (per chip), per the assignment.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (~45GB/s eff; assignment: ~50)

#: Env knob selecting the machine profile by name (``MACHINES`` keys).
MACHINE_ENV = "REPRO_MACHINE"


@dataclasses.dataclass(frozen=True)
class Machine:
    """Per-chip peak rates a roofline is drawn against.

    The v5e numbers are the assignment's constants above; ``cpu-interpret``
    is a deliberately coarse host profile (one modern server core's DRAM
    stream + vector peak, order-of-magnitude only) so interpret-mode bench
    runs report an achieved-bandwidth *fraction* against a ceiling that is
    at least the right power of ten — CI uses it to sanity-bound the
    packed-kernel traffic numbers, never to compare against TPU rooflines.
    """

    name: str
    peak_flops: float        # FLOP/s
    hbm_bw: float            # bytes/s (main-memory stream bandwidth)
    ici_bw: float            # bytes/s/link (interconnect; 0 = none)


MACHINES: dict[str, Machine] = {
    "v5e": Machine("v5e", PEAK_FLOPS, HBM_BW, ICI_BW),
    "cpu-interpret": Machine("cpu-interpret", 5e10, 2e10, 1e10),
}


def current_machine() -> Machine:
    """Active machine profile: ``REPRO_MACHINE`` if set (ValueError on an
    unknown name), else ``v5e`` on TPU and ``cpu-interpret`` elsewhere."""
    name = os.environ.get(MACHINE_ENV)
    if name is not None:
        if name not in MACHINES:
            raise ValueError(
                f"{MACHINE_ENV}={name!r} is not a known machine profile; "
                f"valid values: {sorted(MACHINES)}")
        return MACHINES[name]
    import jax
    return MACHINES["v5e" if jax.default_backend() == "tpu"
                    else "cpu-interpret"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[16,4096,128]{2,1,0} all-gather(%x), ...
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\]"
    r"(?:\{[^}]*\})?[\s\S]{0,80}?\b(" + "|".join(_COLLECTIVES) + r")")
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + "|".join(_COLLECTIVES) + r")")
_ELEM_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COLL_LINE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _line_collective(stripped: str):
    """Parse '%x = <shape> all-reduce(...)' lines; returns (kind, bytes).
    The output shape sits between '=' and the op name (instruction names
    also contain the op string, so naive substring matching is wrong)."""
    m = _COLL_LINE.search(stripped)
    if not m:
        return None
    total = 0
    for e in _ELEM_RE.finditer(m.group(1)):
        total += _shape_bytes(e.group(1), e.group(2))
    if total == 0:
        return None
    return m.group(2), total


# NOTE: while-loop bodies have tuple-typed parameters -> NESTED parens in
# the header; the param list must be matched greedily, not with [^)]*.
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLED = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def parse_hlo_computations(hlo_text: str):
    """Split optimized HLO text into computations.  Returns
    (comps: name -> list[str] lines, entry_name)."""
    comps, entry = {}, None
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps, entry


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective output bytes with WHILE-LOOP TRIP COUNTS applied.

    The optimized module is walked as a call graph from ENTRY; a while op
    multiplies its body's (and transitively called computations')
    contribution by the trip count recovered from the loop condition's
    integer constant.  Collectives outside loops (e.g. the once-per-step
    gradient reduction) count once; FSDP all-gathers inside the scanned
    layer-group body count n_groups times — matching real execution.
    """
    comps, entry = parse_hlo_computations(hlo_text)
    if entry is None:
        # fall back: flat scan, no loop scaling
        comps = {"main": [l.strip() for l in hlo_text.splitlines()]}
        entry = "main"

    def cond_trips(cond_name: str) -> int:
        ints = []
        for line in comps.get(cond_name, []):
            for m in _CONST_INT.finditer(line):
                ints.append(int(m.group(1)))
        return max(ints) if ints else 1

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def walk(name: str) -> tuple:
        """Returns tuple of (kind, bytes) totals dict for one execution."""
        totals = {k: 0 for k in _COLLECTIVES}
        for line in comps.get(name, ()):
            hit = _line_collective(line)
            if hit:
                totals[hit[0]] += hit[1]
            if " while(" in line:
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                if mb:
                    trips = cond_trips(mc.group(1)) if mc else 1
                    sub = dict(walk(mb.group(1)))
                    for k in _COLLECTIVES:
                        totals[k] += sub[k] * trips
            else:
                for m in _CALLED.finditer(line):
                    callee = m.group(1)
                    if callee in comps and callee != name:
                        sub = dict(walk(callee))
                        for k in _COLLECTIVES:
                            totals[k] += sub[k]
        return tuple(totals.items())

    out = dict(walk(entry))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float            # per-device, loop-corrected
    flops_raw_hlo: float    # per-device, as reported (loop bodies once)
    hbm_bytes: float        # per-device, loop-corrected
    coll_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    loop_factor: float      # corrected / raw

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: dict, coll: dict, *, model_flops_per_device: float,
            jaxpr_flops_per_device: float | None = None,
            machine: Machine | None = None) -> Roofline:
    """Derive the three terms.  ``cost_analysis`` counts while/scan bodies
    ONCE (verified; see jaxpr_cost.py), so when a jaxpr-derived count is
    supplied we use it for the compute term and scale the compiled byte
    count by the same body-repeat factor (the scanned layer groups dominate
    both flops and HBM traffic).  ``machine`` defaults to the v5e profile
    (the dry-run artifacts target that part); pass ``current_machine()``
    to roofline against the active backend instead."""
    if machine is None:
        machine = MACHINES["v5e"]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    if jaxpr_flops_per_device and raw_flops > 0:
        factor = max(jaxpr_flops_per_device / raw_flops, 1.0)
    else:
        factor = 1.0
    flops = raw_flops * factor if factor > 1.0 else raw_flops
    if jaxpr_flops_per_device:
        flops = jaxpr_flops_per_device
    hbm = raw_bytes * factor
    cb = float(coll.get("total", 0))
    terms = {
        "compute": flops / machine.peak_flops,
        "memory": hbm / machine.hbm_bw,
        "collective": cb / machine.ici_bw if machine.ici_bw else 0.0,
    }
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        flops=flops, flops_raw_hlo=raw_flops, hbm_bytes=hbm, coll_bytes=cb,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        model_flops=model_flops_per_device,
        useful_ratio=(model_flops_per_device / flops) if flops else 0.0,
        loop_factor=factor,
    )


def model_flops(cfg, shape, n_devices: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per step, where D =
    tokens processed; decode steps process global_batch tokens."""
    n_params = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * n_params * tokens / n_devices


def active_param_count(cfg) -> float:
    """Parameter count excluding inactive experts (MoE uses top_k of E)."""
    import jax
    from repro.launch import specs as lspecs
    shapes = lspecs.params_shapes(cfg)

    def leaf_count(path, s):
        keys = [getattr(p, "key", "") for p in path]
        n = 1
        for d in s.shape:
            n *= d
        name = keys[-1]
        if (name in ("w_up", "w_gate", "w_down") and len(s.shape) >= 3
                and cfg.n_experts):
            # expert-stacked: count only the top-k active fraction
            n = n * cfg.top_k / cfg.n_experts
        if name == "embed":
            # embedding gathers are not 6ND matmul work; count once (unembed
            # matmul is counted via `unembed`/tied read below).
            n = 0 if not cfg.tie_embeddings else n
        return n

    import jax.tree_util as jtu
    leaves = jtu.tree_leaves_with_path(shapes)
    return float(sum(leaf_count(p, s) for p, s in leaves))
