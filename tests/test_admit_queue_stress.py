"""AdmitQueue concurrency stress: submit/lookup/rotate/flush hammered
from multiple threads.

The queue's published guarantees were only ever exercised single-threaded
(plus one worker); this module drives them under real contention with a
seeded schedule:

* READ-YOUR-WRITES — every thread's lookup of tokens it has already
  submitted must hit, no matter how many other threads are admitting,
  flushing or rotating at that moment.
* DRAIN-BARRIER ORDERING — a rotation may never overlap an in-flight
  ``admit_fps`` (the worker holds the index lock across each batch; the
  remap takes it after the flush), asserted by instrumenting the index
  with an in-admit counter that ``_rotate`` observes.
* FAILURE SURFACING — a worker exception raised mid-schedule must come
  out of the NEXT barrier (flush/rotate/close) as ``RuntimeError``
  instead of killing the drain loop or vanishing, and the queue must
  keep admitting afterwards.

Capacity/window knobs are sized so the schedule has no evictions and no
throttles — total installs then have a closed-form expectation the final
asserts check against, which would catch lost or double-admitted
batches."""
from __future__ import annotations

import threading
import time

import numpy as np
import pytest

from repro.data.pipeline import fingerprint_blocks
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig, KVSlabStore,
                                  MonarchKVIndex)

N_THREADS = 4
BATCHES_PER_THREAD = 6
CHUNKS_PER_BATCH = 8


def _mk_index(n_shards: int = 1) -> MonarchKVIndex:
    # ample ways + huge window: no evictions, no throttles, so every
    # unique fingerprint submitted must end up (and stay) resident
    return MonarchKVIndex(KVIndexConfig(
        n_sets=8, set_ways=256, admit_after_reads=0, m_writes=1 << 20,
        window_ops=1 << 30, rotate_every=1 << 30, n_shards=n_shards))


def _thread_tokens(tid: int, rng: np.random.Generator) -> list[np.ndarray]:
    """Disjoint token batches per thread (disjoint token values =>
    distinct chunks; murmur3 collisions across ~200 fps are ~2^-15 and
    the schedule is seeded, so a pass is reproducible)."""
    lo = 1 + tid * 100_000
    return [rng.integers(lo, lo + 90_000,
                         (1, CHUNKS_PER_BATCH * CHUNK_TOKENS)
                         ).astype(np.int32)
            for _ in range(BATCHES_PER_THREAD)]


@pytest.mark.parametrize("n_shards", [1, 2])
def test_concurrent_submit_lookup_rotate_flush(n_shards):
    idx = _mk_index(n_shards)
    q = AdmitQueue(idx, background=True, read_your_writes=True)

    # ordering instrumentation: rotation must observe zero in-flight admits
    in_admit = [0]
    overlap = []
    real_admit = idx.admit_fps
    real_rotate = idx._rotate

    def counting_admit(fps):
        in_admit[0] += 1
        try:
            real_admit(fps)
        finally:
            in_admit[0] -= 1

    def checking_rotate():
        if in_admit[0] != 0:
            overlap.append(in_admit[0])
        real_rotate()

    idx.admit_fps = counting_admit
    idx._rotate = checking_rotate

    errors = []
    barrier = threading.Barrier(N_THREADS + 1)

    def worker(tid: int):
        rng = np.random.default_rng(1000 + tid)
        try:
            batches = _thread_tokens(tid, rng)
            barrier.wait(timeout=30)
            for i, toks in enumerate(batches):
                q.submit_tokens(toks)
                # read-your-writes: my own submissions must be visible
                assert q.lookup(toks).all(), f"tid={tid} batch={i}"
                if rng.random() < 0.3:
                    q.flush()
                # ...and must STILL be visible on a later re-lookup
                probe = batches[rng.integers(0, i + 1)]
                assert q.lookup(probe).all(), f"tid={tid} re-probe@{i}"
        except BaseException as e:  # noqa: BLE001 — surfaced in main thread
            errors.append((tid, e))

    def rotator():
        try:
            barrier.wait(timeout=30)
            for _ in range(5):
                q.rotate()
        except BaseException as e:  # noqa: BLE001
            errors.append(("rotator", e))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(N_THREADS)] + [threading.Thread(target=rotator)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "stress thread hung (deadlock?)"
    assert not errors, errors
    q.flush()
    assert not overlap, f"rotation overlapped {overlap} in-flight admits"
    assert idx.stats.rotations == 5
    assert q.pending() == 0

    # closed-form accounting: every unique fp admitted exactly once,
    # still resident (no evictions/throttles possible at this sizing)
    all_fps = np.unique(np.concatenate([
        fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1)
        for tid in range(N_THREADS)
        for toks in _thread_tokens(tid, np.random.default_rng(1000 + tid))]))
    assert idx.stats.evictions == 0 and idx.stats.throttled == 0
    assert idx.stats.admissions == all_fps.size
    assert set(idx.slot_of) == {int(fp) for fp in all_fps}
    assert idx._shadow_hits(all_fps).all()
    q.close()


def test_decode_overlap_read_your_writes_includes_slabs():
    """The resume-path race: submit-after-prefill admissions (fingerprints
    staged WITH their KV slabs) run on the worker while other threads'
    decode loops are already looking up the same prefixes.  Read-your-
    writes must cover the SLAB too: once my lookup reports a chunk hit,
    the slab the resume engine is about to fetch must be resident —
    a hit whose slab lags behind would silently degrade every resume to
    a recompute (or worse, race ``store.get`` against the commit).

    Threads share zipf-style prefixes, so the same fingerprints are
    re-offered concurrently from several threads (install on one,
    resident-refresh commits on the rest); a slowed ``admit_fps`` keeps
    batches deterministically pending at lookup time."""
    idx = MonarchKVIndex(
        KVIndexConfig(n_sets=8, set_ways=256, admit_after_reads=0,
                      m_writes=1 << 20, window_ops=1 << 30,
                      rotate_every=1 << 30, fingerprint="prefix"),
        slab_store=KVSlabStore())
    q = AdmitQueue(idx, background=True, read_your_writes=True)
    real_admit = idx.admit_fps
    idx.admit_fps = lambda fps: (time.sleep(0.02), real_admit(fps))[-1]

    shared = [np.arange(1 + p * 1000, 1 + p * 1000 + 2 * CHUNK_TOKENS,
                        dtype=np.int32)[None] for p in range(3)]
    errors: list[tuple] = []
    barrier = threading.Barrier(N_THREADS)

    def serving_thread(tid: int):
        rng = np.random.default_rng(40 + tid)
        try:
            barrier.wait(timeout=30)
            for i in range(BATCHES_PER_THREAD):
                prefix = shared[rng.integers(0, len(shared))]
                tail = rng.integers(1 + (tid + 10) * 100_000,
                                    (tid + 11) * 100_000,
                                    (1, 2 * CHUNK_TOKENS)).astype(np.int32)
                toks = np.concatenate([prefix, tail], axis=1)
                fps = idx.fingerprints(toks).reshape(-1)
                # submit-after-prefill: slabs staged with the fingerprints
                q.submit_tokens(toks, slabs={
                    int(f): np.full(4, int(f) & 0xFF) for f in fps})
                # the decode loop's next lookup: every chunk I just
                # submitted must hit AND carry a fetchable slab
                hits = q.lookup(toks)
                assert hits.all(), f"tid={tid} batch={i}"
                for f in fps:
                    assert idx.slab_store.get(int(f)) is not None, \
                        f"tid={tid} batch={i}: hit without resident slab"
        except BaseException as e:  # noqa: BLE001 — surfaced in main thread
            errors.append((tid, e))

    threads = [threading.Thread(target=serving_thread, args=(t,))
               for t in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "decode-overlap thread hung"
    assert not errors, errors
    q.flush()
    # lockstep held under the race: no resident fp lost its slab, no
    # slab outlived its fp
    audit = idx.slab_lockstep_report()
    assert not audit["missing_slabs"] and not audit["orphan_slabs"]
    assert idx.stats.evictions == 0
    q.close()


def test_worker_exception_mid_schedule_surfaces_at_next_barrier():
    """Fault injection under concurrency: one submitter's batches start
    failing mid-schedule; SOME barrier (flush/rotate/close) must re-raise
    RuntimeError while every other thread keeps working, and the queue
    must drain normally once the fault clears."""
    idx = _mk_index()
    q = AdmitQueue(idx, background=True, read_your_writes=False)
    real_admit = idx.admit_fps
    poison = np.asarray([0xDEAD], np.uint32)

    def flaky_admit(fps):
        # membership, not exact-batch identity: the worker may legally
        # coalesce the poison batch with disjoint neighbors
        if poison[0] in fps:
            raise ValueError("injected mid-schedule failure")
        real_admit(fps)

    idx.admit_fps = flaky_admit
    caught = []
    done = threading.Event()

    def good_submitter():
        rng = np.random.default_rng(7)
        for _ in range(8):
            q.submit(np.unique(rng.integers(1, 50_000, 16).astype(np.uint32)))
        done.set()

    def barrier_poller():
        # keep hitting barriers until one surfaces the injected failure
        for _ in range(200):
            try:
                q.flush()
            except RuntimeError as e:
                caught.append(e)
                return
            if done.is_set() and caught:
                return

    t1 = threading.Thread(target=good_submitter)
    t1.start()
    q.submit(poison)                       # the failing batch
    t2 = threading.Thread(target=barrier_poller)
    t2.start()
    t1.join(timeout=60)
    t2.join(timeout=60)
    assert not t1.is_alive() and not t2.is_alive()
    assert caught, "injected failure never surfaced at a barrier"
    assert "admission batch failed" in str(caught[0])
    # the drain loop survived: later batches admitted, barrier clean
    q.submit(np.asarray([1, 2, 3], np.uint32))
    q.flush()
    assert {1, 2, 3} <= set(idx.slot_of)
    q.close()


@pytest.mark.parametrize("n_shards", [1, 2])
def test_coalesced_drain_matches_inline_and_saves_dispatches(n_shards):
    """Disjoint pending batches drain as ONE admit_fps call with state
    bit-identical to the same calls inline (touch counts included: the
    re-offered batch shares fps, so it must NOT merge into its unit)."""
    cfg = dict(n_sets=8, set_ways=64, admit_after_reads=1, m_writes=1 << 20,
               window_ops=1 << 30, rotate_every=1 << 30, n_shards=n_shards)
    inline = MonarchKVIndex(KVIndexConfig(**cfg))
    queued = MonarchKVIndex(KVIndexConfig(**cfg))
    # background=False: submits pile up only because we enqueue under the
    # worker-less path below — use the queue internals to stage a backlog
    # deterministically, then drain once.
    q = AdmitQueue(queued, background=False, coalesce=True)
    rng = np.random.default_rng(3)
    disjoint = [np.asarray(block, np.uint32) for block in
                np.split(rng.choice(np.arange(1, 100_000, dtype=np.uint32),
                                    size=96, replace=False), 6)]
    batches = disjoint + [disjoint[2]]          # re-offer: shared fps
    for fps in batches:
        inline.admit_fps(fps)
        with q._cv:                              # stage without draining
            q._queue.append(fps)
            q._pending.update(int(f) for f in fps)
    q.stats.submitted += sum(int(b.size) for b in batches)
    calls = [0]
    real_admit = queued.admit_fps

    def counting_admit(fps):
        calls[0] += 1
        real_admit(fps)

    queued.admit_fps = counting_admit
    q.flush()
    # 6 disjoint batches merged into one call; the re-offer needed its own
    assert calls[0] == 2
    assert q.stats.batches == len(batches)
    assert q.stats.coalesced == len(disjoint) - 1
    assert q.pending() == 0
    # bit-identical to inline: shadow map, touch counts, install stats
    assert queued.slot_of == inline.slot_of
    assert queued.first_touch == inline.first_touch
    assert np.array_equal(queued.valid_np, inline.valid_np)
    assert np.array_equal(queued.fp_of_np, inline.fp_of_np)
    assert queued.stats.admissions == inline.stats.admissions
    assert queued.stats.admission_skips == inline.stats.admission_skips
    assert queued.wear_report() == inline.wear_report()
    q.close()


def test_concurrent_flushes_do_not_deadlock_or_double_raise():
    """Many threads flushing the same failed batch: exactly one barrier
    re-raises (the error is consumed), none hang."""
    idx = _mk_index()
    q = AdmitQueue(idx, background=True)
    idx.admit_fps = lambda fps: (_ for _ in ()).throw(ValueError("boom"))
    q.submit(np.asarray([9], np.uint32))
    # wait until the worker has consumed the batch (error latched)
    deadline = threading.Event()
    for _ in range(100):
        if q.pending() == 0:
            break
        deadline.wait(0.05)
    raises = []

    def flusher():
        try:
            q.flush()
        except RuntimeError:
            raises.append(1)

    threads = [threading.Thread(target=flusher) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    assert sum(raises) == 1
    q.close()


# ---------------------------------------------------------------------------
# close() lifecycle and max_pending back-pressure


def _wait(pred, timeout: float = 10.0) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(0.005)
    return False


def test_submit_and_lookup_after_close_raise():
    """The original bug: submit() after close() silently enqueued into a
    queue whose worker had exited, so the next flush() hung forever on
    the drain predicate.  Now both entry points fail fast."""
    q = AdmitQueue(_mk_index())
    toks = np.arange(1, 1 + 2 * CHUNK_TOKENS, dtype=np.int32).reshape(1, -1)
    q.submit_tokens(toks)
    q.close()
    with pytest.raises(RuntimeError, match="close"):
        q.submit(np.asarray([5], np.uint32))
    with pytest.raises(RuntimeError, match="close"):
        q.lookup(toks)
    q.close()                                  # still idempotent
    assert q.index.lookup(toks).all()          # the index itself lives on


def test_close_surfaces_wedged_worker_instead_of_swallowing():
    """A worker that never stops within the join timeout is a real hang
    (it holds the index lock) — close() must raise, not return as if the
    shutdown succeeded."""
    q = AdmitQueue(_mk_index())
    q.flush()
    hang = threading.Event()
    dummy = threading.Thread(target=hang.wait, daemon=True)
    dummy.start()
    q._worker = dummy              # stand-in for a worker stuck mid-admit
    with pytest.raises(RuntimeError, match="failed to stop"):
        q.close(timeout=0.1)
    hang.set()
    dummy.join(timeout=10)


def test_shed_policy_drops_oldest_queued_batch():
    idx = _mk_index()
    q = AdmitQueue(idx, max_pending=6, policy="shed")
    first = np.asarray([1, 2, 3], np.uint32)
    second = np.asarray([10, 11, 12], np.uint32)
    third = np.asarray([20, 21, 22], np.uint32)
    with q._idx_lock:                  # stall the worker mid-admission
        assert q.submit(first)
        assert _wait(lambda: q._inflight == 1)   # popped, blocked on lock
        assert q.submit(second)        # queued: pending == bound
        assert q.submit(third)         # over bound -> oldest QUEUED shed
    assert q.stats.shed == 1 and q.stats.shed_fps == 3
    q.flush()
    assert {1, 2, 3, 20, 21, 22} <= set(idx.slot_of)
    assert not {10, 11, 12} & set(idx.slot_of)
    q.close()


def test_defer_policy_rejects_then_accepts_after_drain():
    idx = _mk_index()
    q = AdmitQueue(idx, max_pending=4, policy="defer")
    with q._idx_lock:
        assert q.submit(np.asarray([1, 2, 3], np.uint32))
        assert _wait(lambda: q._inflight == 1)
        assert q.submit(np.asarray([7, 8], np.uint32)) is False
    assert q.stats.deferred == 1
    q.flush()                          # drained: the caller's retry lands
    assert q.submit(np.asarray([7, 8], np.uint32))
    q.flush()
    assert {7, 8} <= set(idx.slot_of)
    q.close()


def test_block_policy_waits_for_drain_then_completes():
    idx = _mk_index()
    q = AdmitQueue(idx, max_pending=4, policy="block")
    unblocked = threading.Event()

    def submitter():
        q.submit(np.asarray([7, 8], np.uint32))
        unblocked.set()

    t = threading.Thread(target=submitter)
    with q._idx_lock:
        assert q.submit(np.asarray([1, 2, 3], np.uint32))
        assert _wait(lambda: q._inflight == 1)
        t.start()
        assert not unblocked.wait(0.2), "submit did not block at the bound"
    assert unblocked.wait(10), "blocked submit never completed after drain"
    t.join(timeout=10)
    q.flush()
    assert {7, 8} <= set(idx.slot_of)
    q.close()


def test_close_wakes_blocked_submitter_with_runtime_error():
    idx = _mk_index()
    q = AdmitQueue(idx, max_pending=4, policy="block")
    result: list[str] = []

    def submitter():
        try:
            q.submit(np.asarray([7, 8], np.uint32))
            result.append("accepted")
        except RuntimeError:
            result.append("raised")

    q._idx_lock.acquire()
    try:
        q.submit(np.asarray([1, 2, 3], np.uint32))
        assert _wait(lambda: q._inflight == 1)
        t = threading.Thread(target=submitter)
        t.start()
        time.sleep(0.1)                # let it park at the bound
        closer = threading.Thread(target=q.close)
        closer.start()
        assert _wait(lambda: bool(result)), "submitter never woke"
        assert result == ["raised"]
    finally:
        q._idx_lock.release()
    closer.join(timeout=30)
    t.join(timeout=10)
    assert not closer.is_alive()


def test_oversize_batch_accepted_once_drained():
    """A single batch larger than max_pending must admit (after a full
    drain), never deadlock or reject forever."""
    q = AdmitQueue(_mk_index(), max_pending=4, policy="block")
    assert q.submit(np.arange(1, 20, dtype=np.uint32))   # 19 fps > bound
    q.flush()
    assert q.pending() == 0
    q.close()


@pytest.mark.parametrize("policy", ["block", "shed", "defer"])
@pytest.mark.parametrize("n_shards", [1, 2])
def test_bounded_queue_state_matches_unbounded(policy, n_shards):
    """Back-pressure pin: when the bound is never hit, every policy is
    bit-identical to the unbounded queue (the pre-bound behavior) —
    the policies gate WHICH batches enter, never how they drain."""
    cfg = dict(n_sets=8, set_ways=64, admit_after_reads=1, m_writes=1 << 20,
               window_ops=1 << 30, rotate_every=1 << 30, n_shards=n_shards)
    plain = MonarchKVIndex(KVIndexConfig(**cfg))
    bound = MonarchKVIndex(KVIndexConfig(**cfg))
    qp = AdmitQueue(plain, background=False)
    qb = AdmitQueue(bound, background=False, max_pending=1 << 20,
                    policy=policy)
    rng = np.random.default_rng(5)
    for _ in range(6):
        toks = rng.integers(1, 90_000,
                            (1, 4 * CHUNK_TOKENS)).astype(np.int32)
        qp.submit_tokens(toks)
        assert qb.submit_tokens(toks)
        assert np.array_equal(qp.lookup(toks), qb.lookup(toks))
    qp.flush()
    qb.flush()
    assert bound.slot_of == plain.slot_of
    assert bound.first_touch == plain.first_touch
    assert np.array_equal(bound.valid_np, plain.valid_np)
    assert np.array_equal(bound.fp_of_np, plain.fp_of_np)
    assert bound.wear_report() == plain.wear_report()
    qp.close()
    qb.close()


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
