"""HTTP network edge: endpoint round-trips, back-pressure -> 429 with
Retry-After, micro-batch coalescing, concurrent clients sharing ONE
index (read-your-writes over the socket), graceful-shutdown drain with
no lost admissions, and bounded==unbounded index-state equality.

Most tests drive a real loopback ``HttpFrontend`` over toy prefill/
decode fns (the router contract doesn't care); one end-to-end test
boots the full ``launch/httpd.py`` stack (reduced model, resume path)
and pins that a prefix hit resumes decode token-identically through
the socket.  Parametrized over ``n_shards`` {1, 4} — on the forced-
4-device CI leg the shards get real placement.
"""
from __future__ import annotations

import contextlib
import http.client
import json
import os
import sys
import threading
import time

import numpy as np
import pytest

from repro.serve.admit_queue import AdmitQueue
from repro.serve.http_frontend import (HttpFrontend, RouterClosed,
                                       ServeRouter)
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig,
                                  MonarchKVIndex)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:                      # for `import benchmarks.*`
    sys.path.insert(0, ROOT)


def _mk_index(n_shards: int = 1, **kw) -> MonarchKVIndex:
    cfg = dict(n_sets=8, set_ways=16, admit_after_reads=0,
               rotate_every=1 << 30, n_shards=n_shards)
    cfg.update(kw)
    return MonarchKVIndex(KVIndexConfig(**cfg))


def _toks(i: int, chunks: int = 2, rows: int = 1) -> np.ndarray:
    base = 1 + i * 10_000
    n = rows * chunks * CHUNK_TOKENS
    return np.arange(base, base + n, dtype=np.int32).reshape(rows, -1)


@contextlib.contextmanager
def _frontend(n_shards: int = 1, *, prefill=None, decode="echo",
              admit_kw=None, **router_kw):
    """Loopback HttpFrontend over a toy router; always torn down."""
    q = AdmitQueue(_mk_index(n_shards), **(admit_kw or {}))
    router = ServeRouter(
        q, prefill_fn=prefill or (lambda t, h: None),
        decode_fn=(lambda t, s: t[:, -1:]) if decode == "echo" else decode,
        batch_window_s=router_kw.pop("batch_window_s", 0.0), **router_kw)
    fe = HttpFrontend(router).start()
    try:
        yield fe, q
    finally:
        with contextlib.suppress(Exception):
            fe.shutdown()
        with contextlib.suppress(RuntimeError):
            q.close()


def _req(fe: HttpFrontend, method: str, path: str, body=None,
         timeout: float = 30.0):
    host, port = fe.address
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    conn.request(method, path,
                 body=None if body is None else json.dumps(body))
    resp = conn.getresponse()
    doc = json.loads(resp.read())
    headers = dict(resp.getheaders())
    conn.close()
    return resp.status, doc, headers


# ---------------------------------------------------------------------------
# endpoint round-trips


@pytest.mark.parametrize("n_shards", [1, 4])
def test_generate_healthz_stats_round_trip(n_shards):
    with _frontend(n_shards) as (fe, q):
        status, doc, _ = _req(fe, "GET", "/healthz")
        assert status == 200 and doc["status"] == "ok"

        toks = _toks(0)
        status, doc, _ = _req(fe, "POST", "/v1/generate",
                              {"tokens": toks.tolist()})
        assert status == 200
        assert doc["tokens"] == [[int(toks[0, -1])]]   # echo decode
        assert doc["chunks"] == 2 and doc["hit_chunks"] == 0
        assert doc["admitted"] and not doc["dropped"]
        assert doc["server_ms"] >= doc["service_ms"] >= 0

        # read-your-writes through the shared index: the same prompt is
        # fully cached on its second trip through the socket
        status, doc, _ = _req(fe, "POST", "/v1/generate",
                              {"tokens": toks.tolist()})
        assert status == 200 and doc["hit_chunks"] == doc["chunks"] == 2

        q.flush()                       # settle async admissions
        status, doc, _ = _req(fe, "GET", "/stats")
        assert status == 200
        assert doc["index"]["hit_rate"] == pytest.approx(0.5)
        assert doc["admit_queue"]["pending"] == 0
        assert "installs_per_set_max" in doc["wear"]
        assert doc["lifetime"]["years"] > 0
        assert doc["router"]["completed"] == 2
        assert doc["router"]["workers"] == 2


def test_bad_requests():
    with _frontend() as (fe, _):
        assert _req(fe, "GET", "/nope")[0] == 404
        assert _req(fe, "POST", "/nope")[0] == 404
        host, port = fe.address
        conn = http.client.HTTPConnection(host, port, timeout=10)
        conn.request("POST", "/v1/generate", body=b"{not json")
        assert conn.getresponse().status == 400
        conn.close()
        assert _req(fe, "POST", "/v1/generate",
                    {"tokens": "strings"})[0] == 400
        assert _req(fe, "POST", "/v1/generate",
                    {"tokens": [[1, 2], [3]]})[0] == 400     # ragged
        assert _req(fe, "POST", "/v1/generate", {"tokens": []})[0] == 400
        assert _req(fe, "POST", "/v1/generate", {"wrong": 1})[0] == 400
        # per-request token cap -> 400, not a wedged worker
        big = np.ones((1, (1 << 16) + CHUNK_TOKENS), np.int32)
        status, doc, _ = _req(fe, "POST", "/v1/generate",
                              {"tokens": big.tolist()})
        assert status == 400 and "cap" in doc["error"]


# ---------------------------------------------------------------------------
# back-pressure -> HTTP 429


def test_429_on_full_router_queue_with_retry_after():
    gate = threading.Event()

    def prefill(toks, hits):
        gate.wait(10)

    with _frontend(prefill=prefill, n_workers=1, max_queue=1) as (fe, q):
        done: list = []

        def client(i):
            done.append(_req(fe, "POST", "/v1/generate",
                             {"tokens": _toks(i).tolist()})[0])

        a = threading.Thread(target=client, args=(0,))
        a.start()                       # occupies the single worker
        deadline = time.monotonic() + 5
        while fe.router.depth() < 1 and time.monotonic() < deadline:
            time.sleep(0.005)
        b = threading.Thread(target=client, args=(1,))
        b.start()                       # fills the queue (bound = 1)
        while fe.router.depth() < 2 and time.monotonic() < deadline:
            time.sleep(0.005)

        status, doc, headers = _req(fe, "POST", "/v1/generate",
                                    {"tokens": _toks(2).tolist()})
        assert status == 429
        assert int(headers["Retry-After"]) >= 1
        assert doc["retry_after_s"] > 0
        gate.set()
        a.join(10)
        b.join(10)
        assert done == [200, 200]       # accepted work never shed
        assert fe.router.stats.rejected_busy == 1


def test_router_submit_validation_and_busy():
    q = AdmitQueue(_mk_index())
    router = ServeRouter(q, prefill_fn=lambda t, h: None,
                         batch_window_s=0.0)
    with pytest.raises(ValueError, match="non-empty"):
        router.submit(np.arange(4, dtype=np.int32))        # 1-D
    with pytest.raises(ValueError, match="cap"):
        router.submit(np.ones((2, 1 << 16), np.int32))
    with pytest.raises(ValueError, match="n_workers"):
        ServeRouter(q, prefill_fn=lambda t, h: None, n_workers=0)
    router.begin_close()
    with pytest.raises(RouterClosed):
        router.submit(_toks(0))
    router.close()
    q.close()


# ---------------------------------------------------------------------------
# micro-batcher


def test_micro_batcher_coalesces_same_shape_requests():
    gate = threading.Event()
    calls: list[tuple] = []

    def prefill(toks, hits):
        calls.append(toks.shape)
        if len(calls) == 1:
            gate.wait(10)               # hold the worker on request 0

    with _frontend(prefill=prefill, n_workers=1, max_queue=16,
                   batch_window_s=0.2, max_batch_rows=8) as (fe, q):
        results: dict[int, dict] = {}

        def client(i, chunks):
            status, doc, _ = _req(fe, "POST", "/v1/generate",
                                  {"tokens": _toks(i, chunks).tolist()})
            results[i] = (status, doc)

        t0 = threading.Thread(target=client, args=(0, 2))
        t0.start()
        deadline = time.monotonic() + 5
        # wait until request 0 is IN prefill (dequeued), so the batch
        # below can't swallow it
        while not calls and time.monotonic() < deadline:
            time.sleep(0.005)
        # three same-shape requests queue up first ...
        rest = [threading.Thread(target=client, args=(i, 2))
                for i in (1, 2, 3)]
        for t in rest:
            t.start()
        while fe.router.depth() < 4 and time.monotonic() < deadline:
            time.sleep(0.005)
        # ... then one different-shape request lands BEHIND them (the
        # coalescer preserves FIFO order: it stops at a shape mismatch)
        t4 = threading.Thread(target=client, args=(4, 3))
        t4.start()
        rest.append(t4)
        while fe.router.depth() < 5 and time.monotonic() < deadline:
            time.sleep(0.005)
        gate.set()
        for t in [t0] + rest:
            t.join(10)

        assert all(results[i][0] == 200 for i in range(5))
        # requests 1-3 shared ONE prefill batch of 3 rows; request 4
        # (different shape) was served alone
        assert results[1][1]["batched_rows"] == 3
        assert results[2][1]["batched_rows"] == 3
        assert results[3][1]["batched_rows"] == 3
        assert results[4][1]["batched_rows"] == 1
        assert (3, 2 * CHUNK_TOKENS) in calls
        assert fe.router.stats.coalesced == 2
        # per-request accounting still splits correctly
        for i in (1, 2, 3):
            assert results[i][1]["chunks"] == 2
            assert results[i][1]["tokens"] == [[int(_toks(i)[0, -1])]]


# ---------------------------------------------------------------------------
# concurrent clients over ONE shared index


@pytest.mark.parametrize("n_shards", [1, 4])
def test_concurrent_clients_read_your_writes(n_shards):
    with _frontend(n_shards, n_workers=4, max_queue=64) as (fe, q):
        failures: list = []

        def client(i):
            toks = _toks(i, chunks=3).tolist()
            s1, d1, _ = _req(fe, "POST", "/v1/generate", {"tokens": toks})
            s2, d2, _ = _req(fe, "POST", "/v1/generate", {"tokens": toks})
            if s1 != 200 or s2 != 200:
                failures.append((i, s1, s2))
            elif d2["hit_chunks"] != d2["chunks"]:
                failures.append((i, "second trip missed", d2))

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(30)
        assert not failures, failures
        assert fe.router.stats.completed == 16


@pytest.mark.parametrize("n_shards", [1, 4])
def test_bounded_equals_unbounded_index_state(n_shards):
    """The admission bound must only pace admissions, never change
    them.  Sequentially (deterministic order) the bounded-queue index
    is BIT-identical to the unbounded one; under concurrent clients
    (order nondeterministic) the resident-fingerprint set and admission
    totals still match exactly."""
    def drive_sequential(admit_kw):
        with _frontend(n_shards, admit_kw=admit_kw) as (fe, q):
            for i in range(6):
                s, _, _ = _req(fe, "POST", "/v1/generate",
                               {"tokens": _toks(i, chunks=3).tolist()})
                assert s == 200
            q.flush()
            idx = q.index
            return (dict(idx.slot_of), np.asarray(idx.valid).copy(),
                    np.asarray(idx.fp_of).copy(),
                    idx.stats.admissions)

    bounded = drive_sequential({"max_pending": 4, "policy": "block"})
    unbounded = drive_sequential({})
    assert bounded[0] == unbounded[0]
    np.testing.assert_array_equal(bounded[1], unbounded[1])
    np.testing.assert_array_equal(bounded[2], unbounded[2])
    assert bounded[3] == unbounded[3]

    def drive_concurrent(admit_kw):
        with _frontend(n_shards, n_workers=4, max_queue=64,
                       admit_kw=admit_kw) as (fe, q):
            threads = [threading.Thread(
                target=lambda i=i: _req(fe, "POST", "/v1/generate",
                                        {"tokens": _toks(i, 3).tolist()}))
                for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(30)
            q.flush()
            return (frozenset(int(f) for f in q.index.slot_of),
                    q.index.stats.admissions)

    con_b = drive_concurrent({"max_pending": 4, "policy": "block"})
    con_u = drive_concurrent({})
    assert con_b == con_u


# ---------------------------------------------------------------------------
# graceful shutdown


def test_graceful_shutdown_drains_without_losing_admissions():
    gate = threading.Event()

    def prefill(toks, hits):
        gate.wait(10)

    with _frontend(prefill=prefill, n_workers=1, max_queue=8) as (fe, q):
        done: list = []

        def client(i):
            done.append(_req(fe, "POST", "/v1/generate",
                             {"tokens": _toks(i).tolist()})[0])

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        deadline = time.monotonic() + 5
        while fe.router.depth() < 3 and time.monotonic() < deadline:
            time.sleep(0.005)

        fe.begin_shutdown()             # the SIGTERM half
        status, _, _ = _req(fe, "POST", "/v1/generate",
                            {"tokens": _toks(9).tolist()})
        assert status == 503
        h_status, h_doc, _ = _req(fe, "GET", "/healthz")
        assert h_status == 503 and h_doc["status"] == "draining"

        gate.set()
        fe.shutdown()                   # drains router + admit queue
        for t in threads:
            t.join(10)
        assert done == [200, 200, 200]  # accepted requests all served
        # ... and none of their admissions were lost in the drain
        assert q.index.stats.admissions == 3 * 2
        assert fe.router.stats.rejected_closed == 1


# ---------------------------------------------------------------------------
# the serve_bench HTTP leg


def test_serve_bench_http_leg_fields():
    from benchmarks import serve_bench
    reqs = serve_bench._requests(6, seed=3)
    arrivals = np.linspace(0.0, 0.05, 6)
    leg = serve_bench._run_http_leg(reqs, arrivals, label="test http")
    for field in ("n_requests", "p50_ms", "p99_ms", "mean_ms",
                  "goodput_rps", "shed_rate", "hit_rate",
                  "transport_overhead_ms"):
        assert isinstance(leg[field], (int, float)), field
    assert leg["n_requests"] == 6
    assert leg["transport_overhead_ms"] >= 0
    assert 0.0 <= leg["hit_rate"] <= 1.0
    assert leg["p50_ms"] <= leg["p99_ms"]


# ---------------------------------------------------------------------------
# the full stack: launch/httpd.py end-to-end (reduced model, resume)


def test_httpd_end_to_end_prefix_hit_resumes_decode():
    from repro.launch import httpd
    args = httpd.build_parser().parse_args(
        ["--arch", "yi-9b", "--reduced", "--port", "0",
         "--prompt-len", "48", "--decode-tokens", "3",
         "--batch-window-ms", "0", "--n-workers", "2",
         "--admit-after-reads", "0"])
    fe, q = httpd.build_frontend(args)
    fe.start()
    try:
        toks = np.arange(1, 49, dtype=np.int32).reshape(1, 48) % 500 + 1
        status, first, _ = _req(fe, "POST", "/v1/generate",
                                {"tokens": toks.tolist()}, timeout=120)
        assert status == 200
        assert np.asarray(first["tokens"]).shape == (1, 3)
        assert first["chunks"] == 3 and first["hit_chunks"] == 0

        status, second, _ = _req(fe, "POST", "/v1/generate",
                                 {"tokens": toks.tolist()}, timeout=120)
        assert status == 200
        assert second["hit_chunks"] == 3          # fully cached prompt
        # the resume path actually restored KV slabs (capped at
        # (S-1)//16 = 2 of the 3 chunks)...
        assert second["resumed_chunks"] == 2
        # ...and decode is token-identical to the full prefill
        assert second["tokens"] == first["tokens"]

        fe.begin_shutdown()
        assert _req(fe, "POST", "/v1/generate",
                    {"tokens": toks.tolist()})[0] == 503
    finally:
        fe.shutdown()
        q.close()


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
