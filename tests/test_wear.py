"""Wear-leveling / durability state machine tests (paper §8, Fig. 8)."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import geometry, wear
from repro.core.timing import (CPU_HZ, PAPER_3Y_SECONDS, t_mww_seconds,
                               t_mww_cycles)


def _cfg(**kw):
    defaults = dict(n_supersets=16, m_writes=3, dc_limit=8192,
                    wc_limit=1 << 22, t_mww_cycles=1000,
                    blocks_per_superset=4)
    defaults.update(kw)
    return wear.WearConfig(**defaults)


# ---------------------------------------------------------------------------
# t_MWW math (§6.2).
# ---------------------------------------------------------------------------

def test_t_mww_paper_example():
    """Paper: 3-year lifetime (94.6e6 s), endurance 1e8 -> t_MWW = 0.94*M s."""
    for m in (1, 2, 3, 4):
        s = t_mww_seconds(m, PAPER_3Y_SECONDS, 1e8)
        assert s == pytest.approx(0.946 * m, rel=1e-3)
    assert t_mww_cycles(1, PAPER_3Y_SECONDS, 1e8) == pytest.approx(
        0.946 * CPU_HZ, rel=1e-3)


# ---------------------------------------------------------------------------
# MSB ratio detector (divider-free WR, Fig. 8).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("x,want", [(0, -1), (1, 0), (2, 1), (3, 1),
                                    (512, 9), (513, 9), (1 << 20, 20)])
def test_msb_index(x, want):
    assert int(wear.msb_index(jnp.asarray(x, jnp.int32))) == want


def test_wr_signal_512x_threshold():
    import dataclasses
    cfg = _cfg()
    st_ = wear.init_state(cfg)
    # writes = 512 * supersets -> MSB gap = 9 -> WR fires
    st_ = dataclasses.replace(
        st_, write_counter=jnp.asarray(1 << 12, jnp.int32),
        superset_counter=jnp.asarray(8, jnp.int32))
    assert bool(wear.wr_signal(st_, cfg))
    st2 = dataclasses.replace(
        st_, write_counter=jnp.asarray((1 << 12) - 1, jnp.int32))
    assert not bool(wear.wr_signal(st2, cfg))
    # zero supersets -> no signal regardless of writes
    st3 = dataclasses.replace(
        st_, superset_counter=jnp.asarray(0, jnp.int32))
    assert not bool(wear.wr_signal(st3, cfg))


# ---------------------------------------------------------------------------
# record_write: SWT flags, counters, rotate, t_MWW locking.
# ---------------------------------------------------------------------------

def test_swt_counters_first_write_only():
    cfg = _cfg()
    st_ = wear.init_state(cfg)
    c = jnp.asarray(0)
    for i in range(3):
        st_, rot, _ = wear.record_write(st_, cfg, jnp.asarray(2),
                                        jnp.asarray(True), c)
    assert int(st_.superset_counter) == 1        # counted once
    assert int(st_.dirty_counter) == 1
    assert int(st_.write_counter) == 3
    assert int(st_.swt_w[2]) == 1 and int(st_.swt_d[2]) == 1
    assert int(st_.swt_w[0]) == 0


def test_t_mww_lock_and_window_rollover():
    cfg = _cfg(n_supersets=4, m_writes=1, blocks_per_superset=2,
               t_mww_cycles=100)   # budget = 2 writes / window
    st_ = wear.init_state(cfg)
    s = jnp.asarray(1)
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False), jnp.asarray(0))
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False), jnp.asarray(1))
    assert not bool(wear.is_locked(st_, s, jnp.asarray(2)))
    # third write in the same window exceeds the budget -> locked
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False), jnp.asarray(2))
    assert bool(wear.is_locked(st_, s, jnp.asarray(3)))
    # lock expires when the window rolls over
    assert not bool(wear.is_locked(st_, s, jnp.asarray(200)))
    # a fresh window resets the budget
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False),
                                  jnp.asarray(250))
    assert not bool(wear.is_locked(st_, s, jnp.asarray(251)))
    # other supersets never locked
    assert not bool(wear.is_locked(st_, jnp.asarray(0), jnp.asarray(3)))


def test_rotate_on_dirty_limit_flushes_and_resets():
    cfg = _cfg(n_supersets=8, dc_limit=2, t_mww_cycles=1 << 20)
    st_ = wear.init_state(cfg)
    st_, rot, fl = wear.record_write(st_, cfg, jnp.asarray(0),
                                     jnp.asarray(True), jnp.asarray(0))
    assert not bool(rot)
    st_, rot, fl = wear.record_write(st_, cfg, jnp.asarray(1),
                                     jnp.asarray(True), jnp.asarray(1))
    assert bool(rot)                       # DC = 2 reached
    assert int(fl) == 2                    # both dirty supersets flushed
    # SWT + counters reset, offsets bumped
    assert int(st_.write_counter) == 0
    assert int(st_.superset_counter) == 0
    assert int(jnp.sum(st_.swt_d)) == 0
    assert int(st_.offsets.rotate_count) == 1
    assert int(st_.offsets.superset) == geometry.ROTATE_PRIMES["superset"]
    assert int(st_.total_rotates) == 1
    assert int(st_.total_flushed) == 2


def test_record_write_is_jittable():
    cfg = _cfg()
    st_ = wear.init_state(cfg)
    f = jax.jit(lambda s, sup, d, c: wear.record_write(s, cfg, sup, d, c))
    st2, rot, fl = f(st_, jnp.asarray(3), jnp.asarray(True), jnp.asarray(5))
    assert int(st2.write_counter) == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_writes=st.integers(1, 60))
def test_wear_counters_invariants(seed, n_writes):
    """Invariants under random write streams: superset_counter <= distinct
    supersets touched; dirty_counter <= superset_counter; counters reset on
    rotate."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(n_supersets=8, dc_limit=5, t_mww_cycles=1 << 20)
    st_ = wear.init_state(cfg)
    touched, dirty_touched = set(), set()
    for i in range(n_writes):
        s = int(rng.integers(0, 8))
        d = bool(rng.integers(0, 2))
        st_, rot, _ = wear.record_write(st_, cfg, jnp.asarray(s),
                                        jnp.asarray(d), jnp.asarray(i))
        if bool(rot):
            touched.clear()
            dirty_touched.clear()
        else:
            touched.add(s)
            if d:
                dirty_touched.add(s)
        assert int(st_.superset_counter) == len(touched)
        assert int(st_.dirty_counter) >= len(dirty_touched) - 1  # rotate timing
        assert int(st_.dirty_counter) <= cfg.dc_limit


# ---------------------------------------------------------------------------
# D/R install filter (§8 "Mitigating Writes").
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,r,install,forward", [
    (True, True, True, False),    # D&R: install
    (True, False, False, True),   # D&!R: forward to DRAM
    (False, True, True, False),   # !D&R: install read-only
    (False, False, False, False),  # !D&!R: drop
])
def test_install_decision_truth_table(d, r, install, forward):
    i, f = wear.install_decision(jnp.asarray(d), jnp.asarray(r))
    assert bool(i) == install and bool(f) == forward


# ---------------------------------------------------------------------------
# Lifetime replay (§10.3).
# ---------------------------------------------------------------------------

def test_lifetime_rotation_beats_no_rotation():
    from repro.core import lifetime
    w = np.zeros(64)
    w[:4] = 1000.0  # concentrated writes
    res = lifetime.estimate_lifetime(w, epoch_cycles=1e9,
                                     rotations_per_epoch=4)
    # rotation spreads the hot supersets -> years must beat the static map
    static_years = lifetime.estimate_lifetime(
        w, epoch_cycles=1e9, rotations_per_epoch=4,
        endurance=1e8).max_cell_writes_per_epoch
    assert res.years <= res.ideal_years           # never beats ideal
    assert res.years > 0
    # even distribution: rotation == ideal
    res_even = lifetime.estimate_lifetime(np.ones(64), epoch_cycles=1e9)
    assert res_even.years == pytest.approx(res_even.ideal_years, rel=0.01)
