"""Wear-leveling / durability state machine tests (paper §8, Fig. 8)."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import geometry, wear
from repro.core.timing import (CPU_HZ, PAPER_3Y_SECONDS, t_mww_seconds,
                               t_mww_cycles)


def _cfg(**kw):
    defaults = dict(n_supersets=16, m_writes=3, dc_limit=8192,
                    wc_limit=1 << 22, t_mww_cycles=1000,
                    blocks_per_superset=4)
    defaults.update(kw)
    return wear.WearConfig(**defaults)


# ---------------------------------------------------------------------------
# t_MWW math (§6.2).
# ---------------------------------------------------------------------------

def test_t_mww_paper_example():
    """Paper: 3-year lifetime (94.6e6 s), endurance 1e8 -> t_MWW = 0.94*M s."""
    for m in (1, 2, 3, 4):
        s = t_mww_seconds(m, PAPER_3Y_SECONDS, 1e8)
        assert s == pytest.approx(0.946 * m, rel=1e-3)
    assert t_mww_cycles(1, PAPER_3Y_SECONDS, 1e8) == pytest.approx(
        0.946 * CPU_HZ, rel=1e-3)


# ---------------------------------------------------------------------------
# MSB ratio detector (divider-free WR, Fig. 8).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("x,want", [(0, -1), (1, 0), (2, 1), (3, 1),
                                    (512, 9), (513, 9), (1 << 20, 20)])
def test_msb_index(x, want):
    assert int(wear.msb_index(jnp.asarray(x, jnp.int32))) == want


def test_wr_signal_512x_threshold():
    import dataclasses
    cfg = _cfg()
    st_ = wear.init_state(cfg)
    # writes = 512 * supersets -> MSB gap = 9 -> WR fires
    st_ = dataclasses.replace(
        st_, write_counter=jnp.asarray(1 << 12, jnp.int32),
        superset_counter=jnp.asarray(8, jnp.int32))
    assert bool(wear.wr_signal(st_, cfg))
    st2 = dataclasses.replace(
        st_, write_counter=jnp.asarray((1 << 12) - 1, jnp.int32))
    assert not bool(wear.wr_signal(st2, cfg))
    # zero supersets -> no signal regardless of writes
    st3 = dataclasses.replace(
        st_, superset_counter=jnp.asarray(0, jnp.int32))
    assert not bool(wear.wr_signal(st3, cfg))


# ---------------------------------------------------------------------------
# record_write: SWT flags, counters, rotate, t_MWW locking.
# ---------------------------------------------------------------------------

def test_swt_counters_first_write_only():
    cfg = _cfg()
    st_ = wear.init_state(cfg)
    c = jnp.asarray(0)
    for i in range(3):
        st_, rot, _ = wear.record_write(st_, cfg, jnp.asarray(2),
                                        jnp.asarray(True), c)
    assert int(st_.superset_counter) == 1        # counted once
    assert int(st_.dirty_counter) == 1
    assert int(st_.write_counter) == 3
    assert int(st_.swt_w[2]) == 1 and int(st_.swt_d[2]) == 1
    assert int(st_.swt_w[0]) == 0


def test_t_mww_lock_and_window_rollover():
    cfg = _cfg(n_supersets=4, m_writes=1, blocks_per_superset=2,
               t_mww_cycles=100)   # budget = 2 writes / window
    st_ = wear.init_state(cfg)
    s = jnp.asarray(1)
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False), jnp.asarray(0))
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False), jnp.asarray(1))
    assert not bool(wear.is_locked(st_, s, jnp.asarray(2)))
    # third write in the same window exceeds the budget -> locked
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False), jnp.asarray(2))
    assert bool(wear.is_locked(st_, s, jnp.asarray(3)))
    # lock expires when the window rolls over
    assert not bool(wear.is_locked(st_, s, jnp.asarray(200)))
    # a fresh window resets the budget
    st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False),
                                  jnp.asarray(250))
    assert not bool(wear.is_locked(st_, s, jnp.asarray(251)))
    # other supersets never locked
    assert not bool(wear.is_locked(st_, jnp.asarray(0), jnp.asarray(3)))


def test_rotate_on_dirty_limit_flushes_and_resets():
    cfg = _cfg(n_supersets=8, dc_limit=2, t_mww_cycles=1 << 20)
    st_ = wear.init_state(cfg)
    st_, rot, fl = wear.record_write(st_, cfg, jnp.asarray(0),
                                     jnp.asarray(True), jnp.asarray(0))
    assert not bool(rot)
    st_, rot, fl = wear.record_write(st_, cfg, jnp.asarray(1),
                                     jnp.asarray(True), jnp.asarray(1))
    assert bool(rot)                       # DC = 2 reached
    assert int(fl) == 2                    # both dirty supersets flushed
    # SWT + counters reset, offsets bumped
    assert int(st_.write_counter) == 0
    assert int(st_.superset_counter) == 0
    assert int(jnp.sum(st_.swt_d)) == 0
    assert int(st_.offsets.rotate_count) == 1
    assert int(st_.offsets.superset) == geometry.ROTATE_PRIMES["superset"]
    assert int(st_.total_rotates) == 1
    assert int(st_.total_flushed) == 2


def test_record_write_is_jittable():
    cfg = _cfg()
    st_ = wear.init_state(cfg)
    f = jax.jit(lambda s, sup, d, c: wear.record_write(s, cfg, sup, d, c))
    st2, rot, fl = f(st_, jnp.asarray(3), jnp.asarray(True), jnp.asarray(5))
    assert int(st2.write_counter) == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_writes=st.integers(1, 60))
def test_wear_counters_invariants(seed, n_writes):
    """Invariants under random write streams: superset_counter <= distinct
    supersets touched; dirty_counter <= superset_counter; counters reset on
    rotate."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(n_supersets=8, dc_limit=5, t_mww_cycles=1 << 20)
    st_ = wear.init_state(cfg)
    touched, dirty_touched = set(), set()
    for i in range(n_writes):
        s = int(rng.integers(0, 8))
        d = bool(rng.integers(0, 2))
        st_, rot, _ = wear.record_write(st_, cfg, jnp.asarray(s),
                                        jnp.asarray(d), jnp.asarray(i))
        if bool(rot):
            touched.clear()
            dirty_touched.clear()
        else:
            touched.add(s)
            if d:
                dirty_touched.add(s)
        assert int(st_.superset_counter) == len(touched)
        assert int(st_.dirty_counter) >= len(dirty_touched) - 1  # rotate timing
        assert int(st_.dirty_counter) <= cfg.dc_limit


# ---------------------------------------------------------------------------
# Differential tests: the batched device ops (record_writes /
# window_would_exceed) against the host per-write loop — the serving path
# and the simulator must be ONE wear implementation, step for step.
# ---------------------------------------------------------------------------

def _random_trace(rng, n, n_supersets):
    ss = rng.integers(0, n_supersets, n).astype(np.int32)
    dirty = rng.integers(0, 2, n).astype(bool)
    cycles = np.cumsum(rng.integers(0, 40, n)).astype(np.int32)
    return ss, dirty, cycles


def _host_loop(cfg, ss, dirty, cycles):
    """One record_write dispatch per trace element — the per-write host
    reference (jitted per step so the loop is affordable; the semantics
    under test are unchanged)."""
    step = jax.jit(lambda st, s, d, c: wear.record_write(st, cfg, s, d, c))
    st = wear.init_state(cfg)
    rots, fls = [], []
    for s, d, c in zip(ss, dirty, cycles):
        st, rot, fl = step(st, jnp.asarray(int(s)), jnp.asarray(bool(d)),
                           jnp.asarray(int(c)))
        rots.append(bool(rot))
        fls.append(int(fl))
    return st, np.asarray(rots), np.asarray(fls)


def _assert_states_equal(a: wear.WearState, b: wear.WearState):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_writes=st.integers(1, 80))
def test_record_writes_matches_host_loop(seed, n_writes):
    """Device batched trace == host record_write loop, step for step:
    per-step rotate/flush outputs and every final-state leaf (small
    dc_limit + t_MWW window so rotations AND locks fire inside the
    trace)."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(n_supersets=8, dc_limit=3, t_mww_cycles=64,
               blocks_per_superset=2, m_writes=1)
    ss, dirty, cycles = _random_trace(rng, n_writes, 8)
    want_st, want_rot, want_fl = _host_loop(cfg, ss, dirty, cycles)
    got_st, got_rot, got_fl = wear.record_writes_device(
        wear.init_state(cfg), cfg, ss, dirty, cycles)
    np.testing.assert_array_equal(np.asarray(got_rot), want_rot)
    np.testing.assert_array_equal(np.asarray(got_fl), want_fl)
    _assert_states_equal(got_st, want_st)
    # internal accounting closes: outputs sum to the state totals
    assert int(got_st.total_rotates) == int(want_rot.sum())
    assert int(got_st.total_flushed) == int(want_fl.sum())


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31))
def test_record_writes_active_mask_skips_padding(seed):
    """Inactive (padding) lanes are exact no-ops: a masked batch equals the
    host loop over only the active subtrace — the pow2-bucketed admission
    pipeline depends on this."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(n_supersets=4, dc_limit=4, t_mww_cycles=128,
               blocks_per_superset=2, m_writes=1)
    n = 32
    ss, dirty, cycles = _random_trace(rng, n, 4)
    active = rng.integers(0, 2, n).astype(bool)
    got_st, got_rot, got_fl = wear.record_writes_device(
        wear.init_state(cfg), cfg, ss, dirty, cycles, active)
    want_st, want_rot, _ = _host_loop(
        cfg, ss[active], dirty[active], cycles[active])
    _assert_states_equal(got_st, want_st)
    np.testing.assert_array_equal(np.asarray(got_rot)[active], want_rot)
    assert not np.asarray(got_rot)[~active].any()
    assert not np.asarray(got_fl)[~active].any()


def test_window_would_exceed_matches_lock_semantics():
    """would_exceed is the reject-before-write twin of the lock-after-
    overflow accounting: it fires exactly when one more record_write would
    set the lock."""
    cfg = _cfg(n_supersets=2, m_writes=1, blocks_per_superset=2,
               t_mww_cycles=100)   # budget = 2 writes / window
    st_ = wear.init_state(cfg)
    s = jnp.asarray(0)
    for i in range(2):
        assert not bool(wear.window_would_exceed(st_, cfg, s, jnp.asarray(i)))
        st_, _, _ = wear.record_write(st_, cfg, s, jnp.asarray(False),
                                      jnp.asarray(i))
    # third write would blow the budget -> predicate fires BEFORE the write
    assert bool(wear.window_would_exceed(st_, cfg, s, jnp.asarray(2)))
    assert not bool(wear.is_locked(st_, s, jnp.asarray(2)))
    # window rollover clears the predicate
    assert not bool(wear.window_would_exceed(st_, cfg, s, jnp.asarray(250)))
    # WearDyn parameterization gives the same answer as the WearConfig
    assert bool(wear.window_would_exceed(st_, wear.dyn_of(cfg), s,
                                         jnp.asarray(2)))


def test_record_writes_total_write_conservation():
    """Write accounting is conserved across rotations: every applied write
    lands in exactly one inter-rotation segment (write_counter resets on
    rotate, so segments + final counter must sum to the trace length)."""
    rng = np.random.default_rng(7)
    cfg = _cfg(n_supersets=8, dc_limit=2, t_mww_cycles=1 << 20)
    n = 64
    ss, dirty, cycles = _random_trace(rng, n, 8)
    dirty[:] = True                       # every write dirties -> rotations
    st_, rots, _ = wear.record_writes_device(
        wear.init_state(cfg), cfg, ss, dirty, cycles)
    rots = np.asarray(rots)
    # each rotate closes a segment; counters reset to 0 at each rotation.
    # Segment lengths sum to n: (writes since last rotate) + (full
    # segments) account for every write exactly once.
    seg_ends = np.nonzero(rots)[0]
    writes_in_segments = 0
    prev = -1
    for e in seg_ends:
        writes_in_segments += e - prev
        prev = e
    assert writes_in_segments + int(st_.write_counter) == n
    assert int(st_.total_rotates) == len(seg_ends)


def test_rebase_clock_preserves_decisions():
    """Shifting clock + stored timestamps together is an exact no-op for
    every window/lock decision (the int32 wrap guard for long-lived
    serving op counters)."""
    cfg = _cfg(n_supersets=2, m_writes=1, blocks_per_superset=2,
               t_mww_cycles=100)   # budget = 2 writes / window
    st_ = wear.init_state(cfg)
    for c in (40, 41):
        st_, _, _ = wear.record_write(st_, cfg, jnp.asarray(0),
                                      jnp.asarray(False), jnp.asarray(c))
    shifted = wear.rebase_clock(st_, 30)
    for cyc in (42, 90, 139, 141, 400):    # in-window, edge, expired
        want = bool(wear.window_would_exceed(st_, cfg, jnp.asarray(0),
                                             jnp.asarray(cyc)))
        got = bool(wear.window_would_exceed(shifted, cfg, jnp.asarray(0),
                                            jnp.asarray(cyc - 30)))
        assert got == want, cyc
        assert (bool(wear.is_locked(shifted, jnp.asarray(0),
                                    jnp.asarray(cyc - 30)))
                == bool(wear.is_locked(st_, jnp.asarray(0),
                                       jnp.asarray(cyc))))
    # never-written supersets floor out instead of underflowing
    many = wear.rebase_clock(wear.rebase_clock(st_, wear.CLOCK_REBASE_AT),
                             wear.CLOCK_REBASE_AT)
    assert int(many.window_start.min()) >= -wear.CLOCK_REBASE_AT


# ---------------------------------------------------------------------------
# One-implementation wiring: hashtable inserts and flat-CAM command traces
# feed the same wear machinery.
# ---------------------------------------------------------------------------

def test_hashtable_inserts_feed_shared_wear_ops():
    from repro.apps.hashtable import HopscotchTable
    cfg = _cfg(n_supersets=8, dc_limit=1 << 20, wc_limit=1 << 20,
               t_mww_cycles=1 << 20, blocks_per_superset=64)
    t = HopscotchTable(8, window=16, wear_cfg=cfg)
    rng = np.random.default_rng(3)
    for k in rng.integers(1, 1 << 40, 150):
        t.insert(int(k), 1)
    rep = t.wear_report()
    # every stats.write was charged to the wear state (device counter) and
    # to the per-superset snapshot
    assert rep["writes_total"] == t.stats.writes
    assert int(t.wear_state.write_counter) == t.stats.writes
    assert t.writes_per_superset.sum() == t.stats.writes
    # the snapshot drives the same Fig. 11 lifetime estimator
    lt = t.lifetime_estimate()
    assert 0 < lt.years <= lt.ideal_years * 1.0001


def test_cam_data_write_tracked_charges_trace_and_wear():
    from repro.core import controller
    cfg = _cfg(n_supersets=4, t_mww_cycles=1 << 20)
    st_ = controller.init_flat_cam(n_sets=2, rows=16, cols=32)
    ws = wear.init_state(cfg)
    key = jnp.ones(16, jnp.int8)
    st_, ws, rot, counts = controller.cam_data_write_tracked(
        st_, ws, cfg, 0, 5, key, superset=2, cycle=0)
    assert int(counts.writes) == 1         # command trace charged
    assert int(ws.write_counter) == 1      # same event recorded as wear
    assert int(ws.swt_w[2]) == 1
    assert not bool(rot)
    assert int(st_.sets_bits[0, 3, 5]) == 1


# ---------------------------------------------------------------------------
# D/R install filter (§8 "Mitigating Writes").
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,r,install,forward", [
    (True, True, True, False),    # D&R: install
    (True, False, False, True),   # D&!R: forward to DRAM
    (False, True, True, False),   # !D&R: install read-only
    (False, False, False, False),  # !D&!R: drop
])
def test_install_decision_truth_table(d, r, install, forward):
    i, f = wear.install_decision(jnp.asarray(d), jnp.asarray(r))
    assert bool(i) == install and bool(f) == forward


# ---------------------------------------------------------------------------
# Lifetime replay (§10.3).
# ---------------------------------------------------------------------------

def test_lifetime_rotation_beats_no_rotation():
    from repro.core import lifetime
    w = np.zeros(64)
    w[:4] = 1000.0  # concentrated writes
    res = lifetime.estimate_lifetime(w, epoch_cycles=1e9,
                                     rotations_per_epoch=4)
    # rotation spreads the hot supersets -> years must beat the static map
    static_years = lifetime.estimate_lifetime(
        w, epoch_cycles=1e9, rotations_per_epoch=4,
        endurance=1e8).max_cell_writes_per_epoch
    assert res.years <= res.ideal_years           # never beats ideal
    assert res.years > 0
    # even distribution: rotation == ideal
    res_even = lifetime.estimate_lifetime(np.ones(64), epoch_cycles=1e9)
    assert res_even.years == pytest.approx(res_even.ideal_years, rel=0.01)
