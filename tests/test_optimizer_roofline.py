"""Optimizer + roofline-analysis unit tests."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline import analysis, jaxpr_cost
from repro.train import optimizer as opt


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    cfg = opt.OptConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                        weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([[1.0, -2.0], [3.0, 0.5]], jnp.float32)
    params = {"w": jnp.zeros((2, 2), jnp.float32)}
    state = opt.init_opt_state(params)
    for _ in range(150):
        grads = {"w": params["w"] - target}
        params, state, metrics = opt.adamw_update(cfg, params, state, grads)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)
    assert float(metrics["grad_norm"]) < 1.0


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(10 * 100.0 ** 2), rel=1e-5)
    assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    # under the limit: untouched
    g2 = {"a": jnp.full((4,), 0.1)}
    c2, _ = opt.clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.1, rtol=1e-6)


def test_lr_schedule_shape():
    cfg = opt.OptConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
    lrs = [float(opt.schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=1e-5)
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-2)   # min_lr_ratio floor
    assert all(b <= a * 1.0001 for a, b in zip(lrs[2:], lrs[3:]))  # decays


def test_no_weight_decay_on_norms():
    cfg = opt.OptConfig(peak_lr=0.0, weight_decay=1.0)  # lr=0: pure decay=0
    params = {"ln1": jnp.ones((4,)), "wq": jnp.ones((4, 4))}
    state = opt.init_opt_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = opt.adamw_update(cfg, params, state, zero_g)
    np.testing.assert_array_equal(np.asarray(new["ln1"]), 1.0)


# ---------------------------------------------------------------------------
# Roofline: HLO collective parsing.
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
HloModule jit_step

%fused (a: f32[8,16]) -> f32[8,16] {
  ROOT %x = f32[8,16] parameter(0)
}

%body (p: (s32[], bf16[4,128])) -> (s32[], bf16[4,128]) {
  %p = (s32[], bf16[4,128]) parameter(0)
  %g = bf16[4,128]{1,0} get-tuple-element(%p), index=1
  %ag = bf16[8,128]{1,0} all-gather(%g), replica_groups={}, dimensions={0}
  %ar = bf16[4,128]{1,0} all-reduce(%g), to_apply=%fused
  ROOT %t = (s32[], bf16[4,128]) tuple(%i, %ar)
}

%cond (p: (s32[], bf16[4,128])) -> pred[] {
  %p = (s32[], bf16[4,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: bf16[4,128]) -> bf16[4,128] {
  %a = bf16[4,128]{1,0} parameter(0)
  %rs = bf16[2,128]{1,0} reduce-scatter(%a), dimensions={0}, to_apply=%fused
  %w = (s32[], bf16[4,128]) while(%init), condition=%cond, body=%body
  ROOT %r = bf16[4,128]{1,0} get-tuple-element(%w), index=1
}
"""


def test_collective_bytes_with_loop_trip_counts():
    out = analysis.collective_bytes(HLO_SAMPLE)
    # reduce-scatter outside the loop: 2*128*2B = 512
    assert out["reduce-scatter"] == 2 * 128 * 2
    # all-gather inside the 10-trip while: 8*128*2B * 10
    assert out["all-gather"] == 8 * 128 * 2 * 10
    assert out["all-reduce"] == 4 * 128 * 2 * 10
    assert out["total"] == (out["all-gather"] + out["all-reduce"]
                            + out["reduce-scatter"])


def test_collective_parser_ignores_instruction_names():
    """Instruction NAMES containing collective substrings must not count."""
    hlo = """
ENTRY %main (a: f32[4]) -> f32[4] {
  %all-reduce-start.1 = f32[4]{0} add(%a, %a)
  ROOT %r = f32[4]{0} negate(%all-reduce-start.1)
}
"""
    out = analysis.collective_bytes(hlo)
    assert out["total"] == 0


def test_roofline_analyze_terms():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"total": 50e9 * 0.5, "all-gather": 50e9 * 0.5, "all-reduce": 0,
            "reduce-scatter": 0, "all-to-all": 0, "collective-permute": 0}
    r = analysis.analyze(cost, coll, model_flops_per_device=100e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_ratio == pytest.approx(100e12 / 197e12)


def test_model_flops_dense_vs_moe():
    from repro import configs
    dense = configs.get_arch("yi-9b")
    moe = configs.get_arch("qwen3-moe-30b-a3b")
    shape = configs.get_shape("train_4k")
    fd = analysis.model_flops(dense, shape, 256)
    fm = analysis.model_flops(moe, shape, 256)
    n_active = analysis.active_param_count(moe)
    n_total_experts = (moe.n_experts * moe.moe_d_ff * moe.d_model
                       * 3 * moe.n_layers)
    # active fraction: top-8 of 128 experts
    assert n_active < n_total_experts
    assert fd > 0 and fm > 0


# ---------------------------------------------------------------------------
# jaxpr trip-count FLOP correction.
# ---------------------------------------------------------------------------

def test_jaxpr_flops_matmul_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    assert jaxpr_cost.step_flops(f, a, b) == 2 * 64 * 32 * 16


def test_jaxpr_flops_counts_scan_trips():
    def f(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    assert jaxpr_cost.step_flops(f, x) == 10 * 2 * 16 ** 3


def test_jaxpr_flops_recurses_remat():
    def f(x):
        @jax.checkpoint
        def g(y):
            return y @ y
        return g(x)
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    assert jaxpr_cost.step_flops(f, x) == 2 * 8 ** 3
