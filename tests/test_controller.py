"""Vault-controller semantics (paper §6.2/§7): mode toggling command
counts, lazy key/mask push, fresh-match-register reuse, cache-mode engine,
and the Fig. 6 user-space API flow."""
from __future__ import annotations

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import controller as ctl
from repro.core.api import MonarchDevice


def _bits(word: int, n: int = 64) -> jnp.ndarray:
    return jnp.asarray([(word >> i) & 1 for i in range(n)], jnp.int8)


# ---------------------------------------------------------------------------
# flat-CAM controller.
# ---------------------------------------------------------------------------

def test_initial_mode_is_ram_rowin():
    st = ctl.init_flat_cam()
    assert int(st.bank_mode) == ctl.RAM
    assert int(st.datapath) == ctl.ROW_IN


def test_cam_write_toggles_modes_once():
    st = ctl.init_flat_cam()
    st, c = ctl.cam_data_write(st, jnp.asarray(0), jnp.asarray(3), _bits(0xAB))
    # from RAM/RowIn we need 1 prepare (RAM->CAM) + 1 activate (RowIn->ColIn)
    assert int(c.prepares) == 1 and int(c.activates) == 1
    assert int(c.writes) == 1
    # a second write needs no further toggling
    st, c2 = ctl.cam_data_write(st, jnp.asarray(0), jnp.asarray(4), _bits(0xCD))
    assert int(c2.prepares) == 0 and int(c2.activates) == 0


def test_key_mask_write_row_parity():
    """RowIn CAM: even row address -> key register, odd -> mask (§6.2)."""
    st = ctl.init_flat_cam()
    st, _ = ctl.key_mask_write(st, jnp.asarray(2), _bits(0x1234))
    np.testing.assert_array_equal(np.asarray(st.key_reg),
                                  np.asarray(_bits(0x1234)))
    st, _ = ctl.key_mask_write(st, jnp.asarray(3), _bits(0xFF))
    np.testing.assert_array_equal(np.asarray(st.mask_reg),
                                  np.asarray(_bits(0xFF)))
    # key survived the mask write
    np.testing.assert_array_equal(np.asarray(st.key_reg),
                                  np.asarray(_bits(0x1234)))


def test_search_lazy_km_push_and_fresh_reuse():
    st = ctl.init_flat_cam(n_sets=2)
    st, _ = ctl.cam_data_write(st, jnp.asarray(0), jnp.asarray(7), _bits(0x77))
    st, _ = ctl.key_mask_write(st, jnp.asarray(0), _bits(0x77))
    st, _ = ctl.key_mask_write(st, jnp.asarray(1), _bits((1 << 64) - 1))

    st, idx, c = ctl.search_read(st, jnp.asarray(0))
    assert int(idx) == 7
    assert int(c.searches) == 1
    assert int(c.writes) == 1          # key/mask pushed down once
    # fresh result: NO new search, NO new km push
    st, idx2, c2 = ctl.search_read(st, jnp.asarray(0))
    assert int(idx2) == 7
    assert int(c2.searches) == 0 and int(c2.writes) == 0


def test_search_no_match_is_null():
    st = ctl.init_flat_cam(n_sets=1)
    st, _ = ctl.key_mask_write(st, jnp.asarray(0), _bits(0xDEAD))
    st, idx, _ = ctl.search_read(st, jnp.asarray(0))
    assert int(idx) == -1              # match register resets to NULL


def test_data_write_invalidates_match_register():
    st = ctl.init_flat_cam(n_sets=1)
    st, _ = ctl.cam_data_write(st, jnp.asarray(0), jnp.asarray(3), _bits(5))
    st, _ = ctl.key_mask_write(st, jnp.asarray(0), _bits(5))
    st, idx, _ = ctl.search_read(st, jnp.asarray(0))
    assert int(idx) == 3
    st, _ = ctl.cam_data_write(st, jnp.asarray(0), jnp.asarray(3), _bits(6))
    st, idx2, c = ctl.search_read(st, jnp.asarray(0))
    assert int(c.searches) == 1        # stale -> re-search
    assert int(idx2) == -1


# ---------------------------------------------------------------------------
# Cache-mode engine.
# ---------------------------------------------------------------------------

def test_cache_lookup_hit_miss():
    st = ctl.init_cache(n_sets=4, ways=8)
    hit, _ = ctl.cache_lookup(st, jnp.asarray(1), jnp.asarray(42))
    assert not bool(hit)
    st, ev, way = ctl.cache_install(st, jnp.asarray(1), jnp.asarray(42),
                                    jnp.asarray(False))
    assert not bool(ev)
    hit, w = ctl.cache_lookup(st, jnp.asarray(1), jnp.asarray(42))
    assert bool(hit) and int(w) == int(way)
    # same tag in a different set is a miss
    hit2, _ = ctl.cache_lookup(st, jnp.asarray(0), jnp.asarray(42))
    assert not bool(hit2)


def test_cache_install_prefers_invalid_then_clean():
    st = ctl.init_cache(n_sets=1, ways=4)
    s = jnp.asarray(0)
    for t in range(4):
        st, ev, _ = ctl.cache_install(st, s, jnp.asarray(t + 1),
                                      jnp.asarray(t < 2))  # tags 1,2 dirty
        assert not bool(ev)            # invalid ways available -> no eviction
    # set full: 1,2 dirty; 3,4 clean -> a clean way must be chosen
    st, ev, way = ctl.cache_install(st, s, jnp.asarray(99), jnp.asarray(False))
    assert not bool(ev)
    assert int(st.dirty[0, way]) == 0 or int(st.tags[0, way]) == 99
    # make everything dirty, then install -> dirty eviction reported
    st2 = ctl.CacheState(tags=st.tags, valid=st.valid,
                         dirty=jnp.ones_like(st.dirty), counter=st.counter)
    st2, ev2, _ = ctl.cache_install(st2, s, jnp.asarray(100),
                                    jnp.asarray(True))
    assert bool(ev2)


def test_cache_counter_advances():
    st = ctl.init_cache(n_sets=1, ways=4)
    c0 = int(st.counter)
    st, _, _ = ctl.cache_install(st, jnp.asarray(0), jnp.asarray(5),
                                 jnp.asarray(False))
    assert int(st.counter) == c0 + 1   # free-running counter (§8)


def test_cache_invalidate_sets_counts_dirty():
    st = ctl.init_cache(n_sets=2, ways=4)
    for t in range(3):
        st, _, _ = ctl.cache_install(st, jnp.asarray(0), jnp.asarray(t + 1),
                                     jnp.asarray(True))
    st, _, _ = ctl.cache_install(st, jnp.asarray(1), jnp.asarray(9),
                                 jnp.asarray(False))
    mask = jnp.asarray([True, True])
    st2, flushed = ctl.cache_invalidate_sets(st, mask)
    assert int(flushed) == 3
    assert int(jnp.sum(st2.valid)) == 0


# ---------------------------------------------------------------------------
# Fig. 6 user-space API (MonarchDevice).
# ---------------------------------------------------------------------------

def test_fig6_kv_store_flow():
    dev = MonarchDevice(n_sets=2, key_bits=64, set_cols=8)
    keys = dev.flat_cam_malloc(8)
    data = dev.flat_ram_malloc(8)
    for i, (k, v) in enumerate([(0xAAA, 111), (0xBBB, 222), (0xCCC, 333)]):
        dev.cam_write(keys, i, k)
        dev.ram_write(data, i, v)
    assert dev.kv_lookup(keys, data, 0xBBB) == 222
    assert dev.kv_lookup(keys, data, 0xDDD) is None


def test_fig6_masked_partial_search():
    """Setting the mask to a byte selects matches on that byte only
    (paper: mask 0x0FF00 searches the second byte)."""
    dev = MonarchDevice(n_sets=1, key_bits=64, set_cols=8)
    keys = dev.flat_cam_malloc(8)
    data = dev.flat_ram_malloc(8)
    dev.cam_write(keys, 0, 0x12_34)
    dev.ram_write(data, 0, 999)
    # full-key lookup with wrong low byte misses...
    assert dev.kv_lookup(keys, data, 0x12_99) is None
    # ...but masking to the second byte hits
    assert dev.kv_lookup(keys, data, 0x12_00, mask=0xFF00) == 999


def test_api_search_elision_visible_in_command_log():
    dev = MonarchDevice(n_sets=1, key_bits=64, set_cols=8)
    keys = dev.flat_cam_malloc(8)
    dev.cam_write(keys, 2, 0x42)
    dev.write_key(0x42)
    m1 = dev.read_match(keys)
    searches_1 = sum(1 for c in dev.command_log if c.startswith("S "))
    m2 = dev.read_match(keys)          # fresh -> elided
    searches_2 = sum(1 for c in dev.command_log if c.startswith("S "))
    assert m1 == m2 == 2
    assert searches_1 == searches_2 == 1


def test_api_malloc_exhaustion():
    dev = MonarchDevice(n_sets=1, key_bits=64, set_cols=8)
    dev.flat_cam_malloc(8)
    with pytest.raises(MemoryError):
        dev.flat_cam_malloc(1)
