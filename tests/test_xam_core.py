"""Bit-accuracy tests for the XAM array model and the Monarch address
geometry (paper §4, §6)."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import geometry, xam


# ---------------------------------------------------------------------------
# XAM array: writes.
# ---------------------------------------------------------------------------

def test_write_row_then_read(rng):
    arr = xam.make_array(16, 32)
    data = jnp.asarray(rng.integers(0, 2, 32), jnp.int8)
    arr = xam.write_row(arr, jnp.asarray(3), data)
    np.testing.assert_array_equal(np.asarray(xam.read_row(arr, jnp.asarray(3))),
                                  np.asarray(data))
    # other rows untouched (V/2 half-select discipline)
    assert int(jnp.sum(jnp.abs(arr.bits))) == int(jnp.sum(data))


def test_write_col_then_read(rng):
    arr = xam.make_array(16, 32)
    data = jnp.asarray(rng.integers(0, 2, 16), jnp.int8)
    arr = xam.write_col(arr, jnp.asarray(5), data)
    np.testing.assert_array_equal(np.asarray(arr.bits[:, 5]), np.asarray(data))
    assert int(jnp.sum(jnp.abs(arr.bits))) == int(jnp.sum(data))


def test_two_step_write_discipline(rng):
    """Step 1 touches exactly the 0-cells of the active line, step 2 exactly
    the 1-cells; the two steps partition the line (§4.1)."""
    arr = xam.make_array(8, 8)
    data = jnp.asarray(rng.integers(0, 2, 8), jnp.int8)
    _, s0, s1 = xam.write_row_steps(arr, jnp.asarray(2), data)
    s0, s1 = np.asarray(s0), np.asarray(s1)
    assert (s0 * s1).sum() == 0                      # disjoint
    line = s0[2] + s1[2]
    np.testing.assert_array_equal(line, np.ones(8))  # covers the line
    assert s0.sum() == (1 - np.asarray(data)).sum()
    assert s1.sum() == np.asarray(data).sum()
    assert s0[[0, 1, 3, 4, 5, 6, 7]].sum() == 0      # inactive rows untouched

    _, c0, c1 = xam.write_col_steps(arr, jnp.asarray(4), data)
    c0, c1 = np.asarray(c0), np.asarray(c1)
    assert (c0 * c1).sum() == 0
    np.testing.assert_array_equal(c0[:, 4] + c1[:, 4], np.ones(8))
    assert c0[:, [0, 1, 2, 3, 5, 6, 7]].sum() == 0


def test_row_col_write_equivalence(rng):
    """Writing the same bit pattern row-wise and column-wise produces the
    same cell states (§4.1.2: 'writing a 0 row-wise and column-wise produce
    the same cell state')."""
    bits = rng.integers(0, 2, (8, 8)).astype(np.int8)
    a = xam.make_array(8, 8)
    for r in range(8):
        a = xam.write_row(a, jnp.asarray(r), jnp.asarray(bits[r]))
    b = xam.make_array(8, 8)
    for c in range(8):
        b = xam.write_col(b, jnp.asarray(c), jnp.asarray(bits[:, c]))
    np.testing.assert_array_equal(np.asarray(a.bits), np.asarray(b.bits))


def test_wear_counts_full_line(rng):
    """Constant-write-voltage assumption: every cell of the active line
    takes a pulse per write, regardless of value change."""
    arr = xam.make_array(8, 8)
    arr = xam.write_row(arr, jnp.asarray(1), jnp.zeros(8, jnp.int8))
    arr = xam.write_row(arr, jnp.asarray(1), jnp.zeros(8, jnp.int8))
    arr = xam.write_col(arr, jnp.asarray(2), jnp.ones(8, jnp.int8))
    w = np.asarray(arr.cell_writes)
    assert (w[1] >= 2).all()
    assert w[1, 2] == 3          # row writes + the col write
    assert w[0, 0] == 0


# ---------------------------------------------------------------------------
# XAM search: analog threshold model pinned to digital semantics.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2 ** 31), rows=st.integers(1, 64),
       cols=st.integers(1, 64))
def test_search_analog_equals_digital(seed, rows, cols):
    rng = np.random.default_rng(seed)
    arr = xam.XamArray(
        bits=jnp.asarray(rng.integers(0, 2, (rows, cols)), jnp.int8),
        cell_writes=jnp.zeros((rows, cols), jnp.int32))
    key = jnp.asarray(rng.integers(0, 2, rows), jnp.int8)
    mask = jnp.asarray(rng.integers(0, 2, rows), jnp.int8)
    analog = np.asarray(xam.search(arr, key, mask))
    digital = np.asarray(xam.search_digital(arr, key, mask))
    np.testing.assert_array_equal(analog, digital)


def test_ref_s_sits_between_match_and_single_mismatch():
    """Ref_S must separate all-match from single-mismatch for any n."""
    for n in (1, 2, 8, 64, 512):
        n_sel = jnp.asarray(n)
        all_match_v = 1.0
        one_miss_v = 1.0 - 1.0 / n
        ref = float(xam.ref_s(n_sel))
        assert one_miss_v < ref < all_match_v


def test_set_search_match_register():
    arr = xam.make_set(8, 32)
    key = jnp.asarray([1, 0, 1, 1, 0, 0, 1, 0], jnp.int8)
    arr = xam.store_key_colwise(arr, jnp.asarray(20), key)
    matches, idx = xam.set_search(arr, key, jnp.ones(8, jnp.int8))
    assert int(idx) == 20
    # no-match resets the register to NULL (-1)
    _, idx2 = xam.set_search(arr, 1 - key, jnp.ones(8, jnp.int8))
    assert int(idx2) == -1


# ---------------------------------------------------------------------------
# Geometry: diagonal sets, address mapping, rotary offsets.
# ---------------------------------------------------------------------------

def test_diagonal_set_layout():
    """(i, j) belongs to set (j - i) % 8; every set selects one subarray per
    grid row and per grid column (Fig. 4)."""
    for k in range(8):
        subs = geometry.subarrays_of_set(k)
        assert len(subs) == 8
        rows = [i for i, _ in subs]
        cols = [j for _, j in subs]
        assert sorted(rows) == list(range(8))
        assert sorted(cols) == list(range(8))
        for i, j in subs:
            assert geometry.set_of_subarray(i, j) == k
    # all 64 subarrays covered exactly once across the 8 sets
    seen = {(i, j) for k in range(8) for i, j in geometry.subarrays_of_set(k)}
    assert len(seen) == 64


def test_port_select_modes():
    cols = geometry.port_select(3, mode_column_in=True)
    assert all(p == "col" for _, _, p in cols)
    rows = geometry.port_select(3, mode_column_in=False)
    assert all(p == "row" for _, _, p in rows)


def test_geometry_capacity():
    assert geometry.GEOM_8GB.capacity_bytes == 8 * 1024 ** 3
    g = geometry.GEOM_8GB.scaled(64)
    assert g.supersets_per_bank == 8
    assert g.capacity_bytes == geometry.GEOM_8GB.capacity_bytes // 64


@settings(max_examples=40, deadline=None)
@given(addr=st.integers(0, geometry.GEOM_8GB.total_blocks - 1))
def test_decompose_compose_roundtrip(addr):
    g = geometry.GEOM_8GB
    c = geometry.decompose(jnp.asarray(addr), g)
    back = int(geometry.compose(c, g))
    assert back == addr
    assert 0 <= int(c.vault) < g.n_vaults
    assert 0 <= int(c.bank) < g.banks_per_vault
    assert 0 <= int(c.superset) < g.supersets_per_bank
    assert 0 <= int(c.set_) < g.sets_per_superset
    assert 0 <= int(c.row) < g.rows_per_set


def test_rotary_offsets_prime_schedule():
    off = geometry.zero_offsets()
    for r in range(1, 17):
        off = geometry.apply_rotate(off)
        assert int(off.bank) == r * 1
        assert int(off.set_) == r * 3
        assert int(off.superset) == r * 7
        assert int(off.vault) == (r // 8) * 5   # every 8th rotate
    assert int(off.rotate_count) == 16


def test_rotation_is_permutation():
    """Offset remapping must be a bijection on block addresses (no two
    logical blocks land on the same physical block)."""
    g = geometry.GEOM_8GB.scaled(256)
    off = geometry.apply_rotate(geometry.apply_rotate(geometry.zero_offsets()))
    addrs = jnp.arange(g.total_blocks, dtype=jnp.int32)
    c = geometry.decompose(addrs, g, off)
    phys = np.asarray(geometry.compose(c, g))
    assert len(np.unique(phys)) == g.total_blocks


def test_ram_to_cam_mapping_unique():
    """Fig. 7: distinct RAM banks map to distinct (cam_bank, set, key_id)
    tag locations."""
    g = geometry.GEOM_8GB
    seen = set()
    for b in range(30):  # 30 RAM banks in the §7 example
        c = geometry.ram_to_cam(jnp.asarray(b), g)
        t = (int(c.bank), int(c.set_), int(c.key_id))
        assert t not in seen
        seen.add(t)
