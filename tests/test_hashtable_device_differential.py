"""Device-vs-host hopscotch backend differential.

``HopscotchTable(backend="device")`` replaces the numpy bucket store with
device-resident uint32 planes whose ``insert``/``delete`` run as single
donated device calls (``kernels.hopscotch.ops.hopscotch_insert_device``:
windowed scatter, hop-chain displacement as a bounded while-loop).  The
contract is BIT-IDENTITY with the host reference — same bucket contents,
same operation counts (probes/swaps/writes feed the §10.4 timing model),
same §8 wear trace — which this module pins over:

* randomized insert/delete/lookup schedules (duplicate-key value updates
  included), state compared after EVERY mutation;
* hop-chain saturation: tiny windows at high load force long forward
  walks and multi-hop displacement chains;
* table-full / failed-chain paths: both backends must rehash at the same
  op with the same partially-moved pre-rehash state folded in.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.apps.hashtable import HopscotchTable
from repro.core import wear


def _pair(log2_size: int, window: int, wear_on: bool = True):
    def mk(backend):
        wc = wear.WearConfig(n_supersets=8, t_mww_cycles=64,
                             blocks_per_superset=4) if wear_on else None
        return HopscotchTable(log2_size, window=window, wear_cfg=wc,
                              backend=backend)
    return mk("host"), mk("device")


def _assert_same(host: HopscotchTable, dev: HopscotchTable, msg: str):
    dev._sync_host()
    np.testing.assert_array_equal(host.keys, dev.keys, err_msg=f"{msg} keys")
    np.testing.assert_array_equal(host.vals, dev.vals, err_msg=f"{msg} vals")
    assert (dataclasses.astuple(host.stats)
            == dataclasses.astuple(dev.stats)), (msg, host.stats, dev.stats)
    assert host.n == dev.n, msg
    if host.wear_cfg is not None:
        assert host.wear_report() == dev.wear_report(), msg


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("window", [4, 8])
def test_randomized_schedule_bit_identical(seed, window):
    rng = np.random.default_rng(seed)
    host, dev = _pair(log2_size=6, window=window)
    universe = rng.choice(np.arange(1, 1 << 20, dtype=np.uint64),
                          size=90, replace=False)
    live: list[int] = []
    for step in range(140):
        op = rng.random()
        if op < 0.6 or not live:
            k = int(universe[rng.integers(0, universe.size)])
            v = int(rng.integers(1, 1 << 60))    # 64-bit value halves both
            assert host.insert(k, v) == dev.insert(k, v)
            if k not in live:
                live.append(k)
        elif op < 0.8:
            k = live.pop(rng.integers(0, len(live)))
            assert host.delete(k) == dev.delete(k), (step, k)
            # double delete: a clean miss on both backends
            assert host.delete(k) == dev.delete(k) is False
        else:
            q = rng.choice(universe, size=13)
            vh, hh = host.lookup_monarch(q)
            vd, hd = dev.lookup_monarch(q)
            np.testing.assert_array_equal(vh, vd, err_msg=str(step))
            np.testing.assert_array_equal(hh, hd)
        _assert_same(host, dev, f"seed={seed} step={step}")
    assert host.stats.inserts > 0 and host.stats.deletes > 0
    assert abs(host.load - dev.load) < 1e-12


def test_hop_chain_saturation_and_duplicate_updates():
    """window=4 at near-full load: inserts must displace multi-hop chains
    (swaps > 0) identically, and re-inserting a resident key must update
    the value in place on both backends without moving buckets."""
    host, dev = _pair(log2_size=5, window=4)
    keys = np.arange(1, 27, dtype=np.uint64) * np.uint64(0x9E3779B9)
    for k in keys:
        assert host.insert(int(k), int(k) ^ 0xFF) == \
            dev.insert(int(k), int(k) ^ 0xFF)
        _assert_same(host, dev, f"saturate k={k}")
    assert host.stats.swaps > 0            # chains actually exercised
    before = dataclasses.astuple(host.stats)
    for k in keys[:9]:                      # duplicate re-offers
        host.insert(int(k), 7)
        dev.insert(int(k), 7)
    _assert_same(host, dev, "dup updates")
    assert host.stats.swaps == before[6]    # value updates never displace
    va, ha = host.lookup_monarch(keys[:9])
    vb, hb = dev.lookup_monarch(keys[:9])
    assert ha.all() and hb.all()
    np.testing.assert_array_equal(va, np.full(9, 7, np.uint64))
    np.testing.assert_array_equal(vb, va)


def test_table_full_rehashes_identically():
    """Overfill a tiny table: both backends must take the rehash path at
    the same inserts (same grown size, same reinsert order -> identical
    final layout) including failed hop chains that leave partial moves."""
    host, dev = _pair(log2_size=3, window=2)   # n=8: fills immediately
    rng = np.random.default_rng(9)
    keys = np.unique(rng.integers(1, 1 << 30, size=60,
                                  dtype=np.uint64))[:40]
    for i, k in enumerate(keys):
        assert host.insert(int(k), i + 1) == dev.insert(int(k), i + 1)
        _assert_same(host, dev, f"fill i={i}")
    assert host.stats.rehashes >= 2
    assert host.n == dev.n > 8
    vh, hh = host.lookup_monarch(keys)
    vd, hd = dev.lookup_monarch(keys)
    assert hh.all() and hd.all()
    np.testing.assert_array_equal(vh, vd)


def test_device_backend_without_wear_tracking():
    """wear_cfg=None path: the insert op's write log is simply dropped."""
    host, dev = _pair(log2_size=5, window=8, wear_on=False)
    for k in range(1, 40):
        host.insert(k, k * 2)
        dev.insert(k, k * 2)
    _assert_same(host, dev, "no-wear")
    with pytest.raises(ValueError, match="wear"):
        dev.wear_report()


def test_plane_format_knob_is_validated_identity():
    """The serving stack's ``plane_format`` knob is accepted here for
    symmetry, but the hopscotch device planes ALREADY store 8 bits per
    byte (split uint32 words), so ``packed8`` must be a validated no-op:
    a packed8 device table replays a schedule bit-identically to the
    default — same buckets, same stats, same §8 wear trace."""
    wc = wear.WearConfig(n_supersets=8, t_mww_cycles=64,
                         blocks_per_superset=4)
    dev = HopscotchTable(6, window=8, wear_cfg=wc, backend="device")
    dev_p = HopscotchTable(6, window=8, wear_cfg=wc, backend="device",
                           plane_format="packed8")
    assert dev_p.plane_format == "packed8"
    rng = np.random.default_rng(3)
    keys = rng.choice(np.arange(1, 1 << 16, dtype=np.uint64), size=40,
                      replace=False)
    for i, k in enumerate(keys):
        assert dev.insert(int(k), i) == dev_p.insert(int(k), i)
    for k in keys[::3]:
        assert dev.delete(int(k)) == dev_p.delete(int(k))
    dev._sync_host()
    dev_p._sync_host()
    np.testing.assert_array_equal(dev.keys, dev_p.keys)
    np.testing.assert_array_equal(dev.vals, dev_p.vals)
    assert (dataclasses.astuple(dev.stats)
            == dataclasses.astuple(dev_p.stats))
    assert dev.wear_report() == dev_p.wear_report()


def test_constructor_knobs_raise_value_error():
    """Bad knob values raise ValueError naming the knob and the valid
    values — never a bare assert (``python -O`` elides those)."""
    with pytest.raises(ValueError, match="backend"):
        HopscotchTable(5, backend="gpu")
    with pytest.raises(ValueError, match="plane_format"):
        HopscotchTable(5, plane_format="packed16")


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
