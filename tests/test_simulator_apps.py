"""Trace simulator + application-level tests (hash table, string match,
KV index) — the paper's §9/§10 substrate."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import simulator
from repro.data import pipeline, traces
from repro.apps.hashtable import HopscotchTable
from repro.apps import stringmatch
from repro.serve.kv_index import KVIndexConfig, MonarchKVIndex


# ---------------------------------------------------------------------------
# Trace simulator.
# ---------------------------------------------------------------------------

def _small_cfgs():
    return simulator.baseline_configs(scale_blocks=1024)


def test_simulator_basic_invariants():
    cfgs = _small_cfgs()
    spec = traces.crono_nas_specs(cfgs["monarch_unbound"].inpkg_blocks,
                                  6_000)[0]
    addrs, wr = traces.generate(spec)
    for name in ("d_cache", "monarch_unbound"):
        res = simulator.simulate_trace(cfgs[name], addrs, wr)
        st = res.stats
        assert res.total_cycles > 0
        assert st["l3_hits"] + st["l3_misses"] == len(addrs)
        assert st["inpkg_hits"] + st["inpkg_misses"] <= st["l3_misses"]
        assert 0.0 <= res.inpkg_hit_rate <= 1.0
        assert res.energy_nj > 0
    # Monarch uses searches for tags; DRAM uses reads
    rm = simulator.simulate_trace(cfgs["monarch_unbound"], addrs, wr)
    rd = simulator.simulate_trace(cfgs["d_cache"], addrs, wr)
    assert rm.stats["inpkg_searches"] > 0
    assert rd.stats["inpkg_searches"] == 0


def test_simulator_ideal_dram_not_slower():
    """Removing P/A/refresh can only help."""
    cfgs = _small_cfgs()
    spec = traces.crono_nas_specs(cfgs["d_cache"].inpkg_blocks, 6_000)[5]
    addrs, wr = traces.generate(spec)
    t_real = simulator.simulate_trace(cfgs["d_cache"], addrs, wr).total_cycles
    t_ideal = simulator.simulate_trace(cfgs["d_cache_ideal"], addrs,
                                       wr).total_cycles
    assert t_ideal <= t_real * 1.001


def test_simulator_wear_rotation_fires():
    cfgs = _small_cfgs()
    cfg = dataclasses.replace(cfgs["monarch_m3"], l3_sets=16, dc_limit=3,
                              t_mww_cycles=1 << 14, window_budget_blocks=16)
    spec = traces.crono_nas_specs(cfg.inpkg_blocks, 8_000)[0]
    addrs, wr = traces.generate(spec)
    res, st = simulator.simulate_trace(cfg, addrs, wr, return_state=True)
    assert res.stats["rotates"] > 0
    assert res.stats["flushed_dirty"] >= res.stats["rotates"]  # DC=3 trigger
    assert int(np.asarray(st.wear.offsets.rotate_count)) == res.stats["rotates"]
    # way-level writes recorded
    assert np.asarray(st.set_way_writes).sum() == res.stats["inpkg_writes"]


def test_simulator_m1_locks_more_than_m4():
    cfgs = _small_cfgs()
    spec = traces.crono_nas_specs(cfgs["monarch_m1"].inpkg_blocks, 8_000)[0]
    addrs, wr = traces.generate(spec)
    res = {}
    for m in (1, 4):
        cfg = dataclasses.replace(
            cfgs[f"monarch_m{m}"], l3_sets=16, dc_limit=512,
            t_mww_cycles=(1 << 13) * m, window_budget_blocks=16)
        res[m] = simulator.simulate_trace(cfg, addrs, wr)
    assert res[1].stats["locked_bypass"] >= res[4].stats["locked_bypass"]


def test_trace_signatures():
    specs = traces.crono_nas_specs(1024, 4_000)
    assert len(specs) == 11
    names = {s.name for s in specs}
    assert names == {"BC", "BFS", "COM", "CON", "DFS", "PR", "SSSP", "TRI",
                     "FT", "CG", "EP"}
    ep = next(s for s in specs if s.name == "EP")
    assert ep.write_frac >= 0.5          # paper: EP is the write-heavy one
    for s in specs:
        addrs, wr = traces.generate(s)
        assert len(addrs) == 4_000
        assert addrs.max() < s.footprint_blocks
        assert 0 <= wr.mean() <= s.write_frac + 0.05


# ---------------------------------------------------------------------------
# Hopscotch hash table.
# ---------------------------------------------------------------------------

def test_hopscotch_insert_lookup_vs_dict(rng):
    t = HopscotchTable(10, window=16)
    ref = {}
    keys = rng.integers(1, 2 ** 60, 600).astype(np.uint64)
    for i, k in enumerate(keys):
        t.insert(int(k), i)
        ref[int(k)] = i
    vals, hits = t.lookup_monarch(keys)
    assert hits.all()
    np.testing.assert_array_equal(vals, [ref[int(k)] for k in keys])
    # misses
    miss_keys = rng.integers(2 ** 61, 2 ** 62, 100).astype(np.uint64)
    _, mhits = t.lookup_monarch(miss_keys)
    assert not mhits.any()


def test_hopscotch_update_existing():
    t = HopscotchTable(8, window=8)
    t.insert(42, 1)
    t.insert(42, 2)
    vals, hits = t.lookup_monarch(np.asarray([42], np.uint64))
    assert hits[0] and vals[0] == 2


def test_hopscotch_rehash_under_pressure(rng):
    t = HopscotchTable(6, window=4)   # 64 slots, tiny window -> rehashes
    keys = rng.integers(1, 2 ** 50, 80).astype(np.uint64)
    for i, k in enumerate(keys):
        assert t.insert(int(k), i)
    assert t.n > 64                    # grew
    vals, hits = t.lookup_monarch(keys)
    assert hits.all()


def test_hopscotch_window_invariant(rng):
    """Every stored key sits within its home window (the hopscotch rule —
    what makes the single-search lookup correct)."""
    t = HopscotchTable(9, window=8)
    keys = rng.integers(1, 2 ** 50, 300).astype(np.uint64)
    for i, k in enumerate(keys):
        t.insert(int(k), i)
    occupied = np.nonzero(t.keys != 0)[0]
    homes = t.home(t.keys[occupied])
    off = occupied - homes
    assert (off >= 0).all() and (off < t.window).all()


# ---------------------------------------------------------------------------
# String match app.
# ---------------------------------------------------------------------------

def test_stringmatch_find(rng):
    corpus = stringmatch.make_corpus(1 << 14, seed=3)
    pat = bytes(corpus[500:512])
    rep = stringmatch.find(corpus, pat)
    # cross-check with python
    raw = bytes(corpus)
    n_py = 0
    i = raw.find(pat)
    while i != -1:
        n_py += 1
        i = raw.find(pat, i + 1)
    assert rep.n_matches == n_py
    assert rep.n_matches >= 1


# ---------------------------------------------------------------------------
# MonarchKVIndex (framework integration of the paper's policies).
# ---------------------------------------------------------------------------

def test_kv_index_no_allocate_then_admit(rng):
    idx = MonarchKVIndex(KVIndexConfig(n_sets=4, admit_after_reads=1))
    toks = rng.integers(1, 1000, (2, 64)).astype(np.int32)
    assert not idx.lookup(toks).any()          # cold
    idx.admit(toks)                            # first touch: no-allocate
    assert idx.stats.admissions == 0
    assert idx.stats.admission_skips > 0
    idx.admit(toks)                            # second touch: admitted
    assert idx.stats.admissions > 0
    assert idx.lookup(toks).all()              # now hits


def test_kv_index_eviction_prefers_cold(rng):
    cfg = KVIndexConfig(n_sets=1, set_ways=8, admit_after_reads=0,
                        window_ops=1 << 30, m_writes=1 << 20)
    idx = MonarchKVIndex(cfg)
    toks = rng.integers(1, 10_000, (1, 16 * 8)).astype(np.int32)
    idx.admit(toks)                            # fills some ways
    hot = idx.lookup(toks)                     # re-read: marks read_after
    idx.admit(toks)
    before = idx.stats.evictions
    toks2 = rng.integers(10_001, 20_000, (1, 16 * 8)).astype(np.int32)
    idx.admit(toks2)
    assert idx.stats.evictions >= before       # space had to be made


def test_kv_index_throttle():
    cfg = KVIndexConfig(n_sets=1, set_ways=512, admit_after_reads=0,
                        m_writes=0, window_ops=1 << 30)
    idx = MonarchKVIndex(cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(1, 100_000, (1, 16 * 32)).astype(np.int32)
    idx.admit(toks)
    assert idx.stats.throttled > 0             # zero budget: all throttled
    assert idx.stats.admissions == 0


def test_kv_index_write_distribution_evens_out(rng):
    idx = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=0))
    for _ in range(6):
        toks = rng.integers(1, 1 << 20, (4, 256)).astype(np.int32)
        idx.admit(toks)
    dist = idx.write_distribution()
    assert dist.sum() == idx.stats.admissions
    assert dist.max() <= dist.mean() * 4 + 8   # no pathological skew
