"""Per-arch smoke tests (reduced configs, CPU): forward + train step with
shape/NaN assertions, decode-vs-forward consistency, cache plumbing."""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import layers, transformer
from repro.train import step as train_step_mod

ALL_ARCHS = sorted(configs.ARCHS)


def _smoke_batch(cfg, rng, b=2, s=32):
    batch = {}
    if cfg.family == "vlm":
        p = cfg.n_prefix_embeds
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, p, cfg.d_model)), jnp.bfloat16)
        batch["tokens"] = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    elif cfg.family == "audio":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(1, cfg.vocab_size, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    """Every assigned architecture: reduced config, one forward + one train
    step on CPU; output shapes correct, loss finite, params updated."""
    cfg = configs.get_arch(arch).reduced()
    b, s = 2, 32
    batch = _smoke_batch(cfg, rng, b, s)

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    out = transformer.forward(params, cfg, batch)
    total_s = s + (cfg.n_prefix_embeds if cfg.family == "vlm" else 0)
    assert out.shape == (b, total_s, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(out.astype(jnp.float32))))

    state = train_step_mod.init_state(jax.random.PRNGKey(1), cfg)
    step_fn = jax.jit(train_step_mod.make_train_step(cfg))
    new_state, metrics = step_fn(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # sanity: random-init loss should be near ln(vocab)
    assert loss < 2.0 * np.log(cfg.vocab_size)
    # parameters moved
    moved = jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), state["params"], new_state["params"])
    assert any(jax.tree.leaves(moved))
    assert int(new_state["opt"]["step"]) == 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_param_count_positive(arch):
    cfg = configs.get_arch(arch).reduced()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    n = transformer.param_count(params)
    assert n > cfg.vocab_size * cfg.d_model  # at least the embedding


@pytest.mark.parametrize("arch", ["yi-9b", "falcon-mamba-7b", "zamba2-2.7b",
                                  "qwen3-moe-30b-a3b"])
def test_decode_matches_forward(arch, rng):
    """prefill(prompt) + decode_step(token) logits must match the full
    forward pass at the same positions (the KV-cache / SSM-state handoff
    is exact up to bf16 accumulation order)."""
    import dataclasses
    cfg = configs.get_arch(arch).reduced()
    if not cfg.supports_decode:
        pytest.skip("encoder-only")
    if cfg.n_experts:
        # capacity-based MoE drops depend on sequence length (and future
        # tokens); decode==forward holds exactly only when capacity does
        # not bind, so make it non-binding for this consistency check.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    b, prompt, total = 2, 12, 16
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, total)), jnp.int32)

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)

    # Reference: full forward, logits at every position.
    x = transformer.forward(params, cfg, {"tokens": toks})
    full_logits = layers.unembed_logits(params["embed"], x)  # (B, S, V) fp32

    # Prefill on the prompt.
    pre_logits, cache = transformer.prefill(
        params, cfg, {"tokens": toks[:, :prompt]}, max_seq=total)
    ref = full_logits[:, prompt - 1]
    np.testing.assert_allclose(
        np.asarray(pre_logits, np.float32), np.asarray(ref, np.float32),
        rtol=0.15, atol=0.2)
    assert (np.argmax(np.asarray(pre_logits), -1)
            == np.argmax(np.asarray(ref), -1)).mean() >= 0.5

    # Decode the remaining tokens one at a time.
    agree = 0
    for t in range(prompt, total):
        logits, cache = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], cache, jnp.asarray(t, jnp.int32))
        ref_t = full_logits[:, t]
        got = np.asarray(logits, np.float32)
        want = np.asarray(ref_t, np.float32)
        if cfg.n_experts:
            # bf16 puts the odd token on a top-k routing boundary; a
            # flipped expert shifts that whole row of logits.  The decode
            # contract for MoE: most rows match tightly, and argmax
            # agrees everywhere (asserted below).
            row_ok = (np.abs(got - want).max(axis=-1) < 0.35)
            assert row_ok.mean() >= 0.5, row_ok
        else:
            np.testing.assert_allclose(got, want, rtol=0.2, atol=0.35)
        agree += int((np.argmax(got, -1) == np.argmax(want, -1)).sum())
    assert agree >= (total - prompt) * b * 0.7


def test_local_attention_ring_cache_decode(rng):
    """gemma3's sliding-window layers decode through an O(W) ring buffer;
    results must match the full forward (window visible either way)."""
    cfg = configs.get_arch("gemma3-27b").reduced()
    b, prompt, total = 1, 10, 14
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, total)), jnp.int32)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    x = transformer.forward(params, cfg, {"tokens": toks})
    full_logits = layers.unembed_logits(params["embed"], x)
    _, cache = transformer.prefill(
        params, cfg, {"tokens": toks[:, :prompt]}, max_seq=total)
    for t in range(prompt, total):
        logits, cache = transformer.decode_step(
            params, cfg, toks[:, t:t + 1], cache, jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=0.2, atol=0.35)


def test_encoder_only_is_bidirectional(rng):
    """hubert: flipping a LATE token must be able to change EARLY outputs
    (no causal mask)."""
    cfg = configs.get_arch("hubert-xlarge").reduced()
    b, s = 1, 16
    emb = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    out1 = transformer.forward(params, cfg, {"embeds": emb})
    emb2 = emb.at[:, -1].set(emb[:, -1] + 1.0)
    out2 = transformer.forward(params, cfg, {"embeds": emb2})
    # early positions see the late change
    delta = jnp.abs(out1[:, 0].astype(jnp.float32)
                    - out2[:, 0].astype(jnp.float32)).max()
    assert float(delta) > 0


def test_causal_lm_is_causal(rng):
    """yi-9b: flipping a LATE token must NOT change EARLY hidden states."""
    cfg = configs.get_arch("yi-9b").reduced()
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 16)), jnp.int32)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    out1 = transformer.forward(params, cfg, {"tokens": toks})
    toks2 = toks.at[0, -1].set((toks[0, -1] % (cfg.vocab_size - 1)) + 1)
    out2 = transformer.forward(params, cfg, {"tokens": toks2})
    np.testing.assert_array_equal(
        np.asarray(out1[:, :-1].astype(jnp.float32)),
        np.asarray(out2[:, :-1].astype(jnp.float32)))


def test_chunked_attention_matches_dense(rng):
    """Online-softmax chunked attention == naive attention (fp32 ref)."""
    b, s, h, kvh, dh = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kvh, dh)), jnp.float32)

    got = layers.chunked_attention(q, k, v, causal=True, window=0,
                                   softcap=0.0, q_offset=0, kv_chunk=16)

    # dense reference
    rep = h // kvh
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * dh ** -0.5, kr)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    want = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_chunked_attention_sliding_window(rng):
    b, s, h, dh = 1, 32, 2, 8
    w = 8
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    got = layers.chunked_attention(q, k, v, causal=True, window=w,
                                   softcap=0.0, q_offset=0, kv_chunk=8)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * dh ** -0.5, k)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - w)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_chunked_ce_loss_matches_dense(rng):
    d, v, b, s = 16, 64, 2, 24
    params = {"embed": jnp.asarray(rng.normal(size=(v, d)), jnp.float32) * 0.1,
              "unembed": jnp.asarray(rng.normal(size=(d, v)), jnp.float32) * 0.1}
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    labels = labels.at[0, :4].set(-1)   # masked positions
    got = layers.chunked_ce_loss(params, x, labels, chunk=7)
    logits = layers.unembed_logits(params, x)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               axis=-1)[..., 0]
    valid = labels >= 0
    want = jnp.where(valid, logz - gold, 0).sum() / valid.sum()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_moe_capacity_and_dispatch(rng):
    """MoE: output differs per token (routing), capacity bounds tokens per
    expert, and zero-capacity drop keeps shapes."""
    from repro.models import moe
    cfg = configs.get_arch("qwen3-moe-30b-a3b").reduced()
    b, s = 2, 16
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
    y = moe.moe_block(params, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y.astype(jnp.float32))))
    c = moe.capacity(cfg, s)
    assert c >= 1
    # Permutation-equivariance holds when capacity does NOT bind (with
    # binding capacity, drop choice is position-dependent by design).
    import dataclasses
    cfg_nb = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    y_nb = moe.moe_block(params, x, cfg_nb)
    perm = jnp.asarray(rng.permutation(s))
    y_perm = moe.moe_block(params, x[:, perm], cfg_nb)
    np.testing.assert_allclose(
        np.asarray(y_nb[:, perm].astype(jnp.float32)),
        np.asarray(y_perm.astype(jnp.float32)), rtol=0.35, atol=0.35)
    # with binding capacity some tokens are dropped: output energy shrinks
    assert (float(jnp.abs(y.astype(jnp.float32)).sum())
            <= float(jnp.abs(y_nb.astype(jnp.float32)).sum()) * 1.25)


def test_mamba1_chunked_matches_sequential(rng):
    """Chunked selective scan == one-token-at-a-time decode recurrence."""
    from repro.models import ssm
    cfg = configs.get_arch("falcon-mamba-7b").reduced()
    b, s = 1, 12
    params = ssm.init_mamba1(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)) * 0.3, jnp.float32)
    out_chunked, h_fin, conv_tail = ssm.mamba1_block(
        params, x.astype(jnp.bfloat16), cfg, chunk=4, return_state=True)

    # sequential: feed tokens through mamba1_decode
    di = ssm.d_inner(cfg)
    h = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    conv = jnp.zeros((b, cfg.ssm_conv - 1, di), jnp.bfloat16)
    outs = []
    for t in range(s):
        o, h, conv = ssm.mamba1_decode(
            params, x[:, t:t + 1].astype(jnp.bfloat16), cfg, h, conv)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_chunked.astype(jnp.float32)),
        np.asarray(seq.astype(jnp.float32)), rtol=0.15, atol=0.15)
    # final state handed to decode continues identically
    o_next, _, _ = ssm.mamba1_decode(
        params, x[:, -1:].astype(jnp.bfloat16), cfg, h_fin,
        conv_tail.astype(jnp.bfloat16))
    assert o_next.shape == (b, 1, cfg.d_model)


def test_mamba2_chunked_matches_decode(rng):
    from repro.models import ssm
    cfg = configs.get_arch("zamba2-2.7b").reduced()
    b, s = 1, 8
    params = ssm.init_mamba2(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)) * 0.3, jnp.bfloat16)
    out_chunked, h_fin, _ = ssm.mamba2_block(params, x, cfg, chunk=4,
                                             return_state=True)
    h = jnp.zeros((b, ssm.m2_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state),
                  jnp.float32)
    di = ssm.d_inner(cfg)
    conv = jnp.zeros((b, cfg.ssm_conv - 1, di + 2 * cfg.ssm_state), jnp.bfloat16)
    outs = []
    for t in range(s):
        o, h, conv = ssm.mamba2_decode(params, x[:, t:t + 1], cfg, h, conv)
        outs.append(o[:, None, :] if o.ndim == 2 else o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_chunked.astype(jnp.float32)).reshape(b, s, -1),
        np.asarray(seq.astype(jnp.float32)).reshape(b, s, -1),
        rtol=0.2, atol=0.2)
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(h),
                               rtol=0.05, atol=0.05)


def test_cell_skip_matrix():
    """The assignment's exact skip set."""
    cells = {(a.name, s.name): ok for a, s, ok, _ in configs.all_cells()}
    assert len(cells) == 40
    expected_skips = {
        ("hubert-xlarge", "decode_32k"),
        ("hubert-xlarge", "long_500k"),
        ("starcoder2-15b", "long_500k"),
        ("command-r-plus-104b", "long_500k"),
        ("yi-9b", "long_500k"),
        ("paligemma-3b", "long_500k"),
        ("qwen3-moe-30b-a3b", "long_500k"),
        ("arctic-480b", "long_500k"),
    }
    skips = {k for k, ok in cells.items() if not ok}
    assert skips == expected_skips
    # long_500k runs for SSM / hybrid / local-attention archs
    assert cells[("falcon-mamba-7b", "long_500k")]
    assert cells[("zamba2-2.7b", "long_500k")]
    assert cells[("gemma3-27b", "long_500k")]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_shapes_lowerable(arch):
    """eval_shape of the FULL config params (no allocation) — catches
    layer-pattern / scan-group factorization bugs at real dims."""
    from repro.launch import specs as lspecs
    cfg = configs.get_arch(arch)
    shapes = lspecs.params_shapes(cfg)
    n = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    assert n > 1e8  # every assigned arch is >100M params
    group, n_groups, rem = cfg.scan_groups()
    assert n_groups * len(group) + len(rem) == cfg.n_layers
