"""Differential step-trace pins: single-dispatch paths vs the PR-4 paths.

The single-dispatch PR replaced the host fan-out lookup (one
``pallas_call`` per shard) with ONE ``shard_map``-wrapped stacked launch,
and the host-gather rotation with an on-device ``ppermute`` boundary
exchange.  Both old paths are KEPT behind
``MonarchKVIndex(..., dispatch="fanout")`` as the oracle, and this module
replays the same randomized schedule through both indexes side by side,
pinning planes / hits / shadow maps / replacement counters / wear
IDENTICAL after EVERY op — not just at end of schedule, so a transient
divergence (e.g. a boundary set landing on the wrong shard mid-remap)
cannot cancel out before the final check.

On a one-device host the "auto" index collapses every shard count to the
unsharded layout, so the differential still pins collapsed-vs-fanout
bit-equality; under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the CI multi-device leg) the same tests drive the real shard_map
dispatch, multi-device placement and the ppermute rotation.

Also here: the no-host-transfer rotation pin (the remap must move no
plane data through the host — ``jax.transfer_guard("disallow")``) and
the jit-cache growth cap of the stacked layout (pow2 Qmax bucketing).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.data.pipeline import fingerprint_blocks
from repro.kernels.xam_search import ops as xam_ops
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig,
                                  MonarchKVIndex)

SHARD_COUNTS = (1, 2, 4)


def _pair(n_shards: int, **kw):
    base = dict(n_sets=8, set_ways=8, admit_after_reads=1, m_writes=2,
                window_ops=256, rotate_every=1 << 30)
    base.update(kw)
    cfg = dict(n_shards=n_shards, **base)
    return (MonarchKVIndex(KVIndexConfig(**cfg)),
            MonarchKVIndex(KVIndexConfig(**cfg), dispatch="fanout"))


def _state(idx: MonarchKVIndex) -> dict:
    return dict(
        slot_of=dict(idx.slot_of),
        first_touch=dict(idx.first_touch),
        offset=idx.offset,
        bits=np.asarray(idx.bits).copy(),
        valid=np.asarray(idx.valid).copy(),
        fp_of=np.asarray(idx.fp_of).copy(),
        read_after=np.asarray(idx.read_after).copy(),
        counter=np.asarray(idx.counter).copy(),
        writes=idx.write_distribution(),
        window_writes=np.asarray(idx.wear_state.window_writes).copy(),
        ops=idx.ops_total,
        stats=(idx.stats.admissions, idx.stats.admission_skips,
               idx.stats.throttled, idx.stats.evictions,
               idx.stats.chunk_hits, idx.stats.chunk_misses,
               idx.stats.rotations),
    )


def _assert_same(sa: dict, sb: dict, msg: str):
    for key in sa:
        if isinstance(sa[key], np.ndarray):
            np.testing.assert_array_equal(sa[key], sb[key],
                                          err_msg=f"{msg}: {key}")
        else:
            assert sa[key] == sb[key], (msg, key, sa[key], sb[key])


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_step_trace(seed, n_shards):
    """Randomized admit/lookup/rotate schedule; auto and fanout indexes
    must agree on EVERY intermediate state and every lookup result."""
    rng = np.random.default_rng(seed)
    auto, ref = _pair(n_shards)
    rotated = False
    for step in range(10):
        toks = rng.integers(1, 600, (2, 6 * CHUNK_TOKENS)).astype(np.int32)
        op = rng.random()
        if op < 0.55:
            fps = np.unique(
                fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
            auto.admit_fps(fps)
            ref.admit_fps(fps)
            if op < 0.35:      # re-offer crosses the no-allocate gate
                auto.admit_fps(fps)
                ref.admit_fps(fps)
        elif op < 0.85:
            np.testing.assert_array_equal(auto.lookup(toks),
                                          ref.lookup(toks))
        else:
            auto._rotate()
            ref._rotate()
            rotated = True
        _assert_same(_state(auto), _state(ref),
                     f"seed={seed} step={step} n_shards={n_shards}")
        assert auto.wear_report() == ref.wear_report(), (seed, step)
    if not rotated:            # every trace must cross a remap at least once
        auto._rotate()
        ref._rotate()
        _assert_same(_state(auto), _state(ref), f"seed={seed} final rotate")
    assert auto.stats.admissions > 0


@pytest.mark.parametrize("n_shards", [2, 4])
def test_differential_boundary_straddle_after_rotation(n_shards):
    """Fingerprints whose sets sit at shard edges, pushed ACROSS the
    boundary by repeated set+7 rotations: residency must survive the
    remap on both paths and the paths must agree bit-for-bit — the exact
    traffic the ppermute boundary exchange carries."""
    auto, ref = _pair(n_shards, admit_after_reads=0, set_ways=16)
    n_sets = auto.cfg.n_sets
    s_part = n_sets // n_shards
    # enough distinct fps that every set — in particular every shard-edge
    # set (local row 0 and s_part-1 of each shard) — holds residents
    fps = np.arange(1, 257, dtype=np.uint32)
    auto.admit_fps(fps)
    ref.admit_fps(fps)
    edge_sets = {b % n_sets
                 for k in range(n_shards)
                 for b in (k * s_part, (k + 1) * s_part - 1)}
    assert {int(s) for s, _ in auto.slot_of.values()} >= edge_sets
    for rot in range(3):       # offset walks 7, 14, 21 (mod 8: 7, 6, 5)
        auto._rotate()
        ref._rotate()
        _assert_same(_state(auto), _state(ref),
                     f"n_shards={n_shards} rot={rot}")
        hits = auto._shadow_hits(fps)
        # every installed fp must still be found by the DEVICE search
        key_bits = xam_ops.words_to_bits_np(fps, auto.cfg.key_bits)
        sets = auto._set_of(fps)
        if auto._use_shard_map and auto.n_parts > 1:
            ways = xam_ops.xam_search_multiset_stacked(
                key_bits, sets, auto._assemble(auto._bits),
                auto._assemble(auto._valid), mesh=auto.set_mesh)
        else:
            ways = xam_ops.xam_search_multiset(
                key_bits, sets, auto._bits[0], auto._valid[0])
        np.testing.assert_array_equal(ways >= 0, hits)
        ways_ref = xam_ops.xam_search_multiset_sharded(
            key_bits, sets, ref._bits, ref._valid)
        np.testing.assert_array_equal(ways, ways_ref)
    assert auto.stats.rotations == 3


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_admission_is_single_dispatch_at_every_shard_count(n_shards):
    """Acceptance pin: with ``admit_dispatch="auto"`` one admit_fps batch
    is ONE device dispatch no matter how many shards (and partitions)
    the index spans, while the fanout oracle pays one dispatch per
    partition holding candidates."""
    auto, ref = _pair(n_shards, admit_after_reads=0)
    rng = np.random.default_rng(0)
    for _ in range(4):
        fps = np.unique(rng.integers(1, 3000, 24).astype(np.uint32))
        before = xam_ops.ADMIT_LAUNCH_COUNT
        auto.admit_fps(fps)
        assert xam_ops.ADMIT_LAUNCH_COUNT == before + 1
        before = xam_ops.ADMIT_LAUNCH_COUNT
        ref.admit_fps(fps)
        want = len(np.unique(ref._set_of(fps) // ref.sets_per_part))
        assert xam_ops.ADMIT_LAUNCH_COUNT == before + want
    assert auto.stats.admit_calls == 4
    _assert_same(_state(auto), _state(ref), f"n_shards={n_shards}")


def test_admission_moves_no_plane_data_through_host():
    """Acceptance pin: the stacked admission path performs NO implicit
    host transfer — candidate grids enter via explicit device_put, the
    wear knobs were replicated at construction, and only the decision
    grids come back (one explicit device_get per batch).  A scratch index
    with the identical config compiles the exact R/K bucket shapes first,
    so the guarded run measures steady-state dispatch, not tracing."""
    fps = np.arange(1, 49, dtype=np.uint32)
    warm, _ = _pair(4, admit_after_reads=0)
    warm.admit_fps(fps)                   # same cfg + batch -> same shapes
    idx, _ = _pair(4, admit_after_reads=0)
    with jax.transfer_guard("disallow"):
        idx.admit_fps(fps)
    # bit-identical to the unguarded twin, installs included
    _assert_same(_state(idx), _state(warm), "guarded admission")
    assert idx.stats.admissions > 0
    # residents = installs minus the same-batch evictions (table was empty)
    assert (idx._shadow_hits(fps).sum()
            == idx.stats.admissions - idx.stats.evictions)


def test_rotation_moves_no_plane_data_through_host():
    """Acceptance pin: the rotate path performs NO host transfer of plane
    data (device_get/device_put both trip the guard).  Runs on every
    device count — one partition exercises the donated local roll,
    several the ppermute boundary exchange."""
    idx, _ = _pair(4, admit_after_reads=0)
    idx.admit_fps(np.arange(1, 65, dtype=np.uint32))
    with jax.transfer_guard("disallow"):
        idx._rotate()
        idx._rotate()
    assert idx.stats.rotations == 2
    # ...and the remap preserved residency (device search vs the host
    # shadow oracle, outside the guard)
    probe = np.arange(1, 65, dtype=np.uint32)
    key_bits = xam_ops.words_to_bits_np(probe, idx.cfg.key_bits)
    sets = idx._set_of(probe)
    if idx._use_shard_map and idx.n_parts > 1:
        ways = xam_ops.xam_search_multiset_stacked(
            key_bits, sets, idx._assemble(idx._bits),
            idx._assemble(idx._valid), mesh=idx.set_mesh)
    else:
        ways = xam_ops.xam_search_multiset(
            key_bits, sets, idx._bits[0], idx._valid[0])
    want = idx._shadow_hits(probe)
    assert want.any()
    np.testing.assert_array_equal(ways >= 0, want)


def test_device_rotation_never_replaces_planes_from_host():
    """Behavioral twin of the transfer-guard pin (the CPU backend's guard
    cannot see host<->device copies — everything is host memory): the
    device rotate path must never route plane data through ``_put`` (the
    host->device placement every fanout re-split uses), while the fanout
    reference with >1 partition must."""
    idx, ref = _pair(4, admit_after_reads=0)
    fps = np.arange(1, 65, dtype=np.uint32)
    idx.admit_fps(fps)
    ref.admit_fps(fps)

    def instrument(index):
        calls = []
        orig = index._put
        index._put = lambda x, k: (calls.append(k), orig(x, k))[-1]
        return calls

    auto_puts = instrument(idx)
    idx._rotate()
    assert auto_puts == []
    ref_puts = instrument(ref)
    ref._rotate()
    if ref.n_parts > 1:
        assert len(ref_puts) >= 4 * ref.n_parts   # 4 planes re-placed/shard
    _assert_same(_state(idx), _state(ref), "post-instrumented rotate")


def test_stacked_layout_caps_jit_cache_growth():
    """Satellite pin: DISTINCT ragged batch sizes may not each compile a
    new program — the stacked grouping buckets Qmax to a pow2, so the
    number of distinct padded shapes (== jit cache entries of the fused
    kernel) is logarithmic in the batch-size range."""
    qs = list(range(1, 120, 7))
    shapes = set()
    for q in qs:
        sets = np.arange(q) % 8
        _, _, block_sets, _, padded_q = (
            xam_ops.group_queries_by_set_stacked(sets, 8, 2))
        shapes.add((padded_q, block_sets.shape))
    # 17 ragged sizes -> a handful of pow2 buckets
    assert len(shapes) <= 4, shapes

    # and the end-to-end index path compiles once per bucket, not per size
    jax.clear_caches()
    idx, _ = _pair(1, admit_after_reads=0, n_sets=8)
    rng = np.random.default_rng(0)
    for q in range(1, 14):
        idx.lookup(rng.integers(1, 10_000,
                                (1, q * CHUNK_TOKENS)).astype(np.int32))
    from repro.kernels.xam_search.kernel import xam_search_multiset_pallas
    n_buckets = len({xam_ops.group_queries_by_set(
        np.zeros(q, np.int64), 8)[2] for q in range(1, 14)})
    assert xam_search_multiset_pallas._cache_size() <= n_buckets + 1


# ---------------------------------------------------------------------------
# Packed planes (plane_format="packed8") vs the int8 layout: the SAME
# randomized schedules replayed through both formats must agree on every
# lookup result and every piece of state — with the stored planes compared
# through the unpack (the only field whose raw bytes legitimately differ).
# ---------------------------------------------------------------------------

def _format_pair(n_shards: int, **kw):
    base = dict(n_sets=8, set_ways=8, admit_after_reads=1, m_writes=2,
                window_ops=256, rotate_every=1 << 30)
    base.update(kw)
    return (MonarchKVIndex(KVIndexConfig(
                n_shards=n_shards, plane_format="packed8", **base)),
            MonarchKVIndex(KVIndexConfig(
                n_shards=n_shards, plane_format="int8", **base)))


def _state_unpacked(idx: MonarchKVIndex) -> dict:
    from repro.kernels.common import unpack_bits_np
    s = _state(idx)
    if s["bits"].dtype == np.uint8:
        s["bits"] = unpack_bits_np(s["bits"], idx.cfg.key_bits, axis=1)
    return s


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
@pytest.mark.parametrize("seed", [0, 1])
def test_differential_packed_vs_int8_step_trace(seed, n_shards):
    """Randomized admit / re-offer / lookup / rotate schedule through a
    packed8 index and an int8 index side by side: identical hits, shadow
    maps, wear, stats, and (unpacked) stored planes after EVERY op."""
    rng = np.random.default_rng(seed)
    packed, plain = _format_pair(n_shards)
    assert packed.bits.dtype == np.uint8 and plain.bits.dtype == np.int8
    for step in range(10):
        toks = rng.integers(1, 600, (2, 6 * CHUNK_TOKENS)).astype(np.int32)
        op = rng.random()
        if op < 0.55:
            fps = np.unique(
                fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
            packed.admit_fps(fps)
            plain.admit_fps(fps)
            if op < 0.35:
                packed.admit_fps(fps)
                plain.admit_fps(fps)
        elif op < 0.85:
            np.testing.assert_array_equal(packed.lookup(toks),
                                          plain.lookup(toks))
        else:
            packed._rotate()
            plain._rotate()
        _assert_same(_state_unpacked(packed), _state_unpacked(plain),
                     f"seed={seed} step={step} n_shards={n_shards}")
        assert packed.wear_report() == plain.wear_report(), (seed, step)
    assert packed.stats.admissions > 0


@pytest.mark.parametrize("n_shards", [2, 4])
def test_differential_packed_rotation_boundary_exchange(n_shards):
    """Rotation-heavy packed differential: repeated set+7 remaps push
    residents across shard edges; the ppermute boundary exchange carries
    uint8 words instead of int8 bit rows and must land bit-identically."""
    packed, plain = _format_pair(n_shards, admit_after_reads=0, set_ways=16)
    fps = np.arange(1, 257, dtype=np.uint32)
    packed.admit_fps(fps)
    plain.admit_fps(fps)
    for rot in range(3):
        packed._rotate()
        plain._rotate()
        _assert_same(_state_unpacked(packed), _state_unpacked(plain),
                     f"n_shards={n_shards} rot={rot}")
        # every resident must still be found by the packed device search
        key_bits = xam_ops.words_to_bits_np(fps, packed.cfg.key_bits)
        sets = packed._set_of(fps)
        if packed._use_shard_map and packed.n_parts > 1:
            ways = xam_ops.xam_search_multiset_stacked(
                key_bits, sets, packed._assemble(packed._bits),
                packed._assemble(packed._valid), mesh=packed.set_mesh)
        else:
            ways = xam_ops.xam_search_multiset(
                key_bits, sets, packed._bits[0], packed._valid[0])
        np.testing.assert_array_equal(
            np.asarray(ways) >= 0, packed._shadow_hits(fps))
    assert packed.stats.rotations == 3


def test_packed_requires_byte_aligned_keys():
    """key_bits not divisible by 8 cannot ride packed planes — the config
    must say so up front, naming the knob."""
    with pytest.raises(ValueError, match="key_bits"):
        MonarchKVIndex(KVIndexConfig(n_sets=8, key_bits=20,
                                     plane_format="packed8"))


def test_packed_install_column_is_fingerprint_bytes():
    """Layout pin: with 32-bit keys a packed stored column IS the
    fingerprint's little-endian bytes (LSB-first packing == LSB-first
    words_to_bits) — the on-disk-obvious identity ARCHITECTURE.md
    documents."""
    idx = MonarchKVIndex(KVIndexConfig(n_sets=8, admit_after_reads=0,
                                       plane_format="packed8"))
    fp = np.uint32(0xDEADBEEF)
    idx.admit_fps(np.asarray([fp], np.uint32))
    (s, w), = [idx.slot_of[int(fp)]]
    col = np.asarray(idx.bits)[s, :, w]
    np.testing.assert_array_equal(
        col, np.frombuffer(np.uint32(fp).tobytes(), np.uint8))


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
