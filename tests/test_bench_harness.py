"""Bench harness: ``_block`` error discipline.

``_block`` exists to close out JAX async dispatch before a timing
sample is taken.  It used to swallow EVERY exception, so a poisoned
computation (device error surfaced at ``block_until_ready``) timed as a
clean pass — the bench reported the dispatch cost of a result that was
never produced.  Only the "this is not a JAX result" complaints
(``TypeError`` / ``ValueError``) may be ignored."""
from __future__ import annotations

import jax.numpy as jnp
import pytest

from repro.bench.harness import _block, time_callable


class _Result:
    """Pytree leaf whose sync raises a chosen exception."""

    def __init__(self, exc: type[BaseException] | None):
        self._exc = exc

    def block_until_ready(self):
        if self._exc is not None:
            raise self._exc("surfaced at sync")
        return self


def test_block_passes_jax_and_host_results():
    _block(jnp.ones(4))            # real device value
    _block(None)                   # plain host objects are fine
    _block({"a": [1, 2.0, "s"]})
    _block(_Result(None))


def test_block_swallows_non_jax_result_complaints():
    _block(_Result(TypeError))
    _block(_Result(ValueError))


@pytest.mark.parametrize("exc", [RuntimeError, OSError])
def test_block_propagates_runtime_failures(exc):
    with pytest.raises(exc, match="surfaced at sync"):
        _block(_Result(exc))


def test_time_callable_does_not_time_a_poisoned_computation():
    """The end-to-end regression: a callable whose result fails at sync
    must fail the bench, not produce a Timing."""
    with pytest.raises(RuntimeError, match="surfaced at sync"):
        time_callable(lambda: _Result(RuntimeError), warmup=1, reps=2)
    t = time_callable(lambda: jnp.ones(8) * 2, warmup=1, reps=2)
    assert t.median_us > 0 and t.reps == 2


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
