"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the host's
real device count (the 512-device env is dry-run-only, per the brief)."""
from __future__ import annotations

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run slow tests (full smoke sweep etc.)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
