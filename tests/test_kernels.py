"""Per-kernel allclose tests: Pallas kernels (interpret mode) vs the
pure-jnp ref.py oracles, swept over shapes/dtypes, plus hypothesis
property tests on the search semantics."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.xam_search import ops as xam_ops
from repro.kernels.xam_search.kernel import MULTISET_BLOCK_Q
from repro.kernels.xam_search.ref import (
    xam_search_ref, xam_match_index_ref, xam_search_multiset_ref)
from repro.kernels.hopscotch import ops as hop_ops
from repro.kernels.hopscotch.kernel import BLOCK_Q as HOP_BLOCK_Q
from repro.kernels.hopscotch.ref import hopscotch_lookup_ref
from repro.kernels.string_match import ops as sm_ops
from repro.kernels.string_match.ref import string_match_ref


# ---------------------------------------------------------------------------
# xam_search
# ---------------------------------------------------------------------------

XAM_SHAPES = [
    (1, 8, 8),          # tiny
    (3, 64, 512),       # one Monarch set (odd Q: padding path)
    (8, 64, 512),
    (128, 64, 512),     # one full query block
    (130, 64, 513),     # both dims ragged vs block
    (16, 32, 100),      # narrow key, ragged columns
    (5, 512, 64),       # tall keys
]


@pytest.mark.parametrize("q,r,c", XAM_SHAPES)
def test_xam_search_matches_ref(q, r, c, rng):
    keys = rng.integers(0, 2, (q, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    masks = rng.integers(0, 2, (q, r)).astype(np.int8)
    got = xam_ops.xam_search(keys, data, masks, use_kernel=True)
    want = xam_search_ref(jnp.asarray(keys), jnp.asarray(data),
                          jnp.asarray(masks))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xam_search_planted_matches(rng):
    """Columns explicitly equal to the key must match; single-bit
    corruptions must not."""
    r, c = 64, 512
    key = rng.integers(0, 2, (1, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    data[:, 7] = key[0]
    data[:, 200] = key[0]
    data[:, 201] = key[0]
    data[17, 201] ^= 1  # one-bit mismatch
    out = np.asarray(xam_ops.xam_search(key, data))
    assert out[0, 7] == 1 and out[0, 200] == 1
    assert out[0, 201] == 0


def test_xam_search_mask_widens_matches(rng):
    """Masking out a bit can only ADD matches, never remove them."""
    r, c = 32, 128
    key = rng.integers(0, 2, (1, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    full = np.asarray(xam_ops.xam_search(key, data))
    mask = np.ones((1, r), np.int8)
    mask[0, :16] = 0
    partial = np.asarray(xam_ops.xam_search(key, data, mask))
    assert (partial >= full).all()


def test_xam_all_masked_matches_everything(rng):
    key = rng.integers(0, 2, (2, 16)).astype(np.int8)
    data = rng.integers(0, 2, (16, 64)).astype(np.int8)
    mask = np.zeros((2, 16), np.int8)
    out = np.asarray(xam_ops.xam_search(key, data, mask))
    assert (out == 1).all()


def test_xam_match_index(rng):
    r, c = 32, 96
    keys = rng.integers(0, 2, (4, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    data[:, 50] = keys[2]
    got = np.asarray(xam_ops.xam_match_index(keys, data))
    want = np.asarray(xam_match_index_ref(
        jnp.asarray(keys), jnp.asarray(data), jnp.ones_like(jnp.asarray(keys))))
    np.testing.assert_array_equal(got, want)
    assert got[2] == 50 or data[:, got[2]].tolist() == keys[2].tolist()


@settings(max_examples=30, deadline=None)
@given(q=st.integers(1, 9), r=st.integers(1, 48), c=st.integers(1, 140),
       seed=st.integers(0, 2 ** 31))
def test_xam_search_property(q, r, c, seed):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 2, (q, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    masks = rng.integers(0, 2, (q, r)).astype(np.int8)
    got = np.asarray(xam_ops.xam_search(keys, data, masks))
    want = np.asarray(xam_search_ref(
        jnp.asarray(keys), jnp.asarray(data), jnp.asarray(masks)))
    np.testing.assert_array_equal(got, want)


def test_words_bits_roundtrip(rng):
    words = rng.integers(0, 2 ** 32, 64, dtype=np.uint32)
    bits = xam_ops.words_to_bits(jnp.asarray(words), 32)
    back = xam_ops.bits_to_words(bits)
    np.testing.assert_array_equal(np.asarray(back), words)
    np.testing.assert_array_equal(
        xam_ops.words_to_bits_np(words, 32), np.asarray(bits))


@pytest.mark.parametrize("q,r,c", [(3, 64, 512), (64, 32, 128), (1, 8, 8)])
def test_xam_int8_and_f32_scoring_bit_identical(q, r, c, rng):
    """The int8 MXU path and the float32 fallback are pinned equal."""
    keys = rng.integers(0, 2, (q, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    masks = rng.integers(0, 2, (q, r)).astype(np.int8)
    got8 = np.asarray(xam_ops.xam_search(keys, data, masks, scoring="int8"))
    got32 = np.asarray(xam_ops.xam_search(keys, data, masks, scoring="f32"))
    np.testing.assert_array_equal(got8, got32)


# ---------------------------------------------------------------------------
# fused multi-set xam search
# ---------------------------------------------------------------------------

def _random_multiset(rng, n_sets, r, c, n_q, plant_every=3):
    planes = rng.integers(0, 2, (n_sets, r, c)).astype(np.int8)
    valid = rng.integers(0, 2, (n_sets, c)).astype(np.int8)
    words = rng.integers(0, 2 ** 32, n_q, dtype=np.uint32)
    sets = rng.integers(0, n_sets, n_q).astype(np.int32)
    bits = xam_ops.words_to_bits_np(words, r)
    for i in range(0, n_q, plant_every):   # guaranteed valid hits
        w = i % c                          # distinct way per plant in a set
        planes[sets[i], :, w] = bits[i]
        valid[sets[i], w] = 1
    return planes, valid, bits, sets


@pytest.mark.parametrize("n_q", [1, 7, 64, 130, 300])  # 300: wide-block path
@pytest.mark.parametrize("scoring", ["int8", "f32"])
def test_xam_multiset_matches_ref(n_q, scoring, rng):
    n_sets, r, c = 8, 32, 256
    planes, valid, bits, sets = _random_multiset(rng, n_sets, r, c, n_q)
    got = xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid), scoring=scoring)
    want = np.asarray(xam_search_multiset_ref(
        jnp.asarray(bits), jnp.ones_like(jnp.asarray(bits)),
        jnp.asarray(sets), jnp.asarray(planes), jnp.asarray(valid)))
    np.testing.assert_array_equal(got, want)
    assert (got[::3] >= 0).all()           # planted hits found


def test_xam_multiset_validity_fused(rng):
    """A matching column with valid=0 must NOT hit (dead-way masking is
    inside the kernel, not a host-side post-pass)."""
    n_sets, r, c = 2, 16, 128
    planes = np.zeros((n_sets, r, c), np.int8)
    valid = np.zeros((n_sets, c), np.int8)
    word = np.asarray([0xABCD], np.uint32)
    bits = xam_ops.words_to_bits_np(word, r)
    planes[1, :, 5] = bits[0]
    got = xam_ops.xam_search_multiset(
        bits, np.asarray([1]), jnp.asarray(planes), jnp.asarray(valid))
    assert got[0] == -1                    # stored but invalid: miss
    valid[1, 5] = 1
    got = xam_ops.xam_search_multiset(
        bits, np.asarray([1]), jnp.asarray(planes), jnp.asarray(valid))
    assert got[0] == 5


def test_xam_multiset_first_valid_way_wins(rng):
    n_sets, r, c = 1, 16, 128
    planes = np.zeros((n_sets, r, c), np.int8)
    valid = np.zeros((n_sets, c), np.int8)
    word = np.asarray([77], np.uint32)
    bits = xam_ops.words_to_bits_np(word, r)
    for w in (9, 40):
        planes[0, :, w] = bits[0]
        valid[0, w] = 1
    got = xam_ops.xam_search_multiset(
        bits, np.asarray([0]), jnp.asarray(planes), jnp.asarray(valid))
    assert got[0] == 9


@settings(max_examples=15, deadline=None)
@given(n_q=st.integers(1, 40), n_sets=st.sampled_from([1, 3, 8]),
       seed=st.integers(0, 2 ** 31))
def test_xam_multiset_property(n_q, n_sets, seed):
    rng = np.random.default_rng(seed)
    r, c = 16, 128
    planes, valid, bits, sets = _random_multiset(rng, n_sets, r, c, n_q)
    got = xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid))
    want = np.asarray(xam_search_multiset_ref(
        jnp.asarray(bits), jnp.ones_like(jnp.asarray(bits)),
        jnp.asarray(sets), jnp.asarray(planes), jnp.asarray(valid)))
    np.testing.assert_array_equal(got, want)


# Parity matrix (PR 3): ragged / non-power-of-two batch sizes, empty sets
# (no queries and/or no valid columns) and both scoring modes, pinned
# bit-identical against the PER-SET single-plane reference — extends PR 2's
# single-shape bit-identity tests to the whole shape envelope the batched
# admission pipeline exercises.

def _per_set_reference(bits, sets, planes, valid):
    """Loop of single-set xam_search_ref calls + host validity masking +
    first-valid-way reduce — the seed's lookup flow."""
    out = -np.ones(bits.shape[0], np.int32)
    for i in range(bits.shape[0]):
        s = int(sets[i])
        m = np.asarray(xam_search_ref(
            jnp.asarray(bits[i:i + 1]), jnp.asarray(planes[s]),
            jnp.ones((1, bits.shape[1]), jnp.int8)))[0]
        m = m & valid[s]
        hits = np.nonzero(m)[0]
        if hits.size:
            out[i] = hits[0]
    return out


@pytest.mark.parametrize("scoring", ["int8", "f32"])
@pytest.mark.parametrize("n_q,n_sets", [
    (1, 1), (5, 3), (13, 8), (31, 5), (100, 6),
])
def test_xam_multiset_parity_matrix(n_q, n_sets, scoring, rng):
    r, c = 24, 96                          # ragged rows AND columns
    planes, valid, bits, sets = _random_multiset(rng, n_sets, r, c, n_q)
    # half the sets are EMPTY (no valid column at all)...
    valid[::2] = 0
    # ...and (when possible) one set receives no queries
    if n_sets > 1:
        sets[sets == n_sets - 1] = 0
    got = np.asarray(xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid),
        scoring=scoring))
    want = _per_set_reference(bits, sets, planes, valid)
    np.testing.assert_array_equal(got, want)
    want_ref = np.asarray(xam_search_multiset_ref(
        jnp.asarray(bits), jnp.ones_like(jnp.asarray(bits)),
        jnp.asarray(sets), jnp.asarray(planes), jnp.asarray(valid)))
    np.testing.assert_array_equal(got, want_ref)


@pytest.mark.parametrize("scoring", ["int8", "f32"])
def test_xam_multiset_all_sets_empty(scoring, rng):
    """Fully empty index (cold start): every query must miss in both
    scoring modes."""
    n_sets, r, c = 4, 16, 128
    planes = np.zeros((n_sets, r, c), np.int8)
    valid = np.zeros((n_sets, c), np.int8)
    bits = xam_ops.words_to_bits_np(
        rng.integers(0, 2 ** 32, 11, dtype=np.uint32), r)
    sets = rng.integers(0, n_sets, 11).astype(np.int32)
    got = np.asarray(xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid),
        scoring=scoring))
    assert (got == -1).all()


@pytest.mark.parametrize("n_q", [1, 2, 3, 9, 17, 33, 100])
@pytest.mark.parametrize("window", [8, 32])
def test_hopscotch_parity_matrix(n_q, window, rng):
    """Ragged / non-pow2 batch sizes through the batched hopscotch kernel,
    bit-identical to the per-query reference (dense collisions so
    first-match tie-breaks are actually exercised)."""
    n_slots = window * 16
    t_lo = rng.integers(0, 6, n_slots, dtype=np.uint32)
    t_hi = rng.integers(0, 2, n_slots, dtype=np.uint32)
    homes = rng.integers(0, n_slots - 2 * window, n_q).astype(np.int32)
    q_lo = rng.integers(0, 6, n_q, dtype=np.uint32)
    q_hi = rng.integers(0, 2, n_q, dtype=np.uint32)
    got = np.asarray(hop_ops.hopscotch_lookup(
        t_lo, t_hi, homes, q_lo, q_hi, window=window))
    want = np.asarray(hopscotch_lookup_ref(
        jnp.asarray(t_lo), jnp.asarray(t_hi), jnp.asarray(homes),
        jnp.asarray(q_lo), jnp.asarray(q_hi), window))
    np.testing.assert_array_equal(got, want)


def test_hopscotch_empty_table(rng):
    """All-EMPTY (zero) table: every non-zero query misses."""
    window, n_q = 16, 9
    t = np.zeros(window * 8, np.uint32)
    homes = rng.integers(0, window * 6, n_q).astype(np.int32)
    q = rng.integers(1, 2 ** 32, n_q, dtype=np.uint32)
    got = np.asarray(hop_ops.hopscotch_lookup(
        t, t, homes, q, q, window=window))
    assert (got == -1).all()


def test_multiset_grouping_layout(rng):
    """Every query lands in a block whose block_set matches its set id."""
    sets = rng.integers(0, 5, 37)
    bq = MULTISET_BLOCK_Q
    slot, block_sets, padded_q, n_blocks = xam_ops.group_queries_by_set(
        sets, 5, bq)
    assert padded_q % bq == 0 and len(block_sets) == padded_q // bq
    assert n_blocks <= padded_q // bq
    assert len(np.unique(slot)) == len(slot)       # injective placement
    for i, s in enumerate(sets):
        assert block_sets[slot[i] // bq] == s
        assert slot[i] // bq < n_blocks            # real rows in real blocks


# ---------------------------------------------------------------------------
# Stacked (single-dispatch sharded) layout — the shapes the shard_map path
# introduces: shards with ZERO queries (Qmax padding only), all-queries-
# one-shard skew, and boundary sets straddling shard edges post-rotation.
# Pinned bit-identical against both the per-set reference and the flat
# fused kernel.
# ---------------------------------------------------------------------------

def test_stacked_grouping_layout(rng):
    """Stacked layout contract: injective (part, slot) placement, local
    block set ids, a common padded Qmax, exact per-part block counts."""
    n_sets, n_parts = 8, 4
    sets = rng.integers(0, n_sets, 41)
    bq = MULTISET_BLOCK_Q
    part_of, slot, block_sets, n_blocks, padded_q = (
        xam_ops.group_queries_by_set_stacked(sets, n_sets, n_parts, bq))
    assert padded_q % bq == 0
    assert block_sets.shape == (n_parts, padded_q // bq)
    assert len({(int(p), int(s)) for p, s in zip(part_of, slot)}) == len(sets)
    s_part = n_sets // n_parts
    for i, s in enumerate(sets):
        p = s // s_part
        assert part_of[i] == p
        assert block_sets[p, slot[i] // bq] == s % s_part
        assert slot[i] // bq < n_blocks[p]         # real rows in real blocks


@pytest.mark.parametrize("scoring", ["int8", "f32"])
@pytest.mark.parametrize("n_parts", [1, 2, 4])
@pytest.mark.parametrize("n_q", [33, 300])     # 300: wide-block path
@pytest.mark.parametrize("case", ["mixed", "one_shard_skew", "empty_shards"])
def test_xam_stacked_parity_matrix(case, n_q, n_parts, scoring, rng):
    """The stacked single-dispatch layout vs the per-set reference and
    the flat fused kernel, over the new edge shapes:

    * ``mixed`` — ragged spread over all shards;
    * ``one_shard_skew`` — every query on ONE shard, all others Qmax==0;
    * ``empty_shards`` — interior shards empty (queries only on the
      outermost shards' boundary sets).
    """
    n_sets, r, c = 8, 24, 96
    planes, valid, bits, sets = _random_multiset(rng, n_sets, r, c, n_q)
    s_part = n_sets // n_parts
    if case == "one_shard_skew":
        sets = (sets % s_part) + (n_parts - 1) * s_part   # last shard only
    elif case == "empty_shards":
        # only the global edge sets 0 and n_sets-1 (first/last shard)
        sets = np.where(sets % 2 == 0, 0, n_sets - 1).astype(sets.dtype)
    got = np.asarray(xam_ops.xam_search_multiset_stacked(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid),
        n_parts=n_parts, scoring=scoring))
    want = _per_set_reference(bits, sets, planes, valid)
    np.testing.assert_array_equal(got, want)
    flat = np.asarray(xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid),
        scoring=scoring))
    np.testing.assert_array_equal(got, flat)


@pytest.mark.parametrize("n_parts", [2, 4])
def test_xam_stacked_boundary_sets_post_rotation(n_parts, rng):
    """Sets that straddle shard boundaries after a set+7 rotary remap:
    roll the planes like the serving remap does, address the queries to
    the rotated (boundary-crossing) sets, and require stacked == flat ==
    per-set reference."""
    n_sets, r, c = 8, 24, 96
    planes, valid, bits, _ = _random_multiset(rng, n_sets, r, c, 24)
    shift = 7 % n_sets
    planes = np.roll(planes, shift, axis=0)
    valid = np.roll(valid, shift, axis=0)
    s_part = n_sets // n_parts
    # probe exactly the shard-edge sets (local rows 0 and s_part-1)
    edges = np.asarray(sorted(
        {(k * s_part) % n_sets for k in range(n_parts)} |
        {(k * s_part - 1) % n_sets for k in range(n_parts)}), np.int64)
    sets = edges[rng.integers(0, edges.size, bits.shape[0])]
    got = np.asarray(xam_ops.xam_search_multiset_stacked(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid),
        n_parts=n_parts))
    np.testing.assert_array_equal(got, _per_set_reference(
        bits, sets, planes, valid))
    np.testing.assert_array_equal(got, np.asarray(xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid))))


def test_stacked_compile_cache_capped_at_pow2_buckets():
    """Jit-cache growth pin for the stacked layout: ~40 distinct ragged
    batch sizes collapse onto the pow2 Qmax buckets, so the fused
    kernel's compiled-shape count stays logarithmic (the host fan-out
    per-shard path obeys the same bucket policy via
    ``group_queries_by_set``)."""
    import jax
    from repro.kernels.xam_search.kernel import xam_search_multiset_pallas
    rng = np.random.default_rng(0)
    n_sets, r, c = 8, 16, 64
    planes = jnp.asarray(rng.integers(0, 2, (n_sets, r, c)).astype(np.int8))
    valid = jnp.asarray(rng.integers(0, 2, (n_sets, c)).astype(np.int8))
    qs = list(range(1, 80, 2))
    buckets = set()
    for q in qs:
        sets = rng.integers(0, n_sets, q)
        _, _, block_sets, _, padded_q = (
            xam_ops.group_queries_by_set_stacked(sets, n_sets, 2))
        buckets.add((padded_q, block_sets.shape[1]))
    assert len(buckets) <= int(np.log2(max(qs))) + 2, buckets
    jax.clear_caches()
    for q in qs:
        sets = rng.integers(0, n_sets, q)
        bits = xam_ops.words_to_bits_np(
            rng.integers(0, 2 ** 32, q, dtype=np.uint32), r)
        xam_ops.xam_search_multiset_stacked(
            bits, sets, planes, valid, n_parts=2)
    assert xam_search_multiset_pallas._cache_size() <= len(buckets)


def test_batched_block_sizes_meet_floor():
    """Acceptance pin: both fused kernels batch >= 8 queries per grid
    step."""
    assert MULTISET_BLOCK_Q >= 8
    assert HOP_BLOCK_Q >= 8


# ---------------------------------------------------------------------------
# Packed bit-planes (plane_format="packed8"): planes stored 8 logical bits
# per uint8 word along R, unpacked in VMEM per tile.  The contract is
# BIT-IDENTITY with the int8 planes across the whole parity envelope —
# packing is a storage re-layout, never a semantic change.
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip(rng):
    from repro.kernels.common import pack_bits_np, unpack_bits_np
    bits = rng.integers(0, 2, (40, 96)).astype(np.int8)
    packed = pack_bits_np(bits, axis=0)
    assert packed.dtype == np.uint8 and packed.shape == (5, 96)
    np.testing.assert_array_equal(unpack_bits_np(packed, axis=0), bits)
    # LSB-first: logical row r -> word r//8, bit r%8 (words_to_bits order)
    col = np.zeros((8, 1), np.int8)
    col[0, 0] = 1
    col[2, 0] = 1
    assert pack_bits_np(col, axis=0)[0, 0] == 5


def test_pack_bits_rejects_ragged_axis():
    from repro.kernels.common import pack_bits_np
    with pytest.raises(ValueError, match="multiple of 8"):
        pack_bits_np(np.zeros((7, 4), np.int8), axis=0)


def test_plane_format_knob_validation():
    from repro.kernels.common import (PLANE_FORMAT_ENV, plane_format_of,
                                      resolve_plane_format)
    with pytest.raises(ValueError, match=PLANE_FORMAT_ENV):
        resolve_plane_format("packed16")
    with pytest.raises(ValueError, match="dtype"):
        plane_format_of(jnp.zeros((1, 8, 8), jnp.float32))
    assert plane_format_of(jnp.zeros((1, 8, 8), jnp.int8)) == "int8"
    assert plane_format_of(jnp.zeros((1, 1, 8), jnp.uint8)) == "packed8"


def test_plane_format_env_knob_validation(monkeypatch):
    from repro.kernels.common import PLANE_FORMAT_ENV, resolve_plane_format
    monkeypatch.setenv(PLANE_FORMAT_ENV, "bogus")
    with pytest.raises(ValueError, match="bogus"):
        resolve_plane_format(None)
    monkeypatch.setenv(PLANE_FORMAT_ENV, "packed8")
    assert resolve_plane_format(None) == "packed8"


def _packed_planes(planes):
    from repro.kernels.common import pack_bits_np
    return jnp.asarray(pack_bits_np(planes, axis=1))


@pytest.mark.parametrize("scoring", ["int8", "f32"])
@pytest.mark.parametrize("r", [16, 24, 32])
@pytest.mark.parametrize("n_q,n_sets", [
    (1, 1), (5, 3), (13, 8), (31, 5), (100, 6),
])
def test_xam_multiset_packed_parity_matrix(n_q, n_sets, r, scoring, rng):
    """PR-3's parity matrix rerun with packed planes: ragged/non-pow2
    batches, empty sets, a query-less set, both scorings, three key
    widths — packed == int8 == per-set reference, bit for bit."""
    c = 96
    planes, valid, bits, sets = _random_multiset(rng, n_sets, r, c, n_q)
    valid[::2] = 0
    if n_sets > 1:
        sets[sets == n_sets - 1] = 0
    got_p = np.asarray(xam_ops.xam_search_multiset(
        bits, sets, _packed_planes(planes), jnp.asarray(valid),
        scoring=scoring))
    got_i = np.asarray(xam_ops.xam_search_multiset(
        bits, sets, jnp.asarray(planes), jnp.asarray(valid),
        scoring=scoring))
    np.testing.assert_array_equal(got_p, got_i)
    np.testing.assert_array_equal(
        got_p, _per_set_reference(bits, sets, planes, valid))


@pytest.mark.parametrize("scoring", ["int8", "f32"])
def test_xam_multiset_packed_all_sets_empty(scoring, rng):
    n_sets, r, c = 4, 16, 128
    planes = np.zeros((n_sets, r, c), np.int8)
    valid = np.zeros((n_sets, c), np.int8)
    bits = xam_ops.words_to_bits_np(
        rng.integers(0, 2 ** 32, 11, dtype=np.uint32), r)
    sets = rng.integers(0, n_sets, 11).astype(np.int32)
    got = np.asarray(xam_ops.xam_search_multiset(
        bits, sets, _packed_planes(planes), jnp.asarray(valid),
        scoring=scoring))
    assert (got == -1).all()


def test_xam_multiset_packed_rejects_ragged_rows(rng):
    """Packed planes carry no row count of their own, so R must be
    exactly 8x the packed rows — a 20-bit key can't ride a packed plane."""
    planes = np.zeros((2, 3, 64), np.uint8)      # 24 packed rows
    bits = np.zeros((4, 20), np.int8)            # but 20-bit keys
    with pytest.raises(ValueError, match="multiple of 8|packed"):
        xam_ops.xam_search_multiset(
            bits, np.zeros(4, np.int32), jnp.asarray(planes),
            jnp.asarray(np.zeros((2, 64), np.int8)))


@pytest.mark.parametrize("q,r,c", [(3, 64, 512), (16, 32, 100), (130, 64, 513),
                                   (5, 33, 64)])
def test_xam_search_packed_matches_int8(q, r, c, rng):
    """Flat search with packed data planes (host pads ragged R to x8 with
    zero bits; mask-0 pad rows are inert) == the int8 path."""
    keys = rng.integers(0, 2, (q, r)).astype(np.int8)
    data = rng.integers(0, 2, (r, c)).astype(np.int8)
    masks = rng.integers(0, 2, (q, r)).astype(np.int8)
    got_p = np.asarray(xam_ops.xam_search(
        keys, data, masks, plane_format="packed8"))
    got_i = np.asarray(xam_ops.xam_search(
        keys, data, masks, plane_format="int8"))
    np.testing.assert_array_equal(got_p, got_i)


@pytest.mark.parametrize("n_parts", [1, 2, 4])
@pytest.mark.parametrize("case", ["mixed", "one_shard_skew", "empty_shards"])
def test_xam_stacked_packed_parity(case, n_parts, rng):
    """The stacked single-dispatch layout with packed planes, over the
    shard-edge shapes, == per-set reference == flat packed kernel."""
    n_sets, r, c, n_q = 8, 24, 96, 33
    planes, valid, bits, sets = _random_multiset(rng, n_sets, r, c, n_q)
    s_part = n_sets // n_parts
    if case == "one_shard_skew":
        sets = (sets % s_part) + (n_parts - 1) * s_part
    elif case == "empty_shards":
        sets = np.where(sets % 2 == 0, 0, n_sets - 1).astype(sets.dtype)
    got = np.asarray(xam_ops.xam_search_multiset_stacked(
        bits, sets, _packed_planes(planes), jnp.asarray(valid),
        n_parts=n_parts))
    np.testing.assert_array_equal(
        got, _per_set_reference(bits, sets, planes, valid))
    np.testing.assert_array_equal(got, np.asarray(xam_ops.xam_search_multiset(
        bits, sets, _packed_planes(planes), jnp.asarray(valid))))


def test_packed_view_matches_kernel_packing(rng):
    """The functional-model layout twins (core.xam.packed_view) agree with
    the kernel-side numpy packer — ONE packing contract, two layers."""
    from repro.core import xam as xam_model
    from repro.kernels.common import pack_bits_np
    bits = rng.integers(0, 2, (32, 64)).astype(np.int8)
    np.testing.assert_array_equal(
        np.asarray(xam_model.packed_view(jnp.asarray(bits))),
        pack_bits_np(bits, axis=0))
    np.testing.assert_array_equal(
        np.asarray(xam_model.unpacked_view(
            xam_model.packed_view(jnp.asarray(bits)))), bits)


# ---------------------------------------------------------------------------
# hopscotch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [8, 32, 64, 128])
@pytest.mark.parametrize("n_q", [1, 7, 64])
def test_hopscotch_matches_ref(window, n_q, rng):
    n_slots = window * 16
    t_lo = rng.integers(0, 2 ** 32, n_slots, dtype=np.uint32)
    t_hi = rng.integers(0, 2 ** 32, n_slots, dtype=np.uint32)
    homes = rng.integers(0, n_slots - 2 * window, n_q).astype(np.int32)
    q_lo = rng.integers(0, 2 ** 32, n_q, dtype=np.uint32)
    q_hi = rng.integers(0, 2 ** 32, n_q, dtype=np.uint32)
    # plant hits for half the queries at random offsets
    for i in range(0, n_q, 2):
        off = int(rng.integers(0, window))
        q_lo[i] = t_lo[homes[i] + off]
        q_hi[i] = t_hi[homes[i] + off]
    got = np.asarray(hop_ops.hopscotch_lookup(
        t_lo, t_hi, homes, q_lo, q_hi, window=window))
    want = np.asarray(hopscotch_lookup_ref(
        jnp.asarray(t_lo), jnp.asarray(t_hi), jnp.asarray(homes),
        jnp.asarray(q_lo), jnp.asarray(q_hi), window))
    np.testing.assert_array_equal(got, want)
    for i in range(0, n_q, 2):  # planted hits found
        assert got[i] >= 0


@pytest.mark.parametrize("block_q", [8, 16])
def test_hopscotch_block_q_equivalent(block_q, rng):
    """Any per-step batch size yields the same offsets as the oracle."""
    window, n_q = 16, 27                   # ragged vs both block sizes
    n_slots = window * 16
    t_lo = rng.integers(0, 8, n_slots, dtype=np.uint32)   # dense collisions
    t_hi = rng.integers(0, 2, n_slots, dtype=np.uint32)
    homes = rng.integers(0, n_slots - 2 * window, n_q).astype(np.int32)
    q_lo = rng.integers(0, 8, n_q, dtype=np.uint32)
    q_hi = rng.integers(0, 2, n_q, dtype=np.uint32)
    got = np.asarray(hop_ops.hopscotch_lookup(
        t_lo, t_hi, homes, q_lo, q_hi, window=window, block_q=block_q))
    want = np.asarray(hopscotch_lookup_ref(
        jnp.asarray(t_lo), jnp.asarray(t_hi), jnp.asarray(homes),
        jnp.asarray(q_lo), jnp.asarray(q_hi), window))
    np.testing.assert_array_equal(got, want)


def test_hopscotch_first_match_wins(rng):
    window = 16
    n_slots = window * 8
    t_lo = np.zeros(n_slots, np.uint32)
    t_hi = np.zeros(n_slots, np.uint32)
    home = 5
    t_lo[home + 3] = 77
    t_lo[home + 9] = 77   # duplicate later in window
    got = np.asarray(hop_ops.hopscotch_lookup(
        t_lo, t_hi, np.asarray([home], np.int32),
        np.asarray([77], np.uint32), np.asarray([0], np.uint32),
        window=window))
    assert got[0] == 3


@settings(max_examples=25, deadline=None)
@given(window=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2 ** 31))
def test_hopscotch_property(window, seed):
    rng = np.random.default_rng(seed)
    n_slots = window * 8
    t_lo = rng.integers(0, 4, n_slots, dtype=np.uint32)  # dense collisions
    t_hi = rng.integers(0, 2, n_slots, dtype=np.uint32)
    n_q = 16
    homes = rng.integers(0, n_slots - 2 * window, n_q).astype(np.int32)
    q_lo = rng.integers(0, 4, n_q, dtype=np.uint32)
    q_hi = rng.integers(0, 2, n_q, dtype=np.uint32)
    got = np.asarray(hop_ops.hopscotch_lookup(
        t_lo, t_hi, homes, q_lo, q_hi, window=window))
    want = np.asarray(hopscotch_lookup_ref(
        jnp.asarray(t_lo), jnp.asarray(t_hi), jnp.asarray(homes),
        jnp.asarray(q_lo), jnp.asarray(q_hi), window))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# string_match
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,tile", [
    (100, 3, 64), (4096, 12, 4096), (5000, 12, 1024),
    (8192, 1, 4096),
    # pattern-as-long-as-tile-fraction stress case: ~23s of interpret-mode
    # Pallas on CPU, far beyond what the other cases already cover
    pytest.param(300, 300, 512, marks=pytest.mark.slow),
])
def test_string_match_matches_ref(n, p, tile, rng):
    text = rng.integers(97, 105, n).astype(np.uint8)   # 8 symbols: collisions
    start = int(rng.integers(0, n - p + 1))
    pattern = text[start:start + p].copy()
    got = np.asarray(sm_ops.string_match(text, pattern, tile=tile))
    want = np.asarray(string_match_ref(jnp.asarray(text), jnp.asarray(pattern)))
    np.testing.assert_array_equal(got, want)
    assert got[start] == 1


def test_string_match_vs_python(rng):
    text = bytes(rng.integers(97, 101, 2000).astype(np.uint8))
    pattern = b"abc"
    got = np.asarray(sm_ops.string_match(
        np.frombuffer(text, np.uint8), np.frombuffer(pattern, np.uint8),
        tile=256))
    expect = np.zeros(len(text), np.int8)
    i = text.find(pattern)
    while i != -1:
        expect[i] = 1
        i = text.find(pattern, i + 1)
    np.testing.assert_array_equal(got, expect)


def test_string_match_cross_tile_boundary(rng):
    """A match straddling a tile boundary must be found (halo logic)."""
    tile = 256
    text = np.full(3 * tile, ord("x"), np.uint8)
    pat = np.frombuffer(b"hello", np.uint8)
    pos = tile - 2  # straddles the first boundary
    text[pos:pos + 5] = pat
    got = np.asarray(sm_ops.string_match(text, pat, tile=tile))
    assert got[pos] == 1 and got.sum() == 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 31), p=st.integers(1, 20))
def test_string_match_property(seed, p):
    rng = np.random.default_rng(seed)
    n = 512
    text = rng.integers(0, 3, n).astype(np.uint8)  # tiny alphabet
    pattern = rng.integers(0, 3, p).astype(np.uint8)
    got = np.asarray(sm_ops.string_match(text, pattern, tile=128))
    want = np.asarray(string_match_ref(jnp.asarray(text), jnp.asarray(pattern)))
    np.testing.assert_array_equal(got, want)
