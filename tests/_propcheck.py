"""Deterministic fallback for ``hypothesis`` so the property tests still
exercise their core assertions from a clean checkout (no test extras
installed).

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

The shim implements exactly the strategy surface this repo uses
(``st.integers``, ``st.sampled_from``): each strategy carries a small fixed
list of example values (bounds, near-bounds, and seeded pseudo-random
interior points — derived from the bounds only, so runs are reproducible),
and ``given`` expands into a loop over those cases.  This is NOT a property
tester — no shrinking, no coverage-guided generation — it is a
deterministic-cases harness that keeps the assertions live; CI installs
real hypothesis via the ``test`` extra.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

N_INTERIOR = 5   # seeded interior points per integer strategy


class Strategy:
    def __init__(self, examples):
        # dedupe, preserve order
        self.examples = list(dict.fromkeys(examples))


def integers(min_value: int, max_value: int) -> Strategy:
    """Bounds, near-bounds, midpoint, and a few seeded interior values."""
    if min_value > max_value:
        raise ValueError("empty integer range")
    pts = [min_value, max_value, min_value + 1, max_value - 1,
           (min_value + max_value) // 2]
    # Seed from the bounds so the cases depend only on the strategy, never
    # on call order or process state.
    rng = np.random.default_rng([min_value & 0xFFFFFFFF,
                                 max_value & 0xFFFFFFFF, 0x9E3779B9])
    pts += [int(v) for v in
            rng.integers(min_value, max_value + 1, N_INTERIOR, np.int64)]
    return Strategy([p for p in pts if min_value <= p <= max_value])


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from of empty collection")
    return Strategy(elements)


def booleans() -> Strategy:
    return Strategy([False, True])


class _StrategiesNamespace:
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)


strategies = _StrategiesNamespace()
st = strategies


def settings(*_args, **_kwargs):
    """No-op stand-in for ``hypothesis.settings``."""
    def deco(fn):
        return fn
    return deco


def given(**named_strategies):
    """Run the test over a deterministic case matrix.

    Cases = round-robin alignment of each strategy's example list (so the
    case count is the LONGEST list, not the product — mirrors hypothesis's
    bounded example budget), plus the all-first and all-last corners.
    """
    for name, strat in named_strategies.items():
        if not isinstance(strat, Strategy):
            raise TypeError(f"{name}: expected _propcheck.Strategy, "
                            f"got {type(strat).__name__}")

    names = list(named_strategies)
    lists = [named_strategies[n].examples for n in names]
    n_cases = max(len(ex) for ex in lists)
    cases = [tuple(ex[i % len(ex)] for ex in lists) for i in range(n_cases)]
    cases.append(tuple(ex[0] for ex in lists))
    cases.append(tuple(ex[-1] for ex in lists))
    cases = list(dict.fromkeys(cases))

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            for case in cases:
                try:
                    fn(*args, **dict(zip(names, case)), **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"deterministic case {dict(zip(names, case))!r} "
                        f"failed: {e}") from e
        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (functools.wraps copies the full signature otherwise).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for pname, p in sig.parameters.items() if pname not in names])
        return wrapper
    return deco
