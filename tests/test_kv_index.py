"""MonarchKVIndex coverage: the fused single-launch lookup pinned against
the seed's per-set reference flow, plus the §8 durability policies —
no-allocate admission, t_MWW throttling, cold-victim eviction, rotary
remap — and randomized lookup-vs-shadow-map agreement."""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

import jax.numpy as jnp

from repro.data.pipeline import fingerprint_blocks
from repro.kernels.xam_search.ref import xam_search_ref
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex


def _small_cfg(**kw) -> KVIndexConfig:
    base = dict(n_sets=4, set_ways=64, admit_after_reads=0,
                m_writes=1 << 20, window_ops=1 << 30)
    base.update(kw)
    return KVIndexConfig(**base)


# ---------------------------------------------------------------------------
# Config hygiene.
# ---------------------------------------------------------------------------

def test_cfg_default_constructed_per_instance():
    a = MonarchKVIndex()
    b = MonarchKVIndex()
    assert a.cfg is not b.cfg          # no shared mutable default
    a.cfg.n_sets = 7
    assert b.cfg.n_sets == KVIndexConfig().n_sets


# ---------------------------------------------------------------------------
# Fused lookup: one launch, bit-identical to the seed's per-set flow.
# ---------------------------------------------------------------------------

def test_lookup_is_single_kernel_launch(rng):
    idx = MonarchKVIndex(_small_cfg(n_sets=8))
    toks = rng.integers(1, 5000, (4, 256)).astype(np.int32)
    idx.admit(toks)
    before = idx.stats.searches
    idx.lookup(toks)                   # 64 chunks spread over all 8 sets
    assert idx.stats.searches == before + 1


def _per_set_reference_lookup(idx: MonarchKVIndex,
                              tokens: np.ndarray) -> np.ndarray:
    """The seed implementation: one xam_search_ref per distinct set with
    host-side validity masking — the bit-identity oracle for lookup()."""
    fps = fingerprint_blocks(tokens, CHUNK_TOKENS)
    flat = fps.reshape(-1)
    sets = idx._set_of(flat)
    hit = np.zeros(flat.shape[0], bool)
    valid = np.asarray(idx.valid)
    bits = np.asarray(idx.bits)
    for s in np.unique(sets):
        sel = sets == s
        keys = ((flat[sel].astype(np.uint32)[:, None]
                 >> np.arange(idx.cfg.key_bits, dtype=np.uint32)) & 1
                ).astype(np.int8)
        m = np.asarray(xam_search_ref(
            jnp.asarray(keys), jnp.asarray(bits[int(s)]),
            jnp.ones_like(jnp.asarray(keys))))
        m = m & valid[int(s)][None, :]
        hit[sel] = m.any(axis=1)
    return hit.reshape(fps.shape)


def test_lookup_bit_identical_to_per_set_reference(rng):
    idx = MonarchKVIndex(_small_cfg(n_sets=8, set_ways=32))
    seen = rng.integers(1, 4000, (4, 128)).astype(np.int32)
    idx.admit(seen)
    mixed = np.concatenate(
        [seen[:2], rng.integers(1, 4000, (3, 128)).astype(np.int32)])
    got = idx.lookup(mixed)
    want = _per_set_reference_lookup(idx, mixed)
    np.testing.assert_array_equal(got, want)
    assert got.any()                   # admitted chunks hit


def test_lookup_empty_and_short_tokens():
    idx = MonarchKVIndex(_small_cfg())
    short = np.ones((2, CHUNK_TOKENS - 1), np.int32)   # 0 whole chunks
    assert idx.lookup(short).shape == (2, 0)


# ---------------------------------------------------------------------------
# Admission policy: no-allocate filter and t_MWW throttle.
# ---------------------------------------------------------------------------

def test_no_allocate_filter_counts_touches(rng):
    idx = MonarchKVIndex(_small_cfg(admit_after_reads=2))
    toks = rng.integers(1, 1000, (1, 64)).astype(np.int32)
    idx.admit(toks)                    # touch 1
    idx.admit(toks)                    # touch 2
    assert idx.stats.admissions == 0
    assert idx.stats.admission_skips > 0
    idx.admit(toks)                    # touch 3: over the R threshold
    assert idx.stats.admissions > 0
    assert idx.lookup(toks).all()


def test_t_mww_throttle_blocks_admissions(rng):
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=1, set_ways=64, admit_after_reads=0, m_writes=0,
        window_ops=1 << 30))
    toks = rng.integers(1, 100_000, (1, 16 * 16)).astype(np.int32)
    idx.admit(toks)
    assert idx.stats.admissions == 0
    assert idx.stats.throttled > 0
    assert not idx.lookup(toks).any()  # recompute-served, never installed


def test_t_mww_window_reset_reopens_admission(rng):
    # budget = set_ways * m_writes = 4 installs per 16-op window (the shared
    # core/wear.py accounting; ops stand in for cycles).
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=1, set_ways=4, admit_after_reads=0, m_writes=1,
        window_ops=16))
    idx.admit_fps(np.arange(1, 9, dtype=np.uint32))
    assert idx.stats.admissions == 4       # budget exhausted mid-batch
    assert idx.stats.throttled == 4
    toks = rng.integers(1, 100_000, (1, 8 * CHUNK_TOKENS)).astype(np.int32)
    idx.lookup(toks)                       # ops advance past the window
    assert idx.ops_total >= 16
    idx.admit_fps(np.arange(100, 103, dtype=np.uint32))
    assert idx.stats.admissions == 7       # window rolled over: admitting again
    assert idx.stats.throttled == 4


# ---------------------------------------------------------------------------
# Eviction: D̄&R̄-style cold victims go first.
# ---------------------------------------------------------------------------

def test_eviction_prefers_never_reread_ways():
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=1, set_ways=8, admit_after_reads=0, m_writes=1 << 20,
        window_ops=1 << 30))
    fps = [np.uint32(f) for f in range(1, 9)]
    for fp in fps:
        idx._admit_one(fp)
    assert len(idx.slot_of) == 8       # set full
    hot = fps[:5]
    for fp in hot:
        idx._admit_one(fp)             # re-touch: marks read_after
    idx._admit_one(np.uint32(1000))    # forces one eviction
    assert idx.stats.evictions == 1
    for fp in hot:                     # re-read ways were not the victim
        assert int(fp) in idx.slot_of
    assert 1000 in idx.slot_of


def test_eviction_updates_device_state():
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=1, set_ways=4, admit_after_reads=0, m_writes=1 << 20,
        window_ops=1 << 30))
    for f in range(1, 10):             # overflows the 4-way set
        idx._admit_one(np.uint32(f))
    assert idx.stats.evictions > 0
    # device planes and host shadow stay consistent through evictions
    assert int(np.asarray(idx.valid).sum()) == len(idx.slot_of)
    resident = np.asarray(sorted(idx.slot_of), np.uint32)
    assert idx._shadow_hits(resident).all()
    fp_plane = np.asarray(idx.fp_of)[0]
    for fp, (s, w) in idx.slot_of.items():
        assert fp_plane[w] == fp


# ---------------------------------------------------------------------------
# Rotary remap.
# ---------------------------------------------------------------------------

def test_rotary_remap_moves_new_placements(rng):
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=8, set_ways=64, admit_after_reads=0, m_writes=1 << 20,
        window_ops=1 << 30, rotate_every=16))
    toks = rng.integers(1, 1 << 20, (4, 256)).astype(np.int32)
    idx.admit(toks)
    assert idx.stats.rotations >= 1
    assert idx.offset == (7 * idx.stats.rotations) % idx.cfg.n_sets
    fp = np.uint32(0xDEAD)
    before = idx._set_of(np.asarray([fp]))[0]
    idx._rotate()
    after = idx._set_of(np.asarray([fp]))[0]
    assert after == (before + 7) % idx.cfg.n_sets


# ---------------------------------------------------------------------------
# Batched admission: one device call, sequential-order equivalence.
# ---------------------------------------------------------------------------

def test_admit_is_single_device_call(rng):
    """The whole admission batch goes through ONE jitted device launch (the
    pre-PR implementation issued one install call per fingerprint)."""
    idx = MonarchKVIndex(_small_cfg(n_sets=8))
    toks = rng.integers(1, 50_000, (4, 256)).astype(np.int32)
    idx.admit(toks)                        # 64 unique chunks, 8 sets
    assert idx.stats.admit_calls == 1
    idx.admit(toks)                        # resident re-offers: still 1 call
    assert idx.stats.admit_calls == 2
    assert idx.stats.admissions == 64


def _snapshot(idx: MonarchKVIndex):
    return dict(
        slot_of=dict(idx.slot_of),
        valid=np.asarray(idx.valid).copy(),
        fp_of=np.asarray(idx.fp_of).copy(),
        read_after=np.asarray(idx.read_after).copy(),
        set_writes=np.asarray(idx.set_writes).copy(),
        counter=np.asarray(idx.counter).copy(),   # per-set replacement ctrs
        ops=idx.ops_total,
        window_writes=np.asarray(idx.wear_state.window_writes).copy(),
        locked_until=np.asarray(idx.wear_state.locked_until).copy(),
        stats=(idx.stats.admissions, idx.stats.admission_skips,
               idx.stats.throttled, idx.stats.evictions),
    )


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_sets=st.sampled_from([1, 4, 8]))
def test_batched_admit_equals_sequential_order(seed, n_sets):
    """Satellite pin: a randomized admit/lookup/evict/rotate schedule run
    through the batched pipeline must equal the same schedule admitted one
    fingerprint at a time (the seed's sequential admission order) — same
    shadow map, device planes, wear state and stats.  The only intentional
    divergence from the seed is documented in kv_index.py: rotation now
    remaps resident entries instead of orphaning them, and the t_MWW window
    is the shared core/wear.py accounting."""
    rng = np.random.default_rng(seed)
    cfg = dict(n_sets=n_sets, set_ways=16, admit_after_reads=1,
               m_writes=1, window_ops=64, rotate_every=1 << 30)
    a = MonarchKVIndex(KVIndexConfig(**cfg))
    b = MonarchKVIndex(KVIndexConfig(**cfg))
    for step in range(6):
        toks = rng.integers(1, 2000, (2, 8 * CHUNK_TOKENS)).astype(np.int32)
        op = rng.random()
        if op < 0.55:
            fps = np.unique(
                fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
            a.admit_fps(fps)               # one batched device call
            for fp in fps:                 # sequential reference order
                b.admit_fps(np.asarray([fp], np.uint32))
        elif op < 0.85:
            got = a.lookup(toks)
            want = b.lookup(toks)
            np.testing.assert_array_equal(got, want)
        else:
            a._rotate()
            b._rotate()
        sa, sb = _snapshot(a), _snapshot(b)
        for k in sa:
            if isinstance(sa[k], np.ndarray):
                np.testing.assert_array_equal(sa[k], sb[k], err_msg=k)
            else:
                assert sa[k] == sb[k], (k, sa[k], sb[k])


def test_clock_rebase_keeps_windows_live():
    """A long-lived op counter folds back before the int32 cycle domain
    wraps, and the t_MWW window keeps expiring/throttling correctly across
    the fold."""
    from repro.core import wear
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=1, set_ways=4, admit_after_reads=0, m_writes=1,
        window_ops=16))
    idx.ops_total = wear.CLOCK_REBASE_AT + 3
    idx.admit_fps(np.arange(1, 9, dtype=np.uint32))
    assert idx.ops_total < wear.CLOCK_REBASE_AT    # clock folded
    assert idx.stats.admissions == 4               # budget still enforced
    assert idx.stats.throttled == 4
    idx.ops_total += 32                            # window expires
    idx.admit_fps(np.arange(100, 103, dtype=np.uint32))
    assert idx.stats.admissions == 7


# ---------------------------------------------------------------------------
# Rotation: device start-gap remap preserves residency; rotation + zipf
# skew levels per-set install wear.
# ---------------------------------------------------------------------------

def test_rotation_remap_preserves_residency(rng):
    """Intentional change vs the seed (documented in kv_index.py): the
    device plane roll moves resident entries WITH the offset bump, so they
    stay searchable after rotation — and stay in agreement with the shadow
    map."""
    idx = MonarchKVIndex(_small_cfg(n_sets=8, set_ways=32))
    toks = rng.integers(1, 4000, (4, 128)).astype(np.int32)
    idx.admit(toks)
    assert idx.lookup(toks).all()
    for _ in range(3):
        idx._rotate()
        got = idx.lookup(toks).reshape(-1)
        want = idx._shadow_hits(
            fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
        np.testing.assert_array_equal(got, want)
        assert got.all()                   # still resident after remap
    # shadow map agrees with the rolled fp planes slot-for-slot
    fp_plane = np.asarray(idx.fp_of)
    for fp, (s, w) in idx.slot_of.items():
        assert fp_plane[s, w] == fp


def _fps_for_set(idx: MonarchKVIndex, n: int, target_set: int) -> np.ndarray:
    """n distinct fingerprints whose (offset-0) home is ``target_set``."""
    out, fp = [], 1
    while len(out) < n:
        cand = np.uint32(fp)
        if int(idx._set_of(np.asarray([cand]))[0]) == target_set:
            out.append(cand)
        fp += 1
    return np.asarray(out, np.uint32)


def test_rotation_levels_skewed_install_wear():
    """Satellite invariant: under a maximally skewed (single-home-set)
    install trace, rotary remapping bounds the max-per-set write count
    relative to the mean; without rotation the wear concentrates."""
    mk = lambda rot: MonarchKVIndex(KVIndexConfig(
        n_sets=8, set_ways=16, admit_after_reads=0, m_writes=1 << 15,
        window_ops=1 << 30, rotate_every=rot))
    hot = mk(1 << 30)
    fps = _fps_for_set(hot, 128, target_set=0)
    for chunk in fps.reshape(8, 16):       # same trace, batch size 16
        hot.admit_fps(chunk)
    w_hot = hot.write_distribution().astype(float)
    assert w_hot.max() / w_hot.mean() == 8.0   # all installs in one set

    lev = mk(16)                           # rotate every 16 admissions
    for chunk in fps.reshape(8, 16):
        lev.admit_fps(chunk)
    assert lev.stats.rotations >= 7
    w_lev = lev.write_distribution().astype(float)
    assert w_lev.sum() == w_hot.sum()      # writes conserved under rotation
    assert w_lev.max() / w_lev.mean() <= 2.0   # leveled across sets


# ---------------------------------------------------------------------------
# Randomized lookup-vs-shadow-map agreement.
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 31), n_sets=st.sampled_from([1, 4, 8]))
def test_lookup_agrees_with_shadow_map(seed, n_sets):
    rng = np.random.default_rng(seed)
    idx = MonarchKVIndex(_small_cfg(n_sets=n_sets, set_ways=32))
    for _ in range(4):
        toks = rng.integers(1, 3000, (2, 128)).astype(np.int32)
        if rng.random() < 0.7:
            idx.admit(toks)
        got = idx.lookup(toks).reshape(-1)
        want = idx._shadow_hits(
            fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
        np.testing.assert_array_equal(got, want)


def test_write_distribution_tracks_admissions(rng):
    idx = MonarchKVIndex(_small_cfg(n_sets=8, set_ways=512))
    for _ in range(4):
        idx.admit(rng.integers(1, 1 << 20, (4, 256)).astype(np.int32))
    dist = idx.write_distribution()
    assert dist.sum() == idx.stats.admissions
    assert (np.asarray(idx.valid).sum(axis=1) == dist).all()


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
