"""Pins for the measured block-shape autotuner (kernels/autotune.py).

The autotune cache only ever changes SPEED, never answers: block shapes
are layout knobs of kernels whose results are layout-independent.  These
tests pin the three load-bearing properties:

* cold cache == deterministic fallback == today's pre-autotune heuristic,
  and the kernel RESULTS are bit-identical with and without the cache
  (the CI cold-cache leg reruns the whole suite under a repointed
  ``REPRO_AUTOTUNE_CACHE`` to prove the same at scale);
* the committed cache file is well-formed: every family is keyed
  ``kernel/backend/plane_format/bucket``, carries MXU-aligned winners
  drawn from the declared candidate sets, and covers both plane formats;
* the env knob / fingerprint plumbing behaves (unknown paths fall back,
  the fingerprint distinguishes cold from warm).
"""
from __future__ import annotations

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.common import pack_bits_np
from repro.kernels.xam_search import ops as xam_ops
from repro.kernels.xam_search.kernel import (
    DEFAULT_BLOCK_C, DEFAULT_BLOCK_Q, MULTISET_BLOCK_Q)


@pytest.fixture
def cold_cache(tmp_path, monkeypatch):
    """Point the loader at a nonexistent cache file for the duration."""
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "absent.json"))
    autotune.reset_cache()
    yield
    autotune.reset_cache()


def test_cold_cache_falls_back_to_heuristic(cold_cache):
    assert autotune.multiset_block_q(16) == MULTISET_BLOCK_Q
    assert autotune.multiset_block_q(autotune.WIDE_BLOCK_AT - 1) == \
        MULTISET_BLOCK_Q
    assert autotune.multiset_block_q(autotune.WIDE_BLOCK_AT) == \
        autotune.WIDE_BLOCK_Q
    assert autotune.multiset_block_q(1000, "packed8") == \
        autotune.WIDE_BLOCK_Q
    assert autotune.search_blocks() == (DEFAULT_BLOCK_Q, DEFAULT_BLOCK_C)
    assert autotune.cache_fingerprint() == "cold"


def test_cold_cache_results_bit_identical(cold_cache, monkeypatch, rng):
    """Fallback block shapes produce the SAME answers as the committed
    winners — the sweep tunes speed, not semantics."""
    n_sets, r, c, n_q = 8, 32, 256, 50
    planes = rng.integers(0, 2, (n_sets, r, c)).astype(np.int8)
    valid = rng.integers(0, 2, (n_sets, c)).astype(np.int8)
    bits = xam_ops.words_to_bits_np(
        rng.integers(0, 2 ** 32, n_q, dtype=np.uint32), r)
    sets = rng.integers(0, n_sets, n_q).astype(np.int32)
    cold = {}
    for fmt, pl in [("int8", planes), ("packed8", pack_bits_np(planes, 1))]:
        cold[fmt] = np.asarray(xam_ops.xam_search_multiset(
            bits, sets, jnp.asarray(pl), jnp.asarray(valid)))
    autotune.reset_cache()
    monkeypatch.delenv(autotune.CACHE_ENV)      # back to the committed file
    for fmt, pl in [("int8", planes), ("packed8", pack_bits_np(planes, 1))]:
        warm = np.asarray(xam_ops.xam_search_multiset(
            bits, sets, jnp.asarray(pl), jnp.asarray(valid)))
        np.testing.assert_array_equal(warm, cold[fmt])


def test_corrupt_cache_is_cold(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setenv(autotune.CACHE_ENV, str(bad))
    autotune.reset_cache()
    try:
        assert autotune.multiset_block_q(16) == MULTISET_BLOCK_Q
        assert autotune.search_blocks() == (DEFAULT_BLOCK_Q, DEFAULT_BLOCK_C)
        assert autotune.cache_fingerprint() != "cold"   # file exists...
    finally:
        autotune.reset_cache()


def test_committed_cache_well_formed():
    """The checked-in winners: every family key is
    kernel/backend/plane_format/bucket, winners come from the declared
    candidate sets, both plane formats and both multiset buckets are
    covered for the committed backend."""
    payload = json.loads(autotune.DEFAULT_CACHE_PATH.read_text())
    fams = payload["families"]
    assert fams, "committed cache must not be empty"
    backend = payload["backend"]
    for key, fam in fams.items():
        kernel, b, fmt, bucket = key.split("/")
        assert kernel in ("xam_multiset", "xam_search")
        assert b == backend
        assert fmt in ("int8", "packed8")
        assert fam["block_q"] in autotune.BLOCK_Q_CANDIDATES
        assert fam["block_q"] % 8 == 0 or fam["block_q"] == 8
        if kernel == "xam_search":
            assert bucket == "default"
            assert fam["block_c"] in autotune.BLOCK_C_CANDIDATES
            assert fam["block_c"] % 128 == 0
        else:
            assert bucket in ("narrow", "wide")
        assert set(fam["swept"]) and fam["median_us"] > 0
    for fmt in ("int8", "packed8"):
        for bucket in ("narrow", "wide"):
            assert f"xam_multiset/{backend}/{fmt}/{bucket}" in fams
        assert f"xam_search/{backend}/{fmt}/default" in fams


def test_committed_cache_served_when_backend_matches(monkeypatch):
    """On the backend the cache was swept on, the consult functions must
    answer with the committed winners (not the fallback) — explicitly
    against the committed file, so the cold-cache CI leg (which repoints
    ``REPRO_AUTOTUNE_CACHE``) still exercises the warm path here."""
    payload = json.loads(autotune.DEFAULT_CACHE_PATH.read_text())
    if payload["backend"] != autotune._backend():
        pytest.skip("cache swept on a different backend")
    monkeypatch.delenv(autotune.CACHE_ENV, raising=False)
    autotune.reset_cache()
    fams = payload["families"]
    key = autotune.family_key("xam_multiset", "packed8", "narrow")
    assert autotune.multiset_block_q(16, "packed8") == fams[key]["block_q"]
    key = autotune.family_key("xam_search", "int8", "default")
    assert autotune.search_blocks("int8") == (
        fams[key]["block_q"], fams[key]["block_c"])
    autotune.reset_cache()


def test_fingerprint_tracks_file_content(tmp_path, monkeypatch):
    a = tmp_path / "a.json"
    a.write_text('{"families": {}}')
    monkeypatch.setenv(autotune.CACHE_ENV, str(a))
    autotune.reset_cache()
    try:
        fp1 = autotune.cache_fingerprint()
        a.write_text('{"families": {"x": 1}}')
        fp2 = autotune.cache_fingerprint()
        assert fp1 != fp2 and "cold" not in (fp1, fp2)
        assert len(fp1) == 16
    finally:
        autotune.reset_cache()


def test_block_q_never_changes_jit_bucket_count(cold_cache):
    """The shape-bucket contract: within one bucket every batch size maps
    to ONE block_q, cold or warm — so the pow2 jit-cache cap holds under
    any cache state.  (The warm path is pinned by the cap tests running
    against the committed cache in the same suite.)"""
    narrow = {autotune.multiset_block_q(q) for q in (1, 8, 64, 255)}
    wide = {autotune.multiset_block_q(q) for q in (256, 300, 1000)}
    assert len(narrow) == 1 and len(wide) == 1


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
