"""Distribution-layer tests: partition specs + divisibility guards,
checkpoint save/restore/restart, elastic reshard, gradient compression,
straggler watchdog, data-pipeline determinism."""
from __future__ import annotations

import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.data import pipeline
from repro.dist import checkpoint, compression, elastic, sharding, straggler
from repro.launch import specs as lspecs
from repro.launch.mesh import make_host_mesh
from repro.models import transformer
from repro.train import step as train_step_mod


def _mesh1():
    return make_host_mesh()


# ---------------------------------------------------------------------------
# Partition specs.
# ---------------------------------------------------------------------------

def test_param_specs_structure_matches_params():
    cfg = configs.get_arch("yi-9b").reduced()
    shapes = lspecs.params_shapes(cfg)
    specs = sharding.param_specs(shapes, _mesh1())
    assert jax.tree_util.tree_structure(shapes) == \
        jax.tree_util.tree_structure(specs)
    flat = jax.tree_util.tree_leaves_with_path(specs)
    for path, spec in flat:
        assert isinstance(spec, P)


def test_param_specs_rules_on_known_leaves():
    """TP axes land where the rules say (verified against a fake mesh big
    enough to divide everything)."""
    cfg = configs.get_arch("yi-9b")          # FULL config (divisible dims)
    shapes = lspecs.params_shapes(cfg)
    devs = np.asarray(jax.devices() * 4)[:4].reshape(2, 2) \
        if len(jax.devices()) >= 4 else None
    if devs is None:
        # single device: fabricate the mesh via axis sizes 1x1 (guards pass
        # everything through; assert the RULE, pre-guard, instead)
        mesh = _mesh1()
    else:
        mesh = Mesh(devs, ("data", "model"))
    specs = sharding.param_specs(shapes, mesh)

    def find(name):
        for path, s in jax.tree_util.tree_leaves_with_path(specs):
            keys = [getattr(p, "key", "") for p in path]
            if keys[-1] == name:
                return keys, s
        raise KeyError(name)

    keys, s = find("wq")
    assert "groups" in keys          # stacked under the scanned group
    assert s[0] is None              # leading stacked axis unsharded
    keys, s = find("final_ln")
    assert all(ax is None for ax in s)   # norms replicated


def test_divisibility_guard_drops_unshardable_dims():
    mesh = _mesh1()                   # (N, 1) — model axis size 1
    # a dim of size 3 cannot shard over data axis size len(devices) unless 1
    got = sharding._guard(("data", "model"), (3, 5), mesh)
    n_data = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    if 3 % n_data != 0:
        assert got[0] is None
    assert got == P(*got)             # always a valid PartitionSpec


def test_batch_and_cache_specs_cover_tree():
    cfg = configs.get_arch("gemma3-27b").reduced()
    mesh = _mesh1()
    batch = lspecs.train_batch_specs(cfg, configs.get_shape("train_4k"))
    bs = sharding.batch_specs(batch, mesh)
    assert jax.tree_util.tree_structure(batch) == \
        jax.tree_util.tree_structure(bs)
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, 4, 64))
    cs = sharding.cache_specs(cache, mesh)
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cs)


# ---------------------------------------------------------------------------
# Checkpointing.
# ---------------------------------------------------------------------------

def _tiny_state():
    cfg = configs.get_arch("yi-9b").reduced()
    return train_step_mod.init_state(jax.random.PRNGKey(0), cfg)


def test_checkpoint_roundtrip(tmp_path):
    state = _tiny_state()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, state, process_index=0)
    step, restored = checkpoint.restore_latest(d, state)
    assert step == 7
    same = jax.tree.map(lambda a, b: bool(jnp.all(jnp.asarray(a) == jnp.asarray(b))),
                        state, restored)
    assert all(jax.tree.leaves(same))


def test_checkpoint_atomic_publish_ignores_partial(tmp_path):
    state = _tiny_state()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, state, process_index=0)
    # simulate a crashed writer: stale tmp dir + a step dir w/o manifest
    os.makedirs(os.path.join(d, "step_9.tmp"))
    os.makedirs(os.path.join(d, "step_5"))
    step, _ = checkpoint.restore_latest(d, state)
    assert step == 1
    checkpoint.save(d, 2, state, process_index=0)  # gc cleans the tmp
    assert not os.path.exists(os.path.join(d, "step_9.tmp"))


def test_checkpoint_keep_last(tmp_path):
    state = _tiny_state()
    d = str(tmp_path / "ckpt")
    for s in range(6):
        checkpoint.save(d, s, state, keep_last=3, process_index=0)
    assert checkpoint.published_steps(d) == [3, 4, 5]


def test_checkpoint_restart_training_equivalence(tmp_path):
    """Kill-and-restart: train 4 steps straight == train 2, checkpoint,
    restore, train 2 more (bitwise on the optimizer step; allclose params)."""
    cfg = configs.get_arch("yi-9b").reduced()
    dcfg = pipeline.DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=2, seed=3)
    step_fn = jax.jit(train_step_mod.make_train_step(cfg))

    def batch(i):
        b = pipeline.batch_at(dcfg, i)
        return {k: jnp.asarray(v) for k, v in b.items()}

    s_direct = train_step_mod.init_state(jax.random.PRNGKey(0), cfg)
    for i in range(4):
        s_direct, _ = step_fn(s_direct, batch(i))

    s_a = train_step_mod.init_state(jax.random.PRNGKey(0), cfg)
    for i in range(2):
        s_a, _ = step_fn(s_a, batch(i))
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 2, s_a, process_index=0)
    step, s_b = checkpoint.restore_latest(d, s_a)
    s_b = jax.tree.map(jnp.asarray, s_b)
    for i in range(step, 4):
        s_b, _ = step_fn(s_b, batch(i))

    assert int(s_direct["opt"]["step"]) == int(s_b["opt"]["step"]) == 4
    for a, b in zip(jax.tree.leaves(s_direct["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Elastic rescaling.
# ---------------------------------------------------------------------------

def test_elastic_reshard_roundtrip(tmp_path):
    state = _tiny_state()
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 3, state, process_index=0)
    new_mesh = _mesh1()     # "new" device topology (same host here)
    step, restored = elastic.resume_elastic(d, state, new_mesh,
                                            run_dir=str(tmp_path))
    assert step == 3
    same = jax.tree.map(lambda a, b: bool(jnp.all(jnp.asarray(a) == jnp.asarray(b))),
                        state, restored)
    assert all(jax.tree.leaves(same))
    assert os.path.exists(os.path.join(str(tmp_path), "scale_events.jsonl"))


@settings(max_examples=20, deadline=None)
@given(gb=st.integers(1, 4096), n=st.integers(1, 64))
def test_elastic_batch_invariants(gb, n):
    per, used = elastic.elastic_batch(gb, n)
    assert per >= 1
    assert used == per * n
    assert used <= max(gb, n)


# ---------------------------------------------------------------------------
# Gradient compression.
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound(rng):
    g = jnp.asarray(rng.normal(size=(1000,)) * 3.0, jnp.float32)
    q, s, pad = compression.quantize_int8(g)
    back = compression.dequantize_int8(q, s, pad, g.shape)
    # error bounded by half a quantization step per block
    err = np.abs(np.asarray(back - g))
    step = np.repeat(np.asarray(s), compression.BLOCK)[: g.shape[0]]
    assert (err <= step * 0.5 + 1e-7).all()


def test_compressed_psum_error_feedback(rng):
    """Over repeated reductions, error feedback keeps the accumulated
    mean-estimate unbiased (residual stays bounded)."""
    mesh = _mesh1()
    if mesh.devices.size != 1:
        pytest.skip("single-device formulation")
    from jax.experimental.shard_map import shard_map
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    r = jnp.zeros_like(g)

    def f(g, r):
        return compression.compressed_psum_leaf(g, r, "data")

    fm = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    total_err = []
    acc = jnp.zeros_like(g)
    for _ in range(8):
        out, r = fm(g, r)
        acc = acc + out
    # accumulated sum ~= 8 * g (error feedback corrects quantization bias)
    np.testing.assert_allclose(np.asarray(acc) / 8, np.asarray(g),
                               atol=np.abs(np.asarray(g)).max() / 100)


# ---------------------------------------------------------------------------
# Straggler watchdog.
# ---------------------------------------------------------------------------

def test_straggler_policy_escalation():
    cfg = straggler.StragglerConfig(quantile=0.5, slack=2.0,
                                    escalate_after=3, min_history=4)
    w = straggler.StragglerWatchdog(cfg)
    for _ in range(8):
        assert w.observe(1.0) in (straggler.OK,)
    # slow steps: retry, retry, then rejoin on the 3rd consecutive
    assert w.observe(10.0) == straggler.RETRY
    assert w.observe(10.0) == straggler.RETRY
    assert w.observe(10.0) == straggler.REJOIN
    # hysteresis: healthy steps decay suspicion
    assert w.observe(1.0) == straggler.OK
    assert w.observe(10.0) == straggler.RETRY


def test_straggler_single_gc_pause_tolerated():
    w = straggler.StragglerWatchdog(straggler.StragglerConfig(min_history=4))
    for _ in range(8):
        w.observe(1.0)
    assert w.observe(50.0) == straggler.RETRY   # one pause: no eviction
    for _ in range(4):
        assert w.observe(1.0) == straggler.OK


# ---------------------------------------------------------------------------
# Data pipeline determinism / addressability.
# ---------------------------------------------------------------------------

def test_batch_at_deterministic_and_shardable():
    cfg = pipeline.DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    a = pipeline.batch_at(cfg, step=5)
    b = pipeline.batch_at(cfg, step=5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipeline.batch_at(cfg, step=6)
    assert (a["tokens"] != c["tokens"]).any()
    # shards are disjoint functions of (step, shard) and stable
    s0 = pipeline.batch_at(cfg, 5, shard=0, n_shards=4)
    s0b = pipeline.batch_at(cfg, 5, shard=0, n_shards=4)
    np.testing.assert_array_equal(s0["tokens"], s0b["tokens"])
    assert s0["tokens"].shape[0] == 2
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_fingerprints_and_dedup(rng):
    toks = rng.integers(1, 1000, (4, 64)).astype(np.int32)
    fps = pipeline.fingerprint_blocks(toks, 16)
    assert fps.shape == (4, 4)
    fps2 = pipeline.fingerprint_blocks(toks, 16)
    np.testing.assert_array_equal(fps, fps2)
    # same block content -> same fingerprint
    toks2 = toks.copy()
    toks2[1] = toks[0]
    fps3 = pipeline.fingerprint_blocks(toks2, 16)
    np.testing.assert_array_equal(fps3[1], fps3[0])


def test_murmur3_jnp_matches_np(rng):
    x = rng.integers(0, 2 ** 32, 100, dtype=np.uint32)
    a = np.asarray(pipeline.murmur3_fmix32(jnp.asarray(x)))
    b = pipeline.murmur3_np(x)
    np.testing.assert_array_equal(a, b)


def test_ycsb_stream_properties():
    cfg = pipeline.YcsbConfig(n_keys=1000, n_ops=10_000, read_fraction=0.95)
    keys, is_read = pipeline.ycsb_ops(cfg)
    assert abs(is_read.mean() - 0.95) < 0.02
    assert len(np.unique(keys[is_read])) <= 1000
