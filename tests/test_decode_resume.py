"""Prefix-cache decode resume: the token-identity tier.

The tentpole pin: greedy decode from a RESUMED cached prefix equals the
full-prefill decode token-for-token — the prefix cache must be a pure
compute optimization, never a numerics change.  Pinned here at three
levels:

* ``transformer.prefill(prefix_kv=...)`` directly: logits, the whole
  decode-cache pytree, AND the returned suffix KV are bit-identical to a
  full prefill, swept over RoPE on/off (``ArchConfig.use_rope``),
  attention kinds (all-global yi-9b, local+global gemma3), and prompt
  lengths straddling ``CHUNK_TOKENS`` boundaries.
* :class:`repro.serve.resume.PrefixResumeEngine` through the index +
  slab store: hits restore slabs, misses recompute, rotation keeps hits
  (slab keys are fingerprints — rotation remaps sets, evicts nothing),
  eviction drops the slab and degrades to a full recompute, a
  hit-without-slab truncates the resume run — in every case the decoded
  tokens match the no-cache reference.
* The full serving loop (``run_request_loop`` + ``AdmitQueue``): a
  randomized zipf schedule replayed at ``n_shards in {1, 2, 4}`` and
  against the kept ``dispatch="fanout"`` oracle produces identical
  per-request hits/resumed counts, identical policy state (installs,
  planes, wear), and identical decoded tokens.  Rides the CI
  forced-4-device leg, where the shard counts get real placement.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.serve import run_request_loop
from repro.models import transformer
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig, KVSlabStore,
                                  MonarchKVIndex)
from repro.serve.resume import PrefixResumeEngine

SHARD_COUNTS = (1, 2, 4)


def _arch(kind: str, use_rope: bool = True):
    """CI-sized archs by attention mix: all-global, all-local, or both."""
    if kind == "global":
        cfg = configs.get_arch("yi-9b").reduced()
    elif kind == "local":
        cfg = configs.get_arch("gemma3-27b").reduced()
    else:                                  # 5 local + 1 global (5:1 pattern)
        cfg = dataclasses.replace(
            configs.get_arch("gemma3-27b").reduced(), n_layers=6)
    return dataclasses.replace(cfg, use_rope=use_rope)


def _greedy(params, cfg, logits, cache, pos, n=3):
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    outs = []
    for t in range(n):
        outs.append(np.asarray(nxt))
        logits, cache = transformer.decode_step(
            params, cfg, nxt, cache, jnp.int32(pos + t))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return np.concatenate(outs, axis=1)


# On the default single-device tier, XLA CPU executes the full-prompt
# and suffix shapes with identical reduction order, so resume is
# bit-for-bit equal.  Under the CI forced-4-device leg XLA splits its
# host thread pool across the virtual devices and re-tiles the fused
# matmuls per shape — bf16 accumulation order then differs between the
# two prefills (~1e-2 on logits), which is numerics, not a resume bug.
# So: bit-identity pinned at 1 device, tight allclose there-plus-token
# -identity (the invariant the paper-level claim actually needs) always.
_EXACT = jax.device_count() == 1


def _arrays_match(a, b, msg):
    if _EXACT:
        assert jnp.array_equal(a, b), msg
    else:
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-2, atol=5e-2, err_msg=msg)


def _tree_match(a, b, msg):
    for pa, (path, la) in zip(jax.tree.leaves(b),
                              jax.tree_util.tree_leaves_with_path(a)):
        _arrays_match(la, pa, (msg, path))


# ---------------------------------------------------------------------------
# Transformer-level bit identity.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_rope", [True, False])
@pytest.mark.parametrize("kind", ["global", "local", "mixed"])
def test_resume_prefill_bit_identity(rng, kind, use_rope):
    """Resume-from-offset prefill == full prefill: logits, every decode
    cache leaf, the suffix KV, and 3 greedy decode tokens, for prefix
    lengths straddling chunk boundaries (S=40 leaves a 8-token partial
    chunk that is always recomputed)."""
    cfg = _arch(kind, use_rope)
    params = transformer.init_params(jax.random.PRNGKey(1), cfg)
    for s, p_chunks in ((48, 1), (48, 2), (40, 1)):
        toks = rng.integers(1, cfg.vocab_size, (2, s)).astype(np.int32)
        max_seq = s + 4
        lg_f, cache_f, kv_f = transformer.prefill(
            params, cfg, {"tokens": toks}, max_seq, return_kv=True)
        p_len = p_chunks * CHUNK_TOKENS
        prefix_kv = jax.tree.map(lambda a: a[..., :p_len, :, :], kv_f)
        lg_r, cache_r, kv_r = transformer.prefill(
            params, cfg, {"tokens": toks[:, p_len:]}, max_seq,
            prefix_kv=prefix_kv, return_kv=True)
        tag = f"{kind} rope={use_rope} S={s} P={p_len}"
        _arrays_match(lg_f, lg_r, tag)
        _tree_match(cache_f, cache_r, tag)
        _tree_match(jax.tree.map(lambda a: a[..., p_len:, :, :], kv_f),
                    kv_r, tag)
        np.testing.assert_array_equal(
            _greedy(params, cfg, lg_f, cache_f, s),
            _greedy(params, cfg, lg_r, cache_r, s), err_msg=tag)


def test_resume_rejects_recurrent_arch():
    """SSM state folds the whole prefix into one vector — the resume
    path must refuse, not silently corrupt."""
    ssm = configs.get_arch("falcon-mamba-7b").reduced()
    assert not transformer.resume_supported(ssm)
    with pytest.raises(NotImplementedError):
        transformer.prefill({}, ssm, {"tokens": np.zeros((1, 32), np.int32)},
                            40, prefix_kv={"dummy": np.zeros((1, 16, 1, 1))})
    idx = MonarchKVIndex(KVIndexConfig(fingerprint="prefix"),
                         slab_store=KVSlabStore())
    with pytest.raises(NotImplementedError):
        PrefixResumeEngine({}, ssm, max_seq=40, index=idx)


def test_engine_requires_prefix_fingerprints_and_store():
    cfg = _arch("global")
    with pytest.raises(ValueError, match="fingerprint"):
        PrefixResumeEngine({}, cfg, max_seq=64,
                           index=MonarchKVIndex(KVIndexConfig(),
                                                slab_store=KVSlabStore()))
    with pytest.raises(ValueError, match="KVSlabStore"):
        PrefixResumeEngine({}, cfg, max_seq=64, index=MonarchKVIndex(
            KVIndexConfig(fingerprint="prefix")))


# ---------------------------------------------------------------------------
# Engine + index + slab store.
# ---------------------------------------------------------------------------

def _mk_index(n_shards=1, **kw):
    base = dict(n_sets=8, set_ways=8, admit_after_reads=0,
                rotate_every=1 << 30, fingerprint="prefix")
    base.update(kw)
    return MonarchKVIndex(KVIndexConfig(n_shards=n_shards, **base),
                          slab_store=KVSlabStore())


def _mk_engine(idx, cfg=None, max_seq=80, seed=1):
    cfg = cfg or _arch("global")
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    return PrefixResumeEngine(params, cfg, max_seq=max_seq, index=idx,
                              decode_tokens=4, jit=False)


def _serve_once(engine, q, toks):
    """One request through the production flow; returns (record-ish,
    decoded)."""
    hits = q.lookup(toks)
    res = engine.prefill(toks, hits)
    q.submit_tokens(toks, slabs=res.slabs)
    return res, engine.decode(res)


def test_engine_hit_resumes_and_decodes_identically(rng):
    """First serving computes + admits; the second serving of the same
    prompt resumes all but the final chunk and decodes the same tokens.
    A fresh no-cache engine double-checks the reference."""
    idx = _mk_index()
    engine = _mk_engine(idx)
    q = AdmitQueue(idx)
    try:
        toks = rng.integers(1, 512, (1, 64)).astype(np.int32)
        res1, dec1 = _serve_once(engine, q, toks)
        assert res1.resumed_chunks == 0 and res1.computed_chunks == 4
        res2, dec2 = _serve_once(engine, q, toks)
        assert res2.resumed_chunks == 3          # run capped at n_chunks-1
        np.testing.assert_array_equal(dec1, dec2)
        # straddling prompt: 4 chunks + 8 leftover tokens, same story
        odd = rng.integers(1, 512, (1, 72)).astype(np.int32)
        r1, d1 = _serve_once(engine, q, odd)
        r2, d2 = _serve_once(engine, q, odd)
        assert r2.resumed_chunks == 4 and r2.computed_chunks == 0
        np.testing.assert_array_equal(d1, d2)
        audit = idx.slab_lockstep_report()
        assert not audit["missing_slabs"] and not audit["orphan_slabs"]
    finally:
        q.close()


def test_engine_hit_survives_rotation(rng):
    """Rotation remaps sets but evicts nothing: the hit AND its slabs
    survive, and the resumed decode still matches."""
    idx = _mk_index()
    engine = _mk_engine(idx)
    q = AdmitQueue(idx)
    try:
        toks = rng.integers(1, 512, (1, 64)).astype(np.int32)
        _, dec_ref = _serve_once(engine, q, toks)
        q.rotate()
        assert idx.stats.rotations == 1
        res, dec = _serve_once(engine, q, toks)
        assert res.resumed_chunks == 3
        np.testing.assert_array_equal(dec_ref, dec)
        audit = idx.slab_lockstep_report()
        assert not audit["missing_slabs"] and not audit["orphan_slabs"]
    finally:
        q.close()


def test_engine_eviction_drops_slab_and_recomputes(rng):
    """Pressure-evicted prefix: the slab store drops in lockstep, the
    next serving misses cleanly and recomputes — same decoded tokens,
    no orphan slabs."""
    idx = _mk_index(n_sets=4, set_ways=4)
    engine = _mk_engine(idx)
    q = AdmitQueue(idx)
    try:
        toks = rng.integers(1, 512, (1, 64)).astype(np.int32)
        _, dec_ref = _serve_once(engine, q, toks)
        fps0 = {int(f) for f in idx.fingerprints(toks).reshape(-1)}
        flood = rng.integers(1 << 20, 1 << 30, 4096).astype(np.uint32)
        q.submit(np.unique(flood))
        q.flush()
        assert idx.stats.evictions > 0
        evicted = fps0 - set(idx.slot_of)
        assert evicted, "flood failed to evict the prefix"
        assert all(idx.slab_store.get(f) is None for f in evicted)
        res, dec = _serve_once(engine, q, toks)
        assert res.resumed_chunks < 3
        np.testing.assert_array_equal(dec_ref, dec)
        audit = idx.slab_lockstep_report()
        assert not audit["orphan_slabs"]
    finally:
        q.close()


def test_engine_truncates_run_at_missing_slab(rng):
    """A hit whose slab is gone (admitted slab-less) truncates the
    resume run instead of serving garbage."""
    idx = _mk_index()
    engine = _mk_engine(idx)
    q = AdmitQueue(idx)
    try:
        toks = rng.integers(1, 512, (1, 64)).astype(np.int32)
        # admit WITHOUT slabs: index hits, store empty
        q.submit_tokens(toks)
        q.flush()
        assert q.lookup(toks).all()
        res, _ = _serve_once(engine, q, toks)
        assert res.resumed_chunks == 0 and res.computed_chunks == 4
        # second serving staged real slabs -> now it resumes
        res2, _ = _serve_once(engine, q, toks)
        assert res2.resumed_chunks == 3
    finally:
        q.close()


# ---------------------------------------------------------------------------
# Schedule replay: shard counts + the fan-out oracle stay in lockstep.
# ---------------------------------------------------------------------------

def _policy_state(idx):
    return dict(
        slot_of=dict(idx.slot_of),
        valid=np.asarray(idx.valid).copy(),
        fp_of=np.asarray(idx.fp_of).copy(),
        writes=idx.write_distribution(),
        window_writes=np.asarray(idx.wear_state.window_writes).copy(),
        slabs=sorted(idx.slab_store.resident_fps()),
        stats=(idx.stats.admissions, idx.stats.admission_skips,
               idx.stats.evictions, idx.stats.chunk_hits,
               idx.stats.chunk_misses),
    )


def _zipf_requests(n, rng):
    """(1, 64) prompts: 2 zipf-shared prefix chunks + 2 fresh tail chunks."""
    prefixes = [rng.integers(1, 512, (1, 2 * CHUNK_TOKENS))
                for _ in range(2)]
    out = []
    for _ in range(n):
        p = prefixes[min(int(rng.zipf(1.5)) - 1, 1)]
        tail = rng.integers(1, 512, (1, 2 * CHUNK_TOKENS))
        out.append(np.concatenate([p, tail], axis=1).astype(np.int32))
    return out


def _replay(idx, requests, cfg, params):
    engine = PrefixResumeEngine(params, cfg, max_seq=72, index=idx,
                                decode_tokens=2, jit=False)
    q = AdmitQueue(idx)
    decoded = []
    _, base_decode = engine.request_fns()

    def decode_fn(toks, result):
        base_decode(toks, result)
        decoded.append(result.state["decoded"])

    try:
        recs = run_request_loop(q, requests, prefill_fn=engine.prefill,
                                decode_fn=decode_fn)
        q.flush()
    finally:
        q.close()
    return recs, decoded, idx


def test_schedule_replay_shard_lockstep(rng):
    """The ISSUE's replay pin: one randomized zipf schedule through the
    REAL loop (read-your-writes lookups, submit-after-prefill, slab
    commits off-thread) at every shard count and against the fan-out
    oracle — identical hits, resumed counts, installs/planes/wear,
    resident slabs, and decoded tokens."""
    cfg = _arch("global")
    params = transformer.init_params(jax.random.PRNGKey(1), cfg)
    requests = _zipf_requests(8, rng)
    runs = {}
    for n in SHARD_COUNTS:
        runs[n] = _replay(_mk_index(n_shards=n, admit_after_reads=1),
                          requests, cfg, params)
    oracle_idx = MonarchKVIndex(
        KVIndexConfig(n_shards=4, n_sets=8, set_ways=8, admit_after_reads=1,
                      rotate_every=1 << 30, fingerprint="prefix"),
        dispatch="fanout", slab_store=KVSlabStore())
    runs["fanout"] = _replay(oracle_idx, requests, cfg, params)

    ref_recs, ref_dec, _ = runs[SHARD_COUNTS[0]]
    assert sum(r.hit_chunks for r in ref_recs) > 0      # schedule hits
    assert sum(r.resumed_chunks for r in ref_recs) > 0  # and resumes
    for key, (recs, dec, _idx) in runs.items():
        for a, b in zip(ref_recs, recs):
            assert (a.chunks, a.hit_chunks, a.resumed_chunks) == \
                   (b.chunks, b.hit_chunks, b.resumed_chunks), key
        for da, db in zip(ref_dec, dec):
            np.testing.assert_array_equal(da, db, err_msg=str(key))

    # Shard-count runs share set geometry -> full policy state (installs,
    # planes, wear, resident slabs) must be identical.  The fan-out
    # oracle shares everything policy-visible too (same geometry, same
    # admission semantics) and is compared on the same state dict.
    ref_state = _policy_state(runs[SHARD_COUNTS[0]][2])
    for key in list(SHARD_COUNTS[1:]) + ["fanout"]:
        st = _policy_state(runs[key][2])
        for k in ref_state:
            if isinstance(ref_state[k], np.ndarray):
                np.testing.assert_array_equal(ref_state[k], st[k],
                                              err_msg=f"{key}: {k}")
            else:
                assert ref_state[k] == st[k], (key, k)


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
