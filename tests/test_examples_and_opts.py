"""Examples run end-to-end (smoke) + §Perf knob regression tests."""
from __future__ import annotations

import dataclasses
import subprocess
import sys
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist import sharding
from repro.models import moe, transformer
from repro.launch.mesh import make_host_mesh
from repro.train import step as train_step_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _run_example(name, *args, timeout=420):
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", name), *args],
        env=ENV, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_quickstart_example():
    out = _run_example("quickstart.py")
    assert "search found column 137" in out
    assert "kv_lookup(0xBEEF) -> 202" in out
    assert "kv_lookup(0xDEAD) -> None" in out


def test_string_search_example():
    out = _run_example("string_search.py", "--mib", "0.25")
    assert "matches: " in out and "fewer memory commands" in out


def test_train_lm_example_loss_down(tmp_path):
    out = _run_example("train_lm.py", "--steps", "6", "--batch", "2",
                       "--seq", "64", "--ckpt-dir", str(tmp_path),
                       "--ckpt-every", "3")
    assert "DOWN" in out
    assert "published" in out


@pytest.mark.slow
def test_kv_store_example():
    out = _run_example("kv_store.py")
    assert "lookup" in out and "searches=" in out


def test_serve_prefix_cache_example():
    """Non-slow smoke of the resume-path example: hits must not just be
    counted — prompt tokens must actually be SERVED from KV slabs (the
    example itself asserts the index/slab-store lockstep audit)."""
    out = _run_example("serve_prefix_cache.py", "--requests", "5",
                       "--decode-tokens", "2")
    assert "chunk hit rate" in out
    assert "prefix KV resumed" in out
    resumed = int(out.split("prefix KV resumed: ")[1].split("/")[0])
    assert resumed > 0, out


# ---------------------------------------------------------------------------
# §Perf knob regressions.
# ---------------------------------------------------------------------------

def test_moe_einsum_dispatch_matches_gather(rng):
    cfg = configs.get_arch("qwen3-moe-30b-a3b").reduced()
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.5, jnp.float32)
    for cf in (1.25, float(cfg.n_experts)):
        c = dataclasses.replace(cfg, capacity_factor=cf)
        y_g = moe._moe_block_gather(params, x, c)
        y_e = moe._moe_block_einsum(params, x, c)
        np.testing.assert_allclose(np.asarray(y_g, np.float32),
                                   np.asarray(y_e, np.float32),
                                   rtol=1e-4, atol=1e-4)


def test_moe_dispatch_flag_routes():
    cfg = dataclasses.replace(configs.get_arch("qwen3-moe-30b-a3b").reduced(),
                              moe_dispatch="einsum")
    params = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((1, 8, cfg.d_model), jnp.bfloat16)
    y = moe.moe_block(params, x, cfg)      # must take the einsum path
    assert y.shape == x.shape


def test_seq_shard_train_step_still_correct(rng):
    """attn_seq_shard is numerics-neutral: same loss with and without."""
    mesh = make_host_mesh()
    cfg = configs.get_arch("yi-9b").reduced()
    cfg_ss = dataclasses.replace(cfg, attn_seq_shard=("data",))
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 32)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                              jnp.int32),
    }
    with mesh:
        s1 = train_step_mod.init_state(jax.random.PRNGKey(0), cfg)
        s2 = train_step_mod.init_state(jax.random.PRNGKey(0), cfg_ss)
        _, m1 = jax.jit(train_step_mod.make_train_step(cfg))(s1, batch)
        _, m2 = jax.jit(train_step_mod.make_train_step(cfg_ss))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)


def test_cache_specs_seq_shard_layout():
    cfg = configs.get_arch("yi-9b").reduced()
    mesh = make_host_mesh()
    cache = jax.eval_shape(lambda: transformer.init_cache(cfg, 4, 64))
    specs = sharding.cache_specs(cache, mesh, seq_shard=True)
    # find a k leaf: S dim (index off+1) must be model-sharded when divisible
    for path, s in jax.tree_util.tree_leaves_with_path(specs):
        keys = [getattr(p, "key", "") for p in path]
        if keys[-1] == "k":
            off = 1 if "groups" in keys else 0
            # model axis size 1 on host mesh -> guard may drop; structure ok
            assert len(s) >= off + 2
            break
    else:
        pytest.fail("no k leaf found")


def test_param_specs_two_d_mlp_rules():
    cfg = configs.get_arch("yi-9b")
    from repro.launch import specs as lspecs
    shapes = lspecs.params_shapes(cfg)
    mesh = make_host_mesh()
    specs = sharding.param_specs(shapes, mesh, two_d_mlp=True)
    found = 0
    for path, s in jax.tree_util.tree_leaves_with_path(specs):
        keys = [getattr(p, "key", "") for p in path]
        if keys[-1] in ("w_up", "w_gate", "w_down"):
            found += 1
            assert isinstance(s, P)
    assert found >= 3


def test_dryrun_build_cell_on_host_mesh():
    """build_cell lowers (abstractly) for a reduced arch on the host mesh —
    exercises the full spec-plumbing path without 512 devices."""
    from repro.launch import dryrun as dr
    mesh = make_host_mesh()
    cfg = configs.get_arch("gemma3-27b").reduced()
    shape = dataclasses.replace(configs.get_shape("train_4k"),
                                seq_len=64, global_batch=2)
    fn, arg_shapes, in_sh, out_sh, donate = dr.build_cell(cfg, shape, mesh)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*arg_shapes)
    assert lowered is not None
