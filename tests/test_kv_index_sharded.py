"""Set-axis sharding + async admission coverage.

The load-bearing pin: a randomized lookup/admit/rotate schedule replayed
at ``n_shards in {1, 2, 4}`` must produce IDENTICAL hits, installs
(shadow map + device planes), per-set replacement counters and wear
reports — sharding is a relabeling of who stores a set, never a policy
change.  Since the single-dispatch PR the index stores state in MESH
PARTITIONS (``idx.n_parts``): on a one-device host every shard count
collapses to the exact unsharded path, and under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI
multi-device leg) the same matrix exercises the real ``shard_map``
lookup and ``ppermute`` rotation.  The step-for-step pins against the
kept PR-4 fan-out paths live in ``tests/test_kv_index_differential.py``.

The AdmitQueue tests pin the async relaxation: flush == the same
``admit_fps`` calls inline, rotation is a drain barrier, and
read-your-writes lookups never miss a pending install (concurrency
stress lives in ``tests/test_admit_queue_stress.py``).
"""
from __future__ import annotations

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # clean checkout: deterministic-cases fallback
    from _propcheck import given, settings, strategies as st

from repro.core import geometry
from repro.data.pipeline import fingerprint_blocks
from repro.launch import mesh as mesh_mod
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex

SHARD_COUNTS = (1, 2, 4)


def _mk(n_shards: int, **kw) -> MonarchKVIndex:
    base = dict(n_sets=8, set_ways=8, admit_after_reads=1, m_writes=2,
                window_ops=256, rotate_every=1 << 30)
    base.update(kw)
    return MonarchKVIndex(KVIndexConfig(n_shards=n_shards, **base))


def _global_state(idx: MonarchKVIndex) -> dict:
    return dict(
        slot_of=dict(idx.slot_of),
        first_touch=dict(idx.first_touch),
        bits=np.asarray(idx.bits).copy(),
        valid=np.asarray(idx.valid).copy(),
        fp_of=np.asarray(idx.fp_of).copy(),
        read_after=np.asarray(idx.read_after).copy(),
        counter=np.asarray(idx.counter).copy(),
        writes=idx.write_distribution(),
        window_writes=np.asarray(idx.wear_state.window_writes).copy(),
        ops=idx.ops_total,
        stats=(idx.stats.admissions, idx.stats.admission_skips,
               idx.stats.throttled, idx.stats.evictions,
               idx.stats.chunk_hits, idx.stats.chunk_misses,
               idx.stats.rotations),
    )


def _assert_same(sa: dict, sb: dict, msg: str):
    for key in sa:
        if isinstance(sa[key], np.ndarray):
            np.testing.assert_array_equal(sa[key], sb[key],
                                          err_msg=f"{msg}: {key}")
        else:
            assert sa[key] == sb[key], (msg, key, sa[key], sb[key])


# ---------------------------------------------------------------------------
# Shard-count invariance: the tentpole pin.
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 31))
def test_shard_count_invariance(seed):
    """Randomized admit/lookup/rotate schedules (driving installs,
    evictions, no-allocate skips AND t_MWW throttles — asserted below)
    replayed at every shard count produce identical hits, installs, and
    wear reports."""
    rng = np.random.default_rng(seed)
    idxs = [_mk(n) for n in SHARD_COUNTS]
    for step in range(8):
        toks = rng.integers(1, 600, (2, 6 * CHUNK_TOKENS)).astype(np.int32)
        op = rng.random()
        if op < 0.6:
            fps = np.unique(
                fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
            for idx in idxs:
                idx.admit_fps(fps)
            if op < 0.4:          # re-offer: crosses the no-allocate gate
                for idx in idxs:
                    idx.admit_fps(fps)
        elif op < 0.9:
            hits = [idx.lookup(toks) for idx in idxs]
            for h in hits[1:]:
                np.testing.assert_array_equal(hits[0], h)
        else:
            for idx in idxs:
                idx._rotate()
        ref = _global_state(idxs[0])
        for n, idx in zip(SHARD_COUNTS[1:], idxs[1:]):
            _assert_same(ref, _global_state(idx),
                         f"seed={seed} step={step} n_shards={n}")
        reports = [idx.wear_report() for idx in idxs]
        for n, rep in zip(SHARD_COUNTS[1:], reports[1:]):
            assert rep == reports[0], (seed, step, n)
    # The schedule must actually exercise the interesting paths.
    s = idxs[0].stats
    assert s.admissions > 0 and s.admission_skips > 0


def test_shard_invariance_under_eviction_and_throttle_pressure():
    """Deterministic heavy trace: tiny sets force evictions, a tight
    window forces throttles, and explicit rotations force the cross-shard
    remap — all shard counts stay in lockstep."""
    idxs = [_mk(n, set_ways=4, admit_after_reads=0, m_writes=1,
                window_ops=64) for n in SHARD_COUNTS]
    fps = np.arange(1, 129, dtype=np.uint32)
    for chunk in fps.reshape(8, 16):
        for idx in idxs:
            idx.admit_fps(chunk)
        for idx in idxs:      # rotation interleaved with admission
            idx._rotate()
        ref = _global_state(idxs[0])
        for idx in idxs[1:]:
            _assert_same(ref, _global_state(idx), "heavy trace")
    s = idxs[0].stats
    assert s.evictions > 0 and s.throttled > 0 and s.rotations == 8


def test_sharded_state_shapes_and_ownership():
    """Storage is one block per MESH PARTITION: n_parts == the ("sets",)
    mesh size under "auto" (1 on a one-device host — co-located shards
    collapse to the unsharded layout), n_shards under the "fanout"
    reference."""
    idx = _mk(4, n_sets=8)
    assert idx.sets_per_shard == 2           # logical shard geometry
    want_parts = mesh_mod.set_partitions(4)  # largest divisor host holds
    assert idx.n_parts == want_parts
    s_loc = 8 // want_parts
    assert len(idx._bits) == want_parts
    for k in range(want_parts):
        assert idx._bits[k].shape == (
            s_loc, idx.cfg.key_bits, idx.cfg.set_ways)
        assert idx._wear_states[k].window_writes.shape == (s_loc,)
        assert idx._counters[k].shape == (s_loc,)
    # the fan-out reference keeps one block per logical shard
    ref = MonarchKVIndex(KVIndexConfig(
        n_shards=4, n_sets=8, set_ways=8), dispatch="fanout")
    assert ref.n_parts == 4 and len(ref._bits) == 4
    assert ref._bits[0].shape == (2, ref.cfg.key_bits, ref.cfg.set_ways)
    # global views concatenate in partition order == global set order
    assert np.asarray(idx.valid).shape == (8, idx.cfg.set_ways)
    shard, local = geometry.shard_of_set(np.arange(8), 8, 4)
    np.testing.assert_array_equal(shard, np.arange(8) // 2)
    np.testing.assert_array_equal(local, np.arange(8) % 2)


def test_shard_count_must_divide_sets():
    with pytest.raises(ValueError):
        MonarchKVIndex(KVIndexConfig(n_sets=8, n_shards=3))


def test_lookup_is_single_dispatch_at_every_shard_count(rng):
    """The tentpole acceptance pin: ONE fused-search device dispatch per
    lookup batch REGARDLESS of n_shards (the stacked shard_map path on a
    multi-device mesh, the collapsed unsharded launch otherwise), counted
    at the ops layer — where every host-side launch site increments
    ``xam_ops.LAUNCH_COUNT`` exactly once.  The kept fan-out reference
    still pays one dispatch per occupied shard."""
    from repro.kernels.xam_search import ops as xam_ops
    toks = rng.integers(1, 50_000, (4, 256)).astype(np.int32)
    for n_shards in SHARD_COUNTS:
        idx = _mk(n_shards, n_sets=8, admit_after_reads=0)
        before = xam_ops.LAUNCH_COUNT
        s_before = idx.stats.searches
        idx.lookup(toks)       # 64 chunks spread over all sets
        assert xam_ops.LAUNCH_COUNT == before + 1, n_shards
        assert idx.stats.searches == s_before + 1
    ref = MonarchKVIndex(KVIndexConfig(
        n_shards=4, n_sets=8, set_ways=8, admit_after_reads=0),
        dispatch="fanout")
    before = xam_ops.LAUNCH_COUNT
    ref.lookup(toks)           # all 4 shards occupied -> 4 dispatches
    assert xam_ops.LAUNCH_COUNT == before + 4


def test_set_mesh_single_device_is_none():
    """On a 1-device host the ("sets",) mesh degenerates: shards
    co-locate and placement is skipped (the dry-run env is the multi-
    device path; tests must see the real device count)."""
    import jax
    if len(jax.devices()) == 1:
        assert mesh_mod.make_set_mesh(4) is None
        assert mesh_mod.set_shard_devices(None, 4) is None
    else:
        mesh = mesh_mod.make_set_mesh(4)
        assert mesh is not None and mesh.axis_names == ("sets",)
        devs = mesh_mod.set_shard_devices(mesh, 4)
        assert len(devs) == 4


# ---------------------------------------------------------------------------
# Async admission queue.
# ---------------------------------------------------------------------------

def _same_index_state(a: MonarchKVIndex, b: MonarchKVIndex):
    assert a.slot_of == b.slot_of
    assert a.first_touch == b.first_touch
    np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid))
    np.testing.assert_array_equal(np.asarray(a.fp_of), np.asarray(b.fp_of))
    np.testing.assert_array_equal(a.write_distribution(),
                                  b.write_distribution())
    assert a.stats.admissions == b.stats.admissions
    assert a.stats.admission_skips == b.stats.admission_skips


@pytest.mark.parametrize("background", [False, True])
@pytest.mark.parametrize("n_shards", [1, 2])
def test_queue_flush_matches_inline_admission(rng, background, n_shards):
    """submit*; flush == the same admit_fps calls inline: same shadow
    map, planes, install counts — order is preserved and batches merge
    only while mutually disjoint (touch-count semantics), which keeps
    the drained state bit-identical."""
    cfg = dict(n_sets=4, set_ways=16, admit_after_reads=1, m_writes=1 << 20,
               window_ops=1 << 30)
    inline = MonarchKVIndex(KVIndexConfig(n_shards=n_shards, **cfg))
    queued = MonarchKVIndex(KVIndexConfig(n_shards=n_shards, **cfg))
    q = AdmitQueue(queued, background=background)
    batches = [np.unique(rng.integers(1, 400, 24).astype(np.uint32))
               for _ in range(6)]
    batches += batches[:3]     # re-offers: exercises the touch counter
    for fps in batches:
        inline.admit_fps(fps)
        q.submit(fps)
    q.flush()
    _same_index_state(inline, queued)
    assert q.stats.batches == len(batches)
    q.close()


def test_queue_read_your_writes_flushes_pending(rng):
    import time
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=4, set_ways=32, admit_after_reads=0))
    q = AdmitQueue(idx, background=True, read_your_writes=True)
    # Slow the drain so the lookup deterministically observes the batch
    # as pending (otherwise worker vs main is a scheduling race).
    real_admit = idx.admit_fps
    idx.admit_fps = lambda fps: (time.sleep(0.5), real_admit(fps))[-1]
    toks = rng.integers(1, 1000, (2, 64)).astype(np.int32)
    q.submit_tokens(toks)
    assert q.lookup(toks).all()        # pending installs became visible
    assert q.stats.rww_flushes >= 1
    # an unrelated lookup needs no flush
    other = rng.integers(10_000, 20_000, (1, 32)).astype(np.int32)
    before = q.stats.rww_flushes
    q.lookup(other)
    assert q.stats.rww_flushes == before
    q.close()


def test_queue_rotate_is_drain_barrier(rng):
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=8, set_ways=32, admit_after_reads=0, n_shards=2))
    q = AdmitQueue(idx, background=True, read_your_writes=False)
    toks = rng.integers(1, 4000, (4, 128)).astype(np.int32)
    q.submit_tokens(toks)
    q.rotate()                          # flush-then-remap
    assert q.pending() == 0
    assert idx.stats.rotations == 1
    q.flush()
    with q._idx_lock:
        want = idx._shadow_hits(
            fingerprint_blocks(toks, CHUNK_TOKENS).reshape(-1))
    got = q.lookup(toks).reshape(-1)
    np.testing.assert_array_equal(got, want)
    assert got.all()                    # installs survived the remap
    q.close()


def test_queue_worker_failure_surfaces_on_flush():
    """A failing admission batch must neither kill the drain loop (later
    flushes would hang forever) nor vanish silently: the next barrier
    re-raises, and the queue keeps working afterwards."""
    idx = MonarchKVIndex(KVIndexConfig(
        n_sets=4, set_ways=8, admit_after_reads=0))
    q = AdmitQueue(idx, background=True)
    real_admit = idx.admit_fps

    def boom(fps):
        raise ValueError("injected admission failure")

    idx.admit_fps = boom
    q.submit(np.asarray([1, 2, 3], np.uint32))
    with pytest.raises(RuntimeError, match="admission batch failed"):
        q.flush()
    idx.admit_fps = real_admit
    q.submit(np.asarray([4, 5, 6], np.uint32))
    q.flush()                        # worker survived; barrier still works
    assert idx.stats.admissions == 3
    q.close()


def test_queue_close_is_idempotent():
    q = AdmitQueue(MonarchKVIndex(KVIndexConfig(n_sets=4, set_ways=8)))
    q.close()
    q.close()


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
