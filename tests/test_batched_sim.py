"""Batched multi-config simulation engine tests: the vmapped grid must
bit-match the single-config ``simulate_trace`` path, across shape families
and dynamic-parameter differences (timing, policy flags, wear knobs)."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import simulator
from repro.data import traces


def _cfgs():
    cfgs = simulator.baseline_configs(scale_blocks=512)
    for name in list(cfgs):
        cfgs[name] = dataclasses.replace(cfgs[name], l3_sets=16)
        if cfgs[name].wear_enabled:
            cfgs[name] = dataclasses.replace(
                cfgs[name], t_mww_cycles=(1 << 12) * cfgs[name].m_writes,
                dc_limit=32, window_budget_blocks=16)
    return cfgs


def _trace_list(cfgs, n_traces=2, n_requests=3_000):
    specs = traces.crono_nas_specs(cfgs["monarch_unbound"].inpkg_blocks,
                                   n_requests)
    picked = [specs[0], specs[-1]][:n_traces]   # BC (graph) + EP (writes)
    return [(s.name, *traces.generate(s)) for s in picked]


# The C1/C3/C7 claim configs: the D-Cache baseline, Monarch unbounded, and
# the bounded M=3 system (wear machinery on) — plus s_cache for a second
# shape family with CAM search under CMOS timing.
GRID_SYSTEMS = ["d_cache", "s_cache", "monarch_unbound", "monarch_m3"]


def test_grid_bitmatches_single_config_path():
    cfgs = _cfgs()
    sub = {n: cfgs[n] for n in GRID_SYSTEMS}
    trace_list = _trace_list(cfgs)
    grid = simulator.simulate_grid(sub, trace_list)
    assert set(grid) == {(c, t) for c in sub for t, _, _ in trace_list}
    for tname, addrs, wr in trace_list:
        for cname in sub:
            single = simulator.simulate_trace(sub[cname], addrs, wr)
            batched = grid[(cname, tname)]
            assert batched.stats == single.stats, (cname, tname)
            assert batched.total_cycles == single.total_cycles, (cname, tname)
            assert batched.energy_nj == pytest.approx(single.energy_nj,
                                                      rel=0, abs=1e-9)


def test_grid_final_states_match_single_config():
    cfgs = _cfgs()
    sub = {n: cfgs[n] for n in ("monarch_m3",)}
    trace_list = _trace_list(cfgs, n_traces=2)
    _, states = simulator.simulate_grid(sub, trace_list, return_state=True)
    for tname, addrs, wr in trace_list:
        _, st_single = simulator.simulate_trace(
            sub["monarch_m3"], addrs, wr, return_state=True)
        st_grid = states[("monarch_m3", tname)]
        np.testing.assert_array_equal(np.asarray(st_grid.set_writes),
                                      np.asarray(st_single.set_writes))
        np.testing.assert_array_equal(np.asarray(st_grid.set_way_writes),
                                      np.asarray(st_single.set_way_writes))
        np.testing.assert_array_equal(
            np.asarray(st_grid.wear.offsets.rotate_count),
            np.asarray(st_single.wear.offsets.rotate_count))


def test_shape_families_group_compatible_configs():
    cfgs = _cfgs()
    # All four Monarch M systems + unbound share one compiled shape.
    monarchs = [cfgs[f"monarch_m{m}"] for m in (1, 2, 3, 4)]
    monarchs.append(cfgs["monarch_unbound"])
    assert simulator.n_shape_families(monarchs) == 1
    # The DRAM pair shares a family; s_cache is its own.
    assert simulator.n_shape_families(
        [cfgs["d_cache"], cfgs["d_cache_ideal"]]) == 1
    assert simulator.n_shape_families(
        [cfgs["d_cache"], cfgs["s_cache"]]) == 2


def test_grid_rejects_mismatched_trace_lengths():
    cfgs = _cfgs()
    a = np.zeros(100, np.int64)
    w = np.zeros(100, bool)
    with pytest.raises(ValueError, match="length"):
        simulator.simulate_grid(
            {"d_cache": cfgs["d_cache"]},
            [("t0", a, w), ("t1", a[:50], w[:50])])


def test_dyn_params_roundtrip_flags():
    cfgs = _cfgs()
    for name, cfg in cfgs.items():
        dyn = simulator.dyn_params(cfg)
        assert bool(dyn.search_tags) == cfg.search_tags, name
        assert bool(dyn.allocate_on_miss) == (not cfg.no_allocate), name
        assert bool(dyn.wear_enabled) == cfg.wear_enabled, name
        assert bool(dyn.dr_filter) == cfg.dr_filter, name
        assert int(dyn.wear.t_mww_cycles) == cfg.t_mww_cycles, name


def test_trace_generation_is_process_stable():
    """Trace content must depend only on (spec, seed) — the seed repo keyed
    a generator on str hash(), which is salted per process, making every
    benchmark number run-dependent.  The pinned fingerprint fails if that
    regresses (or if generation semantics drift silently)."""
    spec = traces.crono_nas_specs(1024, 2_000)[0]   # BC
    a1, w1 = traces.generate(spec)
    a2, w2 = traces.generate(spec)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(w1, w2)
    assert int(np.int64(a1.sum()) % 1_000_003) == 166957
    assert int(w1.sum()) == 139
