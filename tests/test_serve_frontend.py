"""Serving front end: wall-clock t_MWW, the shared request loop, the
launcher's per-batch report, and the serve bench/regression gates.

Wall-clock coverage pins the tentpole contract from three sides:

* CONFIG PLUMBING — ``clock`` validated and threaded through
  ``WearConfig`` / ``KVIndexConfig`` / ``with_lifetime`` (a wall window
  is a real time budget, independent of any op-rate estimate).
* OP-CLOCK BIT-IDENTITY — ``clock="ops"`` (the default) never consults
  the injected wall clock, so every pre-PR schedule is unchanged (the
  existing differential/sharded suites are the behavioral pin; here we
  additionally prove the clock source is untouched).
* WALL SEMANTICS — with a controllable ``now_fn``: window expiry
  unlocks sets as wall time passes, the auto-vs-fanout differential
  oracle still agrees at n_shards {1, 2, 4} (per-batch host-side
  stamps keep device scans deterministic), and the int32 clock rebase
  is exact (an index driven near the rebase boundary matches one
  driven from zero).
"""
from __future__ import annotations

import os
import sys
import warnings

import numpy as np
import pytest

from repro.core import wear
from repro.launch.serve import RequestRecord, run_request_loop
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:                      # for `import benchmarks.*`
    sys.path.insert(0, ROOT)


class FakeClock:
    """Injectable ``now_fn``: seconds, advanced explicitly."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# clock plumbing


def test_wear_config_rejects_unknown_clock():
    with pytest.raises(ValueError, match="clock"):
        wear.WearConfig(n_supersets=1, clock="sundial")


def test_kv_index_config_rejects_unknown_clock():
    with pytest.raises(ValueError, match="clock"):
        MonarchKVIndex(KVIndexConfig(n_sets=4, clock="sundial"))


def test_make_config_wall_window_is_a_time_budget():
    ops = wear.make_config(4, clock="ops")
    wall = wear.make_config(4, clock="wall")
    t_mww_s = wear.t_mww_seconds(3, 10.0 * 365.25 * 24 * 3600, 1e8)
    assert ops.t_mww_cycles == int(t_mww_s * wear.CPU_HZ)
    assert wall.t_mww_cycles == int(t_mww_s * wear.WALL_HZ)
    assert wall.clock == "wall"


def test_with_lifetime_wall_window_ignores_op_rate():
    # the wall window depends only on the lifetime math, not on the
    # ops_per_second estimate the op-clock proxy needs
    a = KVIndexConfig.with_lifetime(t_life_years=10.0, clock="wall")
    b = KVIndexConfig.with_lifetime(t_life_years=10.0, clock="wall",
                                    ops_per_second=123.0)
    assert a.window_ops == b.window_ops == 9467280
    assert a.clock == "wall"


def test_ops_clock_never_consults_the_wall_clock():
    """Bit-identity pin for every pre-PR configuration: under the
    default op-counter clock the injected ``now_fn`` is never called, so
    existing schedules cannot observe wall time at all."""
    def boom():
        raise AssertionError("ops clock consulted now_fn")

    cfg = KVIndexConfig(n_sets=8, set_ways=16, admit_after_reads=0)
    with_clock = MonarchKVIndex(cfg, now_fn=boom)
    plain = MonarchKVIndex(cfg)
    rng = np.random.default_rng(2)
    for _ in range(4):
        toks = rng.integers(1, 50_000,
                            (1, 4 * CHUNK_TOKENS)).astype(np.int32)
        with_clock.admit(toks)
        plain.admit(toks)
        np.testing.assert_array_equal(with_clock.lookup(toks),
                                      plain.lookup(toks))
    assert with_clock.slot_of == plain.slot_of
    assert with_clock.wear_report() == plain.wear_report()


# ---------------------------------------------------------------------------
# wall-clock semantics


def _wall_index(clk, *, n_shards: int = 1, window_s: float = 1.0,
                admit_dispatch=None, **kw):
    cfg = dict(n_sets=8, set_ways=4, admit_after_reads=0, m_writes=1,
               window_ops=int(window_s * wear.WALL_HZ), rotate_every=1 << 30,
               clock="wall", n_shards=n_shards)
    cfg.update(kw)
    return MonarchKVIndex(KVIndexConfig(**cfg),
                          admit_dispatch=admit_dispatch, now_fn=clk)


def test_wall_window_locks_then_expires_with_wall_time():
    """m_writes=1, 1-second window: hammering a tiny index locks sets at
    their budget; the locks must clear as WALL time passes — with no
    further index ops spent — which is exactly what the op-counter proxy
    cannot express."""
    clk = FakeClock()
    idx = _wall_index(clk)
    rng = np.random.default_rng(0)
    fps = np.unique(rng.integers(1, 1 << 30, 256).astype(np.uint32))
    idx.admit_fps(fps)                      # overfill: budgets exhausted
    locked = idx.wear_report()["throttled_sets_now"]
    assert locked > 0
    clk.advance(0.5)                        # still inside the window
    assert idx.wear_report()["throttled_sets_now"] == locked
    clk.advance(1.0)                        # window expired
    assert idx.wear_report()["throttled_sets_now"] == 0
    before = idx.stats.admissions
    idx.admit_fps(np.arange((1 << 31) - 64, 1 << 31, dtype=np.uint32)[:32])
    assert idx.stats.admissions > before    # budget refreshed: admits again


def _state(idx: MonarchKVIndex) -> dict:
    return dict(
        slot_of=dict(idx.slot_of),
        first_touch=dict(idx.first_touch),
        valid=np.asarray(idx.valid).copy(),
        fp_of=np.asarray(idx.fp_of).copy(),
        counter=np.asarray(idx.counter).copy(),
        window_writes=np.asarray(idx.wear_state.window_writes).copy(),
        stats=(idx.stats.admissions, idx.stats.admission_skips,
               idx.stats.throttled, idx.stats.evictions),
    )


def _assert_same(sa: dict, sb: dict, msg: str):
    for key in sa:
        if isinstance(sa[key], np.ndarray):
            np.testing.assert_array_equal(sa[key], sb[key],
                                          err_msg=f"{msg}: {key}")
        else:
            assert sa[key] == sb[key], f"{msg}: {key}"


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_wall_clock_differential_auto_vs_fanout(n_shards):
    """The per-partition fanout oracle must stay bit-identical to the
    stacked dispatch under the wall clock: stamps are taken ONCE per
    admission batch on the host, so both dispatch paths see the same
    cycle values no matter how the batch is partitioned."""
    clk = FakeClock()
    auto = _wall_index(clk, n_shards=n_shards, set_ways=8, m_writes=2)
    ref = _wall_index(clk, n_shards=n_shards, set_ways=8, m_writes=2,
                      admit_dispatch="fanout")
    rng = np.random.default_rng(11)
    for step in range(8):
        fps = np.unique(rng.integers(1, 1 << 20, 48).astype(np.uint32))
        auto.admit_fps(fps)
        ref.admit_fps(fps)
        probe = rng.integers(1, 1 << 20, (1, 3 * CHUNK_TOKENS)
                             ).astype(np.int32)
        np.testing.assert_array_equal(auto.lookup(probe), ref.lookup(probe))
        _assert_same(_state(auto), _state(ref),
                     f"step={step} n_shards={n_shards} t={clk.t}")
        assert auto.wear_report() == ref.wear_report(), (step, clk.t)
        clk.advance(0.37)               # cross several window boundaries


def test_wall_clock_rebase_is_exact():
    """Driving an index from just under the int32 rebase boundary must
    produce the same planes as driving one from t=0: the window
    arithmetic is difference-based, and the rebase folds the origin
    without disturbing any in-window state."""
    rebase_s = wear.CLOCK_REBASE_AT / wear.WALL_HZ
    near, zero = FakeClock(), FakeClock()
    a = _wall_index(near, set_ways=8, m_writes=2)
    b = _wall_index(zero, set_ways=8, m_writes=2)
    near.t = rebase_s - 0.25            # a starts 0.25 s before the fold
    rng = np.random.default_rng(4)
    for _ in range(6):
        fps = np.unique(rng.integers(1, 1 << 20, 32).astype(np.uint32))
        a.admit_fps(fps)
        b.admit_fps(fps)
        near.advance(0.1)               # crosses CLOCK_REBASE_AT mid-run
        zero.advance(0.1)
    assert a._wall_folded == wear.CLOCK_REBASE_AT
    assert b._wall_folded == 0
    _assert_same(_state(a), _state(b), "rebase")
    assert a.wear_report() == b.wear_report()


# ---------------------------------------------------------------------------
# the shared request loop


def test_request_loop_open_loop_latency_counts_backlog():
    """Open-loop accounting: a request that arrives while the loop is
    busy is charged its queueing delay from the SCHEDULED arrival (the
    anti-coordinated-omission contract), and an idle-arrival request
    pays pure service time."""
    clk = FakeClock()
    idx = MonarchKVIndex(KVIndexConfig(n_sets=4, set_ways=16,
                                       admit_after_reads=0))
    q = AdmitQueue(idx, background=False)
    service_s = 0.1

    def prefill(toks, hits):
        clk.advance(service_s)          # deterministic "compute"

    reqs = [np.arange(1 + 64 * i, 1 + 64 * i + 2 * CHUNK_TOKENS,
                      dtype=np.int32).reshape(1, -1) for i in range(3)]
    recs = run_request_loop(
        q, reqs, prefill_fn=prefill, arrivals_s=[0.0, 0.0, 0.5],
        now_fn=clk, sleep_fn=clk.advance)
    q.close()
    lat = [r.latency_s for r in recs]
    assert lat[0] == pytest.approx(service_s)            # served on time
    assert lat[1] == pytest.approx(2 * service_s)        # waited behind 0
    assert lat[2] == pytest.approx(service_s)            # idle arrival
    assert recs[2].arrival_s == pytest.approx(0.5)
    assert all(r.admitted and not r.retried and not r.dropped for r in recs)
    assert all(isinstance(r, RequestRecord) for r in recs)


class _ScriptedQueue:
    """AdmitQueue stand-in with scripted submit outcomes."""

    def __init__(self, outcomes):
        self._outcomes = list(outcomes)

    def lookup(self, tokens):
        return np.zeros((tokens.shape[0],
                         tokens.shape[1] // CHUNK_TOKENS), bool)

    def submit_tokens(self, tokens):
        return self._outcomes.pop(0)


def test_request_loop_defer_retry_and_drop():
    toks = np.arange(1, 1 + 2 * CHUNK_TOKENS, dtype=np.int32).reshape(1, -1)
    # first submit deferred, retry (after decode) accepted
    recs = run_request_loop(_ScriptedQueue([False, True]), [toks],
                            prefill_fn=lambda t, h: None)
    assert recs[0].retried and recs[0].admitted and not recs[0].dropped
    # both rejected: admission forgone, the request itself still served
    recs = run_request_loop(_ScriptedQueue([False, False]), [toks],
                            prefill_fn=lambda t, h: None)
    assert recs[0].retried and recs[0].dropped and not recs[0].admitted


class _DrainingQueue(_ScriptedQueue):
    """Defer-rejecting queue whose backlog drains at a known FakeClock
    time: ``submit_tokens`` succeeds iff nothing is pending."""

    def __init__(self, clk: FakeClock, drain_at: float):
        super().__init__([])
        self._clk, self._drain_at = clk, drain_at

    def pending(self) -> int:
        return 0 if self._clk.t >= self._drain_at else 3

    def submit_tokens(self, tokens):
        return self.pending() == 0


def test_request_loop_defer_retry_waits_for_drain():
    """The decode-less defer retry must NOT race the still-full queue:
    with a bounded drain-wait it polls ``pending()`` (via the injected
    sleep) until the backlog clears, then the ONE retry lands — no
    over-counted drop.  ``retry_wait_s=0`` restores the immediate
    retry, which loses the race and drops."""
    toks = np.arange(1, 1 + 2 * CHUNK_TOKENS, dtype=np.int32).reshape(1, -1)

    clk = FakeClock()
    q = _DrainingQueue(clk, drain_at=0.02)   # drains within the wait
    recs = run_request_loop(q, [toks], prefill_fn=lambda t, h: None,
                            now_fn=clk, sleep_fn=clk.advance,
                            retry_wait_s=0.1)
    assert recs[0].retried and recs[0].admitted and not recs[0].dropped
    assert clk.t < 0.1 + 1e-9               # stopped as soon as it drained

    clk = FakeClock()
    q = _DrainingQueue(clk, drain_at=0.02)
    recs = run_request_loop(q, [toks], prefill_fn=lambda t, h: None,
                            now_fn=clk, sleep_fn=clk.advance,
                            retry_wait_s=0.0)   # old behavior: no wait
    assert recs[0].retried and recs[0].dropped and not recs[0].admitted


# ---------------------------------------------------------------------------
# launcher report (the empty-slice NaN regression)


def test_serve_main_tiny_prompt_reports_na(capsys):
    """Prefix shorter than one chunk: the per-batch report used to take
    an empty-slice mean (NaN + RuntimeWarning); it must print 'n/a'."""
    from repro.launch import serve
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        records = serve.main(
            argv=["--arch", "yi-9b", "--reduced", "--requests", "1",
                  "--batch", "1", "--prompt-len", "16",
                  "--decode-tokens", "2"])
    out = capsys.readouterr().out
    assert "prefix chunks cached n/a" in out
    assert "nan" not in out.lower()
    assert records[0].decoded is not None
    assert records[0].decoded.shape == (1, 2)


def test_serve_main_non_resume_decode_returns_tokens():
    """The non-resume ``model_decode`` used to accumulate greedy tokens
    in ``outs`` and throw them away — the launcher must surface the
    ``(B, decode_tokens)`` array on every record."""
    from repro.launch import serve
    records = serve.main(
        argv=["--arch", "yi-9b", "--reduced", "--no-resume",
              "--requests", "2", "--batch", "1", "--prompt-len", "32",
              "--decode-tokens", "3"])
    assert len(records) == 2
    for rec in records:
        assert rec.decoded is not None
        assert rec.decoded.shape == (1, 3)
        assert rec.decoded.dtype.kind in "iu"


# ---------------------------------------------------------------------------
# trace replay validation (REPRO_SERVE_TRACE)


def _trace_file(tmp_path, payload: str):
    p = tmp_path / "trace.json"
    p.write_text(payload)
    return str(p)


def test_trace_replay_rejects_malformed_traces(tmp_path, monkeypatch):
    """A short/unsorted/negative trace used to slip through
    ``_trace_arrivals`` silently and corrupt backlog accounting — every
    malformed shape must die with a one-line actionable message."""
    from benchmarks import serve_bench as sb
    cases = [
        ("{not json", "not valid JSON"),
        ('{"a": 1}', "non-empty flat list"),
        ("[]", "non-empty flat list"),
        ("[[0.0, 0.1]]", "non-empty flat list"),
        ("[0.0, NaN, 0.2]", "non-finite"),
        ("[0.0, -0.5, 0.2]", "negative arrival offset"),
        ("[0.0, 0.0, 0.0]", "zero makespan"),    # short + nothing to tile
    ]
    for payload, msg in cases:
        path = _trace_file(tmp_path, payload)
        monkeypatch.setenv("REPRO_SERVE_TRACE", path)
        with pytest.raises(ValueError, match=msg):
            sb._trace_arrivals(6)
        assert path in str(pytest.raises(
            ValueError, sb._trace_arrivals, 6).value)   # names the file


def test_trace_replay_sorts_and_tiles(tmp_path, monkeypatch):
    from benchmarks import serve_bench as sb
    # unsorted -> sorted (replay needs nondecreasing arrivals)
    monkeypatch.setenv("REPRO_SERVE_TRACE",
                       _trace_file(tmp_path, "[0.3, 0.0, 0.1]"))
    arr = sb._trace_arrivals(3)
    np.testing.assert_allclose(arr, [0.0, 0.1, 0.3])
    # short trace -> tiled periodically, still nondecreasing, exactly n
    monkeypatch.setenv("REPRO_SERVE_TRACE",
                       _trace_file(tmp_path, "[0.0, 0.1, 0.2]"))
    arr = sb._trace_arrivals(8)
    assert arr.shape == (8,)
    assert np.all(np.diff(arr) >= 0)
    np.testing.assert_allclose(arr[:3], [0.0, 0.1, 0.2])
    assert arr[3] > 0.2                     # repeats shift past makespan
    # exact-length trace passes through untouched
    monkeypatch.setenv("REPRO_SERVE_TRACE",
                       _trace_file(tmp_path, "[0.0, 0.05, 0.1]"))
    np.testing.assert_allclose(sb._trace_arrivals(3), [0.0, 0.05, 0.1])


# ---------------------------------------------------------------------------
# regression-gate behavior (check_regression + serve artifact)


def test_check_regression_missing_current_is_actionable(tmp_path, capsys):
    from benchmarks import check_regression as cr
    rc = cr.main(["--current", str(tmp_path / "BENCH_kernels.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[perf-smoke] ERROR" in out
    assert "artifact not found" in out
    assert "benchmarks.run" in out          # tells the operator what to run
    assert "Traceback" not in out


def _serve_leg(rate, **kw):
    leg = dict(offered_rps=rate, n_requests=32, p50_ms=3.0, p99_ms=9.0,
               mean_ms=4.0, goodput_rps=rate * 0.9, shed_rate=0.0,
               hit_rate=0.5)
    leg.update(kw)
    return leg


def _http_leg(**kw):
    return _serve_leg(120.0, **{"transport_overhead_ms": 0.8, **kw})


def test_serve_structural_gate():
    from benchmarks import check_regression as cr
    good = {"poisson": [_serve_leg(50.0), _serve_leg(400.0)],
            "http": _http_leg()}
    assert cr.serve_structural_gate(good) == []
    assert cr.serve_structural_gate({"poisson": [_serve_leg(50.0)]})
    assert cr.serve_structural_gate({})
    missing = dict(good, poisson=[_serve_leg(50.0),
                                  {k: v for k, v in _serve_leg(400.0).items()
                                   if k != "p99_ms"}])
    assert any("p99_ms" in line for line in cr.serve_structural_gate(missing))
    bad_frac = dict(good, poisson=[_serve_leg(50.0),
                                   _serve_leg(400.0, shed_rate=1.5)])
    assert any("shed_rate" in line
               for line in cr.serve_structural_gate(bad_frac))
    same_rate = dict(good, poisson=[_serve_leg(50.0), _serve_leg(50.0)])
    assert any("distinct" in line
               for line in cr.serve_structural_gate(same_rate))
    inverted = dict(good, poisson=[_serve_leg(50.0),
                                   _serve_leg(400.0, p50_ms=20.0,
                                              p99_ms=5.0)])
    assert any("p50" in line for line in cr.serve_structural_gate(inverted))


def test_serve_structural_gate_requires_http_leg():
    """The socket path must actually have been driven: a serve artifact
    without the HTTP leg (or with an impossible transport tax) fails
    the always-fatal structural gate."""
    from benchmarks import check_regression as cr
    poisson = [_serve_leg(50.0), _serve_leg(400.0)]
    no_http = {"poisson": poisson}
    assert any("socket path was not driven" in line
               for line in cr.serve_structural_gate(no_http))
    no_overhead = {"poisson": poisson,
                   "http": {k: v for k, v in _http_leg().items()
                            if k != "transport_overhead_ms"}}
    assert any("transport_overhead_ms" in line
               for line in cr.serve_structural_gate(no_overhead))
    negative = {"poisson": poisson,
                "http": _http_leg(transport_overhead_ms=-0.2)}
    assert any("undercut" in line
               for line in cr.serve_structural_gate(negative))
    assert cr.serve_structural_gate({"poisson": poisson,
                                     "http": _http_leg()}) == []


def test_serve_latency_keys_for_timing_compare():
    from benchmarks import check_regression as cr
    doc = {"poisson": [_serve_leg(50.0), _serve_leg(400.0)]}
    keys = cr.serve_latencies(doc)
    assert keys["serve.50rps.p50"] == pytest.approx(3000.0)   # ms -> us
    assert keys["serve.400rps.p99"] == pytest.approx(9000.0)
    assert len(keys) == 4


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
