"""Docs CI gate: intra-repo link check + docstring doctests.

    PYTHONPATH=src python tools/check_docs.py

Two checks, both fatal on failure:

* **Links** — every relative markdown link (``[text](path)`` /
  ``[text](path#anchor)``) in ``README.md`` and ``docs/*.md`` must
  resolve to a file or directory in the repo.  External schemes
  (http/https/mailto) are skipped; anchors are checked for existence of
  the TARGET FILE only (heading drift is a review concern, missing files
  are a CI concern).
* **Doctests** — ``doctest`` runs over the public-API modules that carry
  examples (the list below, not a blanket sweep: importing every module
  would drag model/benchmark code into the docs gate).

Run from the repo root (CI does).
"""
from __future__ import annotations

import doctest
import importlib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

#: Markdown files whose links are checked.
DOC_FILES = ["README.md", *sorted(
    str(p.relative_to(ROOT)) for p in (ROOT / "docs").glob("*.md"))]

#: Modules whose docstring examples must stay executable.
DOCTEST_MODULES = [
    "repro.core.geometry",
    "repro.core.wear",
    "repro.core.xam",
    "repro.kernels.common",
    "repro.kernels.xam_search.ops",
    "repro.serve.kv_index",
    "repro.serve.admit_queue",
    "repro.serve.http_frontend",
]

_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def check_links() -> list[str]:
    errors = []
    for rel in DOC_FILES:
        path = ROOT / rel
        if not path.exists():
            errors.append(f"{rel}: listed doc file missing")
            continue
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            for target in _LINK_RE.findall(line):
                if target.startswith(_SKIP_SCHEMES):
                    continue
                fname = target.split("#", 1)[0]
                if not fname:
                    continue
                resolved = (path.parent / fname).resolve()
                if not resolved.exists():
                    errors.append(
                        f"{rel}:{lineno}: broken link -> {target}")
    return errors


def run_doctests() -> tuple[int, list[str]]:
    failures, tested = [], 0
    for name in DOCTEST_MODULES:
        mod = importlib.import_module(name)
        result = doctest.testmod(mod, verbose=False)
        tested += result.attempted
        if result.failed:
            failures.append(f"{name}: {result.failed} doctest failure(s)")
    return tested, failures


def main() -> int:
    link_errors = check_links()
    for e in link_errors:
        print(f"[docs] {e}")
    print(f"[docs] link check: {len(DOC_FILES)} files, "
          f"{len(link_errors)} broken link(s)")
    tested, doc_failures = run_doctests()
    for e in doc_failures:
        print(f"[docs] {e}")
    print(f"[docs] doctests: {tested} example(s) across "
          f"{len(DOCTEST_MODULES)} modules, {len(doc_failures)} failing")
    return 1 if (link_errors or doc_failures) else 0


if __name__ == "__main__":
    sys.exit(main())
