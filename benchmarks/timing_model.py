"""Shared op-level timing model for the flat-mode benchmarks (§10.4/§10.5).

A query is a dependent chain of memory operations; queries overlap up to
MLP outstanding ops; banks bound throughput.  For each system:

    latency_bound = sum(per-query chain latency) / MLP
    bank_bound    = sum(per-op occupancy) / n_banks
    time          = max(latency_bound, bank_bound) / (1 - refresh_tax)

using the Table 3 interface timings verbatim (repro.core.timing).
"""
from __future__ import annotations

import dataclasses

from repro.core.timing import TECH_TIMING, InterfaceTiming

MLP = 16

# CPU<->memory interface bandwidth, bytes per CPU cycle (3.2 GHz core):
# WideIO2 in-package: 64 bits/vault x 8 vaults at 1.6 GHz  -> 32 B/cycle.
# DDR4 off-chip: 2 channels x 8 B at 1.6 GHz               ->  8 B/cycle.
INPKG_IF_BPC = 32.0
DDR_IF_BPC = 8.0


@dataclasses.dataclass
class OpCounts:
    """Per-WORKLOAD totals.  chain_* are per-query dependent latencies
    already multiplied by query count."""
    chain_cycles: float = 0.0     # Σ dependent-latency per query
    reads: float = 0.0            # bank occupancies (ops)
    writes: float = 0.0
    searches: float = 0.0
    ddr_reads: float = 0.0        # spill to main memory (capacity misses)
    ddr_writes: float = 0.0
    bytes_to_cpu: float = 0.0     # data crossing the in-package interface
    ddr_bytes: float = 0.0        # data crossing the DDR interface


def system_time_cycles(t: InterfaceTiming, ops: OpCounts) -> float:
    banks = t.n_vaults * t.banks_per_vault
    ddr = TECH_TIMING["ddr4"]
    ddr_banks = ddr.n_vaults * ddr.banks_per_vault
    occ = (ops.reads * t.tCCD
           + ops.writes * max(t.tCCD, t.tWR)
           + ops.searches * t.tCCD)
    ddr_occ = (ops.ddr_reads * ddr.tRC + ops.ddr_writes * max(ddr.tCCD, ddr.tWR))
    latency_bound = ops.chain_cycles / MLP
    bank_bound = occ / banks + ddr_occ / ddr_banks
    # interface (TSV / DDR bus) bandwidth bound — in-situ searches move
    # RESULTS, not data, across this boundary (the paper's request-count
    # argument); streaming baselines move every byte.
    if_bound = ops.bytes_to_cpu / INPKG_IF_BPC + ops.ddr_bytes / DDR_IF_BPC
    time = max(latency_bound, bank_bound, if_bound)
    return time / (1.0 - t.refresh_overhead)


def read_lat(t: InterfaceTiming) -> float:
    if t.needs_precharge:
        # open-row hit probability ~0.5 for random hashing access
        return 0.5 * (t.tCAS + t.tBL) + 0.5 * (t.tRP + t.tRCD + t.tCAS + t.tBL)
    return t.tRCD + t.tCAS + t.tBL


def write_lat(t: InterfaceTiming) -> float:
    return t.tCWD + t.tWR + t.tBL


def search_lat(t: InterfaceTiming) -> float:
    return t.tRCD + t.tCAS + t.tBL
