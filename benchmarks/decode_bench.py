"""Prefix-cache decode benchmark: cached-resume vs no-cache serving.

    PYTHONPATH=src python -m benchmarks.run --quick --only decode_bench

Measures the END-TO-END payoff of the Monarch prefix index on a real
transformer: the same zipf-shared-prefix request stream is served twice
through :class:`repro.serve.resume.PrefixResumeEngine` —

* ``no_cache``  — every request full-prefills its whole prompt (the
  engine with resume disabled; no index, no slab store traffic).
* ``cached``    — the production path: ``run_request_loop`` +
  ``AdmitQueue`` + ``MonarchKVIndex(fingerprint="prefix")`` with an
  attached :class:`KVSlabStore`; hits restore KV slabs and prefill runs
  only over the suffix from its RoPE offset.

Per leg, into ``BENCH_decode.json``:

* ``tokens_per_s``        — decode tokens emitted / leg wall time (the
  serving-throughput number the prefix cache is supposed to move).
* ``prompt_tokens_per_s`` — prompt tokens ACCOUNTED (resumed + computed)
  per second; the cached leg pays compute only for the computed share.
* ``hit_rate`` / ``resumed_fraction`` — index chunk hit rate and the
  fraction of prompt tokens whose prefill was actually skipped.

Top-level claims: ``speedup`` (cached tokens/s over no-cache tokens/s)
and ``tokens_match`` — the greedy decode output of the cached leg is
TOKEN-IDENTICAL to the no-cache leg's, request by request.  The
structural gate in ``check_regression.py`` fails CI (never downgraded by
``BENCH_WARN_ONLY``) when a leg/field goes missing, ``tokens_match`` is
false, or the cached leg stops hitting; the timing comparison against
the committed baseline honors ``BENCH_WARN_ONLY`` like every timing.

Model config: ``gemma3-27b`` reduced to CI size (d_model 128, 4 heads,
d_head 32, vocab 512) and re-widened to 6 layers so the 5:1
local:global pattern yields BOTH attention kinds (5 sliding-window
w=32 + 1 global) — the two cache-write formulas the resume path must
reproduce.  The full-size shapes this stands in for: 62 layers,
d_model 5376, 32 heads / 16 KV heads, d_head 128, w=1024,
vocab 262 144 (see ``configs/gemma3_27b``).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax

from repro import configs
from repro.bench.emit import emit_json
from repro.launch.serve import run_request_loop
from repro.models import transformer
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import (CHUNK_TOKENS, KVIndexConfig, KVSlabStore,
                                  MonarchKVIndex)
from repro.serve.resume import PrefixResumeEngine

#: Prompt shape: shared prefix chunks (the hit traffic) + fresh tail
#: chunks per request, matching serve_bench's layout.
PREFIX_CHUNKS = 4
TAIL_CHUNKS = 2
#: Shared prefixes in the zipf pool (zipf(1.5) concentrates on the first).
N_PREFIXES = 2


def _arch():
    """CI-sized gemma3 variant with both attention kinds (see module doc)."""
    return dataclasses.replace(
        configs.get_arch("gemma3-27b").reduced(), n_layers=6)


def _requests(n: int, seed: int) -> list[np.ndarray]:
    """(1, S) token batches: zipf-shared prefixes + unique tails."""
    rng = np.random.default_rng(seed)
    vocab = _arch().vocab_size
    prefixes = [rng.integers(1, vocab, (1, PREFIX_CHUNKS * CHUNK_TOKENS))
                for _ in range(N_PREFIXES)]
    out = []
    for _ in range(n):
        p = prefixes[min(int(rng.zipf(1.5)) - 1, N_PREFIXES - 1)]
        tail = rng.integers(1, vocab, (1, TAIL_CHUNKS * CHUNK_TOKENS))
        out.append(np.concatenate([p, tail], axis=1).astype(np.int32))
    return out


def _mk_index() -> MonarchKVIndex:
    """Prefix-fingerprint index with slab store; install on 2nd offer."""
    return MonarchKVIndex(
        KVIndexConfig(n_sets=8, set_ways=64, admit_after_reads=1,
                      rotate_every=1 << 30, fingerprint="prefix"),
        slab_store=KVSlabStore())


def _mk_engine(index: MonarchKVIndex, decode_tokens: int):
    cfg = _arch()
    max_seq = (PREFIX_CHUNKS + TAIL_CHUNKS) * CHUNK_TOKENS + decode_tokens
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    return PrefixResumeEngine(params, cfg, max_seq=max_seq, index=index,
                              decode_tokens=decode_tokens)


def _no_cache_leg(engine, requests, decode_tokens: int):
    """Full prefill + greedy decode per request, no index in the loop."""
    decoded = []
    t0 = time.perf_counter()
    for toks in requests:
        res = engine.prefill(toks, hits=None)       # hits=None: no resume
        decoded.append(engine.decode(res, decode_tokens))
    total_s = time.perf_counter() - t0
    return decoded, {
        "n_requests": len(requests),
        "total_s": round(total_s, 3),
        "tokens_per_s": round(len(requests) * decode_tokens / total_s, 2),
        "prompt_tokens_per_s": round(
            sum(r.shape[1] for r in requests) / total_s, 1),
        "hit_rate": 0.0,
        "resumed_fraction": 0.0,
    }


def _cached_leg(engine, requests, decode_tokens: int):
    """The production path: lookup -> restore -> partial prefill ->
    submit-after-prefill -> decode, via ``run_request_loop``."""
    q = AdmitQueue(engine.index)
    prefill_fn, base_decode = engine.request_fns(decode_tokens)
    decoded = []

    def decode_fn(toks, result):
        base_decode(toks, result)
        decoded.append(result.state["decoded"])

    t0 = time.perf_counter()
    try:
        recs = run_request_loop(q, requests, prefill_fn=prefill_fn,
                                decode_fn=decode_fn)
        q.flush()
    finally:
        q.close()
    total_s = time.perf_counter() - t0
    chunks = sum(r.chunks for r in recs)
    resumed = sum(r.resumed_chunks for r in recs)
    return decoded, {
        "n_requests": len(recs),
        "total_s": round(total_s, 3),
        "tokens_per_s": round(len(recs) * decode_tokens / total_s, 2),
        "prompt_tokens_per_s": round(
            chunks * CHUNK_TOKENS / total_s, 1),
        "hit_rate": round(float(engine.index.hit_rate), 4),
        "resumed_fraction": round(resumed / max(chunks, 1), 4),
    }


def _warmup(requests, decode_tokens: int) -> None:
    """Compile every shape the timed legs hit, on throwaway state: the
    full-prompt prefill, the resumed suffix prefill (all hit runs the
    zipf stream can produce), and the decode step.  The jit cache is
    global, so the timed legs pay zero compilation."""
    idx = _mk_index()
    engine = _mk_engine(idx, decode_tokens)
    decoded, _ = _cached_leg(engine, requests, decode_tokens)
    assert len(decoded) == len(requests)


def run(csv_rows: list[str], quick: bool = False) -> dict:
    n = 10 if quick else 24
    decode_tokens = 4 if quick else 8
    requests = _requests(n, seed=3)
    _warmup(requests, decode_tokens)

    idx = _mk_index()
    engine = _mk_engine(idx, decode_tokens)
    base_decoded, no_cache = _no_cache_leg(engine, requests, decode_tokens)
    print(f"[decode_bench] no_cache: {no_cache['tokens_per_s']:.1f} tok/s "
          f"decode, {no_cache['prompt_tokens_per_s']:.0f} tok/s prompt, "
          f"{no_cache['total_s']:.1f}s")

    cached_decoded, cached = _cached_leg(engine, requests, decode_tokens)
    print(f"[decode_bench] cached:   {cached['tokens_per_s']:.1f} tok/s "
          f"decode, hit {cached['hit_rate']:.0%}, resumed "
          f"{cached['resumed_fraction']:.0%} of prompt tokens, "
          f"{cached['total_s']:.1f}s")

    tokens_match = (len(base_decoded) == len(cached_decoded) and all(
        np.array_equal(a, b)
        for a, b in zip(base_decoded, cached_decoded)))
    speedup = round(cached["tokens_per_s"]
                    / max(no_cache["tokens_per_s"], 1e-9), 3)
    print(f"[decode_bench] speedup {speedup:.2f}x, tokens_match "
          f"{tokens_match} ({n} requests x {decode_tokens} greedy tokens)")

    for name, leg in (("no_cache", no_cache), ("cached", cached)):
        csv_rows.append(
            f"decode_{name},{leg['total_s'] * 1e6 / n:.0f},"
            f"tokens_per_s={leg['tokens_per_s']}")

    cfg = _arch()
    payload = {
        "legs": {"no_cache": no_cache, "cached": cached},
        "speedup": speedup,
        "hit_rate": cached["hit_rate"],
        "tokens_match": bool(tokens_match),
        "config": {
            "arch": cfg.name, "n_layers": cfg.n_layers,
            "layer_pattern": cfg.layer_pattern(),
            "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "sliding_window": cfg.sliding_window,
            "vocab_size": cfg.vocab_size,
            "prefix_chunks": PREFIX_CHUNKS, "tail_chunks": TAIL_CHUNKS,
            "chunk_tokens": CHUNK_TOKENS, "n_prefixes": N_PREFIXES,
            "decode_tokens": decode_tokens,
            "fingerprint": "prefix",
        },
    }
    path = emit_json("decode", payload, quick=quick)
    print(f"[decode_bench] wrote {path}")
    return payload


if __name__ == "__main__":
    rows: list[str] = []
    run(rows, quick=True)
