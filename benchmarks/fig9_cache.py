"""Fig. 9 + Fig. 10 reproduction: hardware-managed cache mode.

Runs the trace simulator (paper timing tables, §7 cache organization, §8
durability machinery) over CRONO/NAS-signature traces for the paper's
systems: D-Cache, D-Cache(Ideal), S-Cache, RC-Unbound, Monarch-Unbound,
Monarch M=1..4.  Reports speedup vs D-Cache (Fig. 9) and in-package hit
rates (Fig. 10), and validates claims C1-C4.

The whole config x app grid goes through ``simulator.simulate_grid``: one
vmapped ``lax.scan`` per shape family (the entire Monarch C1-C4 M-sweep is
a single call) instead of the former serial per-config Python loop.

Capacity scale: 4 GB DRAM -> `scale_blocks` 64B blocks (default 4096,
= 1/16384 scale); all capacity RATIOS and every timing parameter are
unscaled.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.bench import emit_json, stopwatch
from repro.core import simulator
from repro.data import traces


def sweep_configs(scale_blocks: int = 4096) -> dict[str, simulator.SimConfig]:
    """The §10.2 systems with the simulation-scale knobs applied."""
    cfgs = simulator.baseline_configs(scale_blocks)
    # L3 scaled with the in-package capacity (paper ratio 8 MB : 4 GB); a
    # full-size L3 would absorb the reuse that belongs in-package.
    for name in list(cfgs):
        cfgs[name] = dataclasses.replace(cfgs[name], l3_sets=16)
    # Write-window scaled for the sim horizon so t_MWW actually binds.
    # Per the paper the window LENGTH scales with M (t_MWW = M*T_Life/n_W)
    # while the budget is M writes/block: larger M tolerates larger bursts
    # but locks the superset for longer when it is exceeded.
    for name in list(cfgs):
        if cfgs[name].wear_enabled:
            cfgs[name] = dataclasses.replace(
                cfgs[name],
                t_mww_cycles=(1 << 15) * cfgs[name].m_writes, dc_limit=512,
                window_budget_blocks=64)
    return cfgs


def run(csv_rows: list[str], scale_blocks: int = 4096,
        n_requests: int = 120_000, systems: list[str] | None = None,
        quick: bool = False):
    cfgs = sweep_configs(scale_blocks)
    systems = systems or list(cfgs)
    inpkg_blocks = cfgs["monarch_unbound"].inpkg_blocks
    cfgs = {s: cfgs[s] for s in systems}
    specs = traces.crono_nas_specs(inpkg_blocks, n_requests)
    trace_list = [(spec.name, *traces.generate(spec)) for spec in specs]

    timing: dict[str, float] = {}
    with stopwatch(timing, "sweep_s"):
        res = simulator.simulate_grid(cfgs, trace_list)
    n_fam = simulator.n_shape_families(cfgs.values())
    print(f"\n[fig9] {len(cfgs)} configs x {len(specs)} apps = "
          f"{len(res)} sims via {n_fam} vmapped scan(s) "
          f"in {timing['sweep_s']:.1f}s")

    speedups = {s: [] for s in systems}
    hitrates = {s: [] for s in systems}
    writes_saved = []
    print("\n== Fig 9/10: cache-mode performance (speedup vs D-Cache) ==")
    print(f"{'app':>6s} " + " ".join(f"{s:>15s}" for s in systems))
    for spec in specs:
        base = res[("d_cache", spec.name)].total_cycles
        row = []
        for s in systems:
            r = res[(s, spec.name)]
            sp = base / r.total_cycles
            speedups[s].append(sp)
            hitrates[s].append(r.inpkg_hit_rate)
            row.append(f"{sp:15.3f}")
        print(f"{spec.name:>6s} " + " ".join(row))
        mu = res[("monarch_unbound", spec.name)].stats
        total_ev = max(mu["l3_evictions"], 1)
        writes_saved.append(mu["writes_filtered"] / total_ev)

    print(f"{'gmean':>6s} " + " ".join(
        f"{float(np.exp(np.mean(np.log(np.maximum(speedups[s], 1e-9))))):15.3f}"
        for s in systems))
    print("\nhit rates (mean):",
          {s: round(float(np.mean(hitrates[s])), 3) for s in systems})

    unb = float(np.mean(speedups["monarch_unbound"]))
    ideal = float(np.mean(speedups["d_cache_ideal"]))
    m_means = {m: float(np.mean(speedups[f"monarch_m{m}"]))
               for m in (1, 2, 3, 4) if f"monarch_m{m}" in systems}
    wsave = float(np.mean(writes_saved))
    print(f"\nC1 Monarch-unbound vs D-Cache: {unb:.3f}x   (paper: 1.61x)")
    print(f"C2 Monarch-unbound vs Ideal-DRAM: {unb / ideal:.3f}x (paper: 1.21x)")
    best_m = None
    if m_means:
        best_m = max(m_means, key=m_means.get)
        print(f"C3 best bounded M: {best_m} ({m_means})  (paper: M=3)")
    print(f"C4 write-traffic filtered: {wsave:.2%} of L3 evictions "
          f"(paper: ~31% write reduction)")
    csv_rows.append(f"fig9_monarch_unbound_speedup,0,{unb:.3f}")
    csv_rows.append(f"fig9_vs_ideal,0,{unb / ideal:.3f}")
    csv_rows.append(f"fig9_write_filtered_frac,0,{wsave:.3f}")
    for m, v in m_means.items():
        csv_rows.append(f"fig9_monarch_m{m}_speedup,0,{v:.3f}")

    emit_json("fig9", {
        "n_requests": n_requests,
        "scale_blocks": scale_blocks,
        "systems": systems,
        "n_sims": len(res),
        "n_vmapped_scans": n_fam,
        "sweep_seconds": timing["sweep_s"],
        "speedup_gmean": {
            s: float(np.exp(np.mean(np.log(np.maximum(speedups[s], 1e-9)))))
            for s in systems},
        "hit_rate_mean": {s: float(np.mean(hitrates[s])) for s in systems},
        "claims": {
            "C1_unbound_vs_dcache": unb,
            "C2_unbound_vs_ideal": unb / ideal,
            "C3_best_m": best_m,
            "C4_write_filtered_frac": wsave,
        },
    }, quick=quick)
    return {"speedups": speedups, "hitrates": hitrates}
