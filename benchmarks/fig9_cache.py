"""Fig. 9 + Fig. 10 reproduction: hardware-managed cache mode.

Runs the lax.scan trace simulator (paper timing tables, §7 cache
organization, §8 durability machinery) over CRONO/NAS-signature traces for
the paper's systems: D-Cache, D-Cache(Ideal), S-Cache, RC-Unbound,
Monarch-Unbound, Monarch M=1..4.  Reports speedup vs D-Cache (Fig. 9) and
in-package hit rates (Fig. 10), and validates claims C1-C4.

Capacity scale: 4 GB DRAM -> `scale_blocks` 64B blocks (default 4096,
= 1/16384 scale); all capacity RATIOS and every timing parameter are
unscaled.
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator
from repro.data import traces


def run(csv_rows: list[str], scale_blocks: int = 4096,
        n_requests: int = 120_000, systems: list[str] | None = None):
    cfgs = simulator.baseline_configs(scale_blocks)
    # L3 scaled with the in-package capacity (paper ratio 8 MB : 4 GB); a
    # full-size L3 would absorb the reuse that belongs in-package.
    import dataclasses
    for name in list(cfgs):
        cfgs[name] = dataclasses.replace(cfgs[name], l3_sets=16)
    # Write-window scaled for the sim horizon so t_MWW actually binds.
    # Per the paper the window LENGTH scales with M (t_MWW = M*T_Life/n_W)
    # while the budget is M writes/block: larger M tolerates larger bursts
    # but locks the superset for longer when it is exceeded.
    for name in list(cfgs):
        if cfgs[name].wear_enabled:
            import dataclasses
            cfgs[name] = dataclasses.replace(
                cfgs[name],
                t_mww_cycles=(1 << 15) * cfgs[name].m_writes, dc_limit=512,
                window_budget_blocks=64)
    systems = systems or list(cfgs)
    inpkg_blocks = cfgs["monarch_unbound"].inpkg_blocks
    specs = traces.crono_nas_specs(inpkg_blocks, n_requests)

    speedups = {s: [] for s in systems}
    hitrates = {s: [] for s in systems}
    writes_saved = []
    print("\n== Fig 9/10: cache-mode performance (speedup vs D-Cache) ==")
    print(f"{'app':>6s} " + " ".join(f"{s:>15s}" for s in systems))
    for spec in specs:
        addrs, wr = traces.generate(spec)
        res = {}
        for s in systems:
            res[s] = simulator.simulate_trace(cfgs[s], addrs, wr)
        base = res["d_cache"].total_cycles
        row = []
        for s in systems:
            sp = base / res[s].total_cycles
            speedups[s].append(sp)
            hitrates[s].append(res[s].inpkg_hit_rate)
            row.append(f"{sp:15.3f}")
        print(f"{spec.name:>6s} " + " ".join(row))
        mu = res["monarch_unbound"].stats
        total_ev = max(mu["l3_evictions"], 1)
        writes_saved.append(mu["writes_filtered"] / total_ev)

    print(f"{'gmean':>6s} " + " ".join(
        f"{float(np.exp(np.mean(np.log(np.maximum(speedups[s], 1e-9))))):15.3f}"
        for s in systems))
    print("\nhit rates (mean):",
          {s: round(float(np.mean(hitrates[s])), 3) for s in systems})

    unb = float(np.mean(speedups["monarch_unbound"]))
    ideal = float(np.mean(speedups["d_cache_ideal"]))
    m_means = {m: float(np.mean(speedups[f"monarch_m{m}"]))
               for m in (1, 2, 3, 4) if f"monarch_m{m}" in systems}
    wsave = float(np.mean(writes_saved))
    print(f"\nC1 Monarch-unbound vs D-Cache: {unb:.3f}x   (paper: 1.61x)")
    print(f"C2 Monarch-unbound vs Ideal-DRAM: {unb / ideal:.3f}x (paper: 1.21x)")
    if m_means:
        best_m = max(m_means, key=m_means.get)
        print(f"C3 best bounded M: {best_m} ({m_means})  (paper: M=3)")
    print(f"C4 write-traffic filtered: {wsave:.2%} of L3 evictions "
          f"(paper: ~31% write reduction)")
    csv_rows.append(f"fig9_monarch_unbound_speedup,0,{unb:.3f}")
    csv_rows.append(f"fig9_vs_ideal,0,{unb / ideal:.3f}")
    csv_rows.append(f"fig9_write_filtered_frac,0,{wsave:.3f}")
    for m, v in m_means.items():
        csv_rows.append(f"fig9_monarch_m{m}_speedup,0,{v:.3f}")
    return {"speedups": speedups, "hitrates": hitrates}
