"""Fig. 11 reproduction: Monarch (M=3) lifetime vs ideal wear leveling.

Methodology = the paper's (§10.3): record per-superset write counts while
the app runs, then model constantly repeated execution with rotary offsets
applied per rotation; lifetime ends when the hottest cell crosses the
endurance (1e8).  The per-app simulation pass runs all 11 apps through one
vmapped scan (``simulator.simulate_grid``) instead of a serial loop.

Three scale/granularity factors are explicit:

* CAPACITY: the sim uses S_sim supersets standing in for S_REAL = 8 GB /
  32 KB-superset = 262,144; per-superset write RATE shrinks by
  S_sim/S_REAL on the real stack (same application write bandwidth spread
  over more supersets).  Distribution skew (max/mean) carries over.
* TIME: absolute lifetime depends on the application's absolute post-L3
  write bandwidth, which only a cycle-accurate core model (the paper's
  ESESC) produces.  We pin ONE global calibration constant — the CPU
  request rate R_REQ — such that EP's IDEAL lifetime matches the paper's
  16.72 years, then apply the same R_REQ to every app.  Per-app ordering,
  rotate cadence and flush overhead are model output, not calibration.
* GRANULARITY: our snapshots resolve supersets and ways; at that
  granularity the prime-offset rotation + counter-ordered installs level
  wear to ~ideal (measured column `ss_ratio`).  The paper's snapshots
  additionally resolve rows/columns INSIDE each XAM array (tag columns,
  dirty-bit rows), whose residual skew is why their Monarch lands at 61%
  of ideal.  We report the paper-implied intra-array skew (1/0.61 = 1.64)
  as an explicit sensitivity column — labeled, not hidden.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.bench import emit_json, stopwatch
from repro.core import lifetime, simulator
from repro.core.timing import CPU_HZ, DEFAULT_ENDURANCE, SECONDS_PER_YEAR
from repro.data import traces

S_REAL = 262_144        # 8 GB / (512 blocks x 64 B) supersets
PAPER_EP_IDEAL_YEARS = 16.72
PAPER_RESIDUAL_SKEW = 16.72 / 10.22   # intra-array skew implied by Fig. 11


def run(csv_rows: list[str], scale_blocks: int = 4096,
        n_requests: int = 120_000, quick: bool = False):
    cfgs = simulator.baseline_configs(scale_blocks)
    # Same sim-scale knobs as fig9: scaled L3, M-scaled window, scaled
    # budget.  dc_limit scales with the superset count (paper 8192 of
    # 262144 supersets ~ 3%; at 16 sim supersets the analogous distinct-
    # dirty-superset trigger is ~12).
    cfg = dataclasses.replace(cfgs["monarch_m3"], l3_sets=16,
                              t_mww_cycles=(1 << 15) * 3, dc_limit=12,
                              window_budget_blocks=64)
    specs = traces.crono_nas_specs(cfg.inpkg_blocks, n_requests)

    # Pass 1: simulate every app — one vmapped scan over the 11-app grid —
    # and collect write snapshots + way evenness from the final states.
    trace_list = [(spec.name, *traces.generate(spec)) for spec in specs]
    timing: dict[str, float] = {}
    with stopwatch(timing, "sweep_s"):
        results, states = simulator.simulate_grid(
            {cfg.name: cfg}, trace_list, return_state=True)
    print(f"\n[fig11] {len(specs)} apps through 1 vmapped scan "
          f"in {timing['sweep_s']:.1f}s")
    snaps = {}
    for spec in specs:
        res = results[(cfg.name, spec.name)]
        st = states[(cfg.name, spec.name)]
        snaps[spec.name] = (np.asarray(st.set_writes, np.float64), res,
                            np.asarray(st.set_way_writes, np.float64))

    # Calibrate R_REQ on EP's ideal lifetime (see module docstring).
    w_ep, _, _ = snaps["EP"]
    # ideal_years = endurance / (sum(w)/S_REAL) * epoch_s / YEAR with
    # epoch_s = n_requests / R_REQ  ->  solve for R_REQ.
    epoch_s_ep = (PAPER_EP_IDEAL_YEARS * SECONDS_PER_YEAR
                  * (w_ep.sum() / S_REAL) / DEFAULT_ENDURANCE)
    r_req = n_requests / epoch_s_ep
    print("\n== Fig 11: lifetime (years), M=3 vs ideal wear leveling ==")
    print(f"calibration: R_REQ = {r_req:.3e} req/s "
          f"(pins EP ideal to {PAPER_EP_IDEAL_YEARS}y; single global const)")
    print(f"{'app':>6s} {'monarch_y':>10s} {'ideal_y':>10s} {'ss_ratio':>8s} "
          f"{'rotates':>8s} {'flush%':>7s}")

    years_all, ideal_all, ratios = {}, {}, {}
    for spec in specs:
        w, res, ww = snaps[spec.name]
        epoch_seconds = n_requests / r_req
        rotations = res.stats["rotates"]   # 0 = offsets never moved
        lt = lifetime.estimate_lifetime(
            w, epoch_cycles=epoch_seconds * CPU_HZ,
            rotations_per_epoch=rotations, endurance=DEFAULT_ENDURANCE,
            intra_set_skew=PAPER_RESIDUAL_SKEW)
        lt_ss = lifetime.estimate_lifetime(
            w, epoch_cycles=epoch_seconds * CPU_HZ,
            rotations_per_epoch=rotations, endurance=DEFAULT_ENDURANCE)
        scale = S_REAL / len(w)     # capacity rescale (rate per superset)
        years = lt.years * scale
        ideal = lt.ideal_years * scale
        years_all[spec.name] = years
        ideal_all[spec.name] = ideal
        # superset/way-granularity mechanism quality (our model's own):
        ratios[spec.name] = (lt_ss.years / lt_ss.ideal_years
                             if lt_ss.ideal_years else 1.0)
        # C8: flush cost = rotation writebacks / total in-package ops.
        ops = max(res.stats["inpkg_reads"] + res.stats["inpkg_writes"]
                  + res.stats["inpkg_searches"], 1)
        flush_frac = res.stats["flushed_dirty"] / ops
        print(f"{spec.name:>6s} {years:10.2f} {ideal:10.2f} "
              f"{ratios[spec.name]:8.2f} {res.stats['rotates']:8d} "
              f"{flush_frac:7.2%}")
        csv_rows.append(f"fig11_{spec.name}_years,0,{years:.2f}")

    mn_app = min(years_all, key=years_all.get)
    mn, mni = years_all[mn_app], ideal_all[mn_app]
    mech = float(np.mean(list(ratios.values())))
    print(f"\nC7 min lifetime (paper-implied intra-array skew "
          f"{PAPER_RESIDUAL_SKEW:.2f} applied): monarch {mn:.2f}y vs ideal "
          f"{mni:.2f}y at {mn_app} (paper: 10.22 vs 16.72 at EP)")
    print(f"C7 superset-granularity mechanism ratio (measured): {mech:.2f} "
          f"(rotation+counter installs level superset wear to ~ideal; the "
          f"paper's 0.61 residual lives inside arrays, below our "
          f"granularity — see module docstring)")
    print("C8 rotate cadence / flush overhead: rotates and flush% above; "
          "paper: rotate ~ every 260M cycles, flush cost < 1%, +<4% misses "
          "(at full scale; our cadence is at 1/16384 capacity scale)")
    csv_rows.append(f"fig11_min_years,0,{mn:.2f}")
    csv_rows.append(f"fig11_min_ideal_years,0,{mni:.2f}")
    csv_rows.append(f"fig11_ss_mech_ratio,0,{mech:.3f}")

    emit_json("fig11", {
        "n_requests": n_requests,
        "scale_blocks": scale_blocks,
        "sweep_seconds": timing["sweep_s"],
        "r_req_calibration": r_req,
        "years": years_all,
        "ideal_years": ideal_all,
        "ss_mechanism_ratio": ratios,
        "claims": {
            "C7_min_years": mn,
            "C7_min_ideal_years": mni,
            "C7_min_app": mn_app,
            "C7_ss_mech_ratio_mean": mech,
        },
    }, quick=quick)
