"""Open-loop serving front-end benchmark: request latency under load.

    PYTHONPATH=src python -m benchmarks.run --quick --only serve_bench

Drives :func:`repro.launch.serve.run_request_loop` — the SAME loop the
production launcher runs — with synthetic request batches and OPEN-LOOP
arrival schedules: Poisson arrivals at two offered rates (underload and
overload relative to this rig's measured service time) plus a replayed
bursty trace.  Open-loop means a request's latency is charged from its
SCHEDULED arrival, so backlog shows up as queueing delay in p99 instead
of being hidden by the loop slowing its own arrival process
(coordinated omission).

Per leg the bench reports, into ``BENCH_serve.json``:

* ``offered_rps`` / ``goodput_rps`` — scheduled vs completed throughput
  (goodput counts requests whose admission was not dropped).
* ``p50_ms`` / ``p99_ms`` / ``mean_ms`` — front-end latency (lookup +
  service proxy + admission submit, queueing included).
* ``shed_rate`` — fingerprints shed at the ``max_pending`` bound over
  fingerprints accepted (``policy="shed"`` back-pressure).
* ``hit_rate`` — index prefix-chunk hit rate for the leg.

The index runs ``clock="wall"`` (the t_MWW admission window is a real
time budget — this is the latency-era serving configuration) behind a
bounded ``AdmitQueue``.  The service proxy is a small jitted matmul
standing in for prefill/decode compute: it releases the GIL inside XLA
exactly like the real model steps, so the admission worker overlaps it
the same way.  Model quality is irrelevant here — the bench measures
the FRONT END (index + queue), not the transformer.

Latency thresholds against the committed baseline honor
``BENCH_WARN_ONLY`` like every timing; the structural gate on the
artifact (required fields, >=2 Poisson rates) is always fatal — see
``check_regression.py``.
"""
from __future__ import annotations

import json
import os

import numpy as np

import jax
import jax.numpy as jnp

from repro.bench.emit import emit_json
from repro.launch.serve import run_request_loop
from repro.serve.admit_queue import AdmitQueue
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex

#: Offered Poisson rates (requests/s): an underload point and a point
#: chosen to overrun interpret-mode service times, so p99 shows queueing.
OFFERED_RATES = (50.0, 400.0)
#: Admission back-pressure for every leg: shed-oldest at this bound.
MAX_PENDING = 64
#: Prompt shape: ``PREFIX_CHUNKS`` chunks shared across all requests (the
#: hit traffic) + ``TAIL_CHUNKS`` fresh chunks per request (the working
#: set that ages the index).
PREFIX_CHUNKS = 4
TAIL_CHUNKS = 2


def _requests(n: int, seed: int) -> list[np.ndarray]:
    """One (1, S) token batch per request: shared prefix + fresh tail."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, 1 << 15, (1, PREFIX_CHUNKS * CHUNK_TOKENS))
    out = []
    for _ in range(n):
        tail = rng.integers(1, 1 << 15, (1, TAIL_CHUNKS * CHUNK_TOKENS))
        out.append(np.concatenate([prefix, tail], axis=1).astype(np.int32))
    return out


def _poisson_arrivals(n: int, rate_rps: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, n))


def _trace_arrivals(n: int) -> np.ndarray:
    """Replayed bursty trace: ``REPRO_SERVE_TRACE`` (a JSON list of
    arrival offsets in seconds) when set, else the built-in burst
    pattern — groups of 8 back-to-back requests (2 ms spacing) separated
    by 60 ms idle gaps, the on/off shape Poisson cannot produce."""
    path = os.environ.get("REPRO_SERVE_TRACE")
    if path:
        with open(path) as f:
            arr = np.asarray(json.load(f), dtype=float)[:n]
        return arr
    burst, gap_s, step_s = 8, 0.060, 0.002
    t, out = 0.0, []
    while len(out) < n:
        out.extend(t + i * step_s for i in range(burst))
        t += gap_s
    return np.asarray(out[:n])


def _mk_frontend() -> AdmitQueue:
    """Fresh wall-clock index behind a bounded shed-policy queue."""
    idx = MonarchKVIndex(KVIndexConfig.with_lifetime(
        t_life_years=10.0, clock="wall", n_sets=8, set_ways=64,
        admit_after_reads=0, rotate_every=1 << 30))
    return AdmitQueue(idx, max_pending=MAX_PENDING, policy="shed")


def _service_proxy():
    """Jitted stand-in for prefill/decode compute (releases the GIL)."""
    w = jnp.ones((192, 192), jnp.float32)

    @jax.jit
    def step(x):
        return (x @ w).sum()

    step(w).block_until_ready()              # compile outside the timing

    def prefill(toks, hits):
        return step(w)

    def decode(toks, state):
        jax.block_until_ready(state)

    return prefill, decode


def _run_leg(requests, arrivals_s, *, label: str) -> dict:
    q = _mk_frontend()
    prefill, decode = _service_proxy()
    try:
        recs = run_request_loop(q, requests, prefill_fn=prefill,
                                decode_fn=decode, arrivals_s=arrivals_s)
        q.flush()                            # all admissions accounted
    finally:
        q.close()
    lat_ms = np.asarray([r.latency_s for r in recs]) * 1e3
    makespan = max(recs[-1].done_s - recs[0].arrival_s, 1e-9)
    s = q.stats
    leg = {
        "n_requests": len(recs),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "mean_ms": round(float(lat_ms.mean()), 3),
        "goodput_rps": round(
            sum(1 for r in recs if not r.dropped) / makespan, 2),
        "shed_rate": round(s.shed_fps / max(s.submitted, 1), 4),
        "hit_rate": round(float(q.index.hit_rate), 4),
    }
    print(f"[serve_bench] {label}: p50 {leg['p50_ms']:.1f} ms, "
          f"p99 {leg['p99_ms']:.1f} ms, goodput {leg['goodput_rps']:.0f} "
          f"req/s, shed {leg['shed_rate']:.1%}, hit {leg['hit_rate']:.0%}")
    return leg


def _warmup(n: int) -> None:
    """Compile the index lookup/admit kernels and the service proxy on a
    throwaway front end, so no timed leg pays jit compilation (the jit
    cache is global and every leg uses identical shapes).  Runs the SAME
    request count as the timed legs: a fuller index reaches admission
    paths (e.g. the first hopscotch displacement) that only compile once
    enough distinct fingerprints have been installed — a short warmup
    leaves a one-time ~0.5 s stall inside the first timed leg."""
    q = _mk_frontend()
    prefill, decode = _service_proxy()
    try:
        run_request_loop(q, _requests(n, seed=7), prefill_fn=prefill,
                         decode_fn=decode)
        q.flush()
    finally:
        q.close()


def run(csv_rows: list[str], quick: bool = False) -> dict:
    n = 32 if quick else 128
    _warmup(n)
    poisson = []
    for rate in OFFERED_RATES:
        leg = _run_leg(_requests(n, seed=7),
                       _poisson_arrivals(n, rate, seed=11),
                       label=f"poisson {rate:g} req/s")
        leg["offered_rps"] = rate
        poisson.append(leg)
        csv_rows.append(f"serve_poisson_{rate:g}rps,{leg['p50_ms'] * 1e3:.1f}"
                        f",p99_ms={leg['p99_ms']}")
    trace = _run_leg(_requests(n, seed=7), _trace_arrivals(n),
                     label="burst trace")
    trace["offered_rps"] = round(
        len(_trace_arrivals(n)) / max(_trace_arrivals(n)[-1], 1e-9), 2)
    csv_rows.append(f"serve_trace,{trace['p50_ms'] * 1e3:.1f}"
                    f",p99_ms={trace['p99_ms']}")
    payload = {
        "poisson": poisson,
        "trace": trace,
        "config": {
            "max_pending": MAX_PENDING, "policy": "shed", "clock": "wall",
            "prefix_chunks": PREFIX_CHUNKS, "tail_chunks": TAIL_CHUNKS,
            "chunk_tokens": CHUNK_TOKENS,
        },
    }
    path = emit_json("serve", payload, quick=quick)
    print(f"[serve_bench] wrote {path}")
    return payload


if __name__ == "__main__":
    rows: list[str] = []
    run(rows, quick=True)
