"""Open-loop serving front-end benchmark: request latency under load.

    PYTHONPATH=src python -m benchmarks.run --quick --only serve_bench

Drives :func:`repro.launch.serve.run_request_loop` — the SAME loop the
production launcher runs — with synthetic request batches and OPEN-LOOP
arrival schedules: Poisson arrivals at two offered rates (underload and
overload relative to this rig's measured service time) plus a replayed
bursty trace.  Open-loop means a request's latency is charged from its
SCHEDULED arrival, so backlog shows up as queueing delay in p99 instead
of being hidden by the loop slowing its own arrival process
(coordinated omission).

Per leg the bench reports, into ``BENCH_serve.json``:

* ``offered_rps`` / ``goodput_rps`` — scheduled vs completed throughput
  (goodput counts requests whose admission was not dropped).
* ``p50_ms`` / ``p99_ms`` / ``mean_ms`` — front-end latency (lookup +
  service proxy + admission submit, queueing included).
* ``shed_rate`` — fingerprints shed at the ``max_pending`` bound over
  fingerprints accepted (``policy="shed"`` back-pressure).
* ``hit_rate`` — index prefix-chunk hit rate for the leg.

A fourth leg, ``http``, replays the burst/replayed-trace schedule
through the REAL socket path (``repro.serve.http_frontend`` booted on a
loopback port — or an external ``launch/httpd.py`` via
``REPRO_SERVE_HTTP_URL``): same fields, plus ``transport_overhead_ms``
(client wall time minus server-reported handling time, median) and
``coalesced_requests`` (requests the router micro-batcher merged).

The index runs ``clock="wall"`` (the t_MWW admission window is a real
time budget — this is the latency-era serving configuration) behind a
bounded ``AdmitQueue``.  The service proxy is a small jitted matmul
standing in for prefill/decode compute: it releases the GIL inside XLA
exactly like the real model steps, so the admission worker overlaps it
the same way.  Model quality is irrelevant here — the bench measures
the FRONT END (index + queue), not the transformer.

Latency thresholds against the committed baseline honor
``BENCH_WARN_ONLY`` like every timing; the structural gate on the
artifact (required fields, >=2 Poisson rates) is always fatal — see
``check_regression.py``.
"""
from __future__ import annotations

import http.client
import json
import os
import threading
import time
import urllib.parse

import numpy as np

import jax
import jax.numpy as jnp

from repro.bench.emit import emit_json
from repro.launch.serve import run_request_loop
from repro.serve.admit_queue import AdmitQueue
from repro.serve.http_frontend import HttpFrontend, ServeRouter
from repro.serve.kv_index import CHUNK_TOKENS, KVIndexConfig, MonarchKVIndex

#: Offered Poisson rates (requests/s): an underload point and a point
#: chosen to overrun interpret-mode service times, so p99 shows queueing.
OFFERED_RATES = (50.0, 400.0)
#: Admission back-pressure for every leg: shed-oldest at this bound.
MAX_PENDING = 64
#: Prompt shape: ``PREFIX_CHUNKS`` chunks shared across all requests (the
#: hit traffic) + ``TAIL_CHUNKS`` fresh chunks per request (the working
#: set that ages the index).
PREFIX_CHUNKS = 4
TAIL_CHUNKS = 2


def _requests(n: int, seed: int) -> list[np.ndarray]:
    """One (1, S) token batch per request: shared prefix + fresh tail."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, 1 << 15, (1, PREFIX_CHUNKS * CHUNK_TOKENS))
    out = []
    for _ in range(n):
        tail = rng.integers(1, 1 << 15, (1, TAIL_CHUNKS * CHUNK_TOKENS))
        out.append(np.concatenate([prefix, tail], axis=1).astype(np.int32))
    return out


def _poisson_arrivals(n: int, rate_rps: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, n))


def _load_trace(path: str, n: int) -> np.ndarray:
    """Validate + normalize a replayed ``REPRO_SERVE_TRACE`` file.

    ``run_request_loop`` requires nondecreasing arrival offsets, one
    per request — a short, unsorted, or negative trace used to slip
    through silently and corrupt the backlog accounting.  Now:
    non-numeric / non-finite / negative offsets raise with a one-line
    actionable message; an unsorted trace is sorted (arrival ORDER is
    what replay needs — wall-clock offsets already encode it); a trace
    shorter than ``n`` is tiled periodically (each repeat shifted by
    the trace makespan plus its mean gap), or errors when it has zero
    makespan and therefore no period to tile by."""
    with open(path) as f:
        try:
            raw = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"REPRO_SERVE_TRACE {path}: not valid JSON ({e}); "
                "expected a JSON list of arrival offsets in seconds"
            ) from None
    try:
        arr = np.asarray(raw, dtype=float)
    except (TypeError, ValueError):
        arr = None
    if arr is None or arr.ndim != 1 or arr.size == 0:
        raise ValueError(
            f"REPRO_SERVE_TRACE {path}: expected a non-empty flat list "
            "of arrival offsets in seconds, got "
            f"{type(raw).__name__}") from None
    if not np.all(np.isfinite(arr)):
        raise ValueError(
            f"REPRO_SERVE_TRACE {path}: non-finite arrival offsets "
            "(NaN/inf) — every entry must be a finite second offset")
    if (arr < 0).any():
        raise ValueError(
            f"REPRO_SERVE_TRACE {path}: negative arrival offset "
            f"{arr.min():g}s — offsets are seconds from replay start "
            "and must be >= 0")
    arr = np.sort(arr)       # replay needs nondecreasing arrivals
    if arr.size < n:
        if arr[-1] <= 0:
            raise ValueError(
                f"REPRO_SERVE_TRACE {path}: {arr.size} arrivals < {n} "
                "requested and the trace has zero makespan — nothing "
                f"to tile; provide >= {n} offsets or a nonzero span")
        gap = arr[-1] / max(arr.size - 1, 1)
        period = arr[-1] + gap
        reps = -(-n // arr.size)         # ceil division
        arr = np.concatenate([arr + k * period for k in range(reps)])
    return arr[:n]


def _trace_arrivals(n: int) -> np.ndarray:
    """Replayed bursty trace: ``REPRO_SERVE_TRACE`` (a JSON list of
    arrival offsets in seconds, validated/sorted/tiled by
    :func:`_load_trace`) when set, else the built-in burst pattern —
    groups of 8 back-to-back requests (2 ms spacing) separated by
    60 ms idle gaps, the on/off shape Poisson cannot produce."""
    path = os.environ.get("REPRO_SERVE_TRACE")
    if path:
        return _load_trace(path, n)
    burst, gap_s, step_s = 8, 0.060, 0.002
    t, out = 0.0, []
    while len(out) < n:
        out.extend(t + i * step_s for i in range(burst))
        t += gap_s
    return np.asarray(out[:n])


def _mk_frontend() -> AdmitQueue:
    """Fresh wall-clock index behind a bounded shed-policy queue."""
    idx = MonarchKVIndex(KVIndexConfig.with_lifetime(
        t_life_years=10.0, clock="wall", n_sets=8, set_ways=64,
        admit_after_reads=0, rotate_every=1 << 30))
    return AdmitQueue(idx, max_pending=MAX_PENDING, policy="shed")


def _service_proxy():
    """Jitted stand-in for prefill/decode compute (releases the GIL)."""
    w = jnp.ones((192, 192), jnp.float32)

    @jax.jit
    def step(x):
        return (x @ w).sum()

    step(w).block_until_ready()              # compile outside the timing

    def prefill(toks, hits):
        return step(w)

    def decode(toks, state):
        jax.block_until_ready(state)

    return prefill, decode


def _run_leg(requests, arrivals_s, *, label: str) -> dict:
    q = _mk_frontend()
    prefill, decode = _service_proxy()
    try:
        recs = run_request_loop(q, requests, prefill_fn=prefill,
                                decode_fn=decode, arrivals_s=arrivals_s)
        q.flush()                            # all admissions accounted
    finally:
        q.close()
    lat_ms = np.asarray([r.latency_s for r in recs]) * 1e3
    makespan = max(recs[-1].done_s - recs[0].arrival_s, 1e-9)
    s = q.stats
    leg = {
        "n_requests": len(recs),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "mean_ms": round(float(lat_ms.mean()), 3),
        "goodput_rps": round(
            sum(1 for r in recs if not r.dropped) / makespan, 2),
        "shed_rate": round(s.shed_fps / max(s.submitted, 1), 4),
        "hit_rate": round(float(q.index.hit_rate), 4),
    }
    print(f"[serve_bench] {label}: p50 {leg['p50_ms']:.1f} ms, "
          f"p99 {leg['p99_ms']:.1f} ms, goodput {leg['goodput_rps']:.0f} "
          f"req/s, shed {leg['shed_rate']:.1%}, hit {leg['hit_rate']:.0%}")
    return leg


def _run_http_leg(requests, arrivals_s, *, label: str) -> dict:
    """The REAL socket path, open-loop: one client thread per request
    fires ``POST /v1/generate`` at its scheduled arrival against a
    loopback :class:`HttpFrontend` (same service proxy, same bounded
    shed-policy front end as the in-process legs), so the leg measures
    lookup + proxy + admission PLUS the transport: HTTP parse, router
    queue, micro-batching, socket writes.

    ``transport_overhead_ms`` is the median of (client-measured wall
    time) - (server-reported ``server_ms``) per request — the pure
    network-edge tax, directly comparable against the in-process legs'
    latencies.  Set ``REPRO_SERVE_HTTP_URL=http://host:port`` to drive
    an EXTERNALLY booted ``launch/httpd.py`` instead (the CI smoke does
    this); shed/hit accounting then comes from its ``GET /stats``."""
    url = os.environ.get("REPRO_SERVE_HTTP_URL")
    own = None
    if url:
        parsed = urllib.parse.urlparse(url)
        host, port = parsed.hostname, parsed.port
    else:
        q = _mk_frontend()
        prefill, decode = _service_proxy()
        router = ServeRouter(q, prefill_fn=prefill, decode_fn=decode,
                             n_workers=2, max_queue=4 * MAX_PENDING,
                             batch_window_s=0.001)
        own = (HttpFrontend(router).start(), q)
        host, port = own[0].address
    n = len(requests)
    results: list[dict | None] = [None] * n
    t0 = time.monotonic()

    def fire(i: int) -> None:
        wait = float(arrivals_s[i]) - (time.monotonic() - t0)
        if wait > 0:
            time.sleep(wait)
        send = time.monotonic()
        try:
            conn = http.client.HTTPConnection(host, port, timeout=60)
            conn.request("POST", "/v1/generate",
                         body=json.dumps({"tokens": requests[i].tolist()}),
                         headers={"Content-Type": "application/json"})
            resp = conn.getresponse()
            payload = json.loads(resp.read())
            status = resp.status
            conn.close()
        except OSError as e:
            payload, status = {"error": str(e)}, -1
        results[i] = {"arrival": float(arrivals_s[i]),
                      "send": send - t0, "done": time.monotonic() - t0,
                      "status": status, "payload": payload}

    threads = [threading.Thread(target=fire, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    def _stats_doc() -> dict:
        conn = http.client.HTTPConnection(host, port, timeout=60)
        conn.request("GET", "/stats")
        doc = json.loads(conn.getresponse().read())
        conn.close()
        return doc

    stats = _stats_doc()
    if own is not None:
        own[0].shutdown()
        own[1].close()

    ok = [r for r in results if r["status"] == 200]
    if not ok:
        raise RuntimeError(f"HTTP leg: 0/{n} requests succeeded "
                           f"(last: {results[-1]})")
    lat_ms = np.asarray([r["done"] - r["arrival"] for r in ok]) * 1e3
    overhead_ms = np.asarray(
        [(r["done"] - r["send"]) * 1e3 - r["payload"]["server_ms"]
         for r in ok])
    makespan = max(max(r["done"] for r in ok)
                   - min(r["arrival"] for r in ok), 1e-9)
    good = sum(1 for r in ok if not r["payload"].get("dropped"))
    aq = stats["admit_queue"]
    leg = {
        "n_requests": len(ok),
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 3),
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3),
        "mean_ms": round(float(lat_ms.mean()), 3),
        "goodput_rps": round(good / makespan, 2),
        "shed_rate": round(aq["shed_fps"] / max(aq["submitted"], 1), 4),
        "hit_rate": round(float(stats["index"]["hit_rate"]), 4),
        "transport_overhead_ms": round(
            float(np.percentile(overhead_ms, 50)), 3),
        "coalesced_requests": int(stats["router"]["coalesced"]),
    }
    print(f"[serve_bench] {label}: p50 {leg['p50_ms']:.1f} ms, "
          f"p99 {leg['p99_ms']:.1f} ms, transport "
          f"{leg['transport_overhead_ms']:.2f} ms, goodput "
          f"{leg['goodput_rps']:.0f} req/s, hit {leg['hit_rate']:.0%}")
    return leg


def _warmup(n: int) -> None:
    """Compile the index lookup/admit kernels and the service proxy on a
    throwaway front end, so no timed leg pays jit compilation (the jit
    cache is global and every leg uses identical shapes).  Runs the SAME
    request count as the timed legs: a fuller index reaches admission
    paths (e.g. the first hopscotch displacement) that only compile once
    enough distinct fingerprints have been installed — a short warmup
    leaves a one-time ~0.5 s stall inside the first timed leg."""
    q = _mk_frontend()
    prefill, decode = _service_proxy()
    try:
        run_request_loop(q, _requests(n, seed=7), prefill_fn=prefill,
                         decode_fn=decode)
        q.flush()
    finally:
        q.close()


def run(csv_rows: list[str], quick: bool = False) -> dict:
    n = 32 if quick else 128
    _warmup(n)
    poisson = []
    for rate in OFFERED_RATES:
        leg = _run_leg(_requests(n, seed=7),
                       _poisson_arrivals(n, rate, seed=11),
                       label=f"poisson {rate:g} req/s")
        leg["offered_rps"] = rate
        poisson.append(leg)
        csv_rows.append(f"serve_poisson_{rate:g}rps,{leg['p50_ms'] * 1e3:.1f}"
                        f",p99_ms={leg['p99_ms']}")
    arrivals = _trace_arrivals(n)
    trace = _run_leg(_requests(n, seed=7), arrivals, label="burst trace")
    trace["offered_rps"] = round(len(arrivals) / max(arrivals[-1], 1e-9), 2)
    csv_rows.append(f"serve_trace,{trace['p50_ms'] * 1e3:.1f}"
                    f",p99_ms={trace['p99_ms']}")
    # HTTP leg: the SAME burst/replayed schedule through the real socket
    http_leg = _run_http_leg(_requests(n, seed=7), arrivals,
                             label="http burst trace")
    http_leg["offered_rps"] = trace["offered_rps"]
    csv_rows.append(f"serve_http,{http_leg['p50_ms'] * 1e3:.1f}"
                    f",transport_ms={http_leg['transport_overhead_ms']}")
    payload = {
        "poisson": poisson,
        "trace": trace,
        "http": http_leg,
        "config": {
            "max_pending": MAX_PENDING, "policy": "shed", "clock": "wall",
            "prefix_chunks": PREFIX_CHUNKS, "tail_chunks": TAIL_CHUNKS,
            "chunk_tokens": CHUNK_TOKENS,
        },
    }
    path = emit_json("serve", payload, quick=quick)
    print(f"[serve_bench] wrote {path}")
    return payload


if __name__ == "__main__":
    rows: list[str] = []
    run(rows, quick=True)
