"""Figs. 12-14 reproduction: in-package software-managed hashing.

Hopscotch table + YCSB-style zipfian ops at read fractions 100%/95%/75%
(Figs. 12/13/14), window sizes {32, 64, 128}, table log2-sizes swept.

Scaling: the paper sweeps table sizes 2^17..2^25 x 16 B against real
capacities (Monarch 8 GB / HBM 4 GB / CMOS 73 MB).  We sweep 2^12..2^16
with ALL capacities divided by the same 2^9 factor, preserving every
capacity ratio and spill fraction; timing parameters are unscaled.

Per-query dependent chains (timing_model):
  Monarch : 1 search + (hit ? 1 data read)          [flat-CAM]
  RRAM    : E[probes] serial reads (1R flat-RAM)
  HBM-SP  : E[probes] serial DRAM reads
  HBM-C   : E[probes] serial (tag+data) cache reads; spill fraction to DDR4
  CMOS    : E[probes] serial SRAM reads; spill fraction to DDR4
Inserts add probe reads + bucket writes (+ swaps); rehash work is included
via the table's own op counters.  Monarch lookups need no metadata bitmap
(§10.4.2) — baselines charge its maintenance writes on insert.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import timing_model as tm
from repro.apps.hashtable import HopscotchTable
from repro.core.timing import TECH_TIMING
from repro.data import pipeline

CAP_SCALE = 2 ** 9
ENTRY_BYTES = 16


@dataclasses.dataclass
class SysDef:
    name: str
    tech: str
    capacity_bytes: float
    searches: bool = False
    tag_overhead: float = 1.0    # HBM-C compound tag+data accesses


def systems():
    return [
        SysDef("monarch", "monarch", 8 * 2 ** 30 / CAP_SCALE, searches=True),
        SysDef("rram", "rram_1r", 8 * 2 ** 30 / CAP_SCALE),
        # tag+data compound access in the same open row ~ 1.5 accesses
        SysDef("hbm-c", "dram", 4 * 2 ** 30 / CAP_SCALE, tag_overhead=1.5),
        SysDef("hbm-sp", "dram", 4 * 2 ** 30 / CAP_SCALE),
        SysDef("cmos", "cmos", 73 * 2 ** 20 / CAP_SCALE),
    ]


def _measure_probes(table: HopscotchTable, keys: np.ndarray):
    """Baseline probe counts derived from the kernel's match offsets: a hit
    at offset o costs o+1 serial reads; a miss costs reads until the first
    empty bucket in the window (hopscotch invariant)."""
    offs = table._lookup_window(keys)
    hits = offs >= 0
    probes = np.where(hits, offs + 1, 0).astype(np.int64)
    if (~hits).any():
        homes = table.home(keys[~hits]).astype(np.int64)
        w = table.window
        win = table.keys[homes[:, None] + np.arange(w)[None, :]]
        empty = win == 0
        first_empty = np.where(empty.any(1), empty.argmax(1) + 1, w)
        probes[~hits] = first_empty
    return probes, hits


def run_point(log2_size: int, window: int, read_frac: float, seed: int = 0,
              n_ops: int = 8192, density: float = 0.7):
    table = HopscotchTable(log2_size, window=window, seed=seed)
    n_fill = int(table.n * density)
    rng = np.random.default_rng(seed)
    fill_keys = (pipeline.murmur3_np(np.arange(1, n_fill + 1, dtype=np.uint32))
                 .astype(np.uint64) << np.uint64(13)) | np.arange(1, n_fill + 1, dtype=np.uint64)
    # fill in RANDOM order: popularity-ordered fills would park every hot
    # key at window offset 0 and hand the serial-probe baselines a free win
    for k in rng.permutation(fill_keys):
        table.insert(int(k), int(k) ^ 0xABCD)
    # YCSB op stream over the filled keys
    ranks = rng.zipf(1.2, n_ops) % n_fill
    q_keys = fill_keys[ranks]
    is_read = rng.random(n_ops) < read_frac
    r_keys = q_keys[is_read]
    probes, hits = _measure_probes(table, r_keys)
    n_reads = len(r_keys)
    n_writes = int((~is_read).sum())
    # insert cost sample (measured on the table's counters)
    s0 = dataclasses.replace(table.stats)
    wkeys = rng.integers(n_fill + 1, n_fill * 2, n_writes).astype(np.uint64)
    for k in wkeys[: min(n_writes, 512)]:
        table.insert(int((pipeline.murmur3_np(np.asarray([k], np.uint32))[0]
                          .astype(np.uint64) << np.uint64(13)) | k), 1)
    ins_sample = max(min(n_writes, 512), 1)
    ins_probes = (table.stats.insert_probes - s0.insert_probes) / ins_sample
    ins_writes = (table.stats.writes - s0.writes) / ins_sample

    table_bytes = table.n * ENTRY_BYTES
    results = {}
    for sd in systems():
        t = TECH_TIMING[sd.tech]
        spill = max(0.0, 1.0 - sd.capacity_bytes / table_bytes)
        ddr = TECH_TIMING["ddr4"]
        rl, wl, sl = tm.read_lat(t), tm.write_lat(t), tm.search_lat(t)
        rl_eff = (1 - spill) * rl * sd.tag_overhead + spill * tm.read_lat(ddr)
        if sd.searches:
            # lookup: 1 search + (hit) 1 data read.  insert: 1 search
            # (present?) + 1 search for an EMPTY sentinel + writes —
            # Monarch pays searches on inserts too (§10.4.2's metadata-free
            # flow is cheaper, not free).
            chain = (n_reads * (sl + rl)
                     + n_writes * (2 * sl + ins_writes * wl))
            ops = tm.OpCounts(
                chain_cycles=chain,
                searches=n_reads + 2 * n_writes, reads=float(hits.sum()),
                writes=n_writes * (ins_writes + 1),
                ddr_reads=0, ddr_writes=0)
        else:
            total_probes = float(probes.sum())
            chain = total_probes * rl_eff + n_writes * (
                ins_probes * rl_eff + ins_writes * wl)
            # metadata bitmap maintenance (window/8 B per insert) — one
            # extra line write per insert for the baselines (§10.4.2).
            meta_writes = n_writes
            ops = tm.OpCounts(
                chain_cycles=chain,
                reads=total_probes * (1 - spill) + n_writes * ins_probes,
                writes=(n_writes * ins_writes + meta_writes) * (1 - spill),
                ddr_reads=(total_probes + n_writes * ins_probes) * spill,
                ddr_writes=n_writes * ins_writes * spill)
        results[sd.name] = tm.system_time_cycles(t, ops)
    return results


def run(csv_rows: list[str], quick: bool = False):
    read_fracs = [1.0, 0.95, 0.75]
    windows = [32, 64] if quick else [32, 64, 128]
    sizes = [12, 14] if quick else [12, 14, 16]
    print("\n== Figs 12-14: hashing, relative performance vs HBM-C ==")
    best = {}
    for rf in read_fracs:
        fig = {1.0: "fig12", 0.95: "fig13", 0.75: "fig14"}[rf]
        print(f"\n-- {fig}: {int(rf * 100)}% reads --")
        print(f"{'size':>5s} {'win':>4s} " + " ".join(
            f"{s.name:>9s}" for s in systems()))
        for lg in sizes:
            for w in windows:
                r = run_point(lg, w, rf)
                base = r["hbm-c"]
                rel = {k: base / v for k, v in r.items()}
                print(f"2^{lg:<3d} {w:>4d} " + " ".join(
                    f"{rel[s.name]:9.2f}" for s in systems()))
                key = (rf, lg, w)
                best[key] = rel["monarch"]
                csv_rows.append(
                    f"{fig}_sz{lg}_w{w}_monarch_vs_hbmc,0,{rel['monarch']:.3f}")
    mx = max(best.values())
    print(f"\nC5 max Monarch speedup vs HBM-C: {mx:.1f}x "
          f"(paper: up to ~12-13x for key-value search)")
    csv_rows.append(f"hashing_max_speedup,0,{mx:.2f}")
