"""§Roofline summary: aggregate the dry-run JSONs into the per-cell
three-term table (compute / memory / collective seconds, bottleneck,
MODEL_FLOPS/HLO_FLOPs useful ratio) that EXPERIMENTS.md §Roofline records.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun); does NOT
lower anything itself, so it is cheap enough for the default bench run.
"""
from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    if not os.path.isdir(DRYRUN_DIR):
        return cells
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if not (f.startswith(mesh + "__") and f.endswith(".json")):
            continue
        with open(os.path.join(DRYRUN_DIR, f)) as fh:
            cells.append(json.load(fh))
    return cells


def run(csv_rows: list[str], mesh: str = "single"):
    cells = load_cells(mesh)
    ran = [c for c in cells if c.get("runnable")]
    skipped = [c for c in cells if not c.get("runnable")]
    if not cells:
        print("\n== Roofline: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first ==")
        return

    print(f"\n== Roofline summary ({mesh}-pod mesh, {len(ran)} cells ran, "
          f"{len(skipped)} skipped) ==")
    hdr = (f"{'arch':>22s} {'shape':>12s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>9s} {'bound':>7s} {'useful':>7s}")
    print(hdr)
    worst = None
    most_coll = None
    for c in ran:
        r = c["roofline"]
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / max(dom, 1e-30)   # roofline fraction proxy
        print(f"{c['arch']:>22s} {c['shape']:>12s} {r['compute_s']:10.4f} "
              f"{r['memory_s']:10.4f} {r['collective_s']:9.4f} "
              f"{r['bottleneck']:>7s} {r['useful_ratio']:7.3f}")
        csv_rows.append(
            f"roofline_{mesh}_{c['arch']}_{c['shape']}_bottleneck,0,"
            f"{r['bottleneck']}")
        if worst is None or frac < worst[0]:
            worst = (frac, c["arch"], c["shape"])
        cf = r["collective_s"] / max(dom, 1e-30)
        if most_coll is None or cf > most_coll[0]:
            most_coll = (cf, c["arch"], c["shape"])
    for c in skipped:
        print(f"{c['arch']:>22s} {c['shape']:>12s} {'—':>10s} {'—':>10s} "
              f"{'—':>9s} {'skip':>7s}   ({c['skip_reason']})")
    if worst:
        print(f"\nworst roofline fraction: {worst[1]} x {worst[2]} "
              f"(compute/dominant = {worst[0]:.3f})")
        csv_rows.append(f"roofline_worst_cell,0,{worst[1]}__{worst[2]}")
    if most_coll:
        print(f"most collective-bound: {most_coll[1]} x {most_coll[2]} "
              f"(coll/dominant = {most_coll[0]:.3f})")
        csv_rows.append(
            f"roofline_most_collective,0,{most_coll[1]}__{most_coll[2]}")
