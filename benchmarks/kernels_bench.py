"""Kernel micro-benchmarks: us/call of the three Pallas kernels (interpret
mode on this CPU rig; the numbers are CI-tracking, not TPU projections) and
of the MonarchKVIndex batched prefix lookup — the device-resident CAM fast
path (one fused multi-set launch per batch).  Timing discipline (warmup,
median-of-k, block_until_ready) comes from ``repro.bench.harness``.

``benchmarks/check_regression.py`` compares the emitted medians against the
committed ``benchmarks/baselines/BENCH_kernels.json``.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.bench import BenchSizes, emit_json, time_callable
from repro.kernels.hopscotch import ops as hop_ops
from repro.kernels.string_match import ops as sm_ops
from repro.kernels.xam_search import ops as xam_ops
from repro.serve.kv_index import KVIndexConfig, MonarchKVIndex


def run(csv_rows: list[str], quick: bool = False):
    rng = np.random.default_rng(0)
    reps = BenchSizes(quick=quick).kernel_reps
    print("\n== kernel micro-benchmarks (CPU interpret mode) ==")
    timings = {}

    keys = rng.integers(0, 2, (64, 64)).astype(np.int8)
    data = rng.integers(0, 2, (64, 512)).astype(np.int8)
    t = time_callable(lambda: xam_ops.xam_search(keys, data), reps=reps)
    timings["xam_search"] = t
    print(f"xam_search 64q x (64x512): {t.median_us:.0f} us")
    csv_rows.append(f"kernel_xam_search,{t.median_us:.0f},64x512")

    # fused multi-set search: 128 queries over 8 device-resident planes
    n_sets, r, c = 8, 32, 512
    planes = jnp.asarray(rng.integers(0, 2, (n_sets, r, c)).astype(np.int8))
    valid = jnp.asarray(rng.integers(0, 2, (n_sets, c)).astype(np.int8))
    m_words = rng.integers(0, 2 ** 32, 128, dtype=np.uint32)
    m_sets = rng.integers(0, n_sets, 128).astype(np.int32)
    m_bits = xam_ops.words_to_bits_np(m_words, r)
    t = time_callable(
        lambda: xam_ops.xam_search_multiset(m_bits, m_sets, planes, valid),
        reps=reps)
    timings["xam_multiset"] = t
    print(f"xam_multiset 128q x 8 sets (32x512): {t.median_us:.0f} us")
    csv_rows.append(f"kernel_xam_multiset,{t.median_us:.0f},8x32x512")

    h, n = 32, 32 * 256
    t_lo = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    t_hi = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    homes = rng.integers(0, n - 2 * h, 64).astype(np.int32)
    q = rng.integers(0, 2 ** 32, 64, dtype=np.uint32)
    t = time_callable(
        lambda: hop_ops.hopscotch_lookup(t_lo, t_hi, homes, q, q, window=h),
        reps=reps)
    timings["hopscotch_lookup"] = t
    print(f"hopscotch_lookup 64q w32: {t.median_us:.0f} us")
    csv_rows.append(f"kernel_hopscotch,{t.median_us:.0f},w32")

    text = rng.integers(97, 113, 1 << 16).astype(np.uint8)
    pat = text[1000:1012].copy()
    t = time_callable(lambda: sm_ops.string_match(text, pat, tile=4096),
                      reps=reps)
    timings["string_match"] = t
    print(f"string_match 64KiB p12: {t.median_us:.0f} us")
    csv_rows.append(f"kernel_string_match,{t.median_us:.0f},64KiB")

    idx = MonarchKVIndex(KVIndexConfig(n_sets=8))
    toks = rng.integers(1, 1000, (4, 256)).astype(np.int32)
    idx.admit(toks)
    idx.admit(toks)   # second touch -> admitted
    t = time_callable(lambda: idx.lookup(toks), warmup=1, reps=reps)
    timings["kv_index_lookup"] = t
    print(f"kv_index lookup 4x256 tokens: {t.median_us:.0f} us "
          f"(hit rate {idx.hit_rate:.2f}, "
          f"{idx.stats.searches} launches/{idx.stats.lookups} lookups)")
    csv_rows.append(f"kv_index_lookup,{t.median_us:.0f},{idx.hit_rate:.2f}")

    # batch scaling: one launch regardless of batch width
    toks_big = rng.integers(1, 4000, (32, 512)).astype(np.int32)
    idx.admit(toks_big)
    idx.admit(toks_big)
    t = time_callable(lambda: idx.lookup(toks_big), warmup=1, reps=reps)
    timings["kv_index_lookup_32x512"] = t
    print(f"kv_index lookup 32x512 tokens: {t.median_us:.0f} us "
          f"({t.median_us / (32 * 512 // 16):.1f} us/chunk)")
    csv_rows.append(f"kv_index_lookup_32x512,{t.median_us:.0f},")

    emit_json("kernels", {
        "reps": reps,
        "timings_us": {
            name: {"median": t.median_us, "best": t.best_us,
                   "mean": t.mean_us}
            for name, t in timings.items()},
        "kv_index_hit_rate": float(idx.hit_rate),
    }, quick=quick)
