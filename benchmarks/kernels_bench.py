"""Kernel micro-benchmarks: us/call of the three Pallas kernels (interpret
mode on this CPU rig; the numbers are CI-tracking, not TPU projections) and
of the MonarchKVIndex batched prefix lookup."""
from __future__ import annotations

import time

import numpy as np

from repro.kernels.hopscotch import ops as hop_ops
from repro.kernels.string_match import ops as sm_ops
from repro.kernels.xam_search import ops as xam_ops
from repro.serve.kv_index import KVIndexConfig, MonarchKVIndex


def _time(fn, reps=5):
    fn()  # compile
    t0 = time.time()
    for _ in range(reps):
        out = fn()
    try:
        out.block_until_ready()
    except AttributeError:
        pass
    return (time.time() - t0) / reps * 1e6


def run(csv_rows: list[str]):
    rng = np.random.default_rng(0)
    print("\n== kernel micro-benchmarks (CPU interpret mode) ==")

    keys = rng.integers(0, 2, (64, 64)).astype(np.int8)
    data = rng.integers(0, 2, (64, 512)).astype(np.int8)
    us = _time(lambda: xam_ops.xam_search(keys, data))
    print(f"xam_search 64q x (64x512): {us:.0f} us")
    csv_rows.append(f"kernel_xam_search,{us:.0f},64x512")

    h, n = 32, 32 * 256
    t_lo = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    t_hi = rng.integers(0, 2 ** 32, n, dtype=np.uint32)
    homes = rng.integers(0, n - 2 * h, 64).astype(np.int32)
    q = rng.integers(0, 2 ** 32, 64, dtype=np.uint32)
    us = _time(lambda: hop_ops.hopscotch_lookup(t_lo, t_hi, homes, q, q, window=h))
    print(f"hopscotch_lookup 64q w32: {us:.0f} us")
    csv_rows.append(f"kernel_hopscotch,{us:.0f},w32")

    text = rng.integers(97, 113, 1 << 16).astype(np.uint8)
    pat = text[1000:1012].copy()
    us = _time(lambda: sm_ops.string_match(text, pat, tile=4096))
    print(f"string_match 64KiB p12: {us:.0f} us")
    csv_rows.append(f"kernel_string_match,{us:.0f},64KiB")

    idx = MonarchKVIndex(KVIndexConfig(n_sets=8))
    toks = rng.integers(1, 1000, (4, 256)).astype(np.int32)
    idx.admit(toks)
    idx.admit(toks)   # second touch -> admitted
    t0 = time.time()
    hits = idx.lookup(toks)
    us = (time.time() - t0) * 1e6
    print(f"kv_index lookup 4x256 tokens: {us:.0f} us "
          f"(hit rate {idx.hit_rate:.2f})")
    csv_rows.append(f"kv_index_lookup,{us:.0f},{idx.hit_rate:.2f}")
